"""Shared harness for the paper-experiment benchmarks.

Each benchmark module exposes run(out_dir) -> list of CSV rows
(name, us_per_call, derived). Graph sizes are chosen so the full suite
finishes on one CPU core; every generator scales to the paper's sizes.
"""
from __future__ import annotations

import json
import os
import time


from repro.core import baselines
from repro.engine import get_algorithm, run_sync, run_async_block
from repro.graphs import generators as gen

OUT_DEFAULT = "experiments/paper"

# REPRO_BENCH_FAST=1 (set by `benchmarks/run.py --fast`, used by the CI
# smoke job) shrinks every graph ~10x so the whole suite exercises its real
# code paths in seconds instead of minutes.
FAST = os.environ.get("REPRO_BENCH_FAST", "") == "1"
_SCALE = 0.1 if FAST else 1.0


def _sz(n: int) -> int:
    return max(64, int(n * _SCALE))


# name -> (graph thunk, weighted variant needed)
BENCH_GRAPHS = {
    "ic-like": lambda: gen.scrambled(gen.powerlaw_cluster(_sz(4000), 6, p=0.5, seed=1), seed=11),
    "wk-like": lambda: gen.scrambled(gen.barabasi_albert(_sz(8000), 3, seed=4), seed=12),
    "cp-like": lambda: gen.scrambled(gen.erdos_renyi(_sz(6000), 5.0, seed=5), seed=13),
    "lj-like": lambda: gen.scrambled(gen.community_graph(_sz(6000), 60 if not FAST else 12, 7.0, 0.85, seed=6), seed=14),
}

ALGOS = ["pagerank", "sssp", "bfs", "php"]  # the paper's four workloads


def reorderers(seed: int = 0):
    rs = baselines.all_reorderers(seed)
    rs.pop("Random", None)  # the paper's competitor set
    return rs


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6  # us


def run_one(graph, algo_name, rank, bs=64, mode="async", inner=2):
    """inner=2 is the TPU-native async configuration: one VMEM-local
    re-iteration per block makes the intra-block (community) edges that
    clustering orderings concentrate FRESH, at zero extra HBM traffic.
    Orderings without intra-block structure are unaffected (measured in
    block_sensitivity.py), so the comparison stays fair."""
    g = graph if algo_name != "sssp" else gen.with_random_weights(graph, seed=3)
    algo = get_algorithm(algo_name, g)
    if rank is not None:
        algo = algo.relabel(rank)
    if mode == "sync":
        return run_sync(algo)
    return run_async_block(algo, bs=bs, inner=inner)


def save_json(out_dir: str, name: str, payload) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
