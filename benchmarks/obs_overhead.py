"""Observability overhead gate: tracing must be ~free.

The tracer's cost model (`repro.obs.trace`) makes two promises:

* **disabled** (``trace=None`` or ``Tracer(enabled=False)``) — the call
  sites hand out the shared ``NULL_SPAN`` and record nothing, so a solve
  with tracing off must be indistinguishable from one with no tracer at
  all: gated at < 2% rounds-bench wall time (full mode).
* **enabled** (ring buffer + JSONL sink) — all recording is batch-granular
  (one span + one serialized line per engine batch, never per round), so
  even full tracing is gated at < 10%.

Method: the three modes run interleaved (none / disabled / enabled, round
robin per repeat) and each mode's wall time is the MIN over repeats — the
standard way to strip scheduler noise from a gate this tight. ``--fast``
mode (CI smoke) shrinks the graph ~10x, which shrinks the denominator into
noise territory, so the recorded gates widen there (0.25 / 0.60) while the
full-mode gates stay at the contract values; the CI assertion reads the
gates from the payload. Writes ``BENCH_obs.json`` at the repo root
(uploaded as a CI artifact, the cross-PR trajectory).
"""
from __future__ import annotations

import io
import json
import os
import time

from benchmarks import common
from repro.engine import get_algorithm
from repro.engine.api import EngineOptions, solve
from repro.graphs import generators as gen
from repro.obs.trace import Tracer

BS = 64
# the disabled gate is 2% on a ~30ms solve whose run-to-run noise is much
# larger; min-over-repeats converges to the true floor, but only with
# enough draws — hence the large full-mode repeat count
REPEATS = 3 if common.FAST else 25
# full-mode gates are the contract; fast mode's tiny graphs make the
# denominator microseconds, so the smoke gates are correspondingly loose
GATE_DISABLED = 0.25 if common.FAST else 0.02
GATE_ENABLED = 0.60 if common.FAST else 0.10


def _algo():
    g = gen.scrambled(
        gen.powerlaw_cluster(common._sz(6000), 5, p=0.4, seed=1), seed=7
    )
    g = gen.with_random_weights(g, lo=0.1, hi=1.0, seed=2)
    return get_algorithm("sssp", g, source=0)


def _options(mode: str, sink: io.StringIO):
    if mode == "none":
        return EngineOptions(bs=BS)
    if mode == "disabled":
        return EngineOptions(bs=BS, trace=Tracer(enabled=False))
    return EngineOptions(bs=BS, trace=Tracer(jsonl=sink))


def run(out_dir: str = common.OUT_DEFAULT):
    algo = _algo()
    sink = io.StringIO()
    modes = ("none", "disabled", "enabled")
    rounds = {}
    for mode in modes:   # warmup: shared jit cache, first-run constants
        rounds[mode] = solve(algo, options=_options(mode, sink)).rounds
    assert len(set(rounds.values())) == 1, rounds   # tracing never perturbs
    best = {m: float("inf") for m in modes}
    for _ in range(REPEATS):
        for mode in modes:   # interleaved: drift hits every mode equally
            opts = _options(mode, sink)
            t0 = time.perf_counter()
            res = solve(algo, options=opts)
            best[mode] = min(best[mode], time.perf_counter() - t0)
    base = best["none"]
    overhead_disabled = best["disabled"] / base - 1.0
    overhead_enabled = best["enabled"] / base - 1.0
    payload = {
        "config": {
            "n": int(algo.n), "bs": BS, "rounds": int(res.rounds),
            "repeats": REPEATS, "fast": common.FAST,
        },
        "wall_s": {m: best[m] for m in modes},
        "overhead_disabled": overhead_disabled,
        "overhead_enabled": overhead_enabled,
        "gates": {"disabled": GATE_DISABLED, "enabled": GATE_ENABLED},
        "spans_per_solve": len(
            [ln for ln in sink.getvalue().splitlines()]
        ) // (REPEATS + 1),
    }
    common.save_json(out_dir, "obs_overhead", payload)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_obs.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return [(
        "obs_overhead", base * 1e6,
        f"disabled={overhead_disabled * 100:+.1f}% "
        f"enabled={overhead_enabled * 100:+.1f}% "
        f"(gates {GATE_DISABLED * 100:.0f}%/{GATE_ENABLED * 100:.0f}%)",
    )]


if __name__ == "__main__":
    run()
