"""Paper Fig. 8: Sync+Default vs Async+Default vs Async+GoGraph — the
speedup decomposition (update mode vs processing order)."""
from __future__ import annotations

import time

from benchmarks.common import BENCH_GRAPHS, run_one, save_json
from repro.core.gograph import gograph_order


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    for gname, gfn in BENCH_GRAPHS.items():
        g = gfn()
        rank_gg = gograph_order(g)
        results[gname] = {}
        for algo in ("pagerank", "sssp"):
            modes = {}
            for label, rank, mode in [
                ("sync_default", None, "sync"),
                ("async_default", None, "async"),
                ("async_gograph", rank_gg, "async"),
            ]:
                t0 = time.perf_counter()
                r = run_one(g, algo, rank, mode=mode)
                modes[label] = {"rounds": r.rounds,
                                "runtime_s": time.perf_counter() - t0}
            modes["round_speedup_async"] = (
                modes["sync_default"]["rounds"] / max(1, modes["async_default"]["rounds"])
            )
            modes["round_speedup_gograph"] = (
                modes["sync_default"]["rounds"] / max(1, modes["async_gograph"]["rounds"])
            )
            results[gname][algo] = modes
            rows.append((f"fig8/{gname}/{algo}", 0.0,
                         f"sync={modes['sync_default']['rounds']} "
                         f"async={modes['async_default']['rounds']} "
                         f"async+GG={modes['async_gograph']['rounds']} "
                         f"(x{modes['round_speedup_gograph']:.2f})"))
    save_json(out_dir, "fig8_async", results)
    return rows
