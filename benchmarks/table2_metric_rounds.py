"""Paper Table II: M(.) values of each reordering method and the number of
iteration rounds of PageRank/SSSP/BFS/PHP under each order (CP-like graph).

Claim under test: larger M  =>  fewer rounds; GoGraph has the largest M and
the smallest round counts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_GRAPHS, ALGOS, reorderers, run_one, save_json, timed
from repro.core import metric


def run(out_dir: str = "experiments/paper"):
    g = BENCH_GRAPHS["cp-like"]()
    rows = []
    table = {}
    for name, fn in reorderers().items():
        rank, reorder_us = timed(fn, g)
        m = metric.metric_m(g, rank)
        rounds = {}
        for algo in ALGOS:
            r = run_one(g, algo, rank)
            rounds[algo] = r.rounds
        table[name] = {
            "M": int(m), "M_over_E": m / g.m, "rounds": rounds,
            "reorder_us": reorder_us,
        }
        rows.append((f"table2/{name}", reorder_us,
                     f"M/E={m / g.m:.3f} rounds={rounds}"))
    # correlation check: M vs rounds must be negative for every algorithm
    ms = [v["M"] for v in table.values()]
    corr = {}
    for algo in ALGOS:
        rs = [v["rounds"][algo] for v in table.values()]
        corr[algo] = float(np.corrcoef(ms, rs)[0, 1])
    gg = table["GoGraph"]
    assert gg["M"] == max(v["M"] for v in table.values()), "GoGraph must maximize M"
    save_json(out_dir, "table2_metric_rounds", {"table": table, "corr_M_rounds": corr})
    rows.append(("table2/corr", 0.0, f"corr(M,rounds)={corr}"))
    return rows
