"""TPU-adaptation ablation (DESIGN.md §3): how much of the paper's per-vertex
async round reduction survives block Gauss-Seidel, as a function of block
size bs (VMEM tile granularity) and inner sweeps."""
from __future__ import annotations

from benchmarks.common import BENCH_GRAPHS, save_json
from repro.core import metric
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm, run_sync, run_async_block


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    g = BENCH_GRAPHS["wk-like"]()
    rank = gograph_order(g)
    algo = get_algorithm("pagerank", g)
    algo_gg = algo.relabel(rank)
    sync_rounds = run_sync(algo).rounds
    results["sync_default"] = sync_rounds
    for bs in (32, 64, 128, 256, 512):
        for inner in (1, 2):
            r_def = run_async_block(algo, bs=bs, inner=inner)
            r_gg = run_async_block(algo_gg, bs=bs, inner=inner)
            fresh = metric.block_fresh_fraction(g, rank, bs)
            results[f"bs{bs}_inner{inner}"] = {
                "rounds_default": r_def.rounds,
                "rounds_gograph": r_gg.rounds,
                "block_fresh_gograph": fresh["fresh"],
            }
            rows.append((f"block_sens/bs{bs}_in{inner}", 0.0,
                         f"sync={sync_rounds} asyncDef={r_def.rounds} "
                         f"asyncGG={r_gg.rounds} fresh={fresh['fresh']:.2f}"))
    save_json(out_dir, "block_sensitivity", results)
    return rows
