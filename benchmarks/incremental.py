"""Incremental serving vs cold recompute on evolving graphs.

For each workload, converge on the base graph, apply a random delta batch
(insertions; plus a mixed churn row with deletions + reweights), then answer
the "post-delta" query twice: cold (`run_async_block` from x0 on the mutated
graph) and warm (`run_incremental` from the converged state). Reports rounds
and wall-clock for both, the warm/cold round ratio, and whether the warm
result reached the same fixpoint (within tolerance for sum semirings —
both endpoints carry an O(eps/(1-rho)) stopping slack — bitwise for
min/max). The headline acceptance row is the 1% insertion delta:
warm rounds <= 50% of cold.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.engine import get_algorithm, remake, run_async_block, run_incremental
from repro.graphs import generators as gen
from repro.graphs.delta import random_delta

GRAPH = "ic-like"
ALGOS = ["pagerank", "php", "sssp", "bfs"]
ADD_FRACS = [0.001, 0.01, 0.05]
BS, INNER = 64, 2
# both runs stop on successive-change <= eps, so each sits within
# ~eps*rho/(1-rho) of the true fixpoint; 10*eps bounds their disagreement
SUM_TOL_FACTOR = 10.0


def _one(algo_old, algo_new, prior):
    cold, us_cold = common.timed(
        run_async_block, algo_new, bs=BS, inner=INNER
    )
    warm, us_warm = common.timed(
        run_incremental, algo_new, algo_old, prior,
        engine="async_block", bs=BS, inner=INNER,
    )
    if algo_new.semiring.reduce == "sum":
        ok = bool(np.abs(warm.x - cold.x).max()
                  <= SUM_TOL_FACTOR * algo_new.eps)
    else:
        ok = bool(np.array_equal(warm.x, cold.x))
    return {
        "cold_rounds": int(cold.rounds),
        "warm_rounds": int(warm.rounds),
        "ratio": warm.rounds / max(1, cold.rounds),
        "us_cold": us_cold,
        "us_warm": us_warm,
        "same_fixpoint": ok,
    }


def run(out_dir: str):
    graph = common.BENCH_GRAPHS[GRAPH]()
    rows, payload = [], {}
    for name in ALGOS:
        g = graph if name != "sssp" else gen.with_random_weights(graph, seed=3)
        algo_old = get_algorithm(name, g)
        prior, _ = common.timed(run_async_block, algo_old, bs=BS, inner=INNER)
        for frac in ADD_FRACS:
            delta = random_delta(g, frac_add=frac, seed=17)
            algo_new = remake(algo_old, delta.apply(g))
            rec = _one(algo_old, algo_new, prior)
            payload[f"{name}_add{frac}"] = rec
            rows.append((
                f"incr_{name}_add{frac}", rec["us_warm"],
                f"warm={rec['warm_rounds']} cold={rec['cold_rounds']} "
                f"ratio={rec['ratio']:.2f} ok={rec['same_fixpoint']}",
            ))
        # churn: deletions + reweights exercise the signed-residual (sum)
        # and masked-regional-recompute (min/max) paths
        delta = random_delta(g, frac_add=0.005, frac_del=0.005,
                             frac_rew=0.005, seed=19)
        algo_new = remake(algo_old, delta.apply(g))
        rec = _one(algo_old, algo_new, prior)
        payload[f"{name}_churn"] = rec
        rows.append((
            f"incr_{name}_churn", rec["us_warm"],
            f"warm={rec['warm_rounds']} cold={rec['cold_rounds']} "
            f"ratio={rec['ratio']:.2f} ok={rec['same_fixpoint']}",
        ))

    # headline: 1% insertion delta across all workloads (acceptance: <= 0.5)
    head = [payload[f"{name}_add0.01"] for name in ALGOS]
    warm = sum(r["warm_rounds"] for r in head)
    cold = sum(r["cold_rounds"] for r in head)
    ratio = warm / max(1, cold)
    ok = all(r["same_fixpoint"] for r in head)
    payload["headline_add0.01"] = {
        "warm_rounds": warm, "cold_rounds": cold, "ratio": ratio, "ok": ok,
    }
    rows.append((
        "incr_headline_add0.01", 0.0,
        f"warm={warm} cold={cold} ratio={ratio:.2f} ok={ok} target<=0.50",
    ))
    common.save_json(out_dir, "incremental", payload)
    return rows
