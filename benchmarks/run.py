"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes JSON payloads under
experiments/paper/. ``python -m benchmarks.run [--only fig8] [--fast]``.
``--fast`` shrinks every graph ~10x (tiny graphs, few iters) — the CI smoke
mode that keeps the benchmark scripts from rotting.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

MODULES = [
    "table2_metric_rounds",   # Table II
    "fig5_6_overall",         # Fig. 5 + 6
    "fig7_convergence",       # Fig. 7
    "fig8_async",             # Fig. 8
    "fig9_locality",          # Fig. 9/10 (TPU locality proxies)
    "fig12_degrees",          # Fig. 12
    "fig13_partition",        # Fig. 13
    "block_sensitivity",      # TPU adaptation ablation (DESIGN.md §3)
    "priority_sched",         # beyond-paper: Priter-style block scheduling
    "kernel_bench",           # Pallas kernel structural bench
    "roofline_report",        # dry-run roofline aggregation
    "batched_queries",        # batched multi-query engine throughput
    "incremental",            # evolving graphs: warm vs cold serving
    "serving_bench",          # continuous vs static batching (GraphServer)
    "push_bench",             # vertex-granular push vs block sweeps on deltas
    "reorder_bench",          # online reordering on a sustained delta stream
    "obs_overhead",           # tracing overhead gate (disabled ~0, enabled <10%)
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    p.add_argument("--out", default="experiments/paper")
    p.add_argument("--fast", action="store_true",
                   help="tiny graphs / few iters (CI smoke mode)")
    args = p.parse_args()

    if args.fast:
        # must be set before any benchmark module imports benchmarks.common
        os.environ["REPRO_BENCH_FAST"] = "1"

    mods = [m for m in MODULES if args.only is None or args.only in m]
    print("name,us_per_call,derived")
    t_start = time.time()
    failures = 0
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(args.out)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,BENCH-FAILED {type(e).__name__}: {e}")
            continue
        for rname, us, derived in rows:
            derived = str(derived).replace(",", ";")
            print(f"{rname},{us:.1f},{derived}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    print(f"# total {time.time() - t_start:.1f}s, failures={failures}",
          file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
