"""Paper Fig. 9/10 analogue: locality proxies instead of CPU cache misses.

This container cannot measure cache misses; DESIGN.md §3 maps the paper's
cache argument to two measurable structure-level quantities:
  * mean edge span |p(u)-p(v)|  (reuse distance proxy)
  * distinct column-blocks per BSR row-block (= state-tile DMAs per block
    update on TPU)
Also reproduces Fig. 10's partition ablation: GoGraph with vs without the
divide phase.
"""
from __future__ import annotations


from benchmarks.common import BENCH_GRAPHS, reorderers, save_json
from repro.core import metric
from repro.core.gograph import GoGraphConfig, gograph_order
from repro.graphs.blocked import pack_bsr


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    bs = 64
    for gname in ("ic-like", "lj-like"):
        g = BENCH_GRAPHS[gname]()
        results[gname] = {}
        for rname, rfn in reorderers().items():
            rank = rfn(g)
            g2 = g.relabel(rank)
            bsr = pack_bsr(g2, bs)
            stats = bsr.stats()
            results[gname][rname] = {
                "edge_span": metric.edge_span(g, rank),
                "colblocks_per_rowblock": stats["mean_colblocks_per_rowblock"],
            }
        # Fig. 10 ablation: GoGraph without partitioning (single subgraph)
        rank_nopart = gograph_order(
            g, GoGraphConfig(partition_method="bfs", max_subgraph=g.n)
        )
        g2 = g.relabel(rank_nopart)
        results[gname]["GoGraph_nopartition"] = {
            "edge_span": metric.edge_span(g, rank_nopart),
            "colblocks_per_rowblock": pack_bsr(g2, bs).stats()[
                "mean_colblocks_per_rowblock"],
        }
        gg = results[gname]["GoGraph"]["colblocks_per_rowblock"]
        dflt = results[gname]["Default"]["colblocks_per_rowblock"]
        rows.append((f"fig9/{gname}", 0.0,
                     f"DMA proxy: GoGraph={gg:.1f} Default={dflt:.1f} "
                     f"({1 - gg / dflt:.1%} fewer)"))
    save_json(out_dir, "fig9_locality", results)
    return rows
