"""Paper Fig. 13: the impact of the partitioning method inside GoGraph
(labelprop ~ Rabbit-Partition default; louvain; fennel; bfs)."""
from __future__ import annotations

import time

from benchmarks.common import BENCH_GRAPHS, run_one, save_json
from repro.core import metric
from repro.core.gograph import GoGraphConfig, gograph_order


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    g = BENCH_GRAPHS["lj-like"]()
    for method in ("labelprop", "louvain", "fennel", "bfs"):
        t0 = time.perf_counter()
        rank = gograph_order(g, GoGraphConfig(partition_method=method))
        reorder_s = time.perf_counter() - t0
        r = run_one(g, "pagerank", rank)
        results[method] = {
            "M_over_E": metric.positive_edge_fraction(g, rank),
            "rounds": r.rounds,
            "reorder_s": reorder_s,
        }
        rows.append((f"fig13/{method}", reorder_s * 1e6,
                     f"M/E={results[method]['M_over_E']:.3f} rounds={r.rounds}"))
    save_json(out_dir, "fig13_partition", results)
    return rows
