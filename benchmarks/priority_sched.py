"""Beyond-paper extension: Priter-style priority scheduling ([52] in the
paper's related work) at block granularity, composed with GoGraph ordering.

Work is measured in equivalent full sweeps (block updates / nb). Expected
shape of results: parity on uniformly-converging workloads (PageRank on
small-diameter graphs), multi-x savings on frontier-style workloads (SSSP
on high-diameter graphs) where most blocks are quiescent most of the time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm, run_async_block
from repro.engine.priority import run_priority_block
from repro.graphs import generators as gen


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    cases = {
        "pagerank_cluster": ("pagerank",
                             gen.scrambled(gen.powerlaw_cluster(4000, 4, seed=1), seed=9)),
        "sssp_deep": ("sssp",
                      gen.scrambled(gen.barabasi_albert(8000, 1, seed=3), seed=7)),
        "bfs_deep": ("bfs",
                     gen.scrambled(gen.barabasi_albert(8000, 1, seed=3), seed=7)),
    }
    for label, (algo_name, g) in cases.items():
        rank = gograph_order(g)
        graph = gen.with_random_weights(g, seed=2) if algo_name == "sssp" else g
        algo = get_algorithm(algo_name, graph).relabel(rank)
        rf = run_async_block(algo, bs=64, inner=2)
        rp = run_priority_block(algo, bs=64, select_frac=0.125)
        err = float(np.max(np.abs(rp.x - algo.exact())))
        results[label] = {
            "full_sweeps": rf.rounds,
            "priority_equiv_sweeps": rp.rounds,
            "work_ratio": rp.rounds / max(1e-9, rf.rounds),
            "max_err": err,
        }
        rows.append((f"priority/{label}", 0.0,
                     f"full={rf.rounds} priority={rp.rounds:.1f} "
                     f"(x{rf.rounds / max(rp.rounds, 1e-9):.1f} less work) err={err:.0e}"))
        assert err < 1e-4
    save_json(out_dir, "priority_sched", results)
    return rows
