"""Batched multi-query throughput: d queries as one f32[n, d] run vs d
serial scalar runs.

The tentpole claim of the batched execution engine: personalized PageRank
from d seeds (and multi-source SSSP from d sources) shares every per-round
gather/segment-reduce across the batch, so queries/sec scales far better
than re-running the scalar engine d times. Per-column convergence freezing
keeps the round counts honest — each query stops contributing at exactly its
scalar round count, so the batched run does no extra rounds of useful work.

CSV rows report queries/sec for serial vs batched at each d, for both the
sync engine and the block Gauss-Seidel engine.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, save_json
from repro.engine import (
    multi_source_sssp, personalized_pagerank, run_async_block, run_sync,
)
from repro.graphs import generators as gen


def _qps(fn, n_queries: int, repeats: int = 1) -> tuple[float, float]:
    """Returns (queries/sec, seconds) for the best of `repeats` timings."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return n_queries / best, best


def run(out_dir: str = "experiments/paper"):
    n = 400 if FAST else 3000
    ds = [2, 4] if FAST else [4, 16, 64]
    g = gen.scrambled(gen.powerlaw_cluster(n, 4, seed=1), seed=9)
    gw = gen.with_random_weights(g, seed=2)
    rng = np.random.default_rng(0)

    rows = []
    payload = {"n": g.n, "m": g.m, "series": {}}
    cases = [
        ("ppr/sync", lambda a: run_sync(a), personalized_pagerank, g),
        ("ppr/async", lambda a: run_async_block(a, bs=128), personalized_pagerank, g),
        ("ms_sssp/async", lambda a: run_async_block(a, bs=128), multi_source_sssp, gw),
    ]
    for cname, engine, make, graph in cases:
        payload["series"][cname] = []
        for d in ds:
            seeds = rng.choice(graph.n, size=d, replace=False)
            batched = make(graph, seeds)
            scalars = [make(graph, [s]) for s in seeds]
            # warm up jit caches for both shapes before timing
            engine(batched)
            engine(scalars[0])
            qps_b, t_b = _qps(lambda: engine(batched), d)
            qps_s, t_s = _qps(lambda: [engine(a) for a in scalars], d)
            speedup = qps_b / qps_s
            rows.append((
                f"batched/{cname}/d{d}", t_b * 1e6,
                f"batched={qps_b:.1f}q/s serial={qps_s:.1f}q/s speedup={speedup:.2f}x",
            ))
            payload["series"][cname].append({
                "d": int(d), "qps_batched": qps_b, "qps_serial": qps_s,
                "speedup": speedup,
            })
    save_json(out_dir, "batched_queries", payload)
    return rows


if __name__ == "__main__":
    for r in run():
        print(*r, sep=",")
