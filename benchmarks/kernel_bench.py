"""Kernel microbench: gs_sweep wall-clock + flat-vs-dense layout accounting.

Timing is interpret mode on CPU — the absolute numbers are emulation, but the
structural quantities that transfer to TPU are exact: nnz_blocks (= gather
DMAs per sweep), mean DMAs per destination block, and the tile bytes the
ragged flat layout moves vs what the dense ``(nb, k_max)`` padding moved.

Methodology: one warmup call absorbs jit/interpret compilation, then the
reported ``us_per_sweep_interpret`` is the median of ``REPEATS >= 3``
steady-state runs (the old single cold-timed call reported compile time, not
sweep time).

Besides the per-run JSON under ``out_dir``, writes ``BENCH_kernels.json`` at
the repo root so the kernel perf trajectory is tracked across PRs; CI's
bench-smoke job asserts the flat layout's padding win is recorded there.
"""
from __future__ import annotations

import json
import os
import statistics
import time

from benchmarks.common import FAST, save_json
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm
from repro.graphs import generators as gen
from repro.kernels import gs_sweep
from repro.kernels.ops import pack_algorithm

REPEATS = 3
# bs=16 exposes the block-level skew (hub row-blocks vs tail) even on the
# small --fast graph; bs=64 is the TPU-native tile-friendly setting.
BLOCK_SIZES = (16, 64)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sweep_median_us(ops) -> float:
    args = (ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"],
            ops["x0"], ops["fixed"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"])
    # warmup: first call pays jit + interpret lowering, not sweep work
    gs_sweep(*args, ops["x"], **kw).block_until_ready()
    samples = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        gs_sweep(*args, ops["x"], **kw).block_until_ready()
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    n = 200 if FAST else 2000
    g = gen.scrambled(gen.powerlaw_cluster(n, 4, seed=1), seed=5)
    rank = gograph_order(g)
    for label, graph in (("default", g), ("gograph", g.relabel(rank))):
        algo = get_algorithm("pagerank", graph)
        for bs in BLOCK_SIZES:
            ops = pack_algorithm(algo, bs=bs)
            # FlatBSRMatrix.stats() carries the dense-padded baseline's
            # accounting too (dense_tile_bytes / padding_waste), so no dense
            # repack is needed here (tests assert the two layouts' stats agree)
            stats = ops["bsr_stats"]
            us = _sweep_median_us(ops)
            # steady-state VMEM per grid step: 2 double-buffered tiles + 7
            # (bs, d) state blocks (2 gathers, old, acc, c, x0, fixed) —
            # independent of k_max now
            d = int(ops["x"].shape[1])
            vmem_kb = (2 * bs * bs * 4 + 7 * bs * d * 4) / 1024
            results[f"{label}_bs{bs}"] = {
                "us_per_sweep_interpret": us,
                "mean_dma_per_block": stats["mean_colblocks_per_rowblock"],
                "nnz_blocks": stats["nnz_blocks"],
                "dma_per_sweep": stats["nnz_blocks"],
                "k_max": stats["k_max"],
                "padding_waste_dense": stats["padding_waste"],
                "tile_bytes_flat": stats["tile_bytes"],
                "tile_bytes_dense": stats["dense_tile_bytes"],
                "tile_bytes_saved": stats["tile_bytes_saved"],
                "vmem_step_kb": vmem_kb,
            }
            rows.append((f"kernel/gs_sweep/{label}_bs{bs}", us,
                         f"dma/blk={stats['mean_colblocks_per_rowblock']:.1f} "
                         f"waste={stats['padding_waste']:.2f} "
                         f"vmem={vmem_kb:.0f}KB"))
    save_json(out_dir, "kernel_bench", results)
    payload = {
        "graph": {"kind": "powerlaw_cluster", "n": n, "fast": FAST},
        "configs": results,
        "max_padding_waste_dense": max(
            r["padding_waste_dense"] for r in results.values()
        ),
        "total_tile_bytes_saved": sum(
            r["tile_bytes_saved"] for r in results.values()
        ),
    }
    with open(os.path.join(_REPO_ROOT, "BENCH_kernels.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return rows
