"""Kernel microbench: gs_sweep wall-clock + flat-vs-dense layout accounting
+ sweep-batching (launch amortization) + active-frontier traces.

Two timing columns, labeled for what they are:

* ``us_per_sweep_interpret`` — the Pallas kernel under the CPU interpreter.
  Emulation: meaningful only relative to other interpret numbers (and for
  the structural quantities alongside it — nnz_blocks = gather DMAs per
  sweep, tile bytes moved — which are exact and transfer to TPU). The
  column only exists in full runs: ``--fast`` skips it, since interpreted
  sweeps dominate the smoke's wall clock while measuring nothing real.
* ``us_per_sweep_jit_cpu`` — the same block Gauss–Seidel sweep as a jitted
  pure-JAX (gather/segment-reduce) program on the CPU backend: a real
  compiled-code number on this host, the honest CPU baseline the interpret
  column must not be mistaken for.

``us_per_round_batched`` times the persistent megakernel at
``sweeps_per_call`` in {1, 4, 16} from the same cold state (early-out
disabled) and divides by the sweep count: the launch-amortization win the
sweep-batched driver buys. This is measured on a fixed small
(``N_LATENCY``-vertex) graph in *both* fast and full modes — launch
overhead is a fixed per-call cost, so it only shows in the latency-bound
serving regime where per-sweep device time is comparable to it; on the
full-size graph the interpreter's 8ms sweeps bury the ~0.3ms dispatch
saving in timing noise. ``active_block_fraction`` traces a full SSSP
convergence run with ``sweeps_per_call=16`` — the fraction of row-blocks
each sweep actually updates, which frontier skipping shrinks as regions
converge.

Methodology: one warmup call absorbs jit/interpret compilation, then every
reported time is the median of ``REPEATS >= 3`` steady-state runs (the old
single cold-timed call reported compile time, not sweep time).

Besides the per-run JSON under ``out_dir``, writes ``BENCH_kernels.json`` at
the repo root so the kernel perf trajectory is tracked across PRs; CI's
bench-smoke job asserts the flat layout's padding win AND the sweep-batching
win are recorded there.
"""
from __future__ import annotations

import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, save_json
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm, harness, run_async_block
from repro.engine import jax_ops as J
from repro.graphs import generators as gen
from repro.kernels import gs_sweep
from repro.kernels.gs_sweep import gs_multisweep_pallas
from repro.kernels.ops import pack_algorithm

REPEATS = 3
# bs=16 exposes the block-level skew (hub row-blocks vs tail) even on the
# small --fast graph; bs=64 is the TPU-native tile-friendly setting.
BLOCK_SIZES = (16, 64)
SWEEPS_PER_CALL = (1, 4, 16)
# fixed graph size for the launch-amortization measurement (see module
# docstring): the latency-bound serving point, identical in fast/full modes
# so the cross-PR BENCH_kernels.json numbers stay comparable
N_LATENCY = 200

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _median_us(fn) -> float:
    fn()  # warmup: first call pays jit + interpret lowering, not sweep work
    samples = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def _sweep_median_us(ops) -> float:
    args = (ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"],
            ops["x0"], ops["fixed"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"])
    return _median_us(
        lambda: gs_sweep(*args, ops["x"], **kw).block_until_ready()
    )


def _jax_sweep_median_us(algo, bs: int) -> float:
    """One jitted pure-JAX block GS sweep (the engine's own sweep body,
    compiled for CPU) — the non-emulated timing baseline."""
    be, x0, c, fixed, npad = harness.pack(algo, bs)
    nb = be.nb
    d = x0.shape[1]
    esrc, edst = jnp.asarray(be.esrc), jnp.asarray(be.edst)
    ew, emask = jnp.asarray(be.ew), jnp.asarray(be.emask)
    c_blk = jnp.asarray(c).reshape(nb, bs, d)
    fixed_blk = jnp.asarray(fixed).reshape(nb, bs, d)
    x0_blk = jnp.asarray(x0).reshape(nb, bs, d)
    sem, comb = algo.semiring, algo.combine
    ident = sem.identity

    @jax.jit
    def sweep(x):
        def block_update(i, xx):
            msgs = J.edge_op(sem.edge_op, xx[esrc[i]], ew[i])
            msgs = jnp.where(emask[i][:, None], msgs, ident)
            agg = J.segment_reduce(sem.reduce, msgs, edst[i], bs, ident)
            old = jax.lax.dynamic_slice(xx, (i * bs, 0), (bs, d))
            new = J.combine(comb, agg, c_blk[i], old, fixed_blk[i], x0_blk[i])
            return jax.lax.dynamic_update_slice(xx, new, (i * bs, 0))

        return jax.lax.fori_loop(0, nb, block_update, x)

    x_start = jnp.asarray(x0)
    return _median_us(lambda: sweep(x_start).block_until_ready())


def _batched_round_us(ops, sweeps: int, bs: int) -> float:
    """Per-sweep wall time of one ``sweeps``-deep megakernel launch, from
    the same cold state every call. eps=-1 disables the in-kernel early-out;
    frontier skipping stays armed (the real serving configuration), so the
    number is only the pure launch-amortization win if every sweep of the
    batch actually updates every block — true for cold-start pagerank, whose
    blocks keep moving bitwise far past 16 sweeps, and *asserted* below via
    the kernel's own active-block counts so a future workload change cannot
    silently turn this into a frontier benchmark."""
    nb = int(ops["rowptr"].shape[0]) - 1
    dirty = jnp.ones((nb,), jnp.int32)
    args = (ops["rowptr"], ops["tilecols"], ops["revptr"], ops["revrows"],
            dirty, ops["tiles"], ops["c"], ops["x0"], ops["fixed"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"], bs=bs,
              sweeps=sweeps, eps=-1.0)

    active = np.asarray(gs_multisweep_pallas(*args, ops["x"], **kw)[2])
    assert np.all(active[:, 0] == nb), (
        f"us_per_round_batched requires full sweeps; frontier skipped blocks "
        f"(active={active[:, 0].tolist()}, nb={nb}) — pick a workload whose "
        f"blocks keep changing for the whole batch"
    )

    def call():
        out = gs_multisweep_pallas(*args, ops["x"], **kw)
        out[0].block_until_ready()

    return _median_us(call) / sweeps


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    n = 200 if FAST else 2000
    g = gen.scrambled(gen.powerlaw_cluster(n, 4, seed=1), seed=5)
    rank = gograph_order(g)
    for label, graph in (("default", g), ("gograph", g.relabel(rank))):
        algo = get_algorithm("pagerank", graph)
        for bs in BLOCK_SIZES:
            ops = pack_algorithm(algo, bs=bs)
            # FlatBSRMatrix.stats() carries the dense-padded baseline's
            # accounting too (dense_tile_bytes / padding_waste), so no dense
            # repack is needed here (tests assert the two layouts' stats agree)
            stats = ops["bsr_stats"]
            # the interpret-mode sweep dominates the smoke's wall clock and
            # its timing is emulation, not signal — full runs keep the
            # column, --fast drops it (CI's assertion is presence-gated)
            us = None if FAST else _sweep_median_us(ops)
            us_jit = _jax_sweep_median_us(algo, bs)
            # steady-state VMEM per grid step: 2 double-buffered tiles + 7
            # (bs, d) state blocks (2 gathers, old, acc, c, x0, fixed) —
            # independent of k_max now
            d = int(ops["x"].shape[1])
            vmem_kb = (2 * bs * bs * 4 + 7 * bs * d * 4) / 1024
            cfg = {
                "us_per_sweep_jit_cpu": us_jit,
                "mean_dma_per_block": stats["mean_colblocks_per_rowblock"],
                "nnz_blocks": stats["nnz_blocks"],
                "dma_per_sweep": stats["nnz_blocks"],
                "k_max": stats["k_max"],
                "padding_waste_dense": stats["padding_waste"],
                "tile_bytes_flat": stats["tile_bytes"],
                "tile_bytes_dense": stats["dense_tile_bytes"],
                "tile_bytes_saved": stats["tile_bytes_saved"],
                "vmem_step_kb": vmem_kb,
            }
            if us is not None:
                cfg["us_per_sweep_interpret"] = us
            results[f"{label}_bs{bs}"] = cfg
            rows.append((f"kernel/gs_sweep/{label}_bs{bs}",
                         us if us is not None else us_jit,
                         f"jit_cpu={us_jit:.0f}us "
                         f"dma/blk={stats['mean_colblocks_per_rowblock']:.1f} "
                         f"waste={stats['padding_waste']:.2f} "
                         f"vmem={vmem_kb:.0f}KB"))

    # --- sweep batching: per-round cost vs sweeps_per_call (gograph, bs=64)
    # on the fixed latency-bound graph (launch overhead is per-call, so the
    # amortization win is a property of small/fast sweeps — see docstring)
    bs_b = 64
    g_lat = gen.scrambled(gen.powerlaw_cluster(N_LATENCY, 4, seed=1), seed=5)
    algo_b = get_algorithm("pagerank", g_lat.relabel(gograph_order(g_lat)))
    ops_b = pack_algorithm(algo_b, bs=bs_b)
    batched = {}
    for sweeps in SWEEPS_PER_CALL:
        batched[str(sweeps)] = _batched_round_us(ops_b, sweeps, bs_b)
        rows.append((f"kernel/gs_multisweep/round_batched{sweeps}",
                     batched[str(sweeps)],
                     f"megakernel us/round (interpret, n={N_LATENCY})"))
    results["batched_bs64"] = {"n": N_LATENCY,
                               "us_per_round_batched": batched}

    # --- active frontier: full SSSP convergence with sweeps_per_call=16;
    # bs=16 keeps enough row-blocks for a meaningful fraction on --fast
    gw = gen.with_random_weights(g.relabel(rank), seed=3)
    res_f = run_async_block(get_algorithm("sssp", gw), bs=16,
                            backend="pallas", sweeps_per_call=16)
    afrac = [float(a) for a in np.asarray(res_f.active_block_fraction)]
    results["frontier_sssp_bs16"] = {
        "rounds": res_f.rounds,
        "active_block_fraction": afrac,
        "mean_active_fraction": float(np.mean(afrac)) if afrac else 1.0,
    }
    rows.append(("kernel/gs_multisweep/frontier_sssp", 0.0,
                 f"active frac first={afrac[0]:.2f} last={afrac[-1]:.2f} "
                 f"rounds={res_f.rounds}"))

    save_json(out_dir, "kernel_bench", results)
    payload = {
        "graph": {"kind": "powerlaw_cluster", "n": n, "fast": FAST},
        "configs": {k: v for k, v in results.items()
                    if k.startswith(("default_", "gograph_"))},
        "batched": results["batched_bs64"],
        "frontier": results["frontier_sssp_bs16"],
        "max_padding_waste_dense": max(
            v["padding_waste_dense"] for k, v in results.items()
            if k.startswith(("default_", "gograph_"))
        ),
        "total_tile_bytes_saved": sum(
            v["tile_bytes_saved"] for k, v in results.items()
            if k.startswith(("default_", "gograph_"))
        ),
    }
    with open(os.path.join(_REPO_ROOT, "BENCH_kernels.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return rows
