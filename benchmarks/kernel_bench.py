"""Kernel microbench: gs_sweep / bsr_spmm wall-clock (interpret mode — the
numbers are CPU emulation; the derived column reports the structural roofline
quantities that transfer to TPU: VMEM working set and DMA counts)."""
from __future__ import annotations

import time


from benchmarks.common import save_json
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm
from repro.graphs import generators as gen
from repro.kernels import gs_sweep
from repro.kernels.ops import pack_algorithm


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    g = gen.scrambled(gen.powerlaw_cluster(2000, 4, seed=1), seed=5)
    rank = gograph_order(g)
    for label, graph in (("default", g), ("gograph", g.relabel(rank))):
        algo = get_algorithm("pagerank", graph)
        for bs in (64, 128):
            ops = pack_algorithm(algo, bs=bs)
            stats = ops["bsr_stats"]
            t0 = time.perf_counter()
            out = gs_sweep(ops["cols"], ops["tiles"], ops["c"], ops["x0"],
                           ops["fixed"], ops["x"], semiring=ops["semiring"],
                           combine=ops["combine"])
            out.block_until_ready()
            us = (time.perf_counter() - t0) * 1e6
            vmem_kb = (bs * bs * 4 * stats["k_max"] + 2 * bs * 4) / 1024
            results[f"{label}_bs{bs}"] = {
                "us_per_sweep_interpret": us,
                "mean_dma_per_block": stats["mean_colblocks_per_rowblock"],
                "nnz_blocks": stats["nnz_blocks"],
                "vmem_tile_kb": vmem_kb,
            }
            rows.append((f"kernel/gs_sweep/{label}_bs{bs}", us,
                         f"dma/blk={stats['mean_colblocks_per_rowblock']:.1f} "
                         f"vmem={vmem_kb:.0f}KB"))
    save_json(out_dir, "kernel_bench", results)
    return rows
