"""Aggregate the dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os


def load_records(dryrun_dir: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table_rows(recs, mesh: str = "pod_16x16", tag: str = ""):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        if not r.get("applicable"):
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "status": "skip",
                "note": r.get("skip_reason", ""),
            })
            continue
        if "error" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "FAIL", "note": r["error"][:80]})
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_compute_ms": rl["t_compute_s"] * 1e3,
            "t_memory_ms": rl["t_memory_s"] * 1e3,
            "t_collective_ms": rl["t_collective_s"] * 1e3,
            "dominant": rl["dominant"],
            "useful_ratio": r.get("useful_flops_ratio"),
            "roofline_fraction": r.get("roofline_fraction"),
            "mem_gb": r["memory"]["tpu_est_bytes"] / 1e9,
            "fits_16g": bool(r["memory"]["fits_16g"]),
        })
    return rows


def run(out_dir: str = "experiments/paper"):
    recs = load_records()
    rows_out = []
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        for row in table_rows(recs, mesh):
            if row["status"] == "ok":
                rows_out.append((
                    f"roofline/{mesh}/{row['arch']}/{row['shape']}", 0.0,
                    f"dom={row['dominant']} frac={row['roofline_fraction']:.3f} "
                    f"mem={row['mem_gb']:.1f}G fits={row['fits_16g']}",
                ))
            else:
                rows_out.append((
                    f"roofline/{mesh}/{row['arch']}/{row['shape']}", 0.0,
                    row["status"] + " " + row.get("note", "")[:60],
                ))
    return rows_out
