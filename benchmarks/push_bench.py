"""Vertex-granular push vs block sweeps on sparse serving deltas.

The push engine's claim (ROADMAP item 2): absorbing a small graph delta
into a converged state should cost work proportional to the **touched
neighborhood**, not the graph. The block engine re-sweeps every vertex each
round (`rounds * n` swept-vertex relaxations) no matter how small the
change; the push engine settles only supra-threshold residual vertices
(`push_stats["pushed"]`).

Three sections, written to ``BENCH_push.json`` at the repo root (CI
uploads it and gates the numbers):

* ``delta_sssp`` — a 10-edge tighten delta on the converged ic-like SSSP
  state. The gated headline: push touches <= 5% of vertices and does
  <= 0.2x the block engine's swept-vertex work, with **bitwise identical**
  resolved states (min_plus quiescence pins the monotone closure).
* ``delta_pagerank`` — the dense counter-case, reported honestly: a
  10-edge insertion perturbs every out-edge weight of its sources (outdeg
  renormalization) and the eps=1e-6 residual wave reaches the whole
  expander, so push saturates. Correctness still holds (push == cold
  within accumulation noise); the work ratio is reported, not gated.
* ``router`` — the frontier-size routing signal on cold queries: dense
  cold PageRank (fraction 1.0) must route to the sweeps, a 1-seed PPR
  (fraction 1/n) to push, and both arms resolve the same answer.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.engine import (
    get_algorithm,
    personalized_pagerank,
    remake,
    run_async_block,
    run_incremental,
)
from repro.engine.api import solve
from repro.engine.push import estimate_frontier_fraction
from repro.graphs import generators as gen
from repro.graphs.delta import GraphDelta

BS = 64
N_DELTA_EDGES = 10
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _graphs():
    g = common.BENCH_GRAPHS["ic-like"]()
    gw = gen.with_random_weights(g, lo=0.1, hi=1.0, seed=3)
    return g, gw


def _absorb(algo, delta, graph, *, lattice):
    """Warm-absorb ``delta`` with push and with block sweeps; return the
    work accounting and the correctness check against a cold run."""
    prior = run_async_block(algo, bs=BS)
    g2 = delta.apply(graph)
    algo2 = remake(algo, g2)

    t0 = time.perf_counter()
    push = run_incremental(algo2, algo, prior, engine="push")
    push_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    block = run_incremental(algo2, algo, prior, bs=BS)
    block_us = (time.perf_counter() - t0) * 1e6
    cold = run_async_block(algo2, bs=BS)

    s = push.push_stats
    assert s is not None
    work_push = s["pushed"]                 # vertex settles, O(frontier)
    work_block = block.rounds * g2.n        # dense sweeps revisit every row
    xp = np.asarray(push.x)
    xc = np.asarray(cold.x)
    rec = {
        "delta_edges": delta.size,
        "push_rounds": push.rounds,
        "block_rounds": block.rounds,
        "work_push": int(work_push),
        "work_block": int(work_block),
        "work_ratio": work_push / max(1, work_block),
        "edges_relaxed_push": int(s["edges"]),
        "touched_fraction_push": s["touched_fraction"],
        # the jax block engine sweeps every vertex every round
        "touched_fraction_block": 1.0,
        "push_us": push_us,
        "block_us": block_us,
        "maxdiff_vs_cold": float(np.max(np.abs(xp - xc))) if xp.size else 0.0,
        "states_bitwise_equal": bool(np.array_equal(xp, xc)),
    }
    if lattice:
        assert rec["states_bitwise_equal"], "push must pin the min_plus closure"
    return rec


def _route(algo):
    """One router probe: the estimate, the arm `solve(engine="auto")` took
    (push runs carry push_stats), and agreement between the two arms."""
    frac = estimate_frontier_fraction(algo)
    r = solve(algo, engine="auto")
    ref = run_async_block(algo, bs=BS)
    return {
        "frontier_fraction": frac,
        "routed": "push" if r.push_stats is not None else "sweep",
        "rounds": r.rounds,
        "maxdiff_vs_sweep": float(np.max(np.abs(
            np.asarray(r.x) - np.asarray(ref.x)))),
    }


def run(out_dir: str):
    g, gw = _graphs()
    rng = np.random.default_rng(7)

    # 10-edge tighten delta: new weights = 0.9x on existing edges, so the
    # distance improvement is local — the regime serving deltas live in
    pick = rng.choice(gw.m, N_DELTA_EDGES, replace=False)
    d_sssp = GraphDelta(rew_src=gw.src[pick], rew_dst=gw.dst[pick],
                        rew_w=(gw.weights[pick] * 0.9).astype(np.float32))
    sssp = _absorb(get_algorithm("sssp", gw, source=0), d_sssp, gw,
                   lattice=True)

    # 10-edge insertion on pagerank: dense by construction (renormalization)
    src = rng.integers(0, g.n, N_DELTA_EDGES).astype(np.int32)
    dst = rng.integers(0, g.n, N_DELTA_EDGES).astype(np.int32)
    keep = src != dst
    d_pr = GraphDelta(add_src=src[keep], add_dst=dst[keep])
    pr = _absorb(get_algorithm("pagerank", g), d_pr, g, lattice=False)

    router = {
        "pagerank_cold": _route(get_algorithm("pagerank", g)),
        "ppr_cold": _route(personalized_pagerank(g, seeds=[5])),
    }

    payload = {
        "config": {
            "graph": "ic-like", "n": int(g.n), "m": int(g.m), "bs": BS,
            "delta_edges": N_DELTA_EDGES, "fast": common.FAST,
        },
        "delta_sssp": sssp,
        "delta_pagerank": pr,
        "router": router,
    }
    common.save_json(out_dir, "push_bench", payload)
    # repo root regardless of cwd (CI reads/uploads it from there)
    with open(os.path.join(_REPO_ROOT, "BENCH_push.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)

    rows = []
    for name, rec in (("sssp", sssp), ("pagerank", pr)):
        rows.append((
            f"push/delta_{name}", rec["push_us"],
            f"work={rec['work_push']}/{rec['work_block']} "
            f"ratio={rec['work_ratio']:.3f} "
            f"touched={rec['touched_fraction_push']:.3f} "
            f"bitwise={rec['states_bitwise_equal']}",
        ))
    for name, rec in router.items():
        rows.append((
            f"push/router_{name}", 0.0,
            f"frac={rec['frontier_fraction']:.4f} -> {rec['routed']} "
            f"rounds={rec['rounds']} maxdiff={rec['maxdiff_vs_sweep']:.1e}",
        ))
    return rows
