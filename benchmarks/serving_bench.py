"""Continuous vs static batching at equal d (the serving layer's claim).

Workload: SSSP queries with heavily skewed per-query round counts — the
paper-Fig.-7 regime, condensed into a hub-plus-path graph (hub sources
converge in a handful of sweeps, deep-tail sources need dozens to
hundreds). Both modes run the same :class:`repro.serving.GraphServer` with
the same 64-column resident state; the only difference is the refill
policy: ``static`` refills a family's columns only when *every* slot has
resolved (classic batch serving — fast queries idle until the slowest
straggler drains), ``continuous`` swaps a queued query into each column the
batch it converges.

Reported per mode: queries/sec (wall, post-warmup), p99 ticket latency,
total engine rounds, mean slot occupancy. The acceptance headline is the
continuous/static speedup: >= 1.3x queries/sec on this workload. Rounds
are deterministic, so the CI smoke asserts the rounds ratio (exact) and
that wall throughput didn't invert, and uploads ``BENCH_serving.json``
(repo root, like ``BENCH_kernels.json``) as the cross-PR trajectory.

A third section serves the same stream to TWO tenants of one server
(identical graphs, so the fair split is deterministic) and reports the
cross-tenant fairness — min/max share of family batches — which the CI
smoke gates at >= 0.8 alongside the rounds ratio.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.graphs import generators as gen
from repro.graphs.graph import Graph
from repro.serving import GraphServer

SLOTS = 64
ROUNDS_PER_BATCH = 4
BS = 64
HUB_N = 400 if common.FAST else 3000
TAIL_N = 120 if common.FAST else 500
N_QUERIES = 128 if common.FAST else 256
TAIL_FRACTION = 0.25   # share of queries starting on the path tail


def _skewed_graph() -> tuple[Graph, np.ndarray]:
    """Hub cluster + a path tail feeding INTO the hub, scrambled (the
    paper's 'bad default order'), weights in (0, 1].

    Direction matters: a SSSP query only converges when its whole
    *reachable* region stabilizes, so a tail the hub could reach would slow
    every query down equally. Pointing the path at the hub makes tail-depth
    sources slow (the distance wave must walk the path) while hub sources
    never see the tail at all — genuinely skewed per-query round counts.
    """
    hub = gen.powerlaw_cluster(HUB_N, 5, p=0.4, seed=1)
    n = hub.n + TAIL_N
    ps = np.arange(HUB_N + 1, n, dtype=np.int32)        # p_k -> p_{k-1}
    pd = np.arange(HUB_N, n - 1, dtype=np.int32)
    g = Graph(
        n,
        np.concatenate([hub.src, ps, [HUB_N]]),         # p_0 -> hub vertex 0
        np.concatenate([hub.dst, pd, [0]]),
    )
    rank = np.random.default_rng(7).permutation(n).astype(np.int64)
    gw = gen.with_random_weights(g.relabel(rank), lo=0.1, hi=1.0, seed=2)
    return gw, rank   # rank maps pre-scramble ids -> served ids


def _sources(rng: np.random.Generator, rank: np.ndarray) -> list[int]:
    """Mixed-convergence-speed query stream: mostly hub sources (fast), a
    spread of tail depths (slow), interleaved so every static batch of
    SLOTS inherits stragglers — the skew continuous batching absorbs."""
    n_tail = int(N_QUERIES * TAIL_FRACTION)
    hub_ids = rng.integers(0, HUB_N, size=N_QUERIES - n_tail)
    depths = rng.integers(TAIL_N // 4, TAIL_N, size=n_tail)
    mixed = rank[np.concatenate([hub_ids, HUB_N + depths])]
    rng.shuffle(mixed)
    return [int(s) for s in mixed]


def _serve(gw: Graph, sources, refill: str) -> dict:
    srv = GraphServer(
        gw, slots=SLOTS, bs=BS, rounds_per_batch=ROUNDS_PER_BATCH,
        refill=refill, cache=False,
    )
    t0 = time.perf_counter()
    tickets = [srv.submit("sssp", {"source": s}) for s in sources]
    srv.run()
    dt = time.perf_counter() - t0
    assert all(t.converged for t in tickets), refill
    s = srv.stats.summary()
    return {
        "qps": len(tickets) / dt,
        "wall_s": dt,
        "rounds_total": s["rounds_total"],
        "round_slots_total": s["round_slots_total"],
        "batches": s["batches"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p99_s": s["latency_p99_s"],
        "occupancy_mean": s["occupancy_mean"],
        "rounds_p99": s["rounds_p99"],
    }


def _serve_multi(gw: Graph, sources) -> dict:
    """Two tenants, identical graph + query stream each: the round-robin
    interleave must split family batches evenly (fairness -> 1.0) while
    every query still resolves. Identical workloads make the fairness
    number deterministic instead of a property of source luck."""
    srv = GraphServer(
        graphs={"a": gw, "b": gw}, slots=SLOTS, bs=BS,
        rounds_per_batch=ROUNDS_PER_BATCH, refill="continuous", cache=False,
    )
    t0 = time.perf_counter()
    tickets = [
        srv.submit("sssp", {"source": s}, tenant=name)
        for s in sources for name in ("a", "b")
    ]
    srv.run()
    dt = time.perf_counter() - t0
    assert all(t.converged for t in tickets)
    s = srv.stats.summary()
    tb, tr = s["tenant_batches"], s["tenant_rounds"]
    return {
        "tenants": len(srv.tenants),
        "qps": len(tickets) / dt,
        "wall_s": dt,
        "tenant_batches": tb,
        "tenant_rounds": tr,
        # min/max share of family batches across tenants — 1.0 is a
        # perfectly fair split of the server's attention
        "fairness": min(tb.values()) / max(1, max(tb.values())),
        "rounds_total": s["rounds_total"],
        "occupancy_mean": s["occupancy_mean"],
    }


def run(out_dir: str):
    gw, rank = _skewed_graph()
    rng = np.random.default_rng(0)
    sources = _sources(rng, rank)
    # warmup: compile the (d=SLOTS, rounds_per_batch) jit once; both modes
    # reuse it (identical shapes), so neither pays compile time in the timed
    # region
    _serve(gw, sources[: SLOTS // 2], "continuous")

    cont = _serve(gw, sources, "continuous")
    stat = _serve(gw, sources, "static")
    multi = _serve_multi(gw, sources[: N_QUERIES // 2])
    speedup_qps = cont["qps"] / max(1e-12, stat["qps"])
    speedup_rounds = stat["rounds_total"] / max(1, cont["rounds_total"])

    payload = {
        "config": {
            "slots": SLOTS, "rounds_per_batch": ROUNDS_PER_BATCH, "bs": BS,
            "n": int(gw.n), "m": int(gw.m), "queries": len(sources),
            "tail_fraction": TAIL_FRACTION, "fast": common.FAST,
        },
        "continuous": cont,
        "static": stat,
        "multi_tenant": multi,
        "speedup_qps": speedup_qps,
        "speedup_rounds": speedup_rounds,
    }
    common.save_json(out_dir, "serving", payload)
    # repo root regardless of cwd (CI reads/uploads it from there)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_serving.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)

    rows = []
    for mode, rec in (("continuous", cont), ("static", stat)):
        rows.append((
            f"serving_{mode}", rec["wall_s"] * 1e6,
            f"qps={rec['qps']:.1f} rounds={rec['rounds_total']} "
            f"p99={rec['latency_p99_s'] * 1e3:.0f}ms "
            f"occ={rec['occupancy_mean']:.2f}",
        ))
    rows.append((
        "serving_speedup", 0.0,
        f"qps_ratio={speedup_qps:.2f} rounds_ratio={speedup_rounds:.2f} "
        f"target>=1.30",
    ))
    rows.append((
        "serving_multi_tenant", multi["wall_s"] * 1e6,
        f"tenants={multi['tenants']} fairness={multi['fairness']:.2f} "
        f"qps={multi['qps']:.1f} occ={multi['occupancy_mean']:.2f}",
    ))
    return rows
