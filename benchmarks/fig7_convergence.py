"""Paper Fig. 7: convergence distance dist_t = |sum x* - sum x_t| per round
for PageRank and SSSP on cp-like/lj-like graphs, GoGraph vs competitors."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_GRAPHS, reorderers, run_one, save_json
from repro.engine import get_algorithm
from repro.graphs import generators as gen


def run(out_dir: str = "experiments/paper"):
    rows = []
    curves = {}
    for gname in ("cp-like", "lj-like"):
        g = BENCH_GRAPHS[gname]()
        curves[gname] = {}
        for algo_name in ("pagerank", "sssp"):
            graph = g if algo_name != "sssp" else gen.with_random_weights(g, seed=3)
            x_star_sum = float(np.sum(np.where(
                np.abs(get_algorithm(algo_name, graph).exact()) < 1e30,
                get_algorithm(algo_name, graph).exact(), 0.0)))
            curves[gname][algo_name] = {}
            for rname, rfn in reorderers().items():
                rank = rfn(g) if rname != "Default" else None
                r = run_one(g, algo_name, rank)
                dist = np.abs(x_star_sum - r.state_sums[: r.rounds])
                curves[gname][algo_name][rname] = {
                    "rounds": r.rounds,
                    "dist": [float(d) for d in dist],
                }
            gg = curves[gname][algo_name]["GoGraph"]["rounds"]
            others = [v["rounds"] for k, v in curves[gname][algo_name].items()
                      if k != "GoGraph"]
            rows.append((f"fig7/{gname}/{algo_name}", 0.0,
                         f"GoGraph rounds={gg} vs others mean={np.mean(others):.1f}"))
    save_json(out_dir, "fig7_convergence", curves)
    return rows
