"""Online amortized reordering on a sustained delta stream.

The tentpole claim (ROADMAP item 4): a GoGraph order *decays* as deltas
land — extend-only maintenance (`extend_rank`) places arriving vertices
well but never repairs existing ones, so a stream that rewires the graph
drags M down and rounds back up — and the online path (incremental
`MetricTracker` -> `decayed_regions` -> `regional_rerank`) recovers most
of the lost rounds at O(|region| * deg) cost, without ever recomputing the
full order.

Adversarial-but-realistic stream: a directed path under a shuffled id
assignment (the chain is the best order; positive-edge fraction 1.0), hit
by deltas that reverse contiguous chain segments in place (the graph stays
a single path, but the old order traverses each reversed segment backward:
one round per hop for a block Gauss-Seidel sweep) plus occasional appended
tail vertices. Decay is region-local by construction, which is exactly the
regime regional re-ranking is for.

Three orders are maintained across the same stream and measured with the
same engine (``solve(engine="async_block", rank=...)``, SSSP from the chain
head, so every round count is an end-to-end number through the packed
entry path):

* ``fresh``   — full `gograph_order` recompute after every delta (the
  O(m log m)-per-delta upper bound the online path amortizes away);
* ``decayed`` — extend-only maintenance (the do-nothing lower bound);
* ``online``  — extend + tracker-triggered regional re-ranks.

Gated in ``BENCH_reorder.json`` (CI uploads and asserts, fast mode
included): ``decay_ratio = rounds_decayed / rounds_fresh >= 1.2`` (the
stream really does cost rounds) and ``recovery = (rounds_decayed -
rounds_online) / (rounds_decayed - rounds_fresh) >= 0.8`` (the online path
recovers >= 80% of the gap). The per-delta M-fraction curve for all three
orders rides along (the README plot), as does a GraphServer pass over the
same stream showing the serving loop's ``reorders`` telemetry and resolved
rounds with reordering on vs off.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks import common
from repro.core.gograph import RankMaintainer, gograph_order
from repro.core.metric import MetricTracker, metric_m
from repro.engine.api import solve
from repro.engine.algorithms import get_algorithm
from repro.graphs.delta import GraphDelta
from repro.graphs.graph import Graph
from repro.serving.server import GraphServer

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# bs == inner: within a sweep each block's Jacobi re-iterations reach the
# in-block fixpoint, so rounds are governed purely by *backward block
# crossings* — the quantity the processing order controls (intra-block
# edges are fresh either way; see `core.metric.block_fresh_fraction`)
BS = 8
INNER = 8
THRESHOLD = 0.9        # regional re-rank trigger (M fraction)
REGIONS = 16
N = 512 if common.FAST else 2048
N_DELTAS = 4 if common.FAST else 8
SEG = N // (6 if common.FAST else 12)   # reversed-segment length (hops)


def _shuffled_path(n: int, seed: int = 11):
    """Directed unit-weight path over a shuffled id assignment."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n).astype(np.int64)
    g = Graph(n=n, src=perm[:-1].copy(), dst=perm[1:].copy(),
              w=np.ones(n - 1, np.float32))
    rank = np.empty(n, np.int64)
    rank[perm] = np.arange(n)
    return g, rank, perm.tolist()


def _reverse_segment(chain: list, lo: int, hi: int) -> GraphDelta:
    """Reverse chain positions [lo, hi] *in place* (the graph stays one
    path): delete the old sub-chain through the segment, add the re-linked
    one with the segment traversed backward. Mutates ``chain``."""
    seg = np.asarray(chain[lo - 1:hi + 2], np.int64)  # with both boundaries
    new = np.concatenate([seg[:1], seg[1:-1][::-1], seg[-1:]])
    chain[lo:hi + 1] = chain[lo:hi + 1][::-1]
    return GraphDelta(
        del_src=seg[:-1].copy(), del_dst=seg[1:].copy(),
        add_src=new[:-1].copy(), add_dst=new[1:].copy(),
        add_w=np.ones(len(seg) - 1, np.float32),
    )


def _extend_tail(chain: list, n: int, k: int) -> GraphDelta:
    """Append k vertices continuing the path at the tail. Mutates chain."""
    ids = np.arange(n, n + k, dtype=np.int64)
    src = np.concatenate([[chain[-1]], ids[:-1]])
    chain.extend(ids.tolist())
    return GraphDelta(n_add=k, add_src=src, add_dst=ids,
                      add_w=np.ones(k, np.float32))


def _stream(seed: int = 23):
    """The delta stream: N_DELTAS segment reversals over distinct chunks of
    the chain, a small tail extension after every second one."""
    g, rank, chain = _shuffled_path(N, seed)
    rng = np.random.default_rng(seed)
    deltas = []
    chunk = (N - 2) // N_DELTAS
    for i in range(N_DELTAS):
        lo = 1 + i * chunk + int(rng.integers(0, max(1, chunk - SEG - 2)))
        deltas.append(("rev", _reverse_segment(chain, lo, lo + SEG)))
        if i % 2 == 1:
            deltas.append(("ext", _extend_tail(chain, len(chain), 4)))
    return g, rank, chain, deltas


def _rounds(g: Graph, rank: np.ndarray, source: int) -> int:
    algo = get_algorithm("sssp", g, source=source)
    return solve(algo, engine="async_block", bs=BS, inner=INNER,
                 rank=rank).rounds


def run(out_dir: str):
    g0, rank0, chain, deltas = _stream()
    head = chain[0]

    # three order-maintenance policies over the SAME stream
    g = g0
    decay = RankMaintainer(rank0)
    online = RankMaintainer(rank0)
    tracker = MetricTracker(g0, rank0, regions=REGIONS)
    rank_online = rank0
    curve = []
    reranks = 0
    for kind, d in deltas:
        g = d.apply(g)
        rank_decay = decay.extend(g)
        rank_online = online.extend(g)
        tracker.apply_delta(d, rank_new=rank_online if d.n_add else None)
        assert tracker.M == metric_m(g, rank_online), "tracker drift"
        decayed = tracker.decayed_regions(THRESHOLD)
        if len(decayed):
            from repro.core.gograph import regional_rerank

            members = tracker.region_members(decayed)
            rank_online = regional_rerank(g, rank_online, members)
            tracker.rebase(g, rank_online)
            online = RankMaintainer(rank_online)
            reranks += 1
        rank_fresh = gograph_order(g)
        m = max(1, g.m)
        curve.append({
            "delta": kind,
            "m_frac_fresh": metric_m(g, rank_fresh) / m,
            "m_frac_online": tracker.m_frac,
            "m_frac_decayed": metric_m(g, rank_decay) / m,
        })

    (r_fresh, us_fresh) = common.timed(_rounds, g, rank_fresh, head)
    (r_online, us_online) = common.timed(_rounds, g, rank_online, head)
    (r_decay, us_decay) = common.timed(_rounds, g, rank_decay, head)
    decay_ratio = r_decay / max(1, r_fresh)
    recovery = (r_decay - r_online) / max(1, r_decay - r_fresh)

    # the serving loop over the same stream: reorder_threshold on vs off,
    # the post-stream head query's resolved rounds are the payoff
    def serve(threshold: float):
        srv = GraphServer(g0, slots=2, bs=BS, inner=INNER,
                          rounds_per_batch=4, transfer_guard="disallow",
                          rank=rank0, reorder_threshold=threshold,
                          reorder_regions=REGIONS)
        for _, d in deltas:
            srv.apply_delta(d)
        t = srv.submit("sssp", {"source": head})
        srv.run()
        assert t.converged
        return t, srv.stats.summary()

    t_off, s_off = serve(0.0)
    t_on, s_on = serve(THRESHOLD)
    assert np.array_equal(t_on.result, t_off.result), \
        "reordering changed a resolved state"

    payload = {
        "config": {
            "n": int(g.n), "m": int(g.m), "bs": BS, "deltas": len(deltas),
            "segment": SEG, "threshold": THRESHOLD, "regions": REGIONS,
            "fast": common.FAST,
        },
        "rounds": {"fresh": r_fresh, "online": r_online, "decayed": r_decay},
        "decay_ratio": decay_ratio,
        "recovery": recovery,
        "reranks": reranks,
        "curve": curve,
        "serving": {
            "rounds_reorder_off": t_off.rounds,
            "rounds_reorder_on": t_on.rounds,
            "reorders": s_on["reorders"],
            "reorders_disabled": s_on["reorders_disabled"],
        },
    }
    common.save_json(out_dir, "reorder_bench", payload)
    with open(os.path.join(_REPO_ROOT, "BENCH_reorder.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)

    return [
        ("reorder/fresh", us_fresh, f"rounds={r_fresh}"),
        ("reorder/online", us_online,
         f"rounds={r_online} reranks={reranks} recovery={recovery:.2f}"),
        ("reorder/decayed", us_decay,
         f"rounds={r_decay} ratio={decay_ratio:.2f}"),
        ("reorder/serving", 0.0,
         f"rounds on/off={t_on.rounds}/{t_off.rounds} "
         f"reorders={s_on['reorders']}"),
    ]
