"""Regenerate the machine-made sections of EXPERIMENTS.md from the dry-run
JSONs and the paper-benchmark JSONs. Invoked by hand after sweeps:

    PYTHONPATH=src python -m benchmarks.make_experiments_tables
"""
from __future__ import annotations

import glob
import json
import os


def fmt(x, nd=3):
    if x is None:
        return "—"
    return f"{x:.{nd}f}"


def dryrun_table(mesh: str) -> str:
    lines = [
        "| arch | shape | compile | mem/dev (TPU est) | fits 16G | T_compute | T_memory | T_collective | dominant | useful F ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        if r.get("mesh") != mesh or r.get("tag"):
            continue
        if not r.get("applicable"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                f"SKIP | — | — |"
            )
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | | |")
            continue
        rl = r["roofline"]
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f}s "
            f"| {m['tpu_est_bytes']/1e9:.1f}G | {'yes' if m['fits_16g'] else 'NO'} "
            f"| {rl['t_compute_s']*1e3:.1f}ms | {rl['t_memory_s']*1e3:.1f}ms "
            f"| {rl['t_collective_s']*1e3:.1f}ms | {rl['dominant']} "
            f"| {fmt(r.get('useful_flops_ratio'), 2)} "
            f"| {fmt(r.get('roofline_fraction'), 3)} |"
        )
    return "\n".join(lines)


def skip_list() -> str:
    out = []
    for f in sorted(glob.glob("experiments/dryrun/*__pod_16x16.json")):
        r = json.load(open(f))
        if not r.get("applicable") and not r.get("tag"):
            out.append(f"* **{r['arch']} × {r['shape']}** — {r['skip_reason']}")
    return "\n".join(out)


def hillclimb_rows(pattern: str) -> str:
    lines = [
        "| tag | T_compute | T_memory | T_collective | dominant | useful | frac | mem/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for f in sorted(glob.glob(pattern), key=os.path.getmtime):
        r = json.load(open(f))
        if "error" in r:
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r.get('tag') or 'baseline'} | {rl['t_compute_s']:.3f}s "
            f"| {rl['t_memory_s']:.3f}s | {rl['t_collective_s']:.3f}s "
            f"| {rl['dominant']} | {fmt(r.get('useful_flops_ratio'),2)} "
            f"| {fmt(r.get('roofline_fraction'),3)} "
            f"| {r['memory']['tpu_est_bytes']/1e9:.1f}G |"
        )
    return "\n".join(lines)


def main():
    parts = {
        "DRYRUN_SINGLE": dryrun_table("pod_16x16"),
        "DRYRUN_MULTI": dryrun_table("multipod_2x16x16"),
        "SKIPS": skip_list(),
        "HC_XLSTM": hillclimb_rows("experiments/hillclimb/xlstm-350m__train_4k__pod_16x16*.json"),
        "HC_GEMMA": hillclimb_rows("experiments/hillclimb/gemma-7b__prefill_32k__pod_16x16*.json"),
        "HC_INTERNVL": hillclimb_rows("experiments/hillclimb/internvl2-76b__train_4k__pod_16x16*.json"),
    }
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/generated_tables.json", "w") as f:
        json.dump(parts, f)
    for k, v in parts.items():
        print(f"=== {k} ===")
        print(v)
        print()


if __name__ == "__main__":
    main()
