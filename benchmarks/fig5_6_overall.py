"""Paper Fig. 5/6: normalized runtime and iteration rounds of the four
algorithms under every reordering method, across graphs (Default = 1.0).

Runtime on this CPU container is engine wall-clock of the jitted sweep loop;
rounds is the hardware-independent quantity the paper's mechanism predicts.
"""
from __future__ import annotations

import time

from benchmarks.common import BENCH_GRAPHS, ALGOS, reorderers, run_one, save_json


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    for gname, gfn in BENCH_GRAPHS.items():
        g = gfn()
        results[gname] = {}
        for rname, rfn in reorderers().items():
            rank = rfn(g) if rname != "Default" else None
            entry = {}
            for algo in ALGOS:
                t0 = time.perf_counter()
                r = run_one(g, algo, rank)
                dt = time.perf_counter() - t0
                entry[algo] = {"rounds": r.rounds, "runtime_s": dt,
                               "converged": bool(r.converged)}
            results[gname][rname] = entry
        base = results[gname]["Default"]
        for rname, entry in results[gname].items():
            for algo in ALGOS:
                entry[algo]["norm_rounds"] = (
                    entry[algo]["rounds"] / max(1, base[algo]["rounds"])
                )
        gg = results[gname]["GoGraph"]
        mean_reduction = 1 - sum(
            gg[a]["norm_rounds"] for a in ALGOS) / len(ALGOS)
        rows.append((f"fig5_6/{gname}", 0.0,
                     f"GoGraph mean round reduction vs Default: {mean_reduction:.2%}"))
    save_json(out_dir, "fig5_6_overall", results)
    return rows
