"""Paper Fig. 12 / §V-H: Barabasi-Albert graphs with average degree
2/4/6/8 — PageRank rounds & runtime per reorderer (n scaled down; the
paper uses 1M vertices)."""
from __future__ import annotations

import time

from benchmarks.common import reorderers, run_one, save_json
from repro.graphs import generators as gen


def run(out_dir: str = "experiments/paper"):
    rows = []
    results = {}
    for m in (1, 2, 3, 4):  # BA attachment -> avg degree ~2m
        g = gen.scrambled(gen.barabasi_albert(5000, m, seed=m), seed=21)
        results[f"avg_deg_{2*m}"] = {}
        for rname, rfn in reorderers().items():
            rank = rfn(g) if rname != "Default" else None
            t0 = time.perf_counter()
            r = run_one(g, "pagerank", rank)
            results[f"avg_deg_{2*m}"][rname] = {
                "rounds": r.rounds, "runtime_s": time.perf_counter() - t0,
            }
        gg = results[f"avg_deg_{2*m}"]["GoGraph"]["rounds"]
        dflt = results[f"avg_deg_{2*m}"]["Default"]["rounds"]
        rows.append((f"fig12/deg{2*m}", 0.0, f"rounds GoGraph={gg} Default={dflt}"))
    save_json(out_dir, "fig12_degrees", results)
    return rows
