"""Serve a small LM with batched requests: prefill + decode loop over a
KV cache, greedy sampling, per-request lengths — the serving-side driver.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --tokens 32
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.model import build_model


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gemma3-4b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--tokens", type=int, default=24)
    args = p.parse_args()

    cfg = get_reduced(args.arch)
    if cfg.arch_type == "encdec":
        raise SystemExit("use whisper decode via tests; this driver is decoder-only")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.tokens

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len))
    prompts = jnp.asarray(prompts, jnp.int32)

    print(f"prefill: batch={args.batch} prompt_len={args.prompt_len}")
    t0 = time.perf_counter()
    kw = {}
    if cfg.prefix_len:
        kw["prefix_embeds"] = jnp.zeros(
            (args.batch, cfg.prefix_len, cfg.d_model), jnp.float32)
    last_logits, caches = model.prefill(params, prompts, max_seq=max_seq, **kw)
    print(f"  prefill {time.perf_counter()-t0:.2f}s")

    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    tok = jnp.argmax(last_logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)[:, 0]]
    pos = jnp.full((args.batch,), args.prompt_len + cfg.prefix_len - 1, jnp.int32)

    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = pos + 1
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok)[:, 0])
    dt = time.perf_counter() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"decoded {args.tokens} tokens/request in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s total)")
    print("generated ids (req 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
