"""Distributed graph engine demo: vertex-sharded PageRank over a device
mesh (sync across shards, Gauss-Seidel within), with GoGraph keeping
cross-shard edges scarce.

Run with multiple host devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_pagerank.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import numpy as np
import jax

from repro import get_algorithm, solve
from repro.core.gograph import gograph_order
from repro.graphs import generators as gen


def main():
    print(f"devices: {len(jax.devices())}")
    g = gen.scrambled(gen.powerlaw_cluster(20_000, 5, seed=1), seed=3)
    rank = gograph_order(g)
    algo = get_algorithm("pagerank", g).relabel(rank)

    # fraction of edges that stay within a shard (the GoGraph locality win)
    ndev = len(jax.devices())
    shard = (np.arange(g.n) * ndev) // g.n
    g2 = g.relabel(rank)
    intra = float(np.mean(shard[g2.src] == shard[g2.dst]))
    print(f"intra-shard edge fraction after GoGraph: {intra:.2f}")

    r_single = solve(algo, engine="async_block", bs=64)
    r_dist = solve(algo, engine="distributed", bs=64)
    err = np.max(np.abs(r_dist.x - algo.exact()))
    print(f"single-device async rounds: {r_single.rounds}")
    print(f"{ndev}-device hybrid rounds: {r_dist.rounds} (err {err:.1e})")
    print("cross-shard staleness costs rounds; locality keeps it bounded "
          "(DESIGN.md §3)")


if __name__ == "__main__":
    main()
