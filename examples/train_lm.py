"""Train a language model end-to-end with the production loop: sharded init,
AdamW, microbatching, checkpoint/restart, straggler monitoring.

Default preset is CPU-sized (runs in ~2 min); `--preset 100m --steps 300` is
the ~100M-parameter configuration for real hardware.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --arch xlstm-350m --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")


from repro.ckpt.manager import CheckpointManager
from repro.configs import get_reduced
from repro.data.tokens import TokenDataset, TokenDatasetConfig
from repro.launch.mesh import make_debug_mesh
from repro.models.model import ModelConfig, build_model
from repro.runtime.fault import StragglerMonitor
from repro.sharding.rules import default_rules
from repro.train import optim
from repro.train.loop import TrainConfig, train_loop


def preset_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", vocab=32768, d_model=640, n_layers=12, n_heads=10,
        n_kv=10, d_ff=2560, pattern=("attn+mlp",), mlp_kind="swiglu",
        norm_kind="rms", remat="none",
    )


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="olmo-1b",
                   help="reduced config of this arch (or --preset 100m)")
    p.add_argument("--preset", default=None, choices=[None, "100m"])
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-every", type=int, default=25)
    args = p.parse_args()

    cfg = preset_100m() if args.preset == "100m" else get_reduced(args.arch)
    model = build_model(cfg)
    n = cfg.n_params()
    print(f"model {cfg.name}: ~{n/1e6:.1f}M params")

    mesh = make_debug_mesh()
    rules = default_rules(mesh)
    tcfg = TrainConfig(
        opt=optim.AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps),
        microbatches=args.microbatches,
    )
    ds = TokenDataset(TokenDatasetConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=0,
        structure=0.9,
    ), prefix_len=cfg.prefix_len, d_model=cfg.d_model,
       frames=cfg.arch_type == "encdec")

    ckpt_dir = tempfile.mkdtemp(prefix="lm_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep_last=2)
    mon = StragglerMonitor(threshold=3.0)

    def hook(step, params, opt_state, metrics, dt):
        mon.observe(step, dt)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f} ms")

    params, opt_state, history = train_loop(
        model, mesh, rules, tcfg, ds, steps=args.steps,
        ckpt_manager=mgr, ckpt_every=args.ckpt_every, hooks=[hook],
    )
    print(f"loss: {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f}")
    print(f"checkpoints at {ckpt_dir}: steps {mgr.all_steps()}")
    if mon.events:
        print(f"straggler events: {len(mon.events)}")


if __name__ == "__main__":
    main()
