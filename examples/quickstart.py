"""Quickstart: the paper in 60 seconds.

Builds a power-law graph, reorders it with GoGraph, and runs PageRank in the
three execution modes the paper compares — synchronous, asynchronous with the
default order, asynchronous with the GoGraph order — printing the metric
M(O_V) and the number of iteration rounds for each.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import get_algorithm, solve
from repro.core import baselines, metric
from repro.core.gograph import gograph_order
from repro.graphs import generators as gen


def main():
    print("generating a scrambled power-law graph (the paper's web-graph regime)...")
    g = gen.scrambled(gen.powerlaw_cluster(8000, 5, seed=1), seed=7)
    print(f"  {g}")

    print("\nreordering with GoGraph (divide-and-conquer, maximizing M)...")
    rank = gograph_order(g)
    m_default = metric.positive_edge_fraction(g, baselines.default_order(g))
    m_gograph = metric.positive_edge_fraction(g, rank)
    print(f"  M/|E| default  = {m_default:.3f}")
    print(f"  M/|E| GoGraph  = {m_gograph:.3f}   (Theorem 2 guarantees >= 0.5)")

    algo = get_algorithm("pagerank", g)
    algo_gg = algo.relabel(rank)

    # inner=2: one VMEM-local re-iteration per block makes the intra-block
    # edges GoGraph concentrates fresh too (DESIGN.md §3) — free on TPU
    r_sync = solve(algo, engine="sync")
    r_async = solve(algo, engine="async_block", bs=64, inner=2)
    r_gg = solve(algo_gg, engine="async_block", bs=64, inner=2)

    print("\nPageRank iteration rounds to 1e-6 convergence:")
    print(f"  sync  + default order : {r_sync.rounds}")
    print(f"  async + default order : {r_async.rounds}")
    print(f"  async + GoGraph order : {r_gg.rounds}")
    speed = r_sync.rounds / max(1, r_gg.rounds)
    print(f"  round speedup (async+GoGraph vs sync): {speed:.2f}x")

    err = np.max(np.abs(r_gg.x - algo_gg.exact()))
    print(f"\nmax |x - exact| = {err:.2e}  (same fixpoint, fewer rounds)")


if __name__ == "__main__":
    main()
