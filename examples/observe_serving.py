"""Observability walkthrough: trace a skewed serving workload end to end.

The PR-5 serving benchmark's workload — a hub cluster plus a path tail
feeding into it, so SSSP sources on the hub converge in a handful of
rounds while tail sources need dozens — served to TWO tenants of one
:class:`repro.serving.GraphServer` with a `repro.obs.Tracer` writing every
span to a JSONL file. Afterwards the script reads the sink back (exactly
what a dashboard would do) and renders, with no dependencies beyond the
stdlib and numpy:

* the per-tenant resolved-rounds histogram (text bars) from the
  ``resolve`` events — the skew made visible;
* the residual decay of one traced solo solve as a unicode sparkline from
  ``RunResult.convergence_trace`` — the paper's Fig. 7 quantity;
* an excerpt of the Prometheus exposition `GraphServer.metrics_text()`
  serves.

    PYTHONPATH=src python examples/observe_serving.py
"""
import json
import os
import tempfile

import numpy as np

from repro import GraphServer, get_algorithm, solve
from repro.engine.api import EngineOptions
from repro.graphs import generators as gen
from repro.graphs.graph import Graph
from repro.obs import Tracer

HUB_N = 500
TAIL_N = 140
N_QUERIES = 48
SLOTS = 8
BARS = "▁▂▃▄▅▆▇█"


def skewed_graph() -> Graph:
    """Hub + path-tail-into-hub (see benchmarks/serving_bench.py): hub
    sources resolve fast, tail sources slow — skewed per-query rounds."""
    hub = gen.powerlaw_cluster(HUB_N, 5, p=0.4, seed=1)
    n = hub.n + TAIL_N
    ps = np.arange(HUB_N + 1, n, dtype=np.int32)
    pd = np.arange(HUB_N, n - 1, dtype=np.int32)
    g = Graph(n, np.concatenate([hub.src, ps, [HUB_N]]),
              np.concatenate([hub.dst, pd, [0]]))
    return gen.with_random_weights(g, lo=0.1, hi=1.0, seed=2)


def sparkline(values, width: int = 48) -> str:
    v = np.asarray(values, dtype=np.float64)
    if len(v) > width:   # resample long traces to the terminal width
        idx = np.linspace(0, len(v) - 1, width).astype(int)
        v = v[idx]
    v = np.log10(np.maximum(v, 1e-12))   # residuals decay geometrically
    lo, hi = v.min(), v.max()
    span = (hi - lo) or 1.0
    return "".join(BARS[int((x - lo) / span * (len(BARS) - 1))] for x in v)


def text_histogram(samples, edges) -> list[str]:
    counts, _ = np.histogram(samples, bins=edges)
    peak = max(int(counts.max()), 1)
    return [
        f"    rounds {int(lo):4d}-{int(hi):<4d} "
        f"{'█' * max(1, int(24 * c / peak)) if c else '':<24} {c}"
        for lo, hi, c in zip(edges[:-1], edges[1:], counts)
    ]


def main() -> None:
    rng = np.random.default_rng(0)
    gw = skewed_graph()
    sink_path = os.path.join(tempfile.mkdtemp(prefix="repro_obs_"),
                             "spans.jsonl")
    tracer = Tracer(jsonl=sink_path)

    # --- 1. one traced solo solve: the convergence trace ----------------
    deep_tail = gw.n - 1
    algo = get_algorithm("sssp", gw, source=deep_tail)
    res = solve(algo, options=EngineOptions(
        bs=64, trace=tracer, transfer_guard="disallow"))
    tr = res.convergence_trace
    print(f"solo SSSP from the tail tip: {res.rounds} rounds, "
          f"converged={res.converged}, unit={tr.unit}, "
          f"total work {tr.total_work:.0f}")
    print(f"  residual decay  {sparkline(tr.residual)}")
    print(f"  active fraction {sparkline(tr.active_fraction)}")

    # --- 2. two tenants, skewed SSSP stream, fully traced ---------------
    srv = GraphServer(gw, graphs={"replica": gw}, slots=SLOTS, bs=64,
                      rounds_per_batch=4, transfer_guard="disallow",
                      trace=tracer)
    n_tail = N_QUERIES // 4
    sources = np.concatenate([
        rng.integers(0, HUB_N, size=N_QUERIES - n_tail),       # fast
        rng.integers(HUB_N + TAIL_N // 4, gw.n, size=n_tail),  # slow
    ])
    rng.shuffle(sources)
    for k, s in enumerate(sources):
        tenant = "default" if k % 2 == 0 else "replica"
        srv.submit("sssp", {"source": int(s)}, tenant=tenant)
    srv.run()
    tracer.close()

    # --- 3. read the sink back, like a dashboard would ------------------
    with open(sink_path, encoding="utf-8") as fh:
        spans = [json.loads(line) for line in fh]
    resolves = [s for s in spans if s["name"] == "resolve"]
    batches = [s for s in spans if s["name"] == "batch"]
    print(f"\nJSONL sink {sink_path}: {len(spans)} spans "
          f"({len(batches)} batches, {len(resolves)} resolves)")
    edges = [0, 8, 16, 32, 64, 128, 512]
    for tenant in ("default", "replica"):
        rounds = [r["rounds"] for r in resolves if r["tenant"] == tenant]
        print(f"  tenant {tenant!r}: {len(rounds)} resolved, "
              f"p99 rounds {int(np.percentile(rounds, 99))}")
        for line in text_histogram(rounds, edges):
            print(line)

    # --- 4. the Prometheus endpoint -------------------------------------
    print("\nmetrics_text() excerpt:")
    wanted = ("repro_queries_resolved_total", "repro_rounds_total",
              "repro_query_rounds_count")
    for line in srv.metrics_text().splitlines():
        if line.startswith(wanted):
            print("  " + line)
    s = srv.stats.summary()
    print(f"\nsummary: rounds p50/p99 {s['rounds_p50']:.0f}/"
          f"{s['rounds_p99']:.0f}, per-tenant batches {s['tenant_batches']}")


if __name__ == "__main__":
    main()
