"""Serving PageRank on an evolving graph: converge once, then absorb a
stream of edge/vertex delta batches by iterating only on each batch's
residual (Maiter-style accumulative correction) instead of recomputing
from scratch.

    PYTHONPATH=src python examples/evolving_pagerank.py [--n 20000] [--batches 5]

Each step prints warm vs cold rounds and the cumulative rounds saved. The
processing order is maintained incrementally too: newly arrived vertices are
placed into the existing GoGraph rank via the GetOptVal insertion scan
(`core.gograph.extend_rank`), not a full reorder; `run_incremental` applies
the rank internally and returns id-space states, so the serving loop only
ever sees vertex ids.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.gograph import extend_rank, gograph_order
from repro.core.metric import positive_edge_fraction
from repro import get_algorithm, remake, run_incremental, solve
from repro.graphs import generators as gen
from repro.graphs.delta import random_delta


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=20_000)
    p.add_argument("--batches", type=int, default=5)
    p.add_argument("--frac-add", type=float, default=0.01)
    p.add_argument("--bs", type=int, default=256)
    args = p.parse_args()

    g = gen.scrambled(gen.powerlaw_cluster(args.n, 5, seed=1), seed=7)
    print(f"base graph: {g}")
    rank = gograph_order(g)
    algo = get_algorithm("pagerank", g)
    t0 = time.perf_counter()
    prior = solve(algo.relabel(rank), bs=args.bs, inner=2)
    x_served = prior.x[rank]  # back to id space: v's value sits at slot rank[v]
    print(f"initial convergence: {prior.rounds} rounds "
          f"({(time.perf_counter() - t0)*1e3:.0f} ms)\n")

    total_warm = total_cold = 0
    for step in range(args.batches):
        delta = random_delta(
            g, frac_add=args.frac_add, n_add_vertices=args.n // 1000,
            seed=100 + step,
        )
        g_new = delta.apply(g)
        algo_new = remake(algo, g_new)
        rank = extend_rank(g_new, rank)

        t0 = time.perf_counter()
        warm = run_incremental(
            algo_new, algo, x_served,
            engine="async_block", bs=args.bs, inner=2, rank=rank,
        )
        t_warm = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold = solve(algo_new.relabel(rank), bs=args.bs, inner=2)
        t_cold = time.perf_counter() - t0

        drift = float(np.abs(warm.x - cold.x[rank]).max())
        total_warm += warm.rounds
        total_cold += cold.rounds
        print(f"batch {step}: +{len(delta.add_src)} edges, "
              f"+{delta.n_add} vertices, M/|E|={positive_edge_fraction(g_new, rank):.3f}"
              f" -> warm {warm.rounds} rounds ({t_warm*1e3:.0f} ms) "
              f"vs cold {cold.rounds} ({t_cold*1e3:.0f} ms), "
              f"|warm-cold|={drift:.1e}")

        g, algo, x_served = g_new, algo_new, warm.x

    print(f"\ntotal rounds: warm {total_warm} vs cold {total_cold} "
          f"({total_warm / max(1, total_cold):.0%})")


if __name__ == "__main__":
    main()
