"""Batched multi-query serving: many personalized-PageRank seeds (and SSSP
sources) answered by ONE engine run over an f32[n, d] state matrix.

    PYTHONPATH=src python examples/multi_query.py [--n 20000] [--d 32]

Prints per-query round counts (each column converges on its own schedule and
freezes) and the throughput of the batched run vs running the scalar engine
d times.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro import multi_source_sssp, personalized_pagerank, solve
from repro.graphs import generators as gen


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=20_000)
    p.add_argument("--d", type=int, default=32)
    p.add_argument("--bs", type=int, default=256)
    args = p.parse_args()

    g = gen.scrambled(gen.powerlaw_cluster(args.n, 5, seed=1), seed=7)
    print(f"graph: {g}")
    rng = np.random.default_rng(0)
    seeds = rng.choice(g.n, size=args.d, replace=False)

    algo = personalized_pagerank(g, seeds)
    solve(algo, bs=args.bs)  # warm the jit cache before timing
    t0 = time.perf_counter()
    r = solve(algo, bs=args.bs)
    t_batched = time.perf_counter() - t0
    print(f"\nPPR x{args.d} batched: {r.rounds} sweeps "
          f"({t_batched*1e3:.0f} ms, {args.d / t_batched:.1f} queries/s)")
    print(f"  per-query rounds: min={int(r.col_rounds.min())} "
          f"median={int(np.median(r.col_rounds))} max={int(r.col_rounds.max())}")

    scalar = personalized_pagerank(g, [int(seeds[0])])
    solve(scalar, bs=args.bs)
    t0 = time.perf_counter()
    for s in seeds[: min(8, args.d)]:
        solve(personalized_pagerank(g, [int(s)]), bs=args.bs)
    t_serial = (time.perf_counter() - t0) / min(8, args.d) * args.d
    print(f"serial x{args.d} (extrapolated): {t_serial*1e3:.0f} ms "
          f"-> batched speedup {t_serial / t_batched:.1f}x")

    gw = gen.with_random_weights(g, seed=2)
    sources = rng.choice(g.n, size=min(8, args.d), replace=False)
    rm = solve(multi_source_sssp(gw, sources), bs=args.bs)
    print(f"\nmulti-source SSSP x{len(sources)}: {rm.rounds} sweeps, "
          f"converged={rm.converged}, x shape {rm.x.shape}")


if __name__ == "__main__":
    main()
