"""End-to-end continuous-batching serving demo.

Poisson arrivals of mixed personalized-PageRank / SSSP traffic hit a
:class:`repro.serving.GraphServer` while the graph itself evolves (a random
edge-delta batch lands every few ticks). Each simulation tick submits the
tick's arrivals and runs one server step; finished columns are swapped out
and queued queries swapped in mid-run, repeat queries are served from the
graph-version result cache, and in-flight queries ride deltas warm.

    PYTHONPATH=src python examples/serving_loop.py
"""
import numpy as np

from repro.graphs import generators as gen
from repro.graphs.delta import random_delta
from repro import GraphServer

N = 1200
TICKS = 60
ARRIVAL_RATE = 2.5      # Poisson mean queries/tick
DELTA_EVERY = 15        # ticks between graph mutations
SLOTS = 8


def main() -> None:
    rng = np.random.default_rng(0)
    g = gen.scrambled(gen.powerlaw_cluster(N, 5, p=0.4, seed=1), seed=9)
    # weights <= 1 keep the PageRank family contractive, so PPR and SSSP
    # traffic can share the one served graph
    gw = gen.with_random_weights(g, lo=0.1, hi=1.0, seed=2)

    srv = GraphServer(gw, slots=SLOTS, bs=64, rounds_per_batch=4,
                      policy="fifo", delta_mode="warm")
    # a small hot set makes the result cache visible in the output
    hot = [int(v) for v in rng.integers(0, N, size=12)]

    print(f"serving {N}-vertex graph | {SLOTS} slots | "
          f"Poisson({ARRIVAL_RATE}) arrivals | delta every {DELTA_EVERY} ticks")
    for tick in range(TICKS):
        if tick and tick % DELTA_EVERY == 0:
            delta = random_delta(srv.g, frac_add=0.01, frac_del=0.002,
                                 frac_rew=0.002, seed=100 + tick)
            srv.apply_delta(delta)
            print(f"tick {tick:3d}  DELTA v{srv.graph_version} "
                  f"({delta.size} edge updates) — cache "
                  f"{srv.cache.stats()['promoted']} promoted / "
                  f"{srv.cache.stats()['invalidated']} invalidated")
        for _ in range(rng.poisson(ARRIVAL_RATE)):
            v = int(rng.choice(hot)) if rng.random() < 0.4 \
                else int(rng.integers(0, N))
            if rng.random() < 0.5:
                srv.submit("ppr", {"seeds": [v]})
            else:
                srv.submit("sssp", {"source": v})
        srv.step()
        if tick % 10 == 9:
            s = srv.stats.summary()
            occ = srv.stats.occupancy_trace
            print(f"tick {tick:3d}  submitted={s['submitted']:3d} "
                  f"resolved={s['resolved']:3d} "
                  f"cache_hits={s['cache_hits']:2d} "
                  f"occupancy={occ[-1] if occ else 0.0:.2f} "
                  f"queued={srv.scheduler.total_pending()}")

    srv.run()   # drain what's left
    s = srv.stats.summary()
    print("-" * 64)
    print(f"drained: {s['resolved']}/{s['submitted']} queries "
          f"({s['cache_hits']} from cache), {s['unconverged']} unconverged")
    print(f"throughput      {s['throughput_qps']:8.1f} queries/sec")
    print(f"latency p50/p99 {s['latency_p50_s'] * 1e3:8.1f} / "
          f"{s['latency_p99_s'] * 1e3:.1f} ms")
    print(f"rounds p50/p99  {s['rounds_p50']:8.0f} / {s['rounds_p99']:.0f}")
    print(f"occupancy mean  {s['occupancy_mean']:8.2f}")
    print(f"graph version   {srv.graph_version:8d} "
          f"(cache: {srv.cache.stats()})")


if __name__ == "__main__":
    main()
