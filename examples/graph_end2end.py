"""End-to-end driver for the paper's workload kind: large-graph iterative
analytics with the full production stack — GoGraph reordering, block
Gauss–Seidel engine, the fused Pallas sweep kernel, checkpointing, and
fault-tolerant execution.

    PYTHONPATH=src python examples/graph_end2end.py [--n 50000] [--pallas]
"""
import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.core import metric
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm, run_async_block
from repro.graphs import generators as gen
from repro.runtime.fault import FaultTolerantRunner, StragglerMonitor


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=50_000)
    p.add_argument("--algo", default="pagerank",
                   choices=["pagerank", "sssp", "bfs", "php", "cc", "katz"])
    p.add_argument("--pallas", action="store_true",
                   help="use the fused gs_sweep Pallas kernel engine")
    p.add_argument("--inject-failure", action="store_true")
    args = p.parse_args()

    t0 = time.perf_counter()
    g = gen.scrambled(gen.powerlaw_cluster(args.n, 5, seed=1), seed=3)
    print(f"graph: {g}  ({time.perf_counter()-t0:.1f}s)")

    t0 = time.perf_counter()
    rank = gograph_order(g)
    print(f"GoGraph reorder: M/E={metric.positive_edge_fraction(g, rank):.3f} "
          f"({time.perf_counter()-t0:.1f}s)")

    graph = gen.with_random_weights(g, seed=2) if args.algo == "sssp" else g
    algo = get_algorithm(args.algo, graph).relabel(rank)

    ckpt_dir = tempfile.mkdtemp(prefix="gograph_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep_last=2)
    injected = {"done": False}

    def step_fn(state, step):
        """One engine macro-step = up to 5 sweeps (checkpointable unit)."""
        if args.inject_failure and step == 1 and not injected["done"]:
            injected["done"] = True
            raise RuntimeError("injected failure (simulated node loss)")
        if args.pallas:
            from repro.kernels.ops import run_async_block_pallas

            r = run_async_block_pallas(algo, bs=128, max_iters=5,
                                       x_init=state["x"])
        else:
            r = run_async_block(algo, bs=128, max_iters=5,
                                x_init=state["x"])
        total = state["rounds"] + r.rounds
        return {"x": r.x, "rounds": total, "converged": bool(r.converged)}

    def save_fn(step, state):
        mgr.save(step, {"x": state["x"],
                        "rounds": np.int64(state["rounds"])})

    def restore_fn():
        tree, man = mgr.restore()
        flat = tree if isinstance(tree, dict) else {}
        return (
            {"x": flat.get("['params']['x']"),
             "rounds": int(flat.get("['params']['rounds']", 0)),
             "converged": False},
            man["step"],
        )

    runner = FaultTolerantRunner(step_fn, save_fn, restore_fn, ckpt_every=1,
                                 max_failures=2,
                                 straggler=StragglerMonitor(threshold=3.0))
    t0 = time.perf_counter()
    state = {"x": algo.x0, "rounds": 0, "converged": False}
    for macro in range(20):
        state, _ = runner.run(state, steps=macro + 1, start_step=macro)
        if state["converged"]:
            break
    dt = time.perf_counter() - t0
    err = np.max(np.abs(state["x"] - algo.exact()))
    print(f"{args.algo}: converged={state['converged']} rounds={state['rounds']} "
          f"({dt:.1f}s), max err vs exact = {err:.2e}")
    if runner.log:
        print("fault log:", *runner.log, sep="\n  ")


if __name__ == "__main__":
    main()
