"""Pallas resource/shape checker (rules PL001-PL005).

A Pallas kernel's resource story is written in three places that nothing
ties together at runtime until a TPU OOMs or Mosaic rejects the lowering:
the BlockSpecs/scratch_shapes (what lives in VMEM/SMEM), the grid (how many
index-map arguments each lambda must take), and ``input_output_aliases``
(which HBM buffers are donated). This checker parses each kernel wrapper in
`repro.kernels`, statically evaluates every shape expression at the
representative points declared in `repro.kernels.budgets.KERNEL_BUDGETS`,
and enforces:

PL001  VMEM/SMEM footprint exceeds the kernel's declared budget at a point
PL002  a pallas_call with no budget entry, or a budget entry whose kernel
       no longer exists (dead contract)
PL003  rank mismatches: index-map arity vs grid (+ scalar-prefetch) rank,
       index-map result rank vs block rank, out_specs vs out_shape arity
PL004  aliasing/donation hazards: an ``input_output_aliases`` index out of
       range, or an alias whose input/output operand is a *pipelined*
       (windowed) BlockSpec — aliasing is only sound for manually-DMA'd
       ``memory_space=ANY`` operands, where the kernel controls write order
PL005  a shape expression the checker cannot resolve at a budget point
       (the budget's point dict is missing a dimension name)

Footprint model: 4 bytes/element everywhere (all kernel operands are
f32/int32), windowed specs double-buffered, ``ANY`` operands free (HBM),
declared ``temp_bytes`` added per point — see `repro.kernels.budgets`.
"""
from __future__ import annotations

import ast
import dataclasses
import glob
import math
import os
from typing import Optional

from tools.check.common import Finding, ShapeEvalError, attr_chain, eval_shape_expr

CHECKER = "pallas"
BYTES_PER_ELEM = 4


@dataclasses.dataclass
class Spec:
    """One BlockSpec: a window (shape + index map) or a memory-space pin."""

    shape: Optional[ast.AST]        # block-shape expression, None if absent
    index_map: Optional[ast.Lambda]
    memory_space: Optional[str]     # "ANY" | "VMEM" | None
    line: int

    @property
    def windowed(self) -> bool:
        return self.shape is not None


@dataclasses.dataclass
class Scratch:
    kind: str                       # "VMEM" | "SMEM" | "sem"
    shape: Optional[ast.AST]
    line: int


@dataclasses.dataclass
class KernelSite:
    """One pl.pallas_call + its grid spec, as parsed from source."""

    name: str                       # enclosing wrapper function name
    path: str
    line: int
    grid: Optional[ast.AST] = None
    num_scalar_prefetch: int = 0
    in_specs: list = dataclasses.field(default_factory=list)
    out_specs: list = dataclasses.field(default_factory=list)
    scratch: list = dataclasses.field(default_factory=list)
    out_shapes: list = dataclasses.field(default_factory=list)  # shape exprs
    aliases: dict = dataclasses.field(default_factory=dict)


def _chain_ends(node: ast.AST, suffix: str) -> bool:
    chain = attr_chain(node)
    return bool(chain) and chain.split(".")[-1] == suffix


def _parse_blockspec(node: ast.AST) -> Optional[Spec]:
    if not (isinstance(node, ast.Call) and _chain_ends(node.func, "BlockSpec")):
        return None
    shape = index_map = None
    memory_space = None
    if node.args:
        shape = node.args[0]
        if len(node.args) > 1 and isinstance(node.args[1], ast.Lambda):
            index_map = node.args[1]
    for kw in node.keywords:
        if kw.arg == "index_map" and isinstance(kw.value, ast.Lambda):
            index_map = kw.value
        elif kw.arg == "block_shape":
            shape = kw.value
        elif kw.arg == "memory_space":
            chain = attr_chain(kw.value) or ""
            memory_space = chain.split(".")[-1] or None
    return Spec(shape, index_map, memory_space, node.lineno)


def _parse_scratch(node: ast.AST) -> Optional[Scratch]:
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func) or ""
        leaf = chain.split(".")[-1]
        if leaf in ("VMEM", "SMEM"):
            return Scratch(leaf, node.args[0] if node.args else None,
                           node.lineno)
        if leaf == "DMA":
            return Scratch("sem", None, node.lineno)
    elif isinstance(node, ast.Attribute) and _chain_ends(node, "DMA"):
        return Scratch("sem", None, node.lineno)
    return None


def _spec_list(node: ast.AST) -> list:
    elts = node.elts if isinstance(node, (ast.List, ast.Tuple)) else [node]
    return [_parse_blockspec(e) or e for e in elts]


def _parse_out_shapes(node: ast.AST) -> list:
    elts = node.elts if isinstance(node, (ast.List, ast.Tuple)) else [node]
    shapes = []
    for e in elts:
        if (isinstance(e, ast.Call)
                and _chain_ends(e.func, "ShapeDtypeStruct") and e.args):
            shapes.append(e.args[0])
        else:
            shapes.append(None)
    return shapes


def _extract_sites(tree: ast.Module, path: str) -> list[KernelSite]:
    sites: list[KernelSite] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        grid_specs: dict[str, ast.Call] = {}   # name -> PrefetchScalarGridSpec
        calls: list[ast.Call] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _chain_ends(node.func, "PrefetchScalarGridSpec"):
                grid_specs["<inline>"] = node
            elif _chain_ends(node.func, "pallas_call"):
                calls.append(node)
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)
                    and _chain_ends(node.value.func, "PrefetchScalarGridSpec")):
                grid_specs[node.targets[0].id] = node.value
        for call in calls:
            site = KernelSite(fn.name, path, call.lineno)
            gs: Optional[ast.Call] = None
            for kw in call.keywords:
                if kw.arg == "grid_spec":
                    if isinstance(kw.value, ast.Name):
                        gs = grid_specs.get(kw.value.id)
                    elif isinstance(kw.value, ast.Call):
                        gs = kw.value
                elif kw.arg == "out_shape":
                    site.out_shapes = _parse_out_shapes(kw.value)
                elif kw.arg == "input_output_aliases":
                    if isinstance(kw.value, ast.Dict):
                        for k, v in zip(kw.value.keys, kw.value.values, strict=True):
                            if (isinstance(k, ast.Constant)
                                    and isinstance(v, ast.Constant)):
                                site.aliases[k.value] = v.value
                elif kw.arg in ("grid", "in_specs", "out_specs",
                                "scratch_shapes"):
                    gs_kw = kw  # plain pallas_call spelling (fixtures)
                    if kw.arg == "grid":
                        site.grid = kw.value
                    elif kw.arg == "in_specs":
                        site.in_specs = _spec_list(kw.value)
                    elif kw.arg == "out_specs":
                        site.out_specs = _spec_list(kw.value)
                    else:
                        site.scratch = [
                            s for s in map(
                                _parse_scratch,
                                kw.value.elts
                                if isinstance(kw.value, (ast.List, ast.Tuple))
                                else [],
                            ) if s
                        ]
                    del gs_kw
            if gs is not None:
                for kw in gs.keywords:
                    if kw.arg == "grid":
                        site.grid = kw.value
                    elif kw.arg == "num_scalar_prefetch":
                        if isinstance(kw.value, ast.Constant):
                            site.num_scalar_prefetch = int(kw.value.value)
                    elif kw.arg == "in_specs":
                        site.in_specs = _spec_list(kw.value)
                    elif kw.arg == "out_specs":
                        site.out_specs = _spec_list(kw.value)
                    elif kw.arg == "scratch_shapes":
                        elts = (kw.value.elts
                                if isinstance(kw.value, (ast.List, ast.Tuple))
                                else [])
                        site.scratch = [
                            s for s in map(_parse_scratch, elts) if s
                        ]
            sites.append(site)
    return sites


def _bytes_of(shape_node: ast.AST, env: dict) -> int:
    shape = eval_shape_expr(shape_node, env)
    if not isinstance(shape, tuple):
        shape = (shape,)
    return int(math.prod(int(s) for s in shape)) * BYTES_PER_ELEM


def _lambda_arity(lam: ast.Lambda) -> tuple[int, bool]:
    a = lam.args
    return len(a.posonlyargs) + len(a.args), a.vararg is not None


def _check_rank(site: KernelSite, grid_rank: int, spec: Spec,
                which: str, findings: list[Finding]) -> None:
    if spec.index_map is None:
        return
    nargs, vararg = _lambda_arity(spec.index_map)
    want = grid_rank + site.num_scalar_prefetch
    if vararg:
        if nargs > want:
            findings.append(Finding(
                CHECKER, "PL003", site.path, spec.line,
                f"{site.name}: {which} index map takes {nargs} fixed args + "
                f"*rest but the grid supplies only {want} "
                f"(grid rank {grid_rank} + {site.num_scalar_prefetch} "
                f"prefetch refs)",
            ))
    elif nargs != want:
        findings.append(Finding(
            CHECKER, "PL003", site.path, spec.line,
            f"{site.name}: {which} index map takes {nargs} args, expected "
            f"{want} (grid rank {grid_rank} + {site.num_scalar_prefetch} "
            f"scalar-prefetch refs)",
        ))
    if spec.shape is not None:
        block_rank = (len(spec.shape.elts)
                      if isinstance(spec.shape, ast.Tuple) else 1)
        body = spec.index_map.body
        out_rank = len(body.elts) if isinstance(body, ast.Tuple) else 1
        if out_rank != block_rank:
            findings.append(Finding(
                CHECKER, "PL003", site.path, spec.line,
                f"{site.name}: {which} index map returns {out_rank} "
                f"coordinates for a rank-{block_rank} block",
            ))


def _check_aliases(site: KernelSite, findings: list[Finding]) -> None:
    n_in = site.num_scalar_prefetch + len(site.in_specs)
    n_out = max(len(site.out_specs), len(site.out_shapes))
    for k, v in site.aliases.items():
        if not (0 <= k < n_in) or not (0 <= v < n_out):
            findings.append(Finding(
                CHECKER, "PL004", site.path, site.line,
                f"{site.name}: input_output_aliases {{{k}: {v}}} out of "
                f"range for {n_in} inputs / {n_out} outputs (alias indices "
                f"count scalar-prefetch operands)",
            ))
            continue
        if k < site.num_scalar_prefetch:
            findings.append(Finding(
                CHECKER, "PL004", site.path, site.line,
                f"{site.name}: alias input {k} is a scalar-prefetch operand "
                f"— donating SMEM prefetch refs is never sound",
            ))
            continue
        for spec, which in ((site.in_specs[k - site.num_scalar_prefetch],
                             f"input {k}"),
                            (site.out_specs[v] if v < len(site.out_specs)
                             else None, f"output {v}")):
            if isinstance(spec, Spec) and (
                    spec.windowed or spec.memory_space == "VMEM"):
                findings.append(Finding(
                    CHECKER, "PL004", site.path, spec.line,
                    f"{site.name}: aliased {which} is a pipelined "
                    f"({spec.memory_space or 'windowed'}) operand; aliasing "
                    f"is only sound for memory_space=ANY buffers whose "
                    f"write order the kernel controls",
                ))


def _footprint_at(site: KernelSite, env: dict) -> tuple[int, int]:
    """(vmem_bytes, smem_bytes) at one point; raises ShapeEvalError."""
    vmem = smem = 0
    for s in site.scratch:
        if s.kind == "VMEM" and s.shape is not None:
            vmem += _bytes_of(s.shape, env)
        elif s.kind == "SMEM" and s.shape is not None:
            smem += _bytes_of(s.shape, env)
    for spec in site.in_specs:
        if isinstance(spec, Spec) and spec.windowed:
            vmem += 2 * _bytes_of(spec.shape, env)   # double-buffered window
    for i, spec in enumerate(site.out_specs):
        if not isinstance(spec, Spec):
            continue
        if spec.windowed:
            vmem += 2 * _bytes_of(spec.shape, env)
        elif spec.memory_space == "VMEM" and i < len(site.out_shapes) \
                and site.out_shapes[i] is not None:
            vmem += _bytes_of(site.out_shapes[i], env)  # whole-array output
    return vmem, smem


def check_sites(sites: list[KernelSite], budgets: dict) -> list[Finding]:
    findings: list[Finding] = []
    seen: set[str] = set()
    for site in sites:
        seen.add(site.name)
        budget = budgets.get(site.name)
        if budget is None:
            findings.append(Finding(
                CHECKER, "PL002", site.path, site.line,
                f"pallas_call in {site.name!r} has no "
                f"kernels.budgets.KERNEL_BUDGETS entry — every kernel "
                f"declares its VMEM/SMEM ceiling",
            ))
            continue
        grid_rank = (len(site.grid.elts)
                     if isinstance(site.grid, ast.Tuple) else 1)
        for spec in site.in_specs:
            if isinstance(spec, Spec):
                _check_rank(site, grid_rank, spec, "in_spec", findings)
        for spec in site.out_specs:
            if isinstance(spec, Spec):
                _check_rank(site, grid_rank, spec, "out_spec", findings)
        if site.out_shapes and site.out_specs \
                and len(site.out_shapes) != len(site.out_specs):
            findings.append(Finding(
                CHECKER, "PL003", site.path, site.line,
                f"{site.name}: {len(site.out_specs)} out_specs for "
                f"{len(site.out_shapes)} out_shape entries",
            ))
        _check_aliases(site, findings)
        for point in budget.points:
            env = dict(point)
            if "n" not in env and "nb" in env and "bs" in env:
                env["n"] = env["nb"] * env["bs"]
            try:
                vmem, smem = _footprint_at(site, env)
            except ShapeEvalError as e:
                findings.append(Finding(
                    CHECKER, "PL005", site.path, site.line,
                    f"{site.name}: unresolvable shape at point {point}: {e}",
                ))
                continue
            vmem += int(env.get("temp_bytes", 0))
            if vmem > budget.vmem_limit_bytes:
                findings.append(Finding(
                    CHECKER, "PL001", site.path, site.line,
                    f"{site.name}: VMEM footprint {vmem} B exceeds budget "
                    f"{budget.vmem_limit_bytes} B at point {point}",
                ))
            if smem > budget.smem_limit_bytes:
                findings.append(Finding(
                    CHECKER, "PL001", site.path, site.line,
                    f"{site.name}: SMEM footprint {smem} B exceeds budget "
                    f"{budget.smem_limit_bytes} B at point {point}",
                ))
    for name in sorted(set(budgets) - seen):
        findings.append(Finding(
            CHECKER, "PL002", "<budgets>", 0,
            f"KERNEL_BUDGETS entry {name!r} matches no pallas_call wrapper "
            f"in the scanned kernels (dead contract)",
        ))
    return findings


def collect_sites(paths: list[str], root: str) -> list[KernelSite]:
    sites: list[KernelSite] = []
    for p in paths:
        with open(p, encoding="utf-8") as fh:
            tree = ast.parse(fh.read())
        sites.extend(_extract_sites(tree, os.path.relpath(p, root)))
    return sites


def footprints(root: str) -> dict[str, list[tuple[dict, int, int]]]:
    """Per-kernel (point, vmem_bytes, smem_bytes) rows — README table input."""
    from repro.kernels.budgets import KERNEL_BUDGETS

    paths = sorted(glob.glob(os.path.join(root, "src/repro/kernels/*.py")))
    out: dict[str, list[tuple[dict, int, int]]] = {}
    for site in collect_sites(paths, root):
        budget = KERNEL_BUDGETS.get(site.name)
        if budget is None:
            continue
        rows = []
        for point in budget.points:
            env = dict(point)
            if "n" not in env and "nb" in env and "bs" in env:
                env["n"] = env["nb"] * env["bs"]
            vmem, smem = _footprint_at(site, env)
            rows.append((point, vmem + int(env.get("temp_bytes", 0)), smem))
        out[site.name] = rows
    return out


def run(root: str) -> list[Finding]:
    from repro.kernels.budgets import KERNEL_BUDGETS

    paths = sorted(glob.glob(os.path.join(root, "src/repro/kernels/*.py")))
    return check_sites(collect_sites(paths, root), KERNEL_BUDGETS)
