"""CLI for repro-lint: ``python -m tools.check [--root DIR]``.

Prints one line per finding (``path:line: RULE [checker] message``) and
exits 1 when any survive pragma filtering; exits 0 on a clean tree.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m tools.check")
    parser.add_argument(
        "--root", default=None,
        help="repo root (default: the directory containing tools/)",
    )
    args = parser.parse_args(argv)
    root = args.root or os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    src = os.path.join(root, "src")
    if src not in sys.path:
        sys.path.insert(0, src)  # registry checkers import repro.*
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from tools.check import run_all

    findings = run_all(root)
    for f in findings:
        print(f.render())
    if findings:
        print(f"repro-lint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("repro-lint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
