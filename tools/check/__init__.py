"""repro-lint: the static gate over the repo's runtime invariants.

Four checkers, one exit code (see each module's docstring for rules):

* `tools.check.host_sync`          — device-residency / host-sync leaks
* `tools.check.semiring_contracts` — kernel/engine registry consistency
* `tools.check.pallas_resources`   — VMEM/SMEM budgets, grid/rank, aliasing
* `tools.check.options_drift`      — EngineOptions validation/doc coverage

Run ``python -m tools.check`` from the repo root (CI runs it as the
``static-analysis`` job). The runtime complement is the transfer-guard
sanitizer: ``EngineOptions(transfer_guard="disallow")`` (and the
``transfer_guard_disallow`` test fixture) turns any unaudited device->host
transfer into a hard fault on accelerators.
"""
from __future__ import annotations

from tools.check.common import Finding

__all__ = ["Finding", "run_all"]


def run_all(root: str) -> list[Finding]:
    """Run every checker; returns all findings (empty = clean tree)."""
    from tools.check import (
        host_sync,
        options_drift,
        pallas_resources,
        semiring_contracts,
    )

    findings: list[Finding] = []
    for checker in (host_sync, semiring_contracts, pallas_resources,
                    options_drift):
        findings.extend(checker.run(root))
    return findings
