"""Shared plumbing for the repro-lint checkers.

A checker is a function that returns a list of :class:`Finding`; the CLI
(`python -m tools.check`) concatenates them and exits nonzero when any
survive. Findings carry a stable ``rule`` id (``HS...`` host-sync,
``SR...`` semiring registry, ``PL...`` pallas resources, ``OD...`` options
drift) so the fixture self-tests can assert exact rule/line pairs.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One checker violation, anchored to a file/line."""

    checker: str   # "host-sync" | "semiring" | "pallas" | "options"
    rule: str      # stable id, e.g. "HS001"
    path: str      # repo-relative when produced by run_all
    line: int      # 1-based; 0 = whole-file / registry-level finding
    message: str
    end_line: int = 0  # last line of the flagged expression (0 = same line)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} [{self.checker}] {self.message}"


# ---------------------------------------------------------------- pragmas

# `# repro: allow-host-sync(reason)` — suppresses host-sync findings on its
# line. The reason is mandatory: a pragma is an audit record, not a mute.
_PRAGMA_RE = re.compile(r"#\s*repro:\s*allow-host-sync\(([^)]*)\)")


def parse_pragmas(source: str) -> dict[int, str]:
    """Map 1-based line number -> pragma reason (may be empty string)."""
    out: dict[int, str] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            out[i] = m.group(1).strip()
    return out


def apply_pragmas(
    findings: list[Finding], pragmas: dict[int, str], path: str
) -> list[Finding]:
    """Drop findings on pragma'd lines; flag pragmas with no reason.

    A pragma covers a finding when it sits on *any* line of the flagged
    expression (multi-line calls put the comment wherever it reads best).
    """

    def covered(f: Finding) -> bool:
        hi = max(f.line, f.end_line)
        return any(ln in pragmas for ln in range(f.line, hi + 1))

    kept = [f for f in findings if not covered(f)]
    for line, reason in pragmas.items():
        if not reason:
            kept.append(Finding(
                "host-sync", "HS006", path, line,
                "allow-host-sync pragma without a reason; pragmas are audit "
                "records — say what transfers and why it is acceptable",
            ))
    return kept


# ------------------------------------------------- safe shape arithmetic

_BINOPS = {
    ast.Add: lambda a, b: a + b,
    ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b,
    ast.FloorDiv: lambda a, b: a // b,
    ast.Div: lambda a, b: a / b,
    ast.Mod: lambda a, b: a % b,
    ast.Pow: lambda a, b: a ** b,
}


class ShapeEvalError(Exception):
    """A shape expression references something outside the point env."""


def eval_shape_expr(node: ast.AST, env: dict):
    """Evaluate a BlockSpec/scratch shape expression at a budget point.

    Supports the arithmetic subset shapes are written in — constants, env
    names, + - * // / % **, tuples, unary minus, and min/max calls. Anything
    else raises :class:`ShapeEvalError` so the checker can report the
    expression as statically unresolvable instead of guessing.
    """
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in env:
            return env[node.id]
        raise ShapeEvalError(f"unknown dimension name {node.id!r}")
    if isinstance(node, ast.Tuple):
        return tuple(eval_shape_expr(e, env) for e in node.elts)
    if isinstance(node, ast.BinOp) and type(node.op) in _BINOPS:
        return _BINOPS[type(node.op)](
            eval_shape_expr(node.left, env), eval_shape_expr(node.right, env)
        )
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -eval_shape_expr(node.operand, env)
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("min", "max") and not node.keywords):
        vals = [eval_shape_expr(a, env) for a in node.args]
        return (min if node.func.id == "min" else max)(vals)
    raise ShapeEvalError(
        f"unsupported shape expression {ast.dump(node)[:80]}"
    )


def attr_chain(node: ast.AST) -> Optional[str]:
    """``jax.experimental.pallas`` -> "jax.experimental.pallas"; None when
    the expression is not a pure dotted name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
