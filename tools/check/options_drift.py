"""Options-drift checker (rules OD001-OD002).

`EngineOptions` is the single knob surface of the engines and
`validate_options` its single validation pass — that was PR 6's whole
point. The failure mode of single-point designs is silent drift: a field
added to the dataclass but never validated is a knob that typos and
nonsense values pass straight through, and a field missing from the README
knob table is a knob nobody can discover. This checker parses the API
module and asserts, for every declared `EngineOptions` field:

OD001  `validate_options` never reads ``o.<field>`` (unvalidated knob)
OD002  the README knob table never mentions `` `<field>` `` (undocumented
       knob)

Both checks are AST/text-level so they also catch fields that *exist* but
are dead: deleting a field while its validation lingers is caught by the
ordinary ruff/mypy lane, so this checker only guards the add-without-wiring
direction.
"""
from __future__ import annotations

import ast
import os
from typing import Optional

from tools.check.common import Finding

CHECKER = "options"

API_PATH = "src/repro/engine/api.py"
README_PATH = "README.md"


def _class_fields(tree: ast.Module, class_name: str) -> dict[str, int]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            return {
                item.target.id: item.lineno
                for item in node.body
                if isinstance(item, ast.AnnAssign)
                and isinstance(item.target, ast.Name)
            }
    return {}


def _validated_fields(tree: ast.Module, fn_name: str,
                      param: str) -> Optional[set[str]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == fn_name:
            return {
                sub.attr for sub in ast.walk(node)
                if isinstance(sub, ast.Attribute)
                and isinstance(sub.value, ast.Name) and sub.value.id == param
            }
    return None


def check_module(
    api_source: str, api_path: str, readme_text: Optional[str],
    readme_path: str = README_PATH, *,
    options_class: str = "EngineOptions",
    validate_fn: str = "validate_options", param: str = "o",
) -> list[Finding]:
    tree = ast.parse(api_source)
    fields = _class_fields(tree, options_class)
    findings: list[Finding] = []
    if not fields:
        return [Finding(
            CHECKER, "OD001", api_path, 0,
            f"no {options_class} dataclass with annotated fields found",
        )]
    validated = _validated_fields(tree, validate_fn, param)
    if validated is None:
        return [Finding(
            CHECKER, "OD001", api_path, 0,
            f"no {validate_fn}() function found to check coverage against",
        )]
    for name, line in sorted(fields.items()):
        if name not in validated:
            findings.append(Finding(
                CHECKER, "OD001", api_path, line,
                f"{options_class}.{name} is never read by {validate_fn}(); "
                f"every knob gets validated in the one pass (even if the "
                f"check is just a type/shape guard)",
            ))
        if readme_text is not None and f"`{name}`" not in readme_text:
            findings.append(Finding(
                CHECKER, "OD002", readme_path, 0,
                f"{options_class}.{name} is missing from the README knob "
                f"table (search key: `{name}`)",
            ))
    return findings


def run(root: str) -> list[Finding]:
    with open(os.path.join(root, API_PATH), encoding="utf-8") as fh:
        api_source = fh.read()
    readme = os.path.join(root, README_PATH)
    readme_text = None
    if os.path.exists(readme):
        with open(readme, encoding="utf-8") as fh:
            readme_text = fh.read()
    return check_module(api_source, API_PATH, readme_text)
