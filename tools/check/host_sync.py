"""Host-sync leak detector (rules HS001-HS006).

The device-residency contract (PR 6): engine hot paths keep state as jax
arrays; the only device->host transfers are the audited, pragma'd readouts
(ticket resolution, the per-batch ``(d,)`` accounting report, run
finalization). Anything else — a stray ``float()`` on a traced scalar, an
``np.asarray`` on a resident matrix, a truthiness test on an array — blocks
the dispatch stream on TPU and silently erodes the perf the kernels buy.

This is a flow-insensitive AST pass over the annotated hot-path modules. It
infers which expressions are *jax-bound*:

* calls rooted at a jax-module alias (``jnp.*``, ``jax.*``, ``pl.*``,
  ``pltpu.*``) — except ``jax.device_get``, whose result is host;
* calls to functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)``
  anywhere in the scanned set, and to the configured device-returning
  helpers (:data:`DEVICE_RETURNING_FUNCS`);
* names assigned from jax-bound expressions (tuple unpacking included);
* ``self.<attr>`` where any method of the class assigns that attribute a
  jax-bound value, and the session attributes every layer treats as
  device-resident (:data:`DEVICE_ATTRS`, e.g. ``fam.session.state``);
* methods/subscripts/arithmetic of jax-bound values. ``.shape``/``.dtype``
  and friends are metadata, not transfers.

and then flags the sink positions:

HS001  float()/int()/bool() on a jax-bound value (implicit D2H sync)
HS002  .item() on a jax-bound value
HS003  np.* call with a jax-bound argument
HS004  truthiness test (if/while/assert/and/or/not) on a jax-bound value
HS005  jax.device_get — *explicit*, but still a sync: every call site must
       carry a ``# repro: allow-host-sync(reason)`` pragma, so the full
       audited-transfer inventory is greppable from the pragmas alone
HS006  a pragma with an empty reason (from `common.apply_pragmas`)

False-negative bias is deliberate: unknown calls launder jaxiness, so the
checker stays quiet on host-only numpy code instead of crying wolf — the
runtime transfer guard (``EngineOptions.transfer_guard="disallow"``) is the
backstop that catches what static inference cannot see.
"""
from __future__ import annotations

import ast
import glob
import os
from typing import Iterable, Optional

from tools.check.common import Finding, apply_pragmas, attr_chain, parse_pragmas

CHECKER = "host-sync"

# Hot-path modules under the residency contract (repo-relative). The
# observability layer and every module with trace-recording hooks are in
# scope: a span attribute that implicitly coerces a jax array is exactly
# the hidden-D2H class this checker exists to catch.
HOT_PATH_GLOBS = (
    "src/repro/core/gograph.py",
    "src/repro/core/metric.py",
    "src/repro/engine/api.py",
    "src/repro/engine/async_block.py",
    "src/repro/engine/harness.py",
    "src/repro/engine/push.py",
    "src/repro/obs/*.py",
    "src/repro/serving/server.py",
    "src/repro/serving/stats.py",
    "src/repro/kernels/*.py",
)

# Functions that return device arrays but are not themselves @jax.jit
# (their jit boundary is nested or they return containers of jax arrays).
DEVICE_RETURNING_FUNCS = {
    "pack_algorithm",           # kernels.ops: dict of jnp operand arrays
    "swap_in_column_device",    # engine.harness: jitted column scatter inside
}

# Attribute names that are device-resident on session/family objects across
# module boundaries (AsyncBlockSession contract), so `fam.session.state`
# reads as jax-bound even where the session type is not inferable.
DEVICE_ATTRS = {"state", "col_done", "col_rounds", "dirty"}

# Array metadata — reading these is free, never a transfer.
METADATA_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "sharding"}

_JAX_ROOT_MODULES = ("jax", "jax.numpy", "jax.experimental.pallas",
                     "jax.experimental.pallas.tpu", "jax.lax")


def _module_aliases(tree: ast.Module) -> tuple[set[str], set[str]]:
    """(jax-rooted local names, numpy-rooted local names) for one module."""
    jax_names: set[str] = set()
    np_names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                local = (a.asname or a.name).split(".")[0]
                if a.name == "numpy" or a.name.startswith("numpy."):
                    np_names.add(a.asname or local)
                elif a.name.split(".")[0] == "jax":
                    jax_names.add(a.asname or local)
        elif isinstance(node, ast.ImportFrom) and node.module:
            root = node.module.split(".")[0]
            for a in node.names:
                if root == "jax":
                    jax_names.add(a.asname or a.name)
                elif root == "numpy":
                    np_names.add(a.asname or a.name)
    return jax_names, np_names


def _is_jit_decorated(fn: ast.AST) -> bool:
    """@jax.jit / @jit / @partial(jax.jit, ...) / @functools.partial(...)."""
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        chain = attr_chain(target) or ""
        if chain.endswith("jit"):
            return True
        if chain.endswith("partial") and isinstance(dec, ast.Call):
            for arg in dec.args:
                if (attr_chain(arg) or "").endswith("jit"):
                    return True
    return False


def collect_jit_functions(trees: Iterable[ast.Module]) -> set[str]:
    """Names of jit-decorated functions across the whole scanned set, so
    `out = _run(...)` is jax-bound even across module boundaries."""
    out = set(DEVICE_RETURNING_FUNCS)
    for tree in trees:
        for node in ast.walk(tree):
            if _is_jit_decorated(node):
                out.add(node.name)
    return out


def _self_device_attrs(cls: ast.ClassDef, checker: "_Jaxiness") -> set[str]:
    """Attributes any method assigns a jax-bound value (`self.x = jnp...`)."""
    found: set[str] = set()
    # two passes: `self.x = jnp.array(self.x0)` may precede `self.x0 = ...`
    for _ in range(2):
        for node in ast.walk(cls):
            if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                continue
            value = node.value
            if value is None:
                continue
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                elts = t.elts if isinstance(t, ast.Tuple) else [t]
                for e in elts:
                    if (isinstance(e, ast.Attribute)
                            and isinstance(e.value, ast.Name)
                            and e.value.id == "self"
                            and checker.is_jaxy(value, set(), found)):
                        found.add(e.attr)
    return found


class _Jaxiness:
    """Decides whether an expression is jax-bound in a given scope."""

    def __init__(self, jax_aliases: set[str], np_aliases: set[str],
                 jit_funcs: set[str]):
        self.jax_aliases = jax_aliases
        self.np_aliases = np_aliases
        self.jit_funcs = jit_funcs

    def _chain_root(self, chain: Optional[str]) -> Optional[str]:
        return chain.split(".")[0] if chain else None

    def is_device_get(self, node: ast.Call) -> bool:
        chain = attr_chain(node.func)
        return bool(chain) and chain.split(".")[-1] == "device_get" \
            and self._chain_root(chain) in self.jax_aliases

    def is_np_call(self, node: ast.Call) -> bool:
        return self._chain_root(attr_chain(node.func)) in self.np_aliases

    def is_jaxy(self, node: ast.AST, names: set[str],
                self_attrs: set[str]) -> bool:
        j = lambda n: self.is_jaxy(n, names, self_attrs)  # noqa: E731
        if isinstance(node, ast.Name):
            return node.id in names
        if isinstance(node, ast.Attribute):
            if node.attr in METADATA_ATTRS:
                return False
            if (isinstance(node.value, ast.Name) and node.value.id == "self"
                    and node.attr in self_attrs):
                return True
            if node.attr in DEVICE_ATTRS:
                return True
            return j(node.value)
        if isinstance(node, ast.Subscript):
            return j(node.value)
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            root = self._chain_root(chain)
            if root in self.jax_aliases:
                return not self.is_device_get(node)  # device_get -> host
            if isinstance(node.func, ast.Name):
                if node.func.id in self.jit_funcs:
                    return True
                if node.func.id in ("tuple", "list") and node.args:
                    return any(j(a) for a in node.args)
                return False
            if isinstance(node.func, ast.Attribute):
                if node.func.attr in self.jit_funcs:
                    return True  # module-qualified call, e.g. harness.<jit fn>
                # method of a jax value (x.reshape, x.at[...].set, ...)
                if node.func.attr in METADATA_ATTRS:
                    return False
                return j(node.func.value)
            return False
        if isinstance(node, (ast.BinOp,)):
            return j(node.left) or j(node.right)
        if isinstance(node, ast.UnaryOp):
            return j(node.operand)
        if isinstance(node, ast.Compare):
            return j(node.left) or any(j(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(j(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return j(node.body) or j(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(j(e) for e in node.elts)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            return j(node.elt)
        if isinstance(node, ast.Starred):
            return j(node.value)
        return False


class _FunctionScanner:
    """Scan one function body: infer jax-bound names, then flag sinks."""

    def __init__(self, jx: _Jaxiness, self_attrs: set[str], path: str):
        self.jx = jx
        self.self_attrs = self_attrs
        self.path = path
        self.names: set[str] = set()
        self.findings: list[Finding] = []

    def _jaxy(self, node: ast.AST) -> bool:
        return self.jx.is_jaxy(node, self.names, self.self_attrs)

    def _infer(self, body: list[ast.stmt]) -> None:
        # two passes: flow-insensitive fixpoint over assignment order
        for _ in range(2):
            for node in body:
                for sub in ast.walk(node):
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        continue
                    if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                        if sub.value is None:
                            continue
                        targets = (sub.targets if isinstance(sub, ast.Assign)
                                   else [sub.target])
                        jaxy = self._jaxy(sub.value)
                        for t in targets:
                            elts = t.elts if isinstance(t, ast.Tuple) else [t]
                            for e in elts:
                                e = e.value if isinstance(e, ast.Starred) else e
                                if isinstance(e, ast.Name) and jaxy:
                                    self.names.add(e.id)
                    elif isinstance(sub, ast.AugAssign):
                        if (isinstance(sub.target, ast.Name)
                                and self._jaxy(sub.value)):
                            self.names.add(sub.target.id)
                    elif isinstance(sub, ast.For):
                        if self._jaxy(sub.iter):
                            for e in ast.walk(sub.target):
                                if isinstance(e, ast.Name):
                                    self.names.add(e.id)

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            CHECKER, rule, self.path, getattr(node, "lineno", 0), message,
            end_line=getattr(node, "end_lineno", 0) or 0,
        ))

    def _scan_sinks(self, body: list[ast.stmt]) -> None:
        for node in body:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    self._scan_call(sub)
                elif isinstance(sub, (ast.If, ast.While)):
                    if self._jaxy(sub.test):
                        self._flag(
                            "HS004", sub.test,
                            "truthiness test on a jax value blocks on device "
                            "completion; compute the predicate on host state "
                            "or keep the branch traced",
                        )
                elif isinstance(sub, ast.Assert):
                    if self._jaxy(sub.test):
                        self._flag(
                            "HS004", sub.test,
                            "assert on a jax value is a hidden device sync",
                        )

    def _scan_call(self, node: ast.Call) -> None:
        jx = self.jx
        if isinstance(node.func, ast.Name) and node.func.id in (
                "float", "int", "bool"):
            if any(self._jaxy(a) for a in node.args):
                self._flag(
                    "HS001", node,
                    f"{node.func.id}() on a jax value forces an implicit "
                    f"device->host sync; read it out with jax.device_get "
                    f"(+ pragma) or keep it on device",
                )
            return
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "item"
                and not node.args and self._jaxy(node.func.value)):
            self._flag(
                "HS002", node,
                ".item() on a jax value is an implicit device->host sync",
            )
            return
        if jx.is_device_get(node):
            self._flag(
                "HS005", node,
                "jax.device_get is the audited explicit sync — annotate the "
                "line with `# repro: allow-host-sync(reason)`",
            )
            return
        if jx.is_np_call(node):
            args = list(node.args) + [k.value for k in node.keywords]
            if any(self._jaxy(a) for a in args):
                self._flag(
                    "HS003", node,
                    "np.* on a jax value copies device memory to host; use "
                    "jnp on device or jax.device_get (+ pragma) to read out",
                )


def check_source(source: str, path: str,
                 jit_funcs: Optional[set[str]] = None) -> list[Finding]:
    """Scan one module's source; returns pragma-filtered findings."""
    tree = ast.parse(source)
    jax_aliases, np_aliases = _module_aliases(tree)
    jx = _Jaxiness(jax_aliases, np_aliases,
                   jit_funcs or collect_jit_functions([tree]))
    findings: list[Finding] = []

    def scan_function(fn, self_attrs: set[str]) -> None:
        scanner = _FunctionScanner(jx, self_attrs, path)
        scanner._infer(fn.body)
        scanner._scan_sinks(fn.body)
        findings.extend(scanner.findings)

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_function(node, set())
        elif isinstance(node, ast.ClassDef):
            self_attrs = _self_device_attrs(node, jx)
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    scan_function(item, self_attrs)
    return apply_pragmas(findings, parse_pragmas(source), path)


def check_paths(paths: list[str], root: str) -> list[Finding]:
    sources = {}
    for p in paths:
        with open(p, encoding="utf-8") as fh:
            sources[p] = fh.read()
    # global jit-function prescan: device-ness crosses module boundaries
    jit_funcs = collect_jit_functions(ast.parse(s) for s in sources.values())
    findings: list[Finding] = []
    for p, src in sources.items():
        findings.extend(
            check_source(src, os.path.relpath(p, root), jit_funcs=jit_funcs)
        )
    return findings


def run(root: str) -> list[Finding]:
    paths: list[str] = []
    for pattern in HOT_PATH_GLOBS:
        paths.extend(sorted(glob.glob(os.path.join(root, pattern))))
    paths = [p for p in paths if not p.endswith("__init__.py")]
    return check_paths(paths, root)
