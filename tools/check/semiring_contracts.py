"""Semiring registry contract checker (rules SR001-SR006).

The kernels and the engines meet at three registries — `kernels.ops.
_KERNEL_SEMIRING` ((reduce, edge_op) -> kernel semiring name),
`kernels.semirings.ACC_IDENTITY` / `TILE_FILL` / `DELTA_METRIC`, and
`kernels.gs_sweep._SUPPORTED` ((semiring, combine) pairs the fused kernel
implements). PR 2's latent bug was exactly a drift between them: ``max_old``
combines ran against the *min*-semiring accumulator identity and silently
computed garbage shaped like an answer. This checker re-verifies the whole
contract surface on every run:

SR001  a kernel semiring reachable from ``pack_algorithm`` is missing an
       ACC_IDENTITY / TILE_FILL / DELTA_METRIC entry
SR002  ACC_IDENTITY disagrees with the algebraic reduce identity of the
       (reduce, edge_op) pair that maps to it — the PR 2 bug class
SR003  a registered algorithm's (semiring, combine) pair is not in the
       fused kernel's _SUPPORTED set (it would die at the kernel boundary
       instead of being served)
SR004  a registered algorithm's residual kind disagrees with the kernel's
       DELTA_METRIC for its semiring (in-kernel and host convergence
       decisions would diverge)
SR005  an unsupported pair fails to raise NotImplementedError at a kernel
       boundary (pack_algorithm / gs_sweep._check_pair / bsr_spmm_pallas)
SR006  a sum-reduce algorithm is registered whose update is not the linear
       ``replace``/``mul`` form `run_incremental`'s Maiter-style delta
       correction assumes (dense_residual would assert at serving time)

The table checks (`check_tables` / `check_algorithm_contracts`) take the
registries as *arguments* so the fixture self-tests can feed broken copies;
`run` wires in the real ones plus the dynamic SR005 probes.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Optional

from tools.check.common import Finding

CHECKER = "semiring"

# Algebraic identity of each reduce direction; BIG mirrors algorithms.BIG.
_BIG = float(__import__("numpy").float32(3.0e38))
REDUCE_IDENTITY = {"sum": 0.0, "min": _BIG, "max": -_BIG}


@dataclasses.dataclass(frozen=True)
class Tables:
    """The registry surface under contract, decoupled for fixture injection."""

    kernel_semiring: dict   # (reduce, edge_op) -> semiring name
    acc_identity: dict      # semiring name -> accumulator identity
    tile_fill: dict         # semiring name -> absent-edge in-tile fill
    delta_metric: dict      # semiring name -> in-kernel convergence metric
    supported: set          # {(semiring name, combine)} the fused kernel runs


def _f(rule: str, message: str, path: str = "", line: int = 0) -> Finding:
    return Finding(CHECKER, rule, path or "<registry>", line, message)


def check_tables(t: Tables) -> list[Finding]:
    """Registry completeness + the PR 2 identity-consistency invariant."""
    out: list[Finding] = []
    for pair, name in sorted(t.kernel_semiring.items()):
        for table, label in ((t.acc_identity, "ACC_IDENTITY"),
                             (t.tile_fill, "TILE_FILL"),
                             (t.delta_metric, "DELTA_METRIC")):
            if name not in table:
                out.append(_f(
                    "SR001",
                    f"kernel semiring {name!r} (reachable from pack_algorithm "
                    f"via {pair}) has no {label} entry",
                ))
        reduce = pair[0]
        expect = REDUCE_IDENTITY.get(reduce)
        got = t.acc_identity.get(name)
        if expect is not None and got is not None and got != expect:
            out.append(_f(
                "SR002",
                f"ACC_IDENTITY[{name!r}] = {got!r} but reduce={reduce!r} "
                f"requires identity {expect!r} — the exact max_old/min-"
                f"identity drift PR 2 fixed; the kernel would reduce from "
                f"the wrong end of the lattice",
            ))
    for name, combine in sorted(t.supported):
        if name not in t.acc_identity:
            out.append(_f(
                "SR001",
                f"_SUPPORTED pair ({name!r}, {combine!r}) names a semiring "
                f"with no ACC_IDENTITY entry",
            ))
    return out


def check_algorithm_contracts(
    t: Tables, instances: dict[str, object]
) -> list[Finding]:
    """Every registered algorithm must be kernel-servable and convergence-
    consistent; sum algorithms must satisfy run_incremental's linearity."""
    out: list[Finding] = []
    for algo_name, inst in sorted(instances.items()):
        sem = inst.semiring
        key = (sem.reduce, sem.edge_op)
        kname = t.kernel_semiring.get(key)
        if kname is None:
            out.append(_f(
                "SR003",
                f"algorithm {algo_name!r} uses pair {key} with no kernel "
                f"semiring mapping; backend='pallas' would reject it",
            ))
            continue
        if (kname, inst.combine) not in t.supported:
            out.append(_f(
                "SR003",
                f"algorithm {algo_name!r} needs ({kname!r}, "
                f"{inst.combine!r}) which gs_sweep._SUPPORTED does not "
                f"implement",
            ))
        metric = t.delta_metric.get(kname)
        if metric is not None and metric != inst.residual:
            out.append(_f(
                "SR004",
                f"algorithm {algo_name!r}: residual={inst.residual!r} but "
                f"DELTA_METRIC[{kname!r}] = {metric!r}; in-kernel and host "
                f"convergence would disagree",
            ))
        if sem.reduce == "sum" and (
                inst.combine != "replace" or sem.edge_op != "mul"):
            out.append(_f(
                "SR006",
                f"algorithm {algo_name!r} is sum-reduce but not the linear "
                f"replace/mul form; run_incremental's delta correction "
                f"assumes x* = c + Wx* and would be unsound for it",
            ))
    return out


def _expect_not_implemented(fn: Callable, what: str) -> Optional[Finding]:
    try:
        fn()
    except NotImplementedError:
        return None
    except Exception as e:  # noqa: BLE001 - any other escape is the finding
        return _f(
            "SR005",
            f"{what} raised {type(e).__name__} instead of "
            f"NotImplementedError for an unsupported pair",
        )
    return _f(
        "SR005",
        f"{what} accepted an unsupported semiring/combine pair instead of "
        f"raising NotImplementedError",
    )


def build_probe_instances() -> dict[str, object]:
    """Instantiate every registered algorithm on a tiny probe graph."""
    import numpy as np

    from repro.engine.algorithms import ALGORITHMS, get_algorithm
    from repro.graphs.graph import Graph

    g = Graph(
        4,
        np.array([0, 1, 2, 0], np.int32),
        np.array([1, 2, 3, 3], np.int32),
        np.array([0.5, 0.25, 0.75, 1.0], np.float32),
    )
    guesses = {"source": 0, "sources": [0, 1], "seeds": [0], "target": 3,
               "targets": [3]}
    out: dict[str, object] = {}
    for name, ctor in ALGORITHMS.items():
        params = {}
        for p in inspect.signature(ctor).parameters.values():
            if (p.default is inspect.Parameter.empty and p.name != "g"
                    and p.kind not in (p.VAR_POSITIONAL, p.VAR_KEYWORD)
                    and p.name in guesses):
                params[p.name] = guesses[p.name]
        out[name] = get_algorithm(name, g, **params)
    return out


def run(root: str) -> list[Finding]:
    import dataclasses as dc

    from repro.engine.algorithms import Semiring
    from repro.kernels import semirings as S
    from repro.kernels.gs_sweep import _SUPPORTED, _check_pair
    from repro.kernels.ops import _KERNEL_SEMIRING, pack_algorithm

    tables = Tables(
        kernel_semiring=dict(_KERNEL_SEMIRING),
        acc_identity=dict(S.ACC_IDENTITY),
        tile_fill=dict(S.TILE_FILL),
        delta_metric=dict(S.DELTA_METRIC),
        supported=set(_SUPPORTED),
    )
    findings = check_tables(tables)
    instances = build_probe_instances()
    findings.extend(check_algorithm_contracts(tables, instances))

    # SR005: unsupported pairs must die loudly at every kernel boundary
    bad_algo = dc.replace(
        next(iter(instances.values())), semiring=Semiring("min", "mul"),
        exact_fn=None, params=None,
    )
    probes = [
        (lambda: pack_algorithm(bad_algo, 4),
         "kernels.ops.pack_algorithm"),
        (lambda: _check_pair("min_plus", "replace"),
         "kernels.gs_sweep._check_pair (mismatched combine)"),
        (lambda: _check_pair("bogus", "replace"),
         "kernels.gs_sweep._check_pair (unknown semiring)"),
        (_probe_bsr_spmm, "kernels.bsr_spmm.bsr_spmm_pallas"),
    ]
    for fn, what in probes:
        f = _expect_not_implemented(fn, what)
        if f is not None:
            findings.append(f)
    return findings


def _probe_bsr_spmm():
    import numpy as np

    from repro.kernels.bsr_spmm import bsr_spmm_pallas

    bsr_spmm_pallas(
        np.zeros(2, np.int32), np.zeros(1, np.int32), np.zeros(1, np.int32),
        np.zeros((1, 4, 4), np.float32), np.zeros((4, 1), np.float32),
        semiring="bogus", bs=4, dj=1, interpret=True,
    )
