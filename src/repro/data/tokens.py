"""Deterministic synthetic token pipeline.

Design goals of a production input pipeline, kept:
  * deterministic as a function of (seed, step) — restart-safe: resuming from
    a checkpoint at step k regenerates exactly the batches k, k+1, ...
  * shard-aware: each data-parallel rank draws only its slice (here we build
    the global batch and device_put with the batch sharding; under multi-host
    the same counter-based generator yields per-host slices without I/O)
  * zero-copy hand-off: arrays are device_put with the target sharding.

The token stream is a counter-based PRNG (threefry via jax.random.fold_in on
host numpy is avoided — we use numpy's Philox with per-(step, row) counters),
plus a structured component (repeated n-grams) so losses are learnable and
training curves are meaningful in examples.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class TokenDatasetConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.5   # fraction of positions from a learnable pattern


class TokenDataset:
    """dataset(step) -> batch dict with tokens/labels (numpy or device)."""

    def __init__(self, cfg: TokenDatasetConfig, sharding=None,
                 prefix_len: int = 0, d_model: int = 0, frames: bool = False):
        self.cfg = cfg
        self.sharding = sharding
        self.prefix_len = prefix_len
        self.d_model = d_model
        self.frames = frames
        # a fixed "grammar": each token deterministically suggests a successor
        rng = np.random.default_rng(cfg.seed + 1234)
        self.successor = rng.integers(0, cfg.vocab, size=cfg.vocab)

    def _raw(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=cfg.seed, spawn_key=(step,))
        )
        # random walk through the successor grammar: with prob `structure`
        # token t+1 = successor(token t) (chained, so the signal survives),
        # else a uniform jump — vectorized over batch, sequential over time
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=cfg.global_batch)
        jumps = rng.integers(0, cfg.vocab, size=(cfg.global_batch, cfg.seq_len))
        use = rng.random((cfg.global_batch, cfg.seq_len)) < cfg.structure
        for t in range(cfg.seq_len):
            toks[:, t + 1] = np.where(use[:, t], self.successor[toks[:, t]],
                                      jumps[:, t])
        return toks.astype(np.int32)

    def __call__(self, step: int) -> dict:
        toks = self._raw(step)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.prefix_len:
            rng = np.random.default_rng(self.cfg.seed + 7 + step)
            batch["prefix_embeds"] = rng.standard_normal(
                (self.cfg.global_batch, self.prefix_len, self.d_model)
            ).astype(np.float32)
        if self.frames:
            rng = np.random.default_rng(self.cfg.seed + 11 + step)
            batch["frames"] = rng.standard_normal(
                (self.cfg.global_batch, self.cfg.seq_len, self.d_model)
            ).astype(np.float32)
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding) for k, v in batch.items()}
        return batch
