"""int8 gradient compression for the data-parallel all-reduce.

Per-tensor symmetric int8 quantization with a pmax-shared scale (every rank
uses the same scale, so the integer psum is exact in int32 and dequantizes
consistently), plus *error feedback*: the per-rank quantization residual is
carried and added to the next step's gradient, the standard trick that keeps
SGD/Adam convergence intact under 4x-compressed collectives (1-bit Adam /
EF-SGD lineage).

Wire format: int8 tensor + one f32 scale per tensor per step; the data-axis
collective volume drops ~4x vs f32 (~2x vs bf16) — the knob for DP-dominated,
cross-pod-bound workloads.

Used inside a shard_map region that is *manual over the data axes, auto over
model* (see train/loop.py manual-DP path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(g, axis_names, err):
    """Quantized psum of one tensor. Returns (mean_grad, new_err)."""
    g32 = g.astype(jnp.float32) + err
    absmax = jnp.max(jnp.abs(g32))
    for ax in axis_names:
        absmax = jax.lax.pmax(absmax, ax)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    qsum = q.astype(jnp.int32)
    for ax in axis_names:
        qsum = jax.lax.psum(qsum, ax)
    total = qsum.astype(jnp.float32) * scale
    return total, new_err


def compressed_psum_tree(grads, axis_names, err_tree, n_ranks: int):
    """Tree version; returns (mean grads, new error-feedback tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    means, errs = [], []
    for g, e in zip(flat_g, flat_e, strict=True):
        tot, ne = compressed_psum(g, axis_names, e)
        means.append(tot / n_ranks)
        errs.append(ne)
    return jax.tree.unflatten(treedef, means), jax.tree.unflatten(treedef, errs)


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
