"""AdamW + LR schedules + global-norm clipping (self-contained, no optax).

Optimizer state mirrors the parameter pytree, so it inherits parameter
sharding; with ZeRO-1 enabled the train-step builder re-shards m/v over the
data axis (see train/loop.py) and XLA inserts the reduce-scatter/all-gather
pair — the standard optimizer-state partitioning.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state, update_shardings=None):
    """Returns (new_params, new_state, metrics).

    update_shardings (optional): a pytree of shardings (the ZeRO-1 m/v
    layout). When given, the f32 master copy and the update math are pinned
    to that DP-sharded layout, so the only replicated-over-data tensor is the
    final bf16 parameter after the all-gather — the ZeRO-1 update flow.
    """
    grads32, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, us=None):
        if us is not None:
            g = jax.lax.with_sharding_constraint(g, us)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        p32 = p.astype(jnp.float32)
        if us is not None:
            p32 = jax.lax.with_sharding_constraint(p32, us)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32
        p_new32 = p32 - lr * delta
        if us is not None:
            p_new32 = jax.lax.with_sharding_constraint(p_new32, us)
        return p_new32.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads32)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_us = (treedef.flatten_up_to(update_shardings)
               if update_shardings is not None else [None] * len(flat_p))
    out = [upd(p, g, m, v, us)
           for p, g, m, v, us in zip(flat_p, flat_g, flat_m, flat_v, flat_us, strict=True)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
