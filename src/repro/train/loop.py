"""Train-step builders and the training loop.

Two step flavors:

* **auto** (default): one pjit'd step — XLA SPMD derives every collective
  from the in/out shardings (params TP/EP-sharded over "model", batch over
  the DP axes, optional ZeRO-1 optimizer-state sharding over "data").
  Microbatch gradient accumulation runs as a lax.scan inside the step.

* **manual-dp**: shard_map manual over the DP axes / auto over "model".
  Per-rank grads are reduced with the int8 compressed psum (+error feedback)
  from train/grad_compress.py — the explicit-collective path for cross-pod
  bandwidth-bound training.

Both return metrics and are lowerable with ShapeDtypeStructs (the dry-run
uses exactly these builders — no divergence between dry-run and real step).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.sharding.rules import (
    ShardingRules, batch_axes_for_mesh, build_param_specs,
)
from repro.train import optim
from repro.train.grad_compress import compressed_psum_tree
from repro.runtime.jax_compat import set_mesh as compat_set_mesh, shard_map as compat_shard_map


@dataclasses.dataclass
class TrainConfig:
    opt: optim.AdamWConfig = dataclasses.field(default_factory=optim.AdamWConfig)
    microbatches: int = 1           # gradient-accumulation chunks per step
    zero1: bool = False             # shard optimizer m/v over the data axis
    zero2_grads: bool = False       # keep the grad accumulator DP-sharded
    grad_compress: bool = False     # int8 compressed DP all-reduce (manual-dp)
    mode: str = "auto"              # auto | manual-dp


def _zero1_specs(mesh, param_shardings):
    """Optimizer-state shardings: add 'data' on the first divisible free dim."""

    def reshard(ns: NamedSharding):
        spec = list(ns.spec) if ns.spec else []
        return ns  # placeholder; refined per-leaf with shapes in build step

    return param_shardings


def build_shardings(model: Model, mesh, rules: ShardingRules):
    shapes, logical = model.param_specs()
    param_sh = build_param_specs(mesh, rules, shapes, logical)
    return shapes, logical, param_sh


def _opt_shardings(mesh, rules, shapes, logical, param_sh, zero1: bool):
    if not zero1:
        m = param_sh
    else:
        ba = batch_axes_for_mesh(mesh)
        dp = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1

        def one(struct, ns):
            spec = list(ns.spec) + [None] * (len(struct.shape) - len(ns.spec))
            used = set()
            for e in spec:
                for a in ((e,) if isinstance(e, str) else (e or ())):
                    used.add(a)
            if not set(ba) & used:
                for i, e in enumerate(spec):
                    if e is None and struct.shape[i] % dp == 0 and struct.shape[i] >= dp:
                        spec[i] = ba if len(ba) > 1 else ba[0]
                        break
            return NamedSharding(mesh, P(*spec))

        m = jax.tree.map(one, shapes, param_sh,
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return {"m": m, "v": m, "step": NamedSharding(mesh, P())}


def make_train_step(
    model: Model, mesh, rules: ShardingRules, tcfg: TrainConfig,
    extra_batch_specs: Optional[dict] = None,
):
    """Returns (step_fn, shardings dict). step(params, opt_state, batch)."""
    shapes, logical, param_sh = build_shardings(model, mesh, rules)
    opt_sh = _opt_shardings(mesh, rules, shapes, logical, param_sh, tcfg.zero1)
    ba = batch_axes_for_mesh(mesh)
    batch_spec = P(ba if len(ba) > 1 else (ba[0] if ba else None))
    data_sh = NamedSharding(mesh, batch_spec)

    def batch_shardings(batch_template: dict):
        out = {}
        for k in batch_template:
            if extra_batch_specs and k in extra_batch_specs:
                out[k] = NamedSharding(mesh, extra_batch_specs[k])
            else:
                out[k] = data_sh
        return out

    opt_cfg = tcfg.opt
    nm = tcfg.microbatches

    def loss_of(params, batch):
        return model.loss_fn(params, batch, mesh=mesh)

    # ZeRO-2: the f32 gradient accumulator (the largest training temp for
    # big models) stays sharded over the DP axes; XLA inserts a per-microbatch
    # reduce-scatter instead of holding a replicated f32 grad tree
    zero2_sh = (
        _opt_shardings(mesh, rules, shapes, logical, param_sh, True)["m"]
        if tcfg.zero2_grads else None
    )

    def _constrain(tree):
        if zero2_sh is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, zero2_sh)

    def grads_of(params, batch):
        if nm == 1:
            (loss, ex), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
            return loss, ex, _constrain(grads)
        # microbatch accumulation: split the batch dim into nm chunks
        def split(x):
            b = x.shape[0]
            return x.reshape(nm, b // nm, *x.shape[1:])

        # keep the *within-microbatch* batch dim sharded over DP: without the
        # constraint GSPMD shards the microbatch index instead, replicating
        # each microbatch's activations on every DP rank
        mb_spec = NamedSharding(mesh, P(None, *batch_spec))
        mb = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(split(x), mb_spec), batch
        )
        zero = _constrain(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )

        def body(carry, mbatch):
            acc, loss_acc = carry
            (loss, ex), g = jax.value_and_grad(loss_of, has_aux=True)(params, mbatch)
            acc = _constrain(
                jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            )
            return (acc, loss_acc + loss), None

        (gacc, loss_sum), _ = jax.lax.scan(body, (zero, 0.0), mb)
        grads = jax.tree.map(lambda g: g / nm, gacc)
        return loss_sum / nm, {"ce": loss_sum / nm, "aux": jnp.zeros(())}, grads

    if tcfg.mode == "auto":
        update_sh = opt_sh["m"] if tcfg.zero1 else None

        def step(params, opt_state, batch):
            loss, ex, grads = grads_of(params, batch)
            new_params, new_opt, om = optim.adamw_update(
                opt_cfg, params, grads, opt_state, update_shardings=update_sh
            )
            metrics = {"loss": loss, **ex, **om}
            return new_params, new_opt, metrics

        jstep = jax.jit(
            step,
            # data_sh is a pytree *prefix* for the whole batch dict: every
            # input leaf gets its leading (batch) dim sharded over the DP axes
            in_shardings=(param_sh, opt_sh, data_sh),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
    elif tcfg.mode == "manual-dp":
        dp_axes = ba
        n_ranks = int(np.prod([mesh.shape[a] for a in dp_axes]))

        def step(params, opt_state, err, batch):
            def inner(params, opt_state, err, batch):
                loss, ex, grads = grads_of(params, batch)
                if tcfg.grad_compress:
                    grads, err = compressed_psum_tree(grads, dp_axes, err, n_ranks)
                else:
                    grads = jax.tree.map(
                        lambda g: jax.lax.pmean(g.astype(jnp.float32), dp_axes[0])
                        if len(dp_axes) == 1
                        else jax.lax.pmean(
                            jax.lax.pmean(g.astype(jnp.float32), dp_axes[0]), dp_axes[1]
                        ),
                        grads,
                    )
                loss = jax.lax.pmean(loss, dp_axes[0])
                new_params, new_opt, om = optim.adamw_update(
                    opt_cfg, params, grads, opt_state
                )
                return new_params, new_opt, err, {"loss": loss, **om}

            return compat_shard_map(
                inner,
                mesh,
                in_specs=(P(), P(), P(), batch_spec),
                out_specs=(P(), P(), P(), P()),
                axis_names=set(dp_axes),
                check_vma=False,
            )(params, opt_state, err, batch)

        jstep = jax.jit(step, donate_argnums=(0, 1, 2))
    else:
        raise ValueError(tcfg.mode)

    shardings = {
        "params": param_sh, "opt": opt_sh, "data": data_sh,
        "batch_shardings": batch_shardings, "param_shapes": shapes,
    }
    return jstep, shardings


def init_train_state(model: Model, mesh, shardings, seed: int = 0):
    """Sharded init: params materialize directly with their target sharding."""
    param_sh = shardings["params"]

    @partial(jax.jit, out_shardings=param_sh)
    def _init(key):
        return model.init(key)

    with compat_set_mesh(mesh):
        params = _init(jax.random.PRNGKey(seed))
        opt_state = jax.jit(
            optim.init_opt_state, out_shardings=shardings["opt"]
        )(params)
    return params, opt_state


def train_loop(
    model: Model, mesh, rules, tcfg: TrainConfig, dataset, steps: int,
    ckpt_manager=None, ckpt_every: int = 0, hooks: Optional[list] = None,
    params=None, opt_state=None, start_step: int = 0,
):
    """The end-to-end driver loop (examples/train_lm.py uses this)."""
    step_fn, shardings = make_train_step(model, mesh, rules, tcfg)
    if params is None:
        params, opt_state = init_train_state(model, mesh, shardings)
    history = []
    with compat_set_mesh(mesh):
        for step in range(start_step, steps):
            t0 = time.perf_counter()
            batch = dataset(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            history.append({"step": step, "loss": loss, "dt": dt})
            for h in hooks or []:
                h(step, params, opt_state, metrics, dt)
            if ckpt_manager is not None and ckpt_every and (step + 1) % ckpt_every == 0:
                ckpt_manager.save(step + 1, params, opt_state)
    return params, opt_state, history
