"""Synthetic graph generators.

The paper evaluates on six real web/social graphs (Table I) plus synthetic
Barabási–Albert graphs (Fig. 12). This container has no network access, so all
experiments run on synthetic generators with the same qualitative structure:
power-law degree distributions, communities, and (for SSSP) weighted variants.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import Graph


def _dedup(n: int, src: np.ndarray, dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst
    _, first = np.unique(key, return_index=True)
    first.sort()
    return src[first], dst[first]


def barabasi_albert(n: int, m: int, seed: int = 0, directed: bool = True) -> Graph:
    """BA preferential attachment (paper §V-H uses this for degree sweeps).

    Each new vertex attaches to `m` existing vertices picked via the repeated-
    nodes trick (Batagelj–Brandes), giving the standard power-law tail. Edges
    are oriented new->old then 50% flipped so both directions occur, matching
    how the paper treats directed iterative workloads.
    """
    rng = np.random.default_rng(seed)
    if n <= m:
        raise ValueError("n must exceed m")
    repeated: list[int] = []
    srcs = np.empty(( (n - m - 1) * m + m,), dtype=np.int32)
    dsts = np.empty_like(srcs)
    e = 0
    # seed clique-ish star among first m+1 vertices
    for v in range(m):
        srcs[e], dsts[e] = m, v
        repeated.extend((m, v))
        e += 1
    for v in range(m + 1, n):
        targets: set[int] = set()
        while len(targets) < m:
            if rng.random() < 0.9 and repeated:
                targets.add(repeated[rng.integers(len(repeated))])
            else:
                targets.add(int(rng.integers(v)))
        for t in targets:
            srcs[e], dsts[e] = v, t
            repeated.extend((v, t))
            e += 1
    src, dst = srcs[:e], dsts[:e]
    if directed:
        flip = rng.random(e) < 0.5
        src2 = np.where(flip, dst, src)
        dst2 = np.where(flip, src, dst)
        src, dst = src2, dst2
    src, dst = _dedup(n, src, dst)
    return Graph(n, src, dst)


def erdos_renyi(n: int, avg_degree: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    src = rng.integers(0, n, size=m, dtype=np.int32)
    dst = rng.integers(0, n, size=m, dtype=np.int32)
    src, dst = _dedup(n, src, dst)
    return Graph(n, src, dst)


def powerlaw_cluster(n: int, m: int, p: float = 0.3, seed: int = 0) -> Graph:
    """BA-like growth with triad closure -> communities + power law.

    This is the closest synthetic stand-in for the paper's web graphs
    (indochina / sk-2005): heavy tail *and* strong local clustering, which is
    what makes partition-based reordering (Rabbit, GoGraph step 2) matter.
    """
    rng = np.random.default_rng(seed)
    repeated: list[int] = []
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(1, min(m + 1, n)):
        src_l.append(v)
        dst_l.append(v - 1)
        repeated.extend((v, v - 1))
    for v in range(m + 1, n):
        last_target = None
        made = 0
        while made < m:
            if last_target is not None and rng.random() < p:
                # triad closure: connect to a neighbor of the last target
                cand = [repeated[rng.integers(len(repeated))]]
                t = cand[0]
            else:
                t = repeated[rng.integers(len(repeated))] if repeated else int(rng.integers(v))
            if t != v:
                src_l.append(v)
                dst_l.append(t)
                repeated.extend((v, t))
                last_target = t
                made += 1
    src = np.asarray(src_l, dtype=np.int32)
    dst = np.asarray(dst_l, dtype=np.int32)
    flip = rng.random(len(src)) < 0.5
    src2 = np.where(flip, dst, src).astype(np.int32)
    dst2 = np.where(flip, src, dst).astype(np.int32)
    src, dst = _dedup(n, src2, dst2)
    return Graph(n, src, dst)


def community_graph(
    n: int,
    n_communities: int,
    avg_degree: float = 8.0,
    p_intra: float = 0.9,
    seed: int = 0,
) -> Graph:
    """Planted-partition graph: p_intra of edges stay inside a community."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_communities, size=n)
    members: list[np.ndarray] = [np.where(comm == c)[0] for c in range(n_communities)]
    m = int(n * avg_degree)
    src = rng.integers(0, n, size=m, dtype=np.int32)
    intra = rng.random(m) < p_intra
    dst = np.empty(m, dtype=np.int32)
    for i in range(m):
        if intra[i]:
            mem = members[comm[src[i]]]
            dst[i] = mem[rng.integers(len(mem))] if len(mem) else rng.integers(n)
        else:
            dst[i] = rng.integers(n)
    src, dst = _dedup(n, src, dst)
    return Graph(n, src, dst)


def grid_2d(rows: int, cols: int, seed: int = 0) -> Graph:
    """Directed 2D grid (right+down) — a worst case for hub-based reorderers."""
    n = rows * cols
    vid = np.arange(n).reshape(rows, cols)
    src = np.concatenate([vid[:, :-1].ravel(), vid[:-1, :].ravel()])
    dst = np.concatenate([vid[:, 1:].ravel(), vid[1:, :].ravel()])
    rng = np.random.default_rng(seed)
    flip = rng.random(len(src)) < 0.25
    s = np.where(flip, dst, src).astype(np.int32)
    d = np.where(flip, src, dst).astype(np.int32)
    return Graph(n, s, d)


def with_random_weights(g: Graph, lo: float = 1.0, hi: float = 10.0, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    w = rng.uniform(lo, hi, size=g.m).astype(np.float32)
    return Graph(g.n, g.src.copy(), g.dst.copy(), w)


def scrambled(g: Graph, seed: int = 0) -> Graph:
    """Random relabeling — used to model a 'bad' default vertex order."""
    rng = np.random.default_rng(seed)
    rank = rng.permutation(g.n).astype(np.int32)
    return g.relabel(rank)


# Registry used by benchmarks / examples. Sizes chosen so the full paper
# benchmark suite finishes on a single CPU core; the generators scale to
# arbitrarily large graphs.
DATASETS = {
    # name: thunk  (named after the paper dataset they stand in for)
    "ic-like": lambda: powerlaw_cluster(8_000, 6, p=0.5, seed=1),       # indochina-ish
    "sk-like": lambda: powerlaw_cluster(20_000, 6, p=0.4, seed=2),      # sk-2005-ish
    "gl-like": lambda: barabasi_albert(30_000, 5, seed=3),              # google-ish
    "wk-like": lambda: barabasi_albert(50_000, 3, seed=4),              # wiki-ish
    "cp-like": lambda: erdos_renyi(40_000, 5.0, seed=5),                # cit-patents-ish
    "lj-like": lambda: community_graph(40_000, 200, 7.0, 0.85, seed=6), # livejournal-ish
}


def load_dataset(name: str) -> Graph:
    return DATASETS[name]()
