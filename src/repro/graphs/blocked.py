"""Block packing of a (reordered) graph for the TPU engines and kernels.

The TPU adaptation of the paper's asynchronous mode works on contiguous
*blocks* of the processing order (DESIGN.md §3). Two packings are built here:

* :class:`BlockedInEdges` — per-destination-block padded in-edge lists, used by
  the pure-JAX block Gauss–Seidel engine (`engine/async_block.py`). Gather/
  segment-reduce friendly.

* :class:`BSRMatrix` — block-sparse rows of dense (bs × bs) tiles of the
  in-adjacency matrix, used by the Pallas kernels (`kernels/bsr_spmm.py`).
  After GoGraph reordering + community partitioning the matrix is block-
  concentrated, so the number of tiles per row-block (= DMAs per output tile
  on TPU) is small; `stats()` reports exactly that locality proxy.

Both packings order edges the same way so engines agree bit-for-bit in tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph


def num_blocks(n: int, bs: int) -> int:
    return (n + bs - 1) // bs


def padded_n(n: int, bs: int) -> int:
    return num_blocks(n, bs) * bs


@dataclasses.dataclass
class BlockedInEdges:
    """Padded per-destination-block in-edge lists.

    For destination block i, edge slot j:
      esrc[i, j]   global source vertex id (0 for pads)
      edst[i, j]   destination vertex id *local to the block* (0 for pads)
      ew[i, j]     edge weight (0 for pads; pads also masked)
      emask[i, j]  True for real edges
    """

    bs: int
    n: int  # real vertex count (before padding)
    esrc: np.ndarray
    edst: np.ndarray
    ew: np.ndarray
    emask: np.ndarray

    @property
    def nb(self) -> int:
        return self.esrc.shape[0]

    @property
    def e_max(self) -> int:
        return self.esrc.shape[1]


def pack_in_edges(g: Graph, bs: int) -> BlockedInEdges:
    nb = num_blocks(g.n, bs)
    blk = g.dst // bs
    order = np.argsort(blk, kind="stable")
    src_s, dst_s, w_s = g.src[order], g.dst[order], g.weights[order]
    counts = np.bincount(blk, minlength=nb)
    e_max = max(1, int(counts.max()) if len(counts) else 1)
    esrc = np.zeros((nb, e_max), dtype=np.int32)
    edst = np.zeros((nb, e_max), dtype=np.int32)
    ew = np.zeros((nb, e_max), dtype=np.float32)
    emask = np.zeros((nb, e_max), dtype=bool)
    offsets = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    for i in range(nb):
        lo, hi = offsets[i], offsets[i + 1]
        k = hi - lo
        esrc[i, :k] = src_s[lo:hi]
        edst[i, :k] = dst_s[lo:hi] - i * bs
        ew[i, :k] = w_s[lo:hi]
        emask[i, :k] = True
    return BlockedInEdges(bs=bs, n=g.n, esrc=esrc, edst=edst, ew=ew, emask=emask)


@dataclasses.dataclass
class BSRMatrix:
    """Block-sparse in-adjacency: y_blk[i] = reduce_k tiles[i,k] (x_blk[cols[i,k]]).

    tiles[i, k] has layout (dst_local, src_local): row r of tile (i,k) holds the
    weights of edges into vertex i*bs+r from vertices cols[i,k]*bs + c.
    Padding tiles point at column-block 0 with `fill` values so semiring
    reduction ignores them (0 for plus_times, +inf for min_plus).
    """

    bs: int
    n: int
    cols: np.ndarray      # int32[nb, k_max]
    colmask: np.ndarray   # bool[nb, k_max]
    tiles: np.ndarray     # float32[nb, k_max, bs, bs]
    fill: float

    @property
    def nb(self) -> int:
        return self.cols.shape[0]

    @property
    def k_max(self) -> int:
        return self.cols.shape[1]

    def stats(self) -> dict:
        """Locality proxies (the TPU analogue of the paper's cache-miss study)."""
        nnz_blocks = int(self.colmask.sum())
        per_row = self.colmask.sum(axis=1)
        diag = 0
        for i in range(self.nb):
            diag += int(np.any(self.cols[i][self.colmask[i]] == i))
        return {
            "nb": self.nb,
            "k_max": self.k_max,
            "nnz_blocks": nnz_blocks,
            "mean_colblocks_per_rowblock": float(per_row.mean()) if self.nb else 0.0,
            "max_colblocks_per_rowblock": int(per_row.max()) if self.nb else 0,
            "diag_fraction": diag / max(1, self.nb),
            "tile_bytes": int(self.tiles.nbytes),
        }


def pack_bsr(g: Graph, bs: int, fill: float = 0.0) -> BSRMatrix:
    nb = num_blocks(g.n, bs)
    bi = (g.dst // bs).astype(np.int64)  # row (dst) block
    bk = (g.src // bs).astype(np.int64)  # col (src) block
    key = bi * nb + bk
    order = np.argsort(key, kind="stable")
    src_s, dst_s, w_s, key_s = g.src[order], g.dst[order], g.weights[order], key[order]
    uniq, start = np.unique(key_s, return_index=True)
    start = np.append(start, len(key_s))
    rows = (uniq // nb).astype(np.int64)
    cols_of = (uniq % nb).astype(np.int64)
    per_row = np.bincount(rows, minlength=nb)
    k_max = max(1, int(per_row.max()) if nb else 1)
    cols = np.zeros((nb, k_max), dtype=np.int32)
    colmask = np.zeros((nb, k_max), dtype=bool)
    tiles = np.full((nb, k_max, bs, bs), fill, dtype=np.float32)
    slot = np.zeros(nb, dtype=np.int64)
    for t in range(len(uniq)):
        i, k = rows[t], cols_of[t]
        s = slot[i]
        slot[i] += 1
        cols[i, s] = k
        colmask[i, s] = True
        lo, hi = start[t], start[t + 1]
        r = dst_s[lo:hi] - i * bs
        c = src_s[lo:hi] - k * bs
        tiles[i, s, r, c] = w_s[lo:hi]
    return BSRMatrix(bs=bs, n=g.n, cols=cols, colmask=colmask, tiles=tiles, fill=fill)


def pad_state(x: np.ndarray, bs: int, fill=0.0) -> np.ndarray:
    """Pad a per-vertex state array (n, ...) up to a whole number of blocks.

    This is the one padding primitive of the shared pack path
    (`engine.harness.pack`): batched (n, d) state matrices pad along axis 0
    only, and ``fill`` must be the semiring-appropriate value — the reduce
    identity for states, the combine-appropriate fill for constants, ``True``
    for ``fixed`` masks (padding vertices are pinned so they never move).
    """
    n = x.shape[0]
    np_ = padded_n(n, bs)
    if np_ == n:
        return x.copy()
    pad_width = [(0, np_ - n)] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad_width, constant_values=fill)
