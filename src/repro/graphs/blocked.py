"""Block packing of a (reordered) graph for the TPU engines and kernels.

The TPU adaptation of the paper's asynchronous mode works on contiguous
*blocks* of the processing order (DESIGN.md §3). Three packings are built
here:

* :class:`BlockedInEdges` — per-destination-block padded in-edge lists, used by
  the pure-JAX block Gauss–Seidel engine (`engine/async_block.py`). Gather/
  segment-reduce friendly.

* :class:`FlatBSRMatrix` — the **ragged flat** block-sparse layout the Pallas
  kernels (`kernels/gs_sweep.py`, `kernels/bsr_spmm.py`) walk: one dense
  ``(bs, bs)`` tile per *nonzero* block of the in-adjacency matrix, stored
  contiguously in CSR-of-tiles form (``tiles[nnz_blocks, bs, bs]`` +
  scalar-prefetched ``rowptr[nb+1]`` / ``tilecols[nnz_blocks]``). Memory, DMA
  count, and semiring FLOPs are all ``O(nnz_blocks)`` — the hub row-blocks
  that GoGraph's HD phase concentrates (paper §IV-A) are paid for once, in
  their own row, not replicated into every row's padding.

* :class:`BSRMatrix` — the legacy *dense-padded* BSR layout
  (``tiles[nb, k_max, bs, bs]``), kept as the comparison baseline: every
  row-block pads to the global ``k_max``, so on a powerlaw graph the densest
  (hub) row-block sets the cost of all of them. ``stats()['padding_waste']``
  reports exactly how much of the tile memory that padding is.

All packings order edges the same way so engines agree bit-for-bit in tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph


def num_blocks(n: int, bs: int) -> int:
    return (n + bs - 1) // bs


def padded_n(n: int, bs: int) -> int:
    return num_blocks(n, bs) * bs


@dataclasses.dataclass
class BlockedInEdges:
    """Padded per-destination-block in-edge lists.

    For destination block i, edge slot j:
      esrc[i, j]   global source vertex id (0 for pads)
      edst[i, j]   destination vertex id *local to the block* (0 for pads)
      ew[i, j]     edge weight (0 for pads; pads also masked)
      emask[i, j]  True for real edges
    """

    bs: int
    n: int  # real vertex count (before padding)
    esrc: np.ndarray
    edst: np.ndarray
    ew: np.ndarray
    emask: np.ndarray

    @property
    def nb(self) -> int:
        return self.esrc.shape[0]

    @property
    def e_max(self) -> int:
        return self.esrc.shape[1]


def pack_in_edges(g: Graph, bs: int) -> BlockedInEdges:
    nb = num_blocks(g.n, bs)
    blk = g.dst // bs
    order = np.argsort(blk, kind="stable")
    src_s, dst_s, w_s = g.src[order], g.dst[order], g.weights[order]
    blk_s = blk[order]
    counts = np.bincount(blk, minlength=nb)
    e_max = max(1, int(counts.max()) if len(counts) else 1)
    esrc = np.zeros((nb, e_max), dtype=np.int32)
    edst = np.zeros((nb, e_max), dtype=np.int32)
    ew = np.zeros((nb, e_max), dtype=np.float32)
    emask = np.zeros((nb, e_max), dtype=bool)
    offsets = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    # slot of edge e within its destination block = position after the stable
    # sort minus the block's first position: one scatter per array, same
    # (block, slot) <- sorted-edge assignment the per-block loop produced.
    slot = np.arange(len(blk_s), dtype=np.int64) - offsets[blk_s]
    esrc[blk_s, slot] = src_s
    edst[blk_s, slot] = dst_s - blk_s * bs
    ew[blk_s, slot] = w_s
    emask[blk_s, slot] = True
    return BlockedInEdges(bs=bs, n=g.n, esrc=esrc, edst=edst, ew=ew, emask=emask)


@dataclasses.dataclass
class FlatBSRMatrix:
    """Ragged flat BSR of the in-adjacency: CSR over (bs, bs) tiles.

    For destination block i, tiles ``rowptr[i]..rowptr[i+1]`` hold its
    nonzero column-blocks in ascending column order:

        y_blk[i] = REDUCE_{t in [rowptr[i], rowptr[i+1])} tiles[t] (x) x_blk[tilecols[t]]

    ``tiles[t]`` has layout (dst_local, src_local). Absent edges *inside* a
    nonzero tile carry ``fill`` — the semiring's absorbing element (0 for
    plus_times, +BIG for min_plus, -BIG for max_min) — but there are no
    padding *tiles*: memory and per-sweep DMAs are O(nnz_blocks), not
    O(nb * k_max). ``tilerows`` is derived (``repeat`` of the rowptr runs) and
    carried so `bsr_spmm` can map grid step -> output block without a search.

    Empty graphs keep one never-referenced zero tile (``rowptr`` all zero) so
    downstream device buffers are never zero-sized; ``nnz_blocks`` reads the
    real count from ``rowptr[-1]``.
    """

    bs: int
    n: int
    rowptr: np.ndarray    # int32[nb + 1]
    tilecols: np.ndarray  # int32[max(nnz_blocks, 1)]
    tilerows: np.ndarray  # int32[max(nnz_blocks, 1)]  (derived)
    tiles: np.ndarray     # float32[max(nnz_blocks, 1), bs, bs]
    fill: float

    @property
    def nb(self) -> int:
        return self.rowptr.shape[0] - 1

    @property
    def nnz_blocks(self) -> int:
        return int(self.rowptr[-1])

    @property
    def k_max(self) -> int:
        """Densest row-block — what the dense layout pads *every* row to."""
        if self.nb == 0:
            return 1
        return max(1, int(np.diff(self.rowptr).max()))

    def reverse_deps(self) -> tuple[np.ndarray, np.ndarray]:
        """Block reverse-dependency CSR: for *source* block j,
        ``revrows[revptr[j]:revptr[j+1]]`` lists the destination blocks that
        own a tile reading j — the set of blocks whose next update can change
        when j's state moves. This is what the frontier megakernel
        (`kernels.gs_sweep`) walks to re-mark dependents dirty."""
        return block_reverse_deps(self.rowptr, self.tilecols)

    def stats(self) -> dict:
        """Locality proxies (the TPU analogue of the paper's cache-miss study)
        plus the layout win over the dense-padded baseline."""
        per_row = np.diff(self.rowptr)
        nnz = self.nnz_blocks
        k_max = self.k_max
        diag = int(
            np.count_nonzero(
                self.tilecols[: nnz] == self.tilerows[: nnz]
            )
        )
        tile_bytes = nnz * self.bs * self.bs * 4
        dense_tile_bytes = self.nb * k_max * self.bs * self.bs * 4
        return {
            "nb": self.nb,
            "k_max": k_max,
            "nnz_blocks": nnz,
            "mean_colblocks_per_rowblock": float(per_row.mean()) if self.nb else 0.0,
            "max_colblocks_per_rowblock": int(per_row.max()) if self.nb else 0,
            "diag_fraction": diag / max(1, self.nb),
            "tile_bytes": tile_bytes,
            "dense_tile_bytes": dense_tile_bytes,
            "tile_bytes_saved": dense_tile_bytes - tile_bytes,
            "padding_waste": 1.0 - nnz / max(1, self.nb * k_max),
        }


@dataclasses.dataclass
class BSRMatrix:
    """Dense-padded block-sparse rows (legacy layout, benchmark baseline).

    tiles[i, k] has layout (dst_local, src_local): row r of tile (i,k) holds
    the weights of edges into vertex i*bs+r from vertices cols[i,k]*bs + c.
    Padding tiles point at column-block 0 with `fill` values so semiring
    reduction ignores them (0 for plus_times, +inf for min_plus). Every
    row-block pays for the global k_max; `stats()['padding_waste']` is the
    fraction of tile memory that padding is.
    """

    bs: int
    n: int
    cols: np.ndarray      # int32[nb, k_max]
    colmask: np.ndarray   # bool[nb, k_max]
    tiles: np.ndarray     # float32[nb, k_max, bs, bs]
    fill: float

    @property
    def nb(self) -> int:
        return self.cols.shape[0]

    @property
    def k_max(self) -> int:
        return self.cols.shape[1]

    def stats(self) -> dict:
        """Locality proxies (the TPU analogue of the paper's cache-miss study)."""
        nnz_blocks = int(self.colmask.sum())
        per_row = self.colmask.sum(axis=1)
        diag = int(np.count_nonzero(
            np.any((self.cols == np.arange(self.nb)[:, None]) & self.colmask,
                   axis=1)
        ))
        return {
            "nb": self.nb,
            "k_max": self.k_max,
            "nnz_blocks": nnz_blocks,
            "mean_colblocks_per_rowblock": float(per_row.mean()) if self.nb else 0.0,
            "max_colblocks_per_rowblock": int(per_row.max()) if self.nb else 0,
            "diag_fraction": diag / max(1, self.nb),
            "tile_bytes": int(self.tiles.nbytes),
            "padding_waste": 1.0 - nnz_blocks / max(1, self.nb * self.k_max),
        }


def _sorted_tile_edges(g: Graph, bs: int):
    """Edges sorted by (dst block, src block); returns the per-tile grouping
    shared by the dense and flat packers so both layouts hold bitwise-identical
    tiles."""
    nb = num_blocks(g.n, bs)
    bi = (g.dst // bs).astype(np.int64)  # row (dst) block
    bk = (g.src // bs).astype(np.int64)  # col (src) block
    key = bi * nb + bk
    order = np.argsort(key, kind="stable")
    src_s, dst_s, w_s = g.src[order], g.dst[order], g.weights[order]
    key_s = key[order]
    uniq, tile_of_edge = np.unique(key_s, return_inverse=True)
    rows = (uniq // nb).astype(np.int64)
    cols_of = (uniq % nb).astype(np.int64)
    return nb, src_s, dst_s, w_s, tile_of_edge, rows, cols_of


def pack_bsr(g: Graph, bs: int, fill: float = 0.0) -> BSRMatrix:
    nb, src_s, dst_s, w_s, tile_of_edge, rows, cols_of = _sorted_tile_edges(g, bs)
    per_row = np.bincount(rows, minlength=nb)
    k_max = max(1, int(per_row.max()) if nb else 1)
    cols = np.zeros((nb, k_max), dtype=np.int32)
    colmask = np.zeros((nb, k_max), dtype=bool)
    tiles = np.full((nb, k_max, bs, bs), fill, dtype=np.float32)
    # tiles arrive sorted by (row, col), so a tile's k-slot is its index minus
    # its row's first tile index — the cumulative-count form of the old
    # per-tile `slot[i]++` bookkeeping, as scatters.
    row_start = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(per_row, out=row_start[1:])
    slot = np.arange(len(rows), dtype=np.int64) - row_start[rows]
    cols[rows, slot] = cols_of
    colmask[rows, slot] = True
    er = rows[tile_of_edge]       # per-edge destination block
    ec = cols_of[tile_of_edge]    # per-edge source block
    tiles[er, slot[tile_of_edge], dst_s - er * bs, src_s - ec * bs] = w_s
    return BSRMatrix(bs=bs, n=g.n, cols=cols, colmask=colmask, tiles=tiles, fill=fill)


def pack_bsr_flat(g: Graph, bs: int, fill: float = 0.0) -> FlatBSRMatrix:
    """Pack the in-adjacency into the ragged flat layout the kernels walk.

    Tile memory is ``nnz_blocks * bs * bs * 4`` bytes — proportional to the
    graph's real block structure, not to ``nb * k_max``.
    """
    nb, src_s, dst_s, w_s, tile_of_edge, rows, cols_of = _sorted_tile_edges(g, bs)
    nnz = len(rows)
    per_row = np.bincount(rows, minlength=nb)
    rowptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(per_row, out=rowptr[1:])
    tiles = np.full((max(1, nnz), bs, bs), fill, dtype=np.float32)
    er = rows[tile_of_edge]
    ec = cols_of[tile_of_edge]
    tiles[tile_of_edge, dst_s - er * bs, src_s - ec * bs] = w_s
    tilecols = cols_of.astype(np.int32) if nnz else np.zeros(1, np.int32)
    tilerows = rows.astype(np.int32) if nnz else np.zeros(1, np.int32)
    return FlatBSRMatrix(
        bs=bs, n=g.n, rowptr=rowptr.astype(np.int32), tilecols=tilecols,
        tilerows=tilerows, tiles=tiles, fill=fill,
    )


def block_reverse_deps(
    rowptr: np.ndarray, tilecols: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """CSC of the tile structure: ``(revptr[nb+1], revrows)`` where source
    (column) block j's dependents — the destination (row) blocks holding a
    tile that reads j — are ``revrows[revptr[j]:revptr[j+1]]``, in ascending
    row order. O(nnz_blocks) memory; the empty structure keeps one
    never-referenced zero entry so device buffers are never zero-sized
    (mirrors `FlatBSRMatrix.tilecols`)."""
    rowptr = np.asarray(rowptr)
    nb = len(rowptr) - 1
    nnz = int(rowptr[-1])
    cols = np.asarray(tilecols)[:nnz]
    rows = np.repeat(np.arange(nb, dtype=np.int32), np.diff(rowptr))
    order = np.argsort(cols, kind="stable")
    revrows = rows[order].astype(np.int32) if nnz else np.zeros(1, np.int32)
    revptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(np.bincount(cols, minlength=nb), out=revptr[1:])
    return revptr.astype(np.int32), revrows


def block_dependency_structure(
    src: np.ndarray, dst: np.ndarray, n: int, bs: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The nonzero block structure only — ``(rowptr, tilerows, tilecols)``
    over unique (dst block, src block) pairs, no tile payloads. This is the
    O(nnz_blocks) skeleton the priority scheduler propagates deltas over
    (``prio[tilerows] += delta[tilecols]``) instead of a dense (nb, nb)
    indicator matmul."""
    nb = num_blocks(n, bs)
    key = (np.asarray(dst, np.int64) // bs) * nb + (np.asarray(src, np.int64) // bs)
    uniq = np.unique(key)
    rows = (uniq // nb).astype(np.int32)
    cols = (uniq % nb).astype(np.int32)
    rowptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=nb), out=rowptr[1:])
    return rowptr.astype(np.int32), rows, cols


def frontier_blocks(frontier, n: int, bs: int) -> np.ndarray:
    """Pack a vertex-level dirty mask into the per-row-block bitmap the
    megakernel's frontier consumes: block i is dirty iff any of its vertices
    is. ``frontier=None`` (cold start / no self-consistency claim) marks
    every block dirty — the only always-safe default, since a clean block is
    a *contract* that its current state already satisfies its update
    equation."""
    nb = num_blocks(n, bs)
    if frontier is None:
        return np.ones(nb, np.int32)
    f = np.asarray(frontier)
    if f.shape != (n,):
        raise ValueError(
            f"frontier must be a vertex-level mask of shape ({n},), got {f.shape}"
        )
    fp = np.zeros(nb * bs, bool)
    fp[:n] = f != 0
    return fp.reshape(nb, bs).any(axis=1).astype(np.int32)


def pad_state(x: np.ndarray, bs: int, fill=0.0) -> np.ndarray:
    """Pad a per-vertex state array (n, ...) up to a whole number of blocks.

    This is the one padding primitive of the shared pack path
    (`engine.harness.pack`): batched (n, d) state matrices pad along axis 0
    only, and ``fill`` must be the semiring-appropriate value — the reduce
    identity for states, the combine-appropriate fill for constants, ``True``
    for ``fixed`` masks (padding vertices are pinned so they never move).
    """
    n = x.shape[0]
    np_ = padded_n(n, bs)
    if np_ == n:
        return x.copy()
    pad_width = [(0, np_ - n)] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad_width, constant_values=fill)
