"""Batched graph mutations for evolving-graph serving.

A :class:`GraphDelta` is one *batch* of updates — edge insertions, edge
deletions, edge reweights, and appended vertices — the unit at which a
serving deployment absorbs change (Maiter's delta-based accumulative model;
the InstantGNN evolving-PPR setting). Applying a delta keeps every surviving
vertex id stable and appends new vertices at the end, which is what lets the
incremental engine (`repro.engine.incremental`) overlay a previously
converged state onto the mutated graph.

Deltas address edges by endpoint pair ``(src, dst)``; parallel edges are not
distinguished (the generators dedupe them), so a deletion removes every copy
of the pair and a reweight retargets all of them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.graphs.graph import Graph


def _as_edges(src, dst) -> tuple[np.ndarray, np.ndarray]:
    src = np.asarray(src, dtype=np.int32).reshape(-1)
    dst = np.asarray(dst, dtype=np.int32).reshape(-1)
    if src.shape != dst.shape:
        raise ValueError("edge src/dst arrays must have the same length")
    return src, dst


_EMPTY_I = np.empty(0, np.int32)
_EMPTY_F = np.empty(0, np.float32)


def out_closure(
    src: np.ndarray, dst: np.ndarray, seeds: np.ndarray, n: int,
    depth: int = 1,
) -> np.ndarray:
    """bool[n] — ``seeds`` plus every vertex within ``depth`` out-edge hops.

    THE out-neighborhood closure helper: the megakernel frontier seeding
    (`engine.async_block.AsyncBlockSession.swap_in`,
    `engine.incremental.run_incremental`) and the push-routing estimate
    (`serving.server`) all need "the vertices whose update equations a
    state/graph change can invalidate", which is the change's support plus
    its out-neighbors — previously re-derived ad hoc at each site.

    ``seeds`` is either a ``bool[n]`` mask or an integer id array;
    ``depth=0`` returns just the seed set as a mask. Vectorized: each hop is
    one boolean gather/scatter over the edge arrays.
    """
    mask = np.zeros(n, bool)
    seeds = np.asarray(seeds)
    if seeds.dtype == bool:
        if seeds.shape != (n,):
            raise ValueError(f"bool seed mask must be (n,) = ({n},), "
                             f"got {seeds.shape}")
        mask |= seeds
    elif len(seeds):
        mask[seeds.astype(np.int64)] = True
    src = np.asarray(src)
    dst = np.asarray(dst)
    for _ in range(depth):
        mask[dst[mask[src]]] = True
    return mask


@dataclasses.dataclass
class GraphDelta:
    """One batch of graph updates: ``apply`` produces the mutated graph.

    n_add        appended vertices (new ids ``g.n .. g.n + n_add - 1``)
    add_src/dst  inserted edges (may reference new vertices)
    add_w        optional weights for the inserted edges
    del_src/dst  deleted edges, addressed by endpoint pair
    rew_src/dst  reweighted existing edges …
    rew_w        … and their new weights
    """

    n_add: int = 0
    add_src: np.ndarray = dataclasses.field(default_factory=lambda: _EMPTY_I.copy())
    add_dst: np.ndarray = dataclasses.field(default_factory=lambda: _EMPTY_I.copy())
    add_w: Optional[np.ndarray] = None
    del_src: np.ndarray = dataclasses.field(default_factory=lambda: _EMPTY_I.copy())
    del_dst: np.ndarray = dataclasses.field(default_factory=lambda: _EMPTY_I.copy())
    rew_src: np.ndarray = dataclasses.field(default_factory=lambda: _EMPTY_I.copy())
    rew_dst: np.ndarray = dataclasses.field(default_factory=lambda: _EMPTY_I.copy())
    rew_w: np.ndarray = dataclasses.field(default_factory=lambda: _EMPTY_F.copy())

    def __post_init__(self):
        self.add_src, self.add_dst = _as_edges(self.add_src, self.add_dst)
        self.del_src, self.del_dst = _as_edges(self.del_src, self.del_dst)
        self.rew_src, self.rew_dst = _as_edges(self.rew_src, self.rew_dst)
        if self.add_w is not None:
            self.add_w = np.asarray(self.add_w, np.float32).reshape(-1)
            if self.add_w.shape != self.add_src.shape:
                raise ValueError("add_w must match add_src/add_dst length")
        self.rew_w = np.asarray(self.rew_w, np.float32).reshape(-1)
        if self.rew_w.shape != self.rew_src.shape:
            raise ValueError("rew_w must match rew_src/rew_dst length")
        if self.n_add < 0:
            raise ValueError("n_add must be >= 0")

    @property
    def size(self) -> int:
        """Total number of edge updates in the batch."""
        return len(self.add_src) + len(self.del_src) + len(self.rew_src)

    def touched_vertices(
        self, g: Optional[Graph] = None, *, closure: int = 0
    ) -> np.ndarray:
        """Sorted unique endpoints of every mutated edge (new-id space) —
        the vertex set whose update equations this delta can directly
        invalidate. The serving layer's cache invalidation and frontier
        seeding both start from this set's blocks; appended vertices
        without edges are deliberately absent (nothing can have depended
        on them).

        ``closure > 0`` widens the set by that many out-edge hops of ``g``
        (the **post-apply** graph — the inserted edges must be walkable):
        the depth-1 set is every vertex a warm restart can perturb in its
        first round, which is what the push router sizes its frontier
        estimate with."""
        verts = np.unique(np.concatenate([
            self.add_src, self.add_dst, self.del_src, self.del_dst,
            self.rew_src, self.rew_dst,
        ]).astype(np.int64))
        if closure == 0:
            return verts
        if g is None:
            raise ValueError(
                "touched_vertices(closure > 0) walks out-edges and needs "
                "the post-apply graph: pass g = delta.apply(old_graph)"
            )
        mask = out_closure(g.src, g.dst, verts, g.n, depth=closure)
        return np.nonzero(mask)[0].astype(np.int64)

    def apply(self, g: Graph) -> Graph:
        """Return the mutated graph; ``g`` is left untouched."""
        n_new = g.n + self.n_add
        # out-of-range del/rew endpoints would alias a *different* edge
        # through the src*n+dst key arithmetic below, so reject them all
        for name, arr in (
            ("add", self.add_src), ("add", self.add_dst),
            ("del", self.del_src), ("del", self.del_dst),
            ("rew", self.rew_src), ("rew", self.rew_dst),
        ):
            if len(arr) and (arr.min() < 0 or arr.max() >= n_new):
                raise ValueError(f"{name} edge endpoint out of range for n={n_new}")
        src, dst = g.src, g.dst
        weighted = (g.w is not None) or (self.add_w is not None) or len(self.rew_w)
        w = g.weights.copy() if weighted else None

        if len(self.del_src):
            drop = _pair_member(src, dst, self.del_src, self.del_dst, n_new)
            keep = ~drop
            src, dst = src[keep], dst[keep]
            if w is not None:
                w = w[keep]

        if len(self.rew_src):
            if w is None:  # reweighting an unweighted graph materializes 1.0s
                w = np.ones(len(src), np.float32)
            key = src.astype(np.int64) * n_new + dst
            rkey = self.rew_src.astype(np.int64) * n_new + self.rew_dst
            order = np.argsort(rkey)
            pos = np.searchsorted(rkey[order], key)
            pos = np.clip(pos, 0, len(rkey) - 1)
            hit = rkey[order][pos] == key
            w = np.where(hit, self.rew_w[order][pos], w).astype(np.float32)

        if len(self.add_src):
            src = np.concatenate([src, self.add_src])
            dst = np.concatenate([dst, self.add_dst])
            if w is not None:
                aw = (self.add_w if self.add_w is not None
                      else np.ones(len(self.add_src), np.float32))
                w = np.concatenate([w, aw])

        return Graph(n_new, src, dst, w)


def _pair_member(
    src: np.ndarray, dst: np.ndarray, qsrc: np.ndarray, qdst: np.ndarray, n: int
) -> np.ndarray:
    """bool[m] — which (src, dst) edges appear in the (qsrc, qdst) set."""
    key = src.astype(np.int64) * n + dst
    qkey = np.unique(qsrc.astype(np.int64) * n + qdst)
    return np.isin(key, qkey)


def random_delta(
    g: Graph,
    *,
    frac_add: float = 0.01,
    frac_del: float = 0.0,
    frac_rew: float = 0.0,
    n_add_vertices: int = 0,
    w_lo: float = 1.0,
    w_hi: float = 10.0,
    seed: int = 0,
) -> GraphDelta:
    """Random delta batch sized as fractions of ``g.m`` (benchmarks/tests).

    Inserted edges draw uniform endpoints (self-loops and duplicates of
    existing edges are re-rolled); deletions and reweights sample existing
    edges without replacement. When ``g`` is weighted, inserted/reweighted
    edges draw uniform weights from ``[w_lo, w_hi)``; unweighted graphs get
    weightless insertions so they stay unweighted.
    """
    rng = np.random.default_rng(seed)
    n_new = g.n + n_add_vertices
    n_ins = int(round(g.m * frac_add))
    n_del = min(int(round(g.m * frac_del)), g.m)
    n_rew = min(int(round(g.m * frac_rew)), g.m)

    existing = set((g.src.astype(np.int64) * n_new + g.dst).tolist())
    add_src, add_dst = [], []
    # new vertices always get at least one incident edge so they join the graph
    for v in range(g.n, n_new):
        u = int(rng.integers(g.n))
        if rng.random() < 0.5:
            add_src.append(v), add_dst.append(u)
            existing.add(v * n_new + u)
        else:
            add_src.append(u), add_dst.append(v)
            existing.add(u * n_new + v)
    attempts = 0
    while len(add_src) < n_ins + n_add_vertices and attempts < 50 * (n_ins + 1):
        attempts += 1
        u = int(rng.integers(n_new))
        v = int(rng.integers(n_new))
        if u == v or (u * n_new + v) in existing:
            continue
        existing.add(u * n_new + v)
        add_src.append(u), add_dst.append(v)

    if n_del:
        pick = rng.choice(g.m, size=n_del, replace=False)
        del_src, del_dst = g.src[pick], g.dst[pick]
    else:
        del_src = del_dst = _EMPTY_I
    # don't reweight edges that are being deleted
    if n_rew:
        avoid = set((del_src.astype(np.int64) * n_new + del_dst).tolist())
        cand = rng.permutation(g.m)
        keep = [e for e in cand
                if (int(g.src[e]) * n_new + int(g.dst[e])) not in avoid][:n_rew]
        rew_src, rew_dst = g.src[keep], g.dst[keep]
        rew_w = rng.uniform(w_lo, w_hi, size=len(keep)).astype(np.float32)
    else:
        rew_src = rew_dst = _EMPTY_I
        rew_w = _EMPTY_F

    weighted = g.w is not None
    return GraphDelta(
        n_add=n_add_vertices,
        add_src=np.asarray(add_src, np.int32),
        add_dst=np.asarray(add_dst, np.int32),
        add_w=(rng.uniform(w_lo, w_hi, size=len(add_src)).astype(np.float32)
               if weighted else None),
        del_src=del_src, del_dst=del_dst,
        rew_src=rew_src, rew_dst=rew_dst, rew_w=rew_w,
    )
