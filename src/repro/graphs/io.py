"""Graph I/O: SNAP-style edge lists and a fast binary cache."""
from __future__ import annotations

import os

import numpy as np

from repro.graphs.graph import Graph


def load_edge_list(path: str, weighted: bool = False, comments: str = "#") -> Graph:
    """Parse a whitespace-separated edge list (`u v [w]` per line).

    Vertex ids are compacted to a dense [0, n) range (SNAP files are sparse in
    id space). Order of first appearance defines the *default* processing
    order, matching how the paper treats original ids.
    """
    srcs: list[int] = []
    dsts: list[int] = []
    ws: list[float] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(comments):
                continue
            parts = line.split()
            srcs.append(int(parts[0]))
            dsts.append(int(parts[1]))
            if weighted:
                ws.append(float(parts[2]) if len(parts) > 2 else 1.0)
    src = np.asarray(srcs, dtype=np.int64)
    dst = np.asarray(dsts, dtype=np.int64)
    # compact ids by first appearance
    interleaved = np.empty(2 * len(src), dtype=np.int64)
    interleaved[0::2] = src
    interleaved[1::2] = dst
    uniq, inv = np.unique(interleaved, return_inverse=True)
    first_pos = np.full(len(uniq), np.iinfo(np.int64).max)
    np.minimum.at(first_pos, inv, np.arange(len(inv)))
    appearance_rank = np.argsort(np.argsort(first_pos))
    compact = appearance_rank[inv]
    src_c = compact[0::2].astype(np.int32)
    dst_c = compact[1::2].astype(np.int32)
    w = np.asarray(ws, dtype=np.float32) if weighted else None
    return Graph(len(uniq), src_c, dst_c, w)


def save_npz(g: Graph, path: str) -> None:
    tmp = path + ".tmp"
    arrays = {"n": np.asarray(g.n), "src": g.src, "dst": g.dst}
    if g.w is not None:
        arrays["w"] = g.w
    np.savez_compressed(tmp, **arrays)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_npz(path: str) -> Graph:
    data = np.load(path)
    w = data["w"] if "w" in data else None
    return Graph(int(data["n"]), data["src"], data["dst"], w)


def load_cached(path: str, weighted: bool = False) -> Graph:
    """Load an edge list, memoized as .npz next to the source file."""
    cache = path + ".npz"
    if os.path.exists(cache) and os.path.getmtime(cache) >= os.path.getmtime(path):
        return load_npz(cache)
    g = load_edge_list(path, weighted=weighted)
    save_npz(g, cache)
    return g
