"""Directed-graph container used by the whole framework.

Everything is plain numpy on the host: graph *preprocessing* (GoGraph, the
baseline reorderers, partitioning, block packing) is host-side work; only the
iterative *compute* runs under JAX. The container keeps an edge list as the
source of truth and lazily materializes CSR (out-edges) / CSC (in-edges).

Vertex ids are dense ints [0, n). Edge weights are optional float32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """A directed graph with `n` vertices and edges (src[i] -> dst[i])."""

    n: int
    src: np.ndarray  # int32[m]
    dst: np.ndarray  # int32[m]
    w: Optional[np.ndarray] = None  # float32[m] or None (unweighted)

    # lazy adjacency caches
    _csr: Optional[tuple] = dataclasses.field(default=None, repr=False)
    _csc: Optional[tuple] = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.w is not None:
            self.w = np.asarray(self.w, dtype=np.float32)
        if self.src.shape != self.dst.shape:
            raise ValueError("src/dst shape mismatch")
        if self.m and (self.src.min() < 0 or self.src.max() >= self.n):
            raise ValueError("src ids out of range")
        if self.m and (self.dst.min() < 0 or self.dst.max() >= self.n):
            raise ValueError("dst ids out of range")

    # ------------------------------------------------------------------ basic
    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def weights(self) -> np.ndarray:
        if self.w is None:
            return np.ones(self.m, dtype=np.float32)
        return self.w

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int64)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n).astype(np.int64)

    def degrees(self) -> np.ndarray:
        return self.out_degrees() + self.in_degrees()

    # ------------------------------------------------------------- adjacency
    def csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Out-adjacency: (indptr[n+1], indices[m]=dst sorted by src, eid[m])."""
        if self._csr is None:
            order = np.argsort(self.src, kind="stable")
            indptr = np.zeros(self.n + 1, dtype=np.int64)
            np.cumsum(np.bincount(self.src, minlength=self.n), out=indptr[1:])
            self._csr = (indptr, self.dst[order], order.astype(np.int64))
        return self._csr

    def csc(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """In-adjacency: (indptr[n+1], indices[m]=src sorted by dst, eid[m])."""
        if self._csc is None:
            order = np.argsort(self.dst, kind="stable")
            indptr = np.zeros(self.n + 1, dtype=np.int64)
            np.cumsum(np.bincount(self.dst, minlength=self.n), out=indptr[1:])
            self._csc = (indptr, self.src[order], order.astype(np.int64))
        return self._csc

    def out_neighbors(self, v: int) -> np.ndarray:
        indptr, idx, _ = self.csr()
        return idx[indptr[v]:indptr[v + 1]]

    def in_neighbors(self, v: int) -> np.ndarray:
        indptr, idx, _ = self.csc()
        return idx[indptr[v]:indptr[v + 1]]

    # ------------------------------------------------------------ transforms
    def relabel(self, rank: np.ndarray) -> "Graph":
        """Relabel vertices so vertex v gets id rank[v] (its ordinal number).

        After relabeling, processing vertices 0..n-1 in id order realizes the
        processing order encoded by `rank`.
        """
        rank = np.asarray(rank)
        if rank.shape != (self.n,):
            raise ValueError("rank must have shape (n,)")
        check_permutation(rank, self.n)
        w = None if self.w is None else self.w.copy()
        return Graph(self.n, rank[self.src], rank[self.dst], w)

    def subgraph(self, vertices: np.ndarray) -> tuple["Graph", np.ndarray]:
        """Induced subgraph. Returns (sub, mapping old_id array of len n_sub)."""
        vertices = np.asarray(vertices, dtype=np.int32)
        mask = np.zeros(self.n, dtype=bool)
        mask[vertices] = True
        keep = mask[self.src] & mask[self.dst]
        new_id = -np.ones(self.n, dtype=np.int32)
        new_id[vertices] = np.arange(len(vertices), dtype=np.int32)
        w = None if self.w is None else self.w[keep]
        sub = Graph(len(vertices), new_id[self.src[keep]], new_id[self.dst[keep]], w)
        return sub, vertices

    def reverse(self) -> "Graph":
        w = None if self.w is None else self.w.copy()
        return Graph(self.n, self.dst.copy(), self.src.copy(), w)

    def undirected_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Symmetrized, deduped edge endpoints (for community detection)."""
        a = np.minimum(self.src, self.dst)
        b = np.maximum(self.src, self.dst)
        key = a.astype(np.int64) * self.n + b
        _, first = np.unique(key, return_index=True)
        return a[first], b[first]

    def __repr__(self) -> str:  # compact: the dataclass repr would dump arrays
        return f"Graph(n={self.n}, m={self.m}, weighted={self.w is not None})"


def check_permutation(rank: np.ndarray, n: int) -> None:
    seen = np.zeros(n, dtype=bool)
    seen[rank] = True
    if not seen.all():
        raise ValueError("rank is not a permutation of 0..n-1")


def order_to_rank(order: np.ndarray) -> np.ndarray:
    """order[i] = vertex processed i-th  ->  rank[v] = position of v."""
    order = np.asarray(order)
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order), dtype=order.dtype)
    return rank


def rank_to_order(rank: np.ndarray) -> np.ndarray:
    return order_to_rank(rank)  # involution
