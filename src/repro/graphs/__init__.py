from repro.graphs.graph import Graph
from repro.graphs import generators, io, blocked

__all__ = ["Graph", "generators", "io", "blocked"]
