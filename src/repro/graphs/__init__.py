from repro.graphs.graph import Graph
from repro.graphs.delta import GraphDelta, random_delta
from repro.graphs import generators, io, blocked

__all__ = ["Graph", "GraphDelta", "random_delta", "generators", "io", "blocked"]
