"""Block Gauss–Seidel engine — the TPU adaptation of the paper's async mode.

The paper's Eq. 2 updates vertices one at a time in processing order, each
consuming neighbors already updated *this* round. A per-vertex sequential
sweep is degenerate on TPU, so we process the order in contiguous *blocks*
(DESIGN.md §3): blocks run sequentially inside one sweep, each block update
gathers the *current* state vector — blocks earlier in the order therefore
contribute this-round values (positive edges at block granularity), later
blocks contribute previous-round values, exactly Eq. 2 lifted to tiles.

`inner > 1` re-runs each block update against the refreshed state, making
intra-block edges fresh too (local Gauss–Seidel refinement); `inner=1` is the
plain blocked sweep. The engine assumes the algorithm instance has already
been relabeled with the processing order (``AlgoInstance.relabel``), so block
b covers ordinals [b*bs, (b+1)*bs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import jax_ops as J
from repro.graphs.blocked import pack_in_edges, padded_n
from repro.graphs.graph import Graph


def _pack(algo: AlgoInstance, bs: int):
    g = Graph(algo.n, algo.src, algo.dst, algo.w)
    be = pack_in_edges(g, bs)
    npad = padded_n(algo.n, bs)

    def pad(a, fill):
        out = np.full((npad,), fill, dtype=a.dtype)
        out[: algo.n] = a
        return out

    x0 = pad(algo.x0, algo.semiring.identity)
    c = pad(algo.c, 0.0 if algo.combine == "replace" else algo.c.dtype.type(algo.semiring.identity))
    fixed = np.zeros(npad, bool)
    fixed[: algo.n] = algo.fixed
    fixed[algo.n:] = True  # padding vertices never move
    return be, x0, c, fixed, npad


@partial(
    jax.jit,
    static_argnames=(
        "bs", "nb", "sem_reduce", "sem_edge", "comb", "res_kind",
        "max_iters", "inner", "n_real",
    ),
)
def _run(
    esrc, edst, ew, emask, x0, c, fixed,
    bs: int, nb: int, n_real: int,
    sem_reduce: str, sem_edge: str, comb: str, res_kind: str,
    eps: float, max_iters: int, identity: float, inner: int,
):
    c_blk = c.reshape(nb, bs)
    fixed_blk = fixed.reshape(nb, bs)
    x0_blk = x0.reshape(nb, bs)
    res_buf = jnp.zeros((max_iters,), jnp.float32)
    sum_buf = jnp.zeros((max_iters,), jnp.float32)
    real_mask = (jnp.arange(nb * bs) < n_real)

    def block_update(i, x):
        srcs = esrc[i]
        msgs = J.edge_op(sem_edge, x[srcs], ew[i])
        msgs = jnp.where(emask[i], msgs, identity)
        agg = J.segment_reduce(sem_reduce, msgs, edst[i], bs, identity)
        old = jax.lax.dynamic_slice(x, (i * bs,), (bs,))
        new = J.combine(comb, agg, c_blk[i], old, fixed_blk[i], x0_blk[i])
        return jax.lax.dynamic_update_slice(x, new, (i * bs,))

    def block_body(i, x):
        def one(_, xx):
            return block_update(i, xx)
        return jax.lax.fori_loop(0, inner, one, x)

    def sweep(x):
        return jax.lax.fori_loop(0, nb, block_body, x)

    def cond(state):
        _, k, res, _, _ = state
        return jnp.logical_and(k < max_iters, res > eps)

    def body(state):
        x, k, _, res_buf, sum_buf = state
        x_new = sweep(x)
        res = J.residual(res_kind, jnp.where(real_mask, x_new, 0), jnp.where(real_mask, x, 0))
        res_buf = res_buf.at[k].set(res)
        sum_buf = sum_buf.at[k].set(
            jnp.sum(jnp.where(real_mask & (jnp.abs(x_new) < 1e30), x_new, 0.0))
        )
        return x_new, k + 1, res, res_buf, sum_buf

    init = (x0, jnp.int32(0), jnp.float32(jnp.inf), res_buf, sum_buf)
    x, k, res, res_buf, sum_buf = jax.lax.while_loop(cond, body, init)
    return x, k, res, res_buf, sum_buf


def run_async_block(
    algo: AlgoInstance, bs: int = 256, max_iters: int = 2000, inner: int = 1,
    x_init: np.ndarray | None = None,
) -> RunResult:
    """x_init: resume from a previous state (checkpointed macro-stepping)."""
    be, x0, c, fixed, npad = _pack(algo, bs)
    x_start = x0
    if x_init is not None:
        x_start = x0.copy()
        x_start[: algo.n] = x_init
    x, k, res, res_buf, sum_buf = _run(
        jnp.asarray(be.esrc), jnp.asarray(be.edst), jnp.asarray(be.ew),
        jnp.asarray(be.emask), jnp.asarray(x_start), jnp.asarray(c), jnp.asarray(fixed),
        bs=bs, nb=be.nb, n_real=algo.n,
        sem_reduce=algo.semiring.reduce,
        sem_edge=algo.semiring.edge_op,
        comb=algo.combine,
        res_kind=algo.residual,
        eps=algo.eps,
        max_iters=max_iters,
        identity=algo.semiring.identity,
        inner=inner,
    )
    k = int(k)
    return RunResult(
        x=np.asarray(x)[: algo.n],
        rounds=k,
        converged=bool(res <= algo.eps),
        residuals=np.asarray(res_buf)[:k],
        state_sums=np.asarray(sum_buf)[:k],
    )
