"""Block Gauss–Seidel engine — the TPU adaptation of the paper's async mode.

The paper's Eq. 2 updates vertices one at a time in processing order, each
consuming neighbors already updated *this* round. A per-vertex sequential
sweep is degenerate on TPU, so we process the order in contiguous *blocks*
(DESIGN.md §3): blocks run sequentially inside one sweep, each block update
gathers the *current* state matrix — blocks earlier in the order therefore
contribute this-round values (positive edges at block granularity), later
blocks contribute previous-round values, exactly Eq. 2 lifted to tiles.

States are batched ``f32[n, d]``: column j is an independent query
(personalized-PageRank seed, SSSP source, ...) riding the same sweep, with
per-column convergence freezing in the shared round driver
(`engine.harness.loop`) so each query keeps its scalar round count and final
state. ``d = 1`` reproduces the scalar engine exactly.

`inner > 1` re-runs each block update against the refreshed state, making
intra-block edges fresh too (local Gauss–Seidel refinement); `inner=1` is the
plain blocked sweep. The engine assumes the algorithm instance has already
been relabeled with the processing order (``AlgoInstance.relabel``), so block
b covers ordinals [b*bs, (b+1)*bs).

``backend="pallas"`` runs sweeps through the fused `kernels.gs_sweep` Pallas
kernel (ragged flat-BSR tiles; interpret mode off-TPU) instead of the
pure-JAX gather/segment-reduce sweep. With ``sweeps_per_call=1`` (default)
each sweep is its own kernel launch and the per-sweep driver
(`harness.loop`) keeps the exact per-column freezing semantics; with
``sweeps_per_call=R > 1`` the persistent multi-sweep megakernel executes up
to R sweeps per launch with in-kernel convergence, early-out, and
active-frontier block skipping, and the host checks convergence once per
batch (`harness.sweep_batched_loop`). ``frontier`` optionally seeds the
dirty bitmap from a vertex mask (warm starts whose untouched blocks are
already self-consistent — see `engine.incremental`).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import harness
from repro.engine import jax_ops as J
from repro.obs.trace import tspan


@partial(
    jax.jit,
    static_argnames=(
        "bs", "nb", "sem_reduce", "sem_edge", "comb", "res_kind",
        "max_iters", "inner", "n_real", "extrapolate_every",
    ),
)
def _run(
    esrc, edst, ew, emask, x_start, x0, c, fixed,
    bs: int, nb: int, n_real: int,
    sem_reduce: str, sem_edge: str, comb: str, res_kind: str,
    eps: float, max_iters: int, identity: float, inner: int,
    extrapolate_every: int,
):
    d = x0.shape[1]
    c_blk = c.reshape(nb, bs, d)
    fixed_blk = fixed.reshape(nb, bs, d)
    x0_blk = x0.reshape(nb, bs, d)  # pin source stays x0 even when warm-started
    real_mask = (jnp.arange(nb * bs) < n_real)

    def block_update(i, x):
        srcs = esrc[i]
        msgs = J.edge_op(sem_edge, x[srcs], ew[i])
        msgs = jnp.where(emask[i][:, None], msgs, identity)
        agg = J.segment_reduce(sem_reduce, msgs, edst[i], bs, identity)
        old = jax.lax.dynamic_slice(x, (i * bs, 0), (bs, d))
        new = J.combine(comb, agg, c_blk[i], old, fixed_blk[i], x0_blk[i])
        return jax.lax.dynamic_update_slice(x, new, (i * bs, 0))

    def block_body(i, x):
        def one(_, xx):
            return block_update(i, xx)
        return jax.lax.fori_loop(0, inner, one, x)

    def sweep(x):
        return jax.lax.fori_loop(0, nb, block_body, x)

    return harness.loop(
        sweep, x_start, res_kind=res_kind, eps=eps, max_iters=max_iters,
        real_mask=real_mask, extrapolate_every=extrapolate_every,
    )


@partial(
    jax.jit,
    static_argnames=("semiring", "combine", "bs", "res_kind", "max_iters",
                     "n_real", "interpret", "extrapolate_every"),
)
def _run_pallas(
    rowptr, tilecols, tiles, c, x0, fixed, x_start,
    semiring: str, combine: str, bs: int, n_real: int,
    res_kind: str, eps: float, max_iters: int, interpret: bool,
    extrapolate_every: int,
):
    from repro.kernels.gs_sweep import gs_sweep_pallas

    real_mask = (jnp.arange(x0.shape[0]) < n_real)

    def sweep(x):
        return gs_sweep_pallas(
            rowptr, tilecols, tiles, c, x0, fixed, x,
            semiring=semiring, combine=combine, bs=bs, interpret=interpret,
        )

    return harness.loop(
        sweep, x_start, res_kind=res_kind, eps=eps, max_iters=max_iters,
        real_mask=real_mask, extrapolate_every=extrapolate_every,
    )


def _solve(algo: AlgoInstance, o) -> RunResult:
    """Engine body behind ``solve(algo, engine="async_block", ...)``; options
    are already validated (`engine.api.validate_options`)."""
    if o.backend == "pallas":
        return _run_async_block_pallas(
            algo, o.bs, o.max_iters, o.inner, o.x_init,
            extrapolate_every=o.extrapolate_every,
            sweeps_per_call=o.sweeps_per_call, frontier=o.frontier,
            tracer=o.trace,
        )
    with tspan(o.trace, "pack", algo=algo.name, n=algo.n, d=algo.d, bs=o.bs):
        be, x0, c, fixed, npad = harness.pack(algo, o.bs)
    x_start = harness.init_state(x0, o.x_init, algo.n)
    out = _run(
        jnp.asarray(be.esrc), jnp.asarray(be.edst), jnp.asarray(be.ew),
        jnp.asarray(be.emask), jnp.asarray(x_start), jnp.asarray(x0),
        jnp.asarray(c), jnp.asarray(fixed),
        bs=o.bs, nb=be.nb, n_real=algo.n,
        sem_reduce=algo.semiring.reduce,
        sem_edge=algo.semiring.edge_op,
        comb=algo.combine,
        res_kind=algo.residual,
        eps=algo.eps,
        max_iters=o.max_iters,
        identity=algo.semiring.identity,
        inner=o.inner,
        extrapolate_every=o.extrapolate_every,
    )
    return harness.finalize(algo, *out)


def run_async_block(
    algo: AlgoInstance, bs: int = 256, max_iters: int = 2000, inner: int = 1,
    x_init: np.ndarray | None = None, backend: str = "jax",
    extrapolate_every: int = 0, sweeps_per_call: int = 1,
    frontier: np.ndarray | None = None,
) -> RunResult:
    """Thin shim over ``solve(algo, engine="async_block")`` — the legacy
    keyword spelling, parity-tested bitwise against `engine.api.solve`.

    x_init: resume from a previous state (checkpointed macro-stepping or
    the incremental serving engine's warm starts).

    backend: "jax" (gather/segment-reduce sweep) or "pallas" (fused
    `gs_sweep` kernel per sweep over the ragged flat-BSR layout; interpret
    mode off-TPU; sum/min/max semirings — see kernels/gs_sweep._SUPPORTED).

    extrapolate_every: Aitken acceleration period for linear (sum-semiring)
    systems; 0 = off (see `harness.loop`).

    sweeps_per_call (pallas backend): sweeps batched into one persistent
    megakernel launch; >1 trades per-sweep host convergence checks (and
    per-column state freezing — see `harness.sweep_batched_loop`) for one
    check per batch plus in-kernel early-out and frontier skipping.

    frontier (pallas backend, bool[n]): vertex-level dirty seed for the
    megakernel's active-frontier path. A vertex outside the frontier claims
    its block's state already satisfies its update equation; None = all
    dirty (the only safe cold-start value).
    """
    from repro.engine.api import EngineOptions, solve

    return solve(algo, engine="async_block", options=EngineOptions(
        x_init=x_init, extrapolate_every=extrapolate_every, backend=backend,
        bs=bs, inner=inner, sweeps_per_call=sweeps_per_call,
        frontier=frontier, max_iters=max_iters,
    ))


def _run_async_block_pallas(
    algo, bs, max_iters, inner, x_init, interpret=None, extrapolate_every=0,
    sweeps_per_call=1, frontier=None, tracer=None,
) -> RunResult:
    from repro.engine.api import EngineOptions, validate_options
    from repro.kernels.ops import _auto_interpret, pack_algorithm

    # also reachable through the kernels.ops back-compat shim, which skips
    # solve(); route its options through the same single validation pass
    validate_options("async_block", EngineOptions(
        x_init=x_init, extrapolate_every=extrapolate_every, backend="pallas",
        bs=bs, inner=inner, sweeps_per_call=sweeps_per_call,
        frontier=frontier, max_iters=max_iters, trace=tracer,
    ), algo)
    with tspan(tracer, "pack", algo=algo.name, n=algo.n, d=algo.d, bs=bs):
        ops = pack_algorithm(algo, bs)
    x_start = harness.init_state(ops["x0_host"], x_init, algo.n)
    if sweeps_per_call == 1 and frontier is None:
        out = _run_pallas(
            ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"], ops["x0"],
            ops["fixed"], jnp.asarray(x_start),
            semiring=ops["semiring"], combine=ops["combine"], bs=bs,
            n_real=algo.n, res_kind=algo.residual, eps=algo.eps,
            max_iters=max_iters, interpret=_auto_interpret(interpret),
            extrapolate_every=extrapolate_every,
        )
        return harness.finalize(algo, *out)
    from repro.graphs.blocked import frontier_blocks
    from repro.kernels.gs_sweep import gs_multisweep_pallas

    nb = int(ops["rowptr"].shape[0]) - 1
    dirty0 = jnp.asarray(frontier_blocks(frontier, algo.n, bs))
    interp = _auto_interpret(interpret)

    def batch_fn(x, dirty):
        return gs_multisweep_pallas(
            ops["rowptr"], ops["tilecols"], ops["revptr"], ops["revrows"],
            dirty, ops["tiles"], ops["c"], ops["x0"], ops["fixed"], x,
            semiring=ops["semiring"], combine=ops["combine"],
            res_kind=algo.residual, bs=bs, sweeps=sweeps_per_call,
            eps=float(algo.eps), interpret=interp,
        )

    real_mask = np.arange(x_start.shape[0]) < algo.n
    out = harness.sweep_batched_loop(
        batch_fn, jnp.asarray(x_start), dirty0,
        eps=algo.eps, max_iters=max_iters, sweeps=sweeps_per_call, nb=nb,
        real_mask=real_mask, tracer=tracer,
    )
    res = harness.finalize(algo, *out[:6])
    res.active_block_fraction = out[6]
    # replace finalize's column-granular trace with the megakernel's finer
    # block-granular work accounting (the frontier-skipping bill)
    from repro.obs.telemetry import trace_from_block_activity

    res.convergence_trace = trace_from_block_activity(
        res.residuals, out[6], rounds=res.rounds, nb=nb, bs=bs, d=algo.d,
    )
    return res


@dataclasses.dataclass
class BatchReport:
    """Outcome of one bounded-round session batch (host-side, per column)."""

    rounds: int                # rounds the batch actually executed
    col_done: np.ndarray       # bool[d]  — converged within THIS batch
    col_rounds: np.ndarray     # int32[d] — rounds each column was active


class AsyncBlockSession:
    """Pre-packed block-GS runner for repeated bounded-round batches over a
    resident ``f32[npad, d]`` state — the engine side of continuous batching.

    `run_async_block` packs, converges, unpacks — one query batch per call.
    A serving event loop (`repro.serving`) instead keeps *one* state matrix
    resident across many short batches, swapping finished query columns out
    and queued queries in between batches. This session packs the family's
    edge structure **once**; each :meth:`run_batch` drives up to
    ``max_iters`` rounds through the shared round driver (per-column
    convergence freezing included), and :meth:`swap_in` performs the
    mid-run column re-init (`harness.swap_in_column`): newcomer ``x0 / c /
    fixed`` written into the packed operand columns, resident state column
    reset to the newcomer's start.

    The session owns the *cumulative* per-column accounting
    (``col_done`` / ``col_rounds``, folded from every batch's report):
    :meth:`swap_in` inverts it for exactly the swapped column
    (`convergence.reinit_columns`), so ``col_rounds[j]`` always reads the
    rounds the slot's **current** query has consumed since its swap-in —
    the number the serving layer bills to its ticket.

    The session is **device-resident**: the packed state matrix, the operand
    matrices (``x0``/``c``/``fixed``), the dirty-block bitmap, and the
    cumulative per-column accounting all live as jax arrays for the
    session's whole life. Batches chain device-to-device (the next batch
    consumes the previous batch's output buffer), swaps are jitted
    functional column updates with a traced slot index
    (`harness.swap_in_column_device` — only the newcomer's three length-n
    vectors transfer H2D), and the only host transfers are the tiny
    ``(d,)`` per-batch report and whatever the serving layer reads at
    ticket resolution via :attr:`state`.

    Backends mirror `solve`: ``"jax"`` (gather/segment-reduce sweep),
    ``"pallas"`` (fused flat-BSR kernel), and ``"distributed"`` (the
    shard_map superstep of `engine.distributed.DistContext`, for families
    whose resident state spans devices; ``mesh``/``axis`` select the
    device mesh). With ``sweeps_per_call > 1`` the persistent megakernel
    runs and the dirty-block frontier bitmap is carried across batches
    *and* swaps: a swapped-in column ORs exactly its support blocks into
    the bitmap (`kernels.gs_sweep.or_dirty_blocks`), so the kernel only
    re-touches what the newcomer needs while blocks clean for every
    in-flight column stay skipped.

    A column's trajectory from swap-in to convergence is exactly what a
    solo `run_async_block` of that query produces: sweeps act columnwise
    independently and batch boundaries are invisible (`harness.loop` keeps
    an active column's post-sweep state, a converging column's pre-sweep
    state). Min/max-semiring columns match a solo run bitwise; sum columns
    to eps under ``sweeps_per_call > 1`` (no mid-batch freezing — see
    `harness.sweep_batched_loop`).
    """

    def __init__(
        self, algo: AlgoInstance, bs: int = 256, inner: int = 1,
        backend: str = "jax", sweeps_per_call: int = 1,
        interpret: bool | None = None, mesh=None, axis: str = "data",
        trace=None, trace_attrs: dict | None = None,
    ):
        from repro.engine.api import EngineOptions, validate_options

        engine = "distributed" if backend == "distributed" else "async_block"
        validate_options(engine, EngineOptions(
            backend="jax" if backend == "distributed" else backend,
            bs=bs, inner=inner, sweeps_per_call=sweeps_per_call,
            mesh=mesh, axis=axis, trace=trace,
        ), algo)
        self.algo = algo
        self.bs = bs
        self.inner = inner
        self.backend = backend
        self.sweeps_per_call = sweeps_per_call
        self.n = algo.n
        self.d = algo.d
        # span tracer + constant attributes (tenant / family / graph_version)
        # the serving layer stamps on every span this session emits
        self.trace = trace
        self.trace_attrs = dict(trace_attrs or {})
        pack_span = tspan(trace, "pack", algo=algo.name, n=algo.n, d=algo.d,
                          bs=bs, backend=backend, **self.trace_attrs)
        if backend == "jax":
            with pack_span:
                be, x0, c, fixed, _ = harness.pack(algo, bs)
            self.nb = be.nb
            self._edges = tuple(
                jnp.asarray(a) for a in (be.esrc, be.edst, be.ew, be.emask)
            )
            self.x0 = jnp.asarray(x0)
            self.c = jnp.asarray(c)
            self.fixed = jnp.asarray(fixed)
        elif backend == "distributed":
            from repro.engine.distributed import DistContext

            with pack_span:
                self._dist = DistContext(algo, bs, mesh=mesh, axis=axis,
                                         inner=inner)
            self.nb = self._dist.nb
            self.x0 = jnp.asarray(self._dist.x0)
            self.c = jnp.asarray(self._dist.c)
            self.fixed = jnp.asarray(self._dist.fixed)
        else:
            from repro.kernels.ops import _auto_interpret, pack_algorithm

            with pack_span:
                ops = pack_algorithm(algo, bs)
            self._ops = ops
            self._interpret = _auto_interpret(interpret)
            self.nb = int(ops["rowptr"].shape[0]) - 1
            self.x0 = ops["x0"]
            self.c = ops["c"]
            self.fixed = ops["fixed"]
            # cold start: every block dirty (the only safe default; swaps
            # and batches keep the bitmap faithful from here on)
            self.dirty = jnp.ones(self.nb, jnp.int32)
        # the resident state: a device buffer distinct from x0 (the pallas
        # kernels donate/alias their state input — x0 must survive swaps)
        self.x = jnp.array(self.x0, copy=True)
        # cumulative per-column accounting across batches; swap_in inverts
        # it for exactly the swapped column (convergence.reinit_columns)
        self.col_done = jnp.zeros(self.d, bool)
        self.col_rounds = jnp.zeros(self.d, jnp.int32)

    @property
    def state(self):
        """The resident (n, d) state, padding rows stripped.

        A device jax array — the serving layer transfers it to host only at
        ticket resolution (`GraphServer._resolve`), never between batches.
        """
        return self.x[: self.n]

    def load_state_column(self, j: int, col) -> None:
        """Overwrite state column ``j`` rows ``< n`` (delta-rebuild carry).

        The serving layer rebuilds a family on a mutated graph and carries
        each in-flight query's warm state into the fresh session; padding
        rows keep their pinned fills. Functional device update — rare path
        (once per family per delta), so no jit wrapper.
        """
        col = jnp.asarray(col, jnp.float32).reshape(-1)
        self.x = self.x.at[: self.n, j].set(col)

    def set_col_rounds(self, j: int, rounds: int) -> None:
        """Seed column ``j``'s cumulative round count (delta-rebuild carry)."""
        self.col_rounds = self.col_rounds.at[j].set(int(rounds))

    def swap_in(self, j: int, q_x0, q_c, q_fixed) -> None:
        """Install a new query into column ``j`` (between batches)."""
        from repro.engine.convergence import reinit_columns

        self.col_done, self.col_rounds = reinit_columns(
            self.col_done, self.col_rounds, [j]
        )
        q_x0, q_c = np.asarray(q_x0), np.asarray(q_c)
        q_fixed = np.asarray(q_fixed).astype(bool)
        self.x, self.x0, self.c, self.fixed = harness.swap_in_column_device(
            self.x, self.x0, self.c, self.fixed, j, self.n, q_x0, q_c,
            q_fixed,  # cast to the operands' dtype (f32 pinned=1.0 on pallas)
            x0_fill=self.algo.semiring.identity,
            c_fill=self.algo.c_pad_fill,
        )
        if self.backend == "pallas" and self.sweeps_per_call > 1:
            from repro.kernels.gs_sweep import or_dirty_blocks

            support = harness.column_support(
                q_x0, q_c, q_fixed,
                reduce=self.algo.semiring.reduce,
                c_fill=self.algo.c_pad_fill,
            )
            # seed the support vertices AND everything their out-edges feed:
            # an injected seed (e.g. the SSSP source) can already satisfy its
            # own update equation, in which case its block never *changes*
            # and would never re-mark dependents — the newcomer's frontier
            # must start at the first vertices whose equations the injection
            # invalidates, exactly the depth-1 out-closure of the support.
            from repro.graphs.delta import out_closure

            touched = out_closure(
                self.algo.src, self.algo.dst, support, self.n, depth=1
            )
            self.dirty = or_dirty_blocks(self.dirty, touched, self.n, self.bs)

    def run_batch(self, max_iters: int) -> BatchReport:
        """Advance every column up to ``max_iters`` rounds; converged
        columns freeze (jax / single-sweep pallas) and the batch stops early
        once all columns are done. Updates the resident state in place.

        With a tracer attached (``trace=`` at construction) the batch is
        wrapped in a ``batch`` span carrying the session's constant
        attributes plus this batch's round count and per-round residuals —
        the residual buffer rides the *same* per-batch ``device_get`` as the
        convergence report, so tracing never adds a sync point.
        """
        with tspan(self.trace, "batch", backend=self.backend,
                   max_iters=max_iters, **self.trace_attrs) as sp:
            return self._run_batch_inner(max_iters, sp)

    def _run_batch_inner(self, max_iters: int, sp) -> BatchReport:
        a = self.algo
        if max_iters % self.sweeps_per_call:
            # the megakernel always executes sweeps_per_call sweeps per
            # launch; a non-multiple budget would advance the state by
            # uncounted sweeps and desynchronize per-column round accounting
            raise ValueError(
                f"max_iters={max_iters} must be a multiple of "
                f"sweeps_per_call={self.sweeps_per_call}"
            )
        if self.backend == "jax":
            out = _run(
                *self._edges, self.x, self.x0, self.c, self.fixed,
                bs=self.bs, nb=self.nb, n_real=self.n,
                sem_reduce=a.semiring.reduce, sem_edge=a.semiring.edge_op,
                comb=a.combine, res_kind=a.residual, eps=a.eps,
                max_iters=max_iters, identity=a.semiring.identity,
                inner=self.inner, extrapolate_every=0,
            )
        elif self.backend == "distributed":
            out = self._dist.run(
                self.x, self.x0, self.c, self.fixed, max_iters=max_iters,
            )
        elif self.sweeps_per_call == 1:
            ops = self._ops
            out = _run_pallas(
                ops["rowptr"], ops["tilecols"], ops["tiles"],
                self.c, self.x0, self.fixed, self.x,
                semiring=ops["semiring"], combine=ops["combine"], bs=self.bs,
                n_real=self.n, res_kind=a.residual, eps=a.eps,
                max_iters=max_iters, interpret=self._interpret,
                extrapolate_every=0,
            )
        else:
            from repro.kernels.gs_sweep import gs_multisweep_pallas

            ops = self._ops
            c_dev, x0_dev, fx_dev = self.c, self.x0, self.fixed

            def batch_fn(x, dirty):
                return gs_multisweep_pallas(
                    ops["rowptr"], ops["tilecols"], ops["revptr"],
                    ops["revrows"], dirty, ops["tiles"], c_dev, x0_dev,
                    fx_dev, x,
                    semiring=ops["semiring"], combine=ops["combine"],
                    res_kind=a.residual, bs=self.bs,
                    sweeps=self.sweeps_per_call, eps=float(a.eps),
                    interpret=self._interpret,
                )

            real_mask = np.arange(self.x.shape[0]) < self.n
            out = harness.sweep_batched_loop(
                batch_fn, self.x, self.dirty,
                eps=a.eps, max_iters=max_iters, sweeps=self.sweeps_per_call,
                nb=self.nb, real_mask=real_mask, tracer=self.trace,
            )
            self.dirty = out[7]  # device bitmap carried into the next batch
        # the state never leaves the device: the next batch (and any swap)
        # consumes this output buffer directly
        self.x = out[0]
        if self.trace is not None and self.trace.enabled:
            # traced: the per-round residual buffer joins the SAME transfer
            # (out[4] is already host numpy on the megakernel path and
            # passes through device_get untouched)
            rounds, col_done, col_rounds, res_buf = jax.device_get(
                (out[1], out[2], out[3], out[4])
            )  # repro: allow-host-sync(per-batch convergence report for the caller)
            rounds = int(rounds)
            sp.set(rounds=rounds,
                   res=[float(v) for v in np.asarray(res_buf)[:rounds]])
        else:
            rounds, col_done, col_rounds = jax.device_get(
                (out[1], out[2], out[3])
            )  # repro: allow-host-sync(per-batch convergence report for the caller)
        rep = BatchReport(
            rounds=int(rounds),
            col_done=np.asarray(col_done),
            col_rounds=np.asarray(col_rounds, np.int32),
        )
        # fold into the cumulative device-side accounting: columns already
        # done before this batch only re-verified (their 1-round report is
        # not progress)
        still_active = ~self.col_done
        self.col_rounds = self.col_rounds + jnp.where(
            still_active, jnp.asarray(rep.col_rounds), 0
        )
        self.col_done = self.col_done | jnp.asarray(rep.col_done)
        return rep
