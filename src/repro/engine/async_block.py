"""Block Gauss–Seidel engine — the TPU adaptation of the paper's async mode.

The paper's Eq. 2 updates vertices one at a time in processing order, each
consuming neighbors already updated *this* round. A per-vertex sequential
sweep is degenerate on TPU, so we process the order in contiguous *blocks*
(DESIGN.md §3): blocks run sequentially inside one sweep, each block update
gathers the *current* state matrix — blocks earlier in the order therefore
contribute this-round values (positive edges at block granularity), later
blocks contribute previous-round values, exactly Eq. 2 lifted to tiles.

States are batched ``f32[n, d]``: column j is an independent query
(personalized-PageRank seed, SSSP source, ...) riding the same sweep, with
per-column convergence freezing in the shared round driver
(`engine.harness.loop`) so each query keeps its scalar round count and final
state. ``d = 1`` reproduces the scalar engine exactly.

`inner > 1` re-runs each block update against the refreshed state, making
intra-block edges fresh too (local Gauss–Seidel refinement); `inner=1` is the
plain blocked sweep. The engine assumes the algorithm instance has already
been relabeled with the processing order (``AlgoInstance.relabel``), so block
b covers ordinals [b*bs, (b+1)*bs).

``backend="pallas"`` runs sweeps through the fused `kernels.gs_sweep` Pallas
kernel (ragged flat-BSR tiles; interpret mode off-TPU) instead of the
pure-JAX gather/segment-reduce sweep. With ``sweeps_per_call=1`` (default)
each sweep is its own kernel launch and the per-sweep driver
(`harness.loop`) keeps the exact per-column freezing semantics; with
``sweeps_per_call=R > 1`` the persistent multi-sweep megakernel executes up
to R sweeps per launch with in-kernel convergence, early-out, and
active-frontier block skipping, and the host checks convergence once per
batch (`harness.sweep_batched_loop`). ``frontier`` optionally seeds the
dirty bitmap from a vertex mask (warm starts whose untouched blocks are
already self-consistent — see `engine.incremental`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import harness
from repro.engine import jax_ops as J


@partial(
    jax.jit,
    static_argnames=(
        "bs", "nb", "sem_reduce", "sem_edge", "comb", "res_kind",
        "max_iters", "inner", "n_real", "extrapolate_every",
    ),
)
def _run(
    esrc, edst, ew, emask, x_start, x0, c, fixed,
    bs: int, nb: int, n_real: int,
    sem_reduce: str, sem_edge: str, comb: str, res_kind: str,
    eps: float, max_iters: int, identity: float, inner: int,
    extrapolate_every: int,
):
    d = x0.shape[1]
    c_blk = c.reshape(nb, bs, d)
    fixed_blk = fixed.reshape(nb, bs, d)
    x0_blk = x0.reshape(nb, bs, d)  # pin source stays x0 even when warm-started
    real_mask = (jnp.arange(nb * bs) < n_real)

    def block_update(i, x):
        srcs = esrc[i]
        msgs = J.edge_op(sem_edge, x[srcs], ew[i])
        msgs = jnp.where(emask[i][:, None], msgs, identity)
        agg = J.segment_reduce(sem_reduce, msgs, edst[i], bs, identity)
        old = jax.lax.dynamic_slice(x, (i * bs, 0), (bs, d))
        new = J.combine(comb, agg, c_blk[i], old, fixed_blk[i], x0_blk[i])
        return jax.lax.dynamic_update_slice(x, new, (i * bs, 0))

    def block_body(i, x):
        def one(_, xx):
            return block_update(i, xx)
        return jax.lax.fori_loop(0, inner, one, x)

    def sweep(x):
        return jax.lax.fori_loop(0, nb, block_body, x)

    return harness.loop(
        sweep, x_start, res_kind=res_kind, eps=eps, max_iters=max_iters,
        real_mask=real_mask, extrapolate_every=extrapolate_every,
    )


@partial(
    jax.jit,
    static_argnames=("semiring", "combine", "bs", "res_kind", "max_iters",
                     "n_real", "interpret", "extrapolate_every"),
)
def _run_pallas(
    rowptr, tilecols, tiles, c, x0, fixed, x_start,
    semiring: str, combine: str, bs: int, n_real: int,
    res_kind: str, eps: float, max_iters: int, interpret: bool,
    extrapolate_every: int,
):
    from repro.kernels.gs_sweep import gs_sweep_pallas

    real_mask = (jnp.arange(x0.shape[0]) < n_real)

    def sweep(x):
        return gs_sweep_pallas(
            rowptr, tilecols, tiles, c, x0, fixed, x,
            semiring=semiring, combine=combine, bs=bs, interpret=interpret,
        )

    return harness.loop(
        sweep, x_start, res_kind=res_kind, eps=eps, max_iters=max_iters,
        real_mask=real_mask, extrapolate_every=extrapolate_every,
    )


def run_async_block(
    algo: AlgoInstance, bs: int = 256, max_iters: int = 2000, inner: int = 1,
    x_init: np.ndarray | None = None, backend: str = "jax",
    extrapolate_every: int = 0, sweeps_per_call: int = 1,
    frontier: np.ndarray | None = None,
) -> RunResult:
    """x_init: resume from a previous state (checkpointed macro-stepping or
    the incremental serving engine's warm starts).

    backend: "jax" (gather/segment-reduce sweep) or "pallas" (fused
    `gs_sweep` kernel per sweep over the ragged flat-BSR layout; interpret
    mode off-TPU; sum/min/max semirings — see kernels/gs_sweep._SUPPORTED).

    extrapolate_every: Aitken acceleration period for linear (sum-semiring)
    systems; 0 = off (see `harness.loop`).

    sweeps_per_call (pallas backend): sweeps batched into one persistent
    megakernel launch; >1 trades per-sweep host convergence checks (and
    per-column state freezing — see `harness.sweep_batched_loop`) for one
    check per batch plus in-kernel early-out and frontier skipping.

    frontier (pallas backend, bool[n]): vertex-level dirty seed for the
    megakernel's active-frontier path. A vertex outside the frontier claims
    its block's state already satisfies its update equation; None = all
    dirty (the only safe cold-start value).
    """
    harness.check_extrapolation(algo, extrapolate_every)
    if backend == "pallas":
        return _run_async_block_pallas(
            algo, bs, max_iters, inner, x_init,
            extrapolate_every=extrapolate_every,
            sweeps_per_call=sweeps_per_call, frontier=frontier,
        )
    if backend != "jax":
        raise ValueError(f"unknown backend {backend!r}")
    if sweeps_per_call != 1 or frontier is not None:
        raise ValueError(
            "sweeps_per_call/frontier amortize kernel launches and DMAs — "
            "pallas-backend knobs; backend='jax' supports neither"
        )
    be, x0, c, fixed, npad = harness.pack(algo, bs)
    x_start = harness.init_state(x0, x_init, algo.n)
    out = _run(
        jnp.asarray(be.esrc), jnp.asarray(be.edst), jnp.asarray(be.ew),
        jnp.asarray(be.emask), jnp.asarray(x_start), jnp.asarray(x0),
        jnp.asarray(c), jnp.asarray(fixed),
        bs=bs, nb=be.nb, n_real=algo.n,
        sem_reduce=algo.semiring.reduce,
        sem_edge=algo.semiring.edge_op,
        comb=algo.combine,
        res_kind=algo.residual,
        eps=algo.eps,
        max_iters=max_iters,
        identity=algo.semiring.identity,
        inner=inner,
        extrapolate_every=extrapolate_every,
    )
    return harness.finalize(algo, *out)


def _run_async_block_pallas(
    algo, bs, max_iters, inner, x_init, interpret=None, extrapolate_every=0,
    sweeps_per_call=1, frontier=None,
) -> RunResult:
    from repro.kernels.ops import _auto_interpret, pack_algorithm

    if inner != 1:
        raise ValueError("backend='pallas' runs the fused sweep; inner must be 1")
    if sweeps_per_call < 1:
        raise ValueError(f"sweeps_per_call must be >= 1, got {sweeps_per_call}")
    ops = pack_algorithm(algo, bs)
    x_start = harness.init_state(np.asarray(ops["x0"]), x_init, algo.n)
    if sweeps_per_call == 1 and frontier is None:
        out = _run_pallas(
            ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"], ops["x0"],
            ops["fixed"], jnp.asarray(x_start),
            semiring=ops["semiring"], combine=ops["combine"], bs=bs,
            n_real=algo.n, res_kind=algo.residual, eps=algo.eps,
            max_iters=max_iters, interpret=_auto_interpret(interpret),
            extrapolate_every=extrapolate_every,
        )
        return harness.finalize(algo, *out)
    # sweep-batched megakernel path: host checks once per batch, so the
    # per-round Aitken bookkeeping of harness.loop has nothing to hook into
    if extrapolate_every:
        raise NotImplementedError(
            "extrapolate_every needs per-sweep host control; "
            "use sweeps_per_call=1"
        )
    from repro.graphs.blocked import frontier_blocks
    from repro.kernels.gs_sweep import gs_multisweep_pallas

    nb = int(ops["rowptr"].shape[0]) - 1
    dirty0 = jnp.asarray(frontier_blocks(frontier, algo.n, bs))
    interp = _auto_interpret(interpret)

    def batch_fn(x, dirty):
        return gs_multisweep_pallas(
            ops["rowptr"], ops["tilecols"], ops["revptr"], ops["revrows"],
            dirty, ops["tiles"], ops["c"], ops["x0"], ops["fixed"], x,
            semiring=ops["semiring"], combine=ops["combine"],
            res_kind=algo.residual, bs=bs, sweeps=sweeps_per_call,
            eps=float(algo.eps), interpret=interp,
        )

    real_mask = np.arange(x_start.shape[0]) < algo.n
    out = harness.sweep_batched_loop(
        batch_fn, jnp.asarray(x_start), dirty0,
        eps=algo.eps, max_iters=max_iters, sweeps=sweeps_per_call, nb=nb,
        real_mask=real_mask,
    )
    res = harness.finalize(algo, *out[:6])
    res.active_block_fraction = out[6]
    return res
