"""Shared packed-run harness for the iterative engines.

Every engine in this package is the same machine with a different sweep:
pack the algorithm's vertex arrays into whole blocks, then drive rounds of
``x -> sweep(x)`` until the residual drops below eps. This module holds the
two shared halves so the engines only contribute their sweep:

* :func:`pack` — the one block-padding path (previously duplicated between
  ``async_block`` and ``distributed`` with *inconsistent* padding fills for
  ``c``: min/max-semiring pads must be the reduce identity, not 0.0).

* :func:`loop` — the one round driver (previously three near-identical
  ``lax.while_loop`` bodies in sync / async_block / distributed). States are
  batched ``(n, d)`` matrices; convergence is tracked *per column*: a column
  whose residual first drops to eps is frozen (later sweeps cannot move it)
  and recorded at its own round count, so query j of a batched run finishes
  with exactly the state and round count of a scalar run of query j.

``loop`` is a plain traced function, not a jit boundary — each engine calls
it inside its own module-level ``jax.jit`` wrapper so compilation caching
keys on the engine's static config exactly as before.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import (
    RunResult,
    converge_step,
    freeze_columns,
)
from repro.engine import jax_ops as J
from repro.graphs.blocked import pack_in_edges, pad_state, padded_n
from repro.graphs.graph import Graph
from repro.obs.telemetry import trace_from_col_rounds
from repro.obs.trace import tspan


def check_extrapolation(algo: AlgoInstance, extrapolate_every: int) -> None:
    """Aitken extrapolation assumes a *linear* update (sum-semiring
    "replace" combine); on min/max lattice sweeps the geometric-tail jump is
    meaningless (it NaNs on BIG sentinels and can't move a min fixpoint
    anyway), so reject it loudly instead of returning garbage."""
    if extrapolate_every and algo.semiring.reduce != "sum":
        raise NotImplementedError(
            f"extrapolate_every is only valid for linear sum-semiring "
            f"systems; {algo.name!r} uses reduce={algo.semiring.reduce!r}"
        )
    if extrapolate_every and not extrapolate_every >= 2:
        # a period of 1 jumps every round off a rho estimated from the
        # previous jump's own step — the 19x amplifications compound with no
        # contraction rounds between and the iteration diverges to NaN
        raise ValueError(
            f"extrapolate_every must be 0 (off) or >= 2, got {extrapolate_every}"
        )


def pack(algo: AlgoInstance, bs: int):
    """Pad the algorithm's (n, d) vertex arrays up to whole blocks of ``bs``.

    Returns ``(be, x0, c, fixed, npad)`` with f32[npad, d] state arrays.
    Padding rows are pinned (``fixed = True``) at the reduce identity so they
    can never influence a real vertex; ``c`` pads use the reduce identity
    except under ``replace`` combine, whose additive pad must be 0.0.
    """
    g = Graph(algo.n, algo.src, algo.dst, algo.w)
    be = pack_in_edges(g, bs)
    npad = padded_n(algo.n, bs)
    ident = algo.semiring.identity
    x0 = pad_state(algo.x0, bs, fill=ident)
    c = pad_state(algo.c, bs, fill=algo.c_pad_fill)
    fixed = pad_state(algo.fixed, bs, fill=True)
    return be, x0, c, fixed, npad


def init_state(
    x0_packed: np.ndarray, x_init, n: int
) -> np.ndarray:
    """Overlay a resume state onto the packed x0 (checkpointed macro-steps).

    ``x_init`` may be (n,), (n, 1) or (n, d) — 1-D resumes of a d = 1 run and
    full-matrix resumes of a batched run both work.
    """
    if x_init is None:
        return x0_packed
    x = np.asarray(x_init, dtype=x0_packed.dtype)
    if x.size % n:
        raise ValueError(
            f"x_init has {x.shape} elements, expected (n, d) rows for n={n}"
        )
    x = x.reshape(n, -1)
    if x.shape[1] != x0_packed.shape[1]:
        raise ValueError(
            f"x_init has {x.shape[1]} columns, run has {x0_packed.shape[1]}"
        )
    out = x0_packed.copy()
    out[:n, :] = x
    return out


def swap_in_column(
    x: np.ndarray, x0: np.ndarray, c: np.ndarray, fixed: np.ndarray,
    j: int, n: int,
    q_x0: np.ndarray, q_c: np.ndarray, q_fixed: np.ndarray,
) -> None:
    """Mid-run per-column re-init — the inverse of :func:`loop`'s freeze.

    The serving layer's continuous batching resolves a converged column and
    packs a *queued* query into its slot between engine batches: overwrite
    column ``j`` of the packed ``(npad, d)`` operand matrices with the
    newcomer's vertex arrays and reset the resident state column to the
    newcomer's ``x0``. Rows ``>= n`` are padding and keep their fills (the
    fills are per-family constants, identical for every column, so a swap
    never has to re-pad). Mutates the arrays in place; the companion
    bookkeeping reset is :func:`repro.engine.convergence.reinit_columns`.
    """
    x0[:n, j] = np.asarray(q_x0, x0.dtype).reshape(-1)
    c[:n, j] = np.asarray(q_c, c.dtype).reshape(-1)
    fixed[:n, j] = np.asarray(q_fixed, fixed.dtype).reshape(-1)
    x[:, j] = x0[:, j]


@jax.jit
def _set_query_columns(x, x0, c, fixed, j, q_x0, q_c, q_fixed):
    # j is traced (an int32 operand, not a static arg): one compiled scatter
    # serves every slot, so serving swaps never recompile per column
    return (
        x.at[:, j].set(q_x0),
        x0.at[:, j].set(q_x0),
        c.at[:, j].set(q_c),
        fixed.at[:, j].set(q_fixed),
    )


def swap_in_column_device(
    x, x0, c, fixed, j: int, n: int,
    q_x0: np.ndarray, q_c: np.ndarray, q_fixed: np.ndarray,
    *, x0_fill: float, c_fill: float,
):
    """:func:`swap_in_column` for device-resident ``(npad, d)`` operands.

    Pads the newcomer's length-``n`` vectors with the family's per-column
    constant fills (the same fills :func:`pack` used, so padding rows stay
    pinned at the reduce identity) and writes all four columns in one jitted
    functional update. Returns new ``(x, x0, c, fixed)`` jax arrays — the
    matrices never round-trip to host; only the newcomer's three length-n
    vectors transfer H2D.
    """
    npad = x.shape[0]
    xq = np.full(npad, x0_fill, np.float32)
    xq[:n] = np.asarray(q_x0, np.float32).reshape(-1)
    cq = np.full(npad, c_fill, np.float32)
    cq[:n] = np.asarray(q_c, np.float32).reshape(-1)
    fq = np.ones(npad, fixed.dtype)  # pads pinned (bool on jax, f32 on pallas)
    fq[:n] = np.asarray(q_fixed).reshape(-1).astype(fq.dtype)
    return _set_query_columns(x, x0, c, fixed, jnp.int32(j), xq, cq, fq)


def permute_state(x: np.ndarray, rank: np.ndarray) -> np.ndarray:
    """Carry a served state across a relabel: vertex v's row moves to
    ``rank[v]`` — the same transform `AlgoInstance.relabel` applies to x0."""
    rank = np.asarray(rank)
    inv = np.empty_like(rank)
    inv[rank] = np.arange(len(rank))
    x = np.asarray(x)
    return x[inv]


@jax.jit
def _gather_rows(x, idx):
    return x[idx]


def gather_rows(x, idx):
    """Device-resident row gather ``x[idx]`` (jitted, returns a jax array).

    The order-swap primitive: permuting a family's packed state matrix (or
    one column) between two processing orders is two of these gathers —
    old-rank -> id space via ``rank_old``, id space -> new-rank via
    ``order_new`` — and a gather is a bit-copy, so min/max warm states move
    across orders bitwise without leaving the device (PR 6 residency
    contract)."""
    return _gather_rows(x, jnp.asarray(idx))


# The value an *untouched* vertex holds at the start of every workload the
# constructors build: 0 for the additive semiring, the +BIG sentinel for
# min-reduce (unreached SSSP/BFS/CC), 0 for max-reduce (SSWP width /
# reachability indicator of an unreached vertex). Vertices whose x0 differs
# from this — sources, seeds, pinned targets — are a query's *inputs*.
X0_FILL = {"sum": 0.0, "min": 3.0e38, "max": 0.0}


def column_support(
    q_x0: np.ndarray, q_c: np.ndarray, q_fixed: np.ndarray,
    *, reduce: str, c_fill: float, x: Optional[np.ndarray] = None,
) -> np.ndarray:
    """bool[n] — the vertices a query's column actually involves.

    A vertex is in a query's support when the query *injects* something at
    it (``x0`` off the workload's untouched-vertex fill, ``c`` off the pack
    fill, or pinned) or — when a finished state ``x`` is supplied — when the
    run *moved* it off ``x0``. Everything outside the support holds the
    inert fill through the whole run, which is what lets (a) a swapped-in
    column seed only its support blocks into the megakernel's dirty
    frontier, and (b) the result cache keep an entry alive across a graph
    delta that touches no supported block (`repro.serving.cache`).
    """
    q_x0 = np.asarray(q_x0).reshape(-1)
    q_c = np.asarray(q_c).reshape(-1)
    q_fixed = np.asarray(q_fixed).reshape(-1)
    support = (q_x0 != np.float32(X0_FILL[reduce])) | (q_c != np.float32(c_fill))
    support |= q_fixed.astype(bool)
    if x is not None:
        support |= np.asarray(x).reshape(-1) != q_x0
    return support


# Aitken extrapolation clamps the contraction-rate estimate here: a rho this
# close to 1 amplifies the current step by rho/(1-rho) = 19x, which a
# contracting base iteration recovers from in a few sweeps even when the
# estimate was noise.
_RHO_MAX = 0.95


def loop(
    round_fn: Callable[[jnp.ndarray], jnp.ndarray],
    x0: jnp.ndarray,
    *,
    res_kind: str,
    eps: float,
    max_iters: int,
    real_mask: Optional[jnp.ndarray] = None,
    extrapolate_every: int = 0,
):
    """Drive ``x -> round_fn(x)`` with per-column convergence freezing.

    x0: f32[N, d]. ``real_mask`` (bool[N]) masks padding rows out of the
    residual and the state-sum trace. Returns
    ``(x, k, col_done, col_rounds, res_buf, sum_buf, change_norm)`` where
    ``res_buf[t]`` is the max residual over the columns still active at round
    t (for d = 1 this is the legacy scalar residual trace).

    A column converging at round k keeps its *pre-sweep* state: the sweep that
    measures residual <= eps is a verification sweep whose candidate is
    discarded. Both the kept state and the candidate satisfy the stopping
    criterion (they differ by <= eps); keeping the pre-sweep one makes the
    driver idempotent — re-running with ``x_init`` set to a converged state
    performs exactly one verification sweep and returns the state bitwise
    unchanged, which is what lets warm-started serving re-runs be no-ops.

    ``extrapolate_every`` (static; 0 = off) enables per-column Aitken
    extrapolation every that-many rounds: the column's contraction rate rho is
    estimated from successive L1 step norms and the remaining geometric tail
    ``step * rho/(1-rho)`` is added in one jump. Only valid for *linear*
    updates (sum-semiring "replace" combine, e.g. the incremental engine's
    delta systems); min/max semiring sweeps are nonlinear and must keep 0.
    """
    d = x0.shape[1]
    res_buf = jnp.zeros((max_iters,), jnp.float32)
    sum_buf = jnp.zeros((max_iters,), jnp.float32)

    def mask_rows(x):
        if real_mask is None:
            return x
        return jnp.where(real_mask[:, None], x, 0.0)

    def cond(state):
        _, k, col_done, _, _, _, _ = state
        return jnp.logical_and(k < max_iters, ~jnp.all(col_done))

    def body(state):
        x, k, col_done, col_rounds, res_buf, sum_buf, prev_norm = state
        x_cand = round_fn(x)
        xm_cand = mask_rows(x_cand)
        xm_old = mask_rows(x)
        res_col = J.residual_cols(res_kind, xm_cand, xm_old)
        newly_done, active, col_done, col_rounds = converge_step(
            res_col, eps, col_done, col_rounds
        )
        x_keep = x_cand
        norm_col = prev_norm  # untouched dummy when extrapolation is off
        if extrapolate_every:  # static — off pays no per-round norm work
            norm_col = jnp.sum(jnp.abs(xm_cand - xm_old), axis=0)
            do_ex = jnp.logical_and(k > 0, (k + 1) % extrapolate_every == 0)
            rho = jnp.clip(
                norm_col / jnp.maximum(prev_norm, 1e-30), 0.0, _RHO_MAX
            )
            factor = jnp.where(
                jnp.logical_and(do_ex, prev_norm > 0), rho / (1.0 - rho), 0.0
            )
            x_keep = x_cand + (xm_cand - xm_old) * factor[None, :]
        # columns converging this round keep their pre-sweep state (see
        # docstring); already-frozen columns stay put; active ones advance
        x_new = freeze_columns(x_keep, x, active, newly_done)
        res_buf = res_buf.at[k].set(jnp.max(jnp.where(active, res_col, 0.0)))
        xm = mask_rows(x_new)
        sum_buf = sum_buf.at[k].set(
            jnp.sum(jnp.where(jnp.abs(xm) < 1e30, xm, 0.0))
        )
        return x_new, k + 1, col_done, col_rounds, res_buf, sum_buf, norm_col

    init = (
        x0, jnp.int32(0), jnp.zeros((d,), bool), jnp.zeros((d,), jnp.int32),
        res_buf, sum_buf, jnp.zeros((d,), jnp.float32),
    )
    return jax.lax.while_loop(cond, body, init)


def sweep_batched_loop(
    batch_fn: Callable,
    x0: jnp.ndarray,
    dirty0: jnp.ndarray,
    *,
    eps: float,
    max_iters: int,
    sweeps: int,
    nb: int,
    real_mask: Optional[np.ndarray] = None,
    tracer=None,
):
    """Host-side round driver for the persistent multi-sweep megakernel.

    ``batch_fn(x, dirty) -> (x, deltas[sweeps, d], active[sweeps, 1],
    dirty)`` runs up to ``sweeps`` Gauss–Seidel sweeps in one kernel launch
    (`kernels.gs_sweep.gs_multisweep_pallas`); this loop synchronizes with
    the host once per *batch*, then replays the kernel's per-sweep delta
    trace to reconstruct exactly the per-column round counts the per-sweep
    driver (:func:`loop`) would have produced: column j converges at the
    first sweep whose delta drops to eps, and skipped blocks contribute a
    bitwise-zero delta, so the trace is identical to full-sweep execution.

    Two documented deviations from :func:`loop`'s semantics, both invisible
    for the lattice (min/max) semirings where converged states are bitwise
    fixpoints of the sweep: (1) columns are not frozen at their pre-sweep
    state — a converged column keeps sweeping until the whole batch stops,
    drifting by at most eps per sweep for contractive sum systems; (2) the
    kernel's in-batch early-out uses the instantaneous all-columns test, so
    a batch may execute up to ``sweeps - 1`` extra sweeps past ``max_iters``
    or past the sticky per-column stop (their results are kept).

    Returns ``(x, k, col_done, col_rounds, res_trace, sum_trace,
    active_trace, dirty)`` — the :func:`loop` tuple shape plus the per-sweep
    active-block-fraction trace (``state_sums`` has batch granularity: the
    post-batch sum is attributed to each of the batch's sweeps) and the
    final dirty-block bitmap, which a serving session carries into its next
    batch so the frontier survives column swaps.

    ``tracer`` (`repro.obs.trace.Tracer`, optional) wraps each kernel launch
    in a ``sweep_call`` span covering dispatch *and* the batch-granular
    readout — the launch itself is asynchronous, so dispatch+readout is the
    only honest per-batch wall time. The span's residual/active attributes
    are stamped from the same once-per-batch ``device_get`` every untraced
    run performs — tracing adds no transfers.
    """
    x = x0
    dirty = dirty0
    d = int(x.shape[1])
    rm = None if real_mask is None else jnp.asarray(real_mask)
    col_done = np.zeros(d, bool)
    col_rounds = np.zeros(d, np.int32)
    res_trace: list[float] = []
    sum_trace: list[float] = []
    act_trace: list[float] = []
    k = 0
    while k < max_iters and not col_done.all():
        with tspan(tracer, "sweep_call", sweeps=sweeps, nb=nb, k=k) as sp:
            x, deltas, active, dirty = batch_fn(x, dirty)
            # state-sum trace on device: the batch only ships the (sweeps, d)
            # delta/active rows and this scalar to the host, never the state
            xm = x if rm is None else jnp.where(rm[:, None], x, 0.0)
            deltas_np, active_np, batch_sum = jax.device_get((
                deltas, active,
                jnp.sum(jnp.where(jnp.abs(xm) < 1e30, xm, 0.0)),
            ))  # repro: allow-host-sync(once-per-batch convergence trace readout)
            batch_sum = float(batch_sum)
            sp.set(
                max_delta=float(np.max(deltas_np)),
                active_blocks=[float(a) for a in active_np[:, 0]],
            )
        for s in range(sweeps):
            if k >= max_iters or col_done.all():
                break
            res_col = deltas_np[s]
            _, active_cols, col_done, col_rounds = converge_step(
                res_col, eps, col_done, col_rounds
            )
            res_trace.append(float(np.max(np.where(active_cols, res_col, 0.0))))
            sum_trace.append(batch_sum)
            act_trace.append(float(active_np[s, 0]) / max(1, nb))
            k += 1
    return (
        x, k, col_done, col_rounds,
        np.asarray(res_trace, np.float32), np.asarray(sum_trace, np.float32),
        np.asarray(act_trace, np.float32), dirty,
    )


def finalize(
    algo: AlgoInstance, x, k, col_done, col_rounds, res_buf, sum_buf, *_extra
) -> RunResult:
    """Convert raw loop outputs into a RunResult (d = 1 keeps 1-D x).

    Also attaches the uniform :class:`~repro.obs.telemetry.ConvergenceTrace`
    — derived purely from the residual buffer and ``col_rounds`` fetched by
    this function's single end-of-run readback, so telemetry never adds a
    transfer (the megakernel path overwrites it with its finer
    block-granular accounting).
    """
    # the one end-of-run device->host readback; device_get passes the sweep
    # drivers' host-side numpy outputs through untouched
    x, k, col_done, col_rounds, res_buf, sum_buf = jax.device_get(
        (x, k, col_done, col_rounds, res_buf, sum_buf)
    )  # repro: allow-host-sync(end-of-run RunResult readout)
    k = int(k)
    xr = np.asarray(x)[: algo.n]
    if algo.d == 1:
        xr = xr[:, 0]
    col_conv = np.asarray(col_done)
    col_rounds = np.asarray(col_rounds)
    residuals = np.asarray(res_buf)[:k]
    return RunResult(
        x=xr,
        rounds=k,
        converged=bool(col_conv.all()),
        residuals=residuals,
        state_sums=np.asarray(sum_buf)[:k],
        col_rounds=col_rounds,
        col_converged=col_conv,
        convergence_trace=trace_from_col_rounds(
            residuals, col_rounds, rounds=k, n=algo.n, d=algo.d
        ),
    )
