"""Shared packed-run harness for the iterative engines.

Every engine in this package is the same machine with a different sweep:
pack the algorithm's vertex arrays into whole blocks, then drive rounds of
``x -> sweep(x)`` until the residual drops below eps. This module holds the
two shared halves so the engines only contribute their sweep:

* :func:`pack` — the one block-padding path (previously duplicated between
  ``async_block`` and ``distributed`` with *inconsistent* padding fills for
  ``c``: min/max-semiring pads must be the reduce identity, not 0.0).

* :func:`loop` — the one round driver (previously three near-identical
  ``lax.while_loop`` bodies in sync / async_block / distributed). States are
  batched ``(n, d)`` matrices; convergence is tracked *per column*: a column
  whose residual first drops to eps is frozen (later sweeps cannot move it)
  and recorded at its own round count, so query j of a batched run finishes
  with exactly the state and round count of a scalar run of query j.

``loop`` is a plain traced function, not a jit boundary — each engine calls
it inside its own module-level ``jax.jit`` wrapper so compilation caching
keys on the engine's static config exactly as before.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import jax_ops as J
from repro.graphs.blocked import pack_in_edges, pad_state, padded_n
from repro.graphs.graph import Graph


def pack(algo: AlgoInstance, bs: int):
    """Pad the algorithm's (n, d) vertex arrays up to whole blocks of ``bs``.

    Returns ``(be, x0, c, fixed, npad)`` with f32[npad, d] state arrays.
    Padding rows are pinned (``fixed = True``) at the reduce identity so they
    can never influence a real vertex; ``c`` pads use the reduce identity
    except under ``replace`` combine, whose additive pad must be 0.0.
    """
    g = Graph(algo.n, algo.src, algo.dst, algo.w)
    be = pack_in_edges(g, bs)
    npad = padded_n(algo.n, bs)
    ident = algo.semiring.identity
    x0 = pad_state(algo.x0, bs, fill=ident)
    c = pad_state(algo.c, bs, fill=algo.c_pad_fill)
    fixed = pad_state(algo.fixed, bs, fill=True)
    return be, x0, c, fixed, npad


def init_state(
    x0_packed: np.ndarray, x_init, n: int
) -> np.ndarray:
    """Overlay a resume state onto the packed x0 (checkpointed macro-steps).

    ``x_init`` may be (n,), (n, 1) or (n, d) — 1-D resumes of a d = 1 run and
    full-matrix resumes of a batched run both work.
    """
    if x_init is None:
        return x0_packed
    x = np.asarray(x_init, dtype=x0_packed.dtype)
    if x.size % n:
        raise ValueError(
            f"x_init has {x.shape} elements, expected (n, d) rows for n={n}"
        )
    x = x.reshape(n, -1)
    if x.shape[1] != x0_packed.shape[1]:
        raise ValueError(
            f"x_init has {x.shape[1]} columns, run has {x0_packed.shape[1]}"
        )
    out = x0_packed.copy()
    out[:n, :] = x
    return out


def loop(
    round_fn: Callable[[jnp.ndarray], jnp.ndarray],
    x0: jnp.ndarray,
    *,
    res_kind: str,
    eps: float,
    max_iters: int,
    real_mask: Optional[jnp.ndarray] = None,
):
    """Drive ``x -> round_fn(x)`` with per-column convergence freezing.

    x0: f32[N, d]. ``real_mask`` (bool[N]) masks padding rows out of the
    residual and the state-sum trace. Returns
    ``(x, k, col_done, col_rounds, res_buf, sum_buf)`` where ``res_buf[t]``
    is the max residual over the columns still active at round t (for d = 1
    this is the legacy scalar residual trace).
    """
    d = x0.shape[1]
    res_buf = jnp.zeros((max_iters,), jnp.float32)
    sum_buf = jnp.zeros((max_iters,), jnp.float32)

    def mask_rows(x):
        if real_mask is None:
            return x
        return jnp.where(real_mask[:, None], x, 0.0)

    def cond(state):
        _, k, col_done, _, _, _ = state
        return jnp.logical_and(k < max_iters, ~jnp.all(col_done))

    def body(state):
        x, k, col_done, col_rounds, res_buf, sum_buf = state
        x_cand = round_fn(x)
        res_col = J.residual_cols(res_kind, mask_rows(x_cand), mask_rows(x))
        active = ~col_done
        # frozen columns keep their converged state; active ones advance
        x_new = jnp.where(active[None, :], x_cand, x)
        col_rounds = col_rounds + active.astype(jnp.int32)
        col_done = col_done | (res_col <= eps)
        res_buf = res_buf.at[k].set(jnp.max(jnp.where(active, res_col, 0.0)))
        xm = mask_rows(x_new)
        sum_buf = sum_buf.at[k].set(
            jnp.sum(jnp.where(jnp.abs(xm) < 1e30, xm, 0.0))
        )
        return x_new, k + 1, col_done, col_rounds, res_buf, sum_buf

    init = (
        x0, jnp.int32(0), jnp.zeros((d,), bool), jnp.zeros((d,), jnp.int32),
        res_buf, sum_buf,
    )
    return jax.lax.while_loop(cond, body, init)


def finalize(
    algo: AlgoInstance, x, k, col_done, col_rounds, res_buf, sum_buf
) -> RunResult:
    """Convert raw loop outputs into a RunResult (d = 1 keeps 1-D x)."""
    k = int(k)
    xr = np.asarray(x)[: algo.n]
    if algo.d == 1:
        xr = xr[:, 0]
    col_conv = np.asarray(col_done)
    return RunResult(
        x=xr,
        rounds=k,
        converged=bool(col_conv.all()),
        residuals=np.asarray(res_buf)[:k],
        state_sums=np.asarray(sum_buf)[:k],
        col_rounds=np.asarray(col_rounds),
        col_converged=col_conv,
    )
