"""Vertex-granular residual push engine — the ultra-sparse regime.

The block engines sweep at ``bs``-block granularity, so a serving delta or a
personalized query touching 0.01% of vertices still pays whole blocks per
round. This engine (ROADMAP item 2; InstantGNN-style residual push with
Maiter's accumulative-delta guarantee) does work proportional to the touched
neighborhood instead: it maintains a ``(p, r)`` pair per query column —
``p`` the settled estimate, ``r`` the pending residual — and each round
*pushes* only the vertices whose residual exceeds their per-vertex
threshold, scattering one semiring message per out-edge onto the neighbors'
residual rows.

**Sum semirings** (``plus_times`` / ``replace``) keep the invariant
``r = c + W p - p``: pushing u moves ``r_u`` into ``p_u`` and adds
``w_uv * r_u`` to each out-neighbor's residual, so ``p + r``'s fixpoint
distance only ever shrinks and ``p`` converges to the same fixpoint the
sweep engines reach (within eps — the stopping rule ``|r| <= eps`` is
exactly the sweeps' linf residual test). The per-vertex threshold is the
InstantGNN ``eps_vec = eps * outdeg**(1 - beta)`` idiom, lifted per column:
``beta = 1`` (default) reproduces the engines' uniform eps bitwise;
``beta < 1`` lets low-degree vertices stop earlier (degree-normalized
approximate PPR).

**Lattice semirings** (min/max) hold in ``r`` the best *pending candidate*
(initialized to the reduce identity): a vertex is pending while
``combine(p, r)`` beats ``p``; pushing installs the candidate and scatters
``edge_op(p_u, w)`` messages. Every scatter is one of the same f32
relaxations a sweep executes, and quiescence (no relaxation can improve
anything) pins the unique monotone closure — so the resolved state is
**bitwise identical** to ``run_async_block``'s.

Initialization is one uniform rule. Sum: ``p0 = x_init or x0``,
``r0 = dense_residual(algo, p0)`` — for `run_incremental`'s delta system
(``x0 = 0, c = r``) that is exactly the delta-touched rows, so a 10-edge
delta starts with a 10-destination frontier. Lattice: cold starts use
``p0 = identity`` with ``r0 = combine(x0, c)`` (the constant candidates —
support vertices seed themselves); warm starts (``x_init``) add one
vectorized full aggregate ``r0 = reduce(r0, W-agg(p0))`` so exactly the
rows whose equation the delta violated become pending. Pinned vertices
carry ``x0`` as their only candidate and are re-clamped every round.

Two backends behind ``EngineOptions.backend``:

* ``"jax"`` — one jitted Jacobi-style push round: all active vertices push
  simultaneously via masked edge messages + segment reduce. Frozen columns
  are masked out of the push, so converged queries stay put bitwise.
* ``"pallas"`` — the bucketed scatter kernel
  (`kernels.push_scatter.push_scatter_pallas`): the host bins the round's
  active vertices into ``EngineOptions.buckets`` priority buckets (best
  first — smallest tentative distance for min_plus, i.e. delta-stepping
  SSSP; largest residual for sum), and the sequential TPU grid gives
  Gauss–Seidel freshness *within* the round. Bucket caps round up to a
  power of two so recompiles stay O(log n) per solve.

The router (`estimate_frontier_fraction` + ``solve(engine="auto")``)
estimates the initial pending fraction from the same initialization rule
and routes to push below ``EngineOptions.push_threshold``, else to the
megakernel sweep.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.api import EngineOptions, validate_options
from repro.engine.convergence import RunResult, converge_step
from repro.graphs.graph import Graph
from repro.kernels.semirings import ACC_IDENTITY, pending_cols

# (reduce, edge_op) -> fused kernel semiring; mirrors kernels.ops. Anything
# else must fail loudly before any state is built.
_KERNEL_SEMIRING: dict[tuple[str, str], str] = {
    ("sum", "mul"): "plus_times",
    ("min", "add"): "min_plus",
    ("max", "min"): "max_min",
    ("max", "mul"): "max_times",
}

_COMBINES = {"plus_times": "replace", "min_plus": "min_old",
             "max_min": "max_old", "max_times": "max_old"}

# static edge-chunk size for the scatter kernel (hubs loop over chunks)
_ECAP = 128


def _kernel_semiring(algo: AlgoInstance) -> str:
    key = (algo.semiring.reduce, algo.semiring.edge_op)
    ks = _KERNEL_SEMIRING.get(key)
    if ks is None or algo.combine != _COMBINES[ks]:
        raise NotImplementedError(
            f"push engine: unsupported semiring/combine "
            f"({key}, {algo.combine!r}); supported: "
            f"{sorted((k, _COMBINES[v]) for k, v in _KERNEL_SEMIRING.items())}"
        )
    return ks


def _overlay_x_init(algo: AlgoInstance, x_init: Optional[np.ndarray]) -> np.ndarray:
    """(n, d) f32 start state: algo.x0 with x_init overlaid (harness.init_state
    semantics), pinned rows clamped to their pin."""
    x = np.asarray(algo.x0, np.float32).reshape(algo.n, algo.d).copy()
    if x_init is not None:
        xi = np.asarray(x_init, np.float32)
        if xi.ndim == 1:
            xi = xi[:, None]
        if xi.shape != (algo.n, algo.d):
            raise ValueError(
                f"x_init shape {xi.shape} != (n, d) = {(algo.n, algo.d)}"
            )
        x = xi.copy()
    return np.where(algo.fixed, algo.x0, x).astype(np.float32)


def _lattice_residual(
    algo: AlgoInstance, ks: str, p0: np.ndarray, aggregate: bool
) -> np.ndarray:
    """Initial pending-candidate matrix r0 for a lattice start at ``p0``.

    The constant candidates combine(x0, c) always participate; ``aggregate``
    adds one vectorized full pass of edge candidates ``edge_op(p0[src], w)``
    — needed for warm starts, a no-op for cold ones (every message from an
    identity row is the identity). All arithmetic stays f32 so candidates
    are the kernels' exact values. Pinned rows carry x0 as their only
    candidate (cold pins establish + push themselves; warm pins are already
    settled and stay quiet)."""
    n, d = algo.n, algo.d
    x0 = np.asarray(algo.x0, np.float32).reshape(n, d)
    c = np.asarray(algo.c, np.float32).reshape(n, d)
    lat_min = ks == "min_plus"
    pair = np.minimum if lat_min else np.maximum
    r0 = pair(x0, c).astype(np.float32)
    if aggregate and len(algo.src):
        w = np.asarray(algo.w, np.float32)[:, None]
        ps = p0[algo.src]
        with np.errstate(over="ignore"):
            if ks == "min_plus":
                msgs = ps + w
            elif ks == "max_min":
                msgs = np.minimum(ps, w)
            else:  # max_times
                msgs = ps * w
        agg = np.full((n, d), ACC_IDENTITY[ks], np.float32)
        if lat_min:
            np.minimum.at(agg, algo.dst, msgs.astype(np.float32))
        else:
            np.maximum.at(agg, algo.dst, msgs.astype(np.float32))
        r0 = pair(r0, agg).astype(np.float32)
    return np.where(algo.fixed, x0, r0).astype(np.float32)


def _init_state(
    algo: AlgoInstance, ks: str, x_init: Optional[np.ndarray]
) -> tuple[np.ndarray, np.ndarray]:
    """The uniform (p0, r0) initialization rule (module docstring)."""
    if ks == "plus_times":
        from repro.engine.incremental import dense_residual

        p0 = _overlay_x_init(algo, x_init)
        return p0, dense_residual(algo, p0)
    if x_init is None:
        p0 = np.full((algo.n, algo.d), ACC_IDENTITY[ks], np.float32)
        return p0, _lattice_residual(algo, ks, p0, aggregate=False)
    p0 = _overlay_x_init(algo, x_init)
    return p0, _lattice_residual(algo, ks, p0, aggregate=True)


def estimate_frontier_fraction(
    algo: AlgoInstance, x_init: Optional[np.ndarray] = None
) -> float:
    """Fraction of vertices the push engine would start active — the router
    signal behind ``solve(engine="auto")``.

    Derived from the engine's own initialization rule, so the estimate *is*
    the round-0 frontier: for sum semirings the rows with supra-eps initial
    residual (cold PageRank -> 1.0, a 1-seed PPR query or an incremental
    delta system -> O(touched)/n); for lattice semirings the rows holding a
    pending candidate (cold SSSP -> the sources; a warm tightened state ->
    the delta-touched destinations; cold max-semiring workloads -> 1.0,
    every vertex must establish its inert 0). One vectorized O(m) pass,
    no iteration.
    """
    ks = _kernel_semiring(algo)
    p0, r0 = _init_state(algo, ks, x_init)
    if ks == "plus_times":
        pend = np.any(np.abs(r0) > algo.eps, axis=1)
    elif ks == "min_plus":
        pend = np.any(np.minimum(p0, r0) != p0, axis=1)
    else:
        pend = np.any(np.maximum(p0, r0) != p0, axis=1)
    return float(pend.mean()) if algo.n else 0.0


def _eps_vec(algo: AlgoInstance, beta: float) -> np.ndarray:
    """Per-vertex push threshold ``eps * outdeg**(1 - beta)`` (sum only).

    beta = 1 -> uniform eps (the sweep engines' linf test, bitwise the same
    stopping rule); beta < 1 raises the bar for low-degree vertices — the
    InstantGNN degree-normalized approximate-push tradeoff."""
    if beta == 1.0:
        return np.full(algo.n, algo.eps, np.float32)
    deg = Graph(algo.n, algo.src, algo.dst, algo.w).out_degrees()
    return (algo.eps * np.maximum(deg, 1).astype(np.float64)
            ** (1.0 - beta)).astype(np.float32)


def _make_prep(ks: str) -> Callable[..., tuple[jnp.ndarray, ...]]:
    """Jitted per-round prep: pending mask, per-column metrics, the
    bucketing priority key, and the state-sum trace sample — one fused
    device pass, so the host reads back only what it must."""
    lat_min = ks == "min_plus"

    @jax.jit
    def prep(p: jnp.ndarray, r: jnp.ndarray, eps_v: jnp.ndarray,
             col_live: jnp.ndarray) -> tuple[jnp.ndarray, ...]:
        if ks == "plus_times":
            pend = jnp.abs(r) > eps_v[:, None]
            metric = jnp.max(jnp.abs(r), axis=0)
        else:
            newp = jnp.minimum(p, r) if lat_min else jnp.maximum(p, r)
            pend = newp != p
            metric = pending_cols(ks, p, r, xp=jnp)
        res_col = jnp.sum(pend.astype(jnp.float32), axis=0)
        live = pend & col_live[None, :]
        active_v = jnp.any(live, axis=1)
        if ks == "plus_times":
            key = -jnp.max(jnp.where(live, jnp.abs(r), 0.0), axis=1)
        else:
            cand = jnp.minimum(p, r) if lat_min else jnp.maximum(p, r)
            best = (jnp.min(cand, axis=1) if lat_min
                    else -jnp.max(cand, axis=1))
            key = jnp.where(active_v, best, jnp.float32(np.inf))
        ssum = jnp.sum(jnp.where(jnp.abs(p) < 1e30, p, 0.0))
        return active_v, res_col, metric, key, ssum

    return prep


def _make_round_jax(algo: AlgoInstance, ks: str) -> Any:
    """The vectorized (Jacobi-style) push round: every active vertex of
    every live column pushes at once; scatters land via segment reduce.
    Converged columns are masked out of the push, so they freeze bitwise."""
    src = jnp.asarray(algo.src)
    dst = jnp.asarray(algo.dst)
    w = jnp.asarray(algo.w, jnp.float32)[:, None]
    fixed = jnp.asarray(algo.fixed)
    x0 = jnp.asarray(algo.x0, jnp.float32).reshape(algo.n, algo.d)
    n = algo.n
    ident = ACC_IDENTITY[ks]
    lat_min = ks == "min_plus"

    @jax.jit
    def round_sum(p: jnp.ndarray, r: jnp.ndarray, active_v: jnp.ndarray,
                  col_live: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        mask = active_v[:, None] & col_live[None, :]
        push = jnp.where(mask, r, 0.0)
        p2 = p + push
        r2 = r - push
        r2 = r2.at[dst].add(w * push[src])
        r2 = jnp.where(fixed, 0.0, r2)
        return p2, r2

    @jax.jit
    def round_lattice(p: jnp.ndarray, r: jnp.ndarray, active_v: jnp.ndarray,
                      col_live: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        mask = active_v[:, None] & col_live[None, :]
        newp = jnp.minimum(p, r) if lat_min else jnp.maximum(p, r)
        p2 = jnp.where(mask, newp, p)
        r2 = jnp.where(mask, jnp.float32(ident), r)
        if ks == "min_plus":
            msgs = p2[src] + w
        elif ks == "max_min":
            msgs = jnp.minimum(p2[src], w)
        else:
            msgs = p2[src] * w
        msgs = jnp.where(mask[src], msgs, jnp.float32(ident))
        if lat_min:
            agg = jnp.full((n, p.shape[1]), ident, p.dtype).at[dst].min(msgs)
            r2 = jnp.minimum(r2, agg)
        else:
            agg = jnp.full((n, p.shape[1]), ident, p.dtype).at[dst].max(msgs)
            r2 = jnp.maximum(r2, agg)
        p2 = jnp.where(fixed, x0, p2)
        r2 = jnp.where(fixed, x0, r2)
        return p2, r2

    return round_sum if ks == "plus_times" else round_lattice


def _pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


class _PallasRound:
    """Host-side bucketing + kernel dispatch for one push round."""

    def __init__(self, algo: AlgoInstance, ks: str, buckets: int) -> None:
        indptr, nbrs, eid = Graph(algo.n, algo.src, algo.dst, algo.w).csr()
        self.indptr = indptr.astype(np.int64)
        self.ks = ks
        self.buckets = buckets
        self.nbrs = jnp.asarray(np.concatenate(
            [nbrs.astype(np.int32), np.zeros(_ECAP, np.int32)]))
        self.ew = jnp.asarray(np.concatenate(
            [np.asarray(algo.w, np.float32)[eid],
             np.zeros(_ECAP, np.float32)]))
        self.fixed = jnp.asarray(algo.fixed)
        self.x0 = jnp.asarray(algo.x0, jnp.float32).reshape(algo.n, algo.d)
        ident = ACC_IDENTITY[ks]

        @jax.jit
        def cleanup(p: jnp.ndarray, r: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
            # pinned rows: clamp the state, drop incoming messages (their x0
            # candidate re-seeds only at init); sum discards pinned residual
            if ks == "plus_times":
                return jnp.where(self.fixed, self.x0, p), \
                    jnp.where(self.fixed, 0.0, r)
            return jnp.where(self.fixed, self.x0, p), \
                jnp.where(self.fixed, jnp.float32(ident), r)

        self._cleanup = cleanup

    def __call__(
        self, p: jnp.ndarray, r: jnp.ndarray,
        ids: np.ndarray, key: np.ndarray,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        from repro.kernels.push_scatter import push_scatter_pallas

        order = np.argsort(key[ids], kind="stable")
        ids = ids[order].astype(np.int64)
        buckets = min(self.buckets, max(1, len(ids)))
        cap = _pow2(-(-len(ids) // buckets))  # pow2 caps bound recompiles
        vid = np.full(buckets * cap, -1, np.int32)
        vid[: len(ids)] = ids
        seg_s = np.zeros(buckets * cap, np.int32)
        seg_l = np.zeros(buckets * cap, np.int32)
        seg_s[: len(ids)] = self.indptr[ids]
        seg_l[: len(ids)] = self.indptr[ids + 1] - self.indptr[ids]
        p2, r2, _, _ = push_scatter_pallas(
            jnp.asarray(vid), jnp.asarray(seg_s), jnp.asarray(seg_l),
            self.nbrs, self.ew, p, r,
            semiring=self.ks, buckets=buckets, cap=cap, ecap=_ECAP,
        )
        return self._cleanup(p2, r2)


def _solve(algo: AlgoInstance, o: EngineOptions) -> RunResult:
    """solve()'s dispatch target for ``engine="push"``.

    Besides the legacy working-round ``residuals`` buffer this driver keeps
    the uniform per-round :class:`~repro.obs.telemetry.ConvergenceTrace`:
    one entry per counted round — *including* the empty-frontier accounting
    rounds (residual 0, work 0) — whose residual is the **post**-round
    pending metric read at the next round's prep. The metric rides the same
    fused per-round readout the untraced driver already performs (this is a
    host-driven engine: its per-round syncs are its execution model, each
    audited below), so telemetry adds no transfers; only a budget-exhausted
    exit pays one extra prep to close the final entry.
    """
    from repro.obs.telemetry import trace_from_push_counts
    from repro.obs.trace import tspan

    ks = _kernel_semiring(algo)
    n, d = algo.n, algo.d
    with tspan(o.trace, "pack", algo=algo.name, n=n, d=d, engine="push",
               backend=o.backend):
        p0, r0 = _init_state(algo, ks, o.x_init)
        eps_v = (
            _eps_vec(algo, o.beta) if ks == "plus_times"
            else np.zeros(n, np.float32)
        )
        outdeg = np.bincount(algo.src, minlength=n).astype(np.int64)

        p = jnp.asarray(p0)
        r = jnp.asarray(r0)
        eps_dev = jnp.asarray(eps_v)
        prep = _make_prep(ks)
        round_jax = _make_round_jax(algo, ks) if o.backend == "jax" else None
        round_pallas = (
            _PallasRound(algo, ks, o.buckets) if o.backend == "pallas" else None
        )

    col_done = np.zeros(d, bool)
    col_rounds = np.zeros(d, np.int32)
    res_buf: list[float] = []
    sum_buf: list[float] = []
    trace_res: list[float] = []     # post-round metric per counted round
    trace_pushed: list[float] = []  # vertices settled per counted round
    open_cols: Optional[np.ndarray] = None  # last round's active columns
    touched = np.zeros(n, bool)
    pushed_total = 0
    edges_total = 0
    k = 0
    while k < o.max_iters:
        col_live = jnp.asarray(~col_done)
        active_v, res_col, metric, key, ssum = prep(p, r, eps_dev, col_live)
        res_col_h, metric_h = (np.asarray(a) for a in jax.device_get(
            (res_col, metric)
        ))  # repro: allow-host-sync(per-round pending counts drive the host frontier loop)
        if open_cols is not None:
            # close the previous round's trace entry with its post-round
            # residual — the value this prep just measured
            trace_res.append(float(np.max(np.where(open_cols, metric_h, 0.0))))
            open_cols = None
        _, active_cols, col_done, col_rounds = converge_step(
            res_col_h, 0.0, col_done, col_rounds
        )
        if bool(col_done.all()):
            break
        mask_h = np.asarray(jax.device_get(
            active_v
        ))  # repro: allow-host-sync(frontier ids select this round's scatter set)
        ids = np.nonzero(mask_h)[0]
        if len(ids) == 0:
            # live columns with zero pending rows: they are done too (their
            # res_col was 0 and converge_step just flagged them) — loop once
            # more to fold the accounting, no work to dispatch
            trace_res.append(0.0)
            trace_pushed.append(0.0)
            k += 1
            continue
        res_buf.append(float(np.max(metric_h[active_cols])))
        sum_buf.append(float(jax.device_get(
            ssum
        )))  # repro: allow-host-sync(per-round state-sum trace sample)
        touched[ids] = True
        pushed_total += int(len(ids))
        edges_total += int(outdeg[ids].sum())
        trace_pushed.append(float(len(ids)))
        open_cols = active_cols.copy()
        if round_pallas is not None:
            key_h = np.asarray(jax.device_get(
                key
            ))  # repro: allow-host-sync(priority keys drive host-side bucketing)
            p, r = round_pallas(p, r, ids, key_h)
        else:
            assert round_jax is not None
            p, r = round_jax(p, r, active_v, col_live)
        k += 1

    if open_cols is not None:
        # budget exhausted mid-frontier: one extra fused prep supplies the
        # final round's post-push metric (unconverged exits only)
        _, _, metric, _, _ = prep(p, r, eps_dev, jnp.asarray(~col_done))
        metric_h = np.asarray(jax.device_get(
            metric
        ))  # repro: allow-host-sync(final trace entry on budget-exhausted exit)
        trace_res.append(float(np.max(np.where(open_cols, metric_h, 0.0))))

    converged = bool(col_done.all())
    x = np.asarray(jax.device_get(
        p
    ), np.float32)  # repro: allow-host-sync(end-of-run RunResult readout)
    if d == 1:
        x = x[:, 0]
    res = RunResult(
        x=x,
        rounds=k,
        converged=converged,
        residuals=np.asarray(res_buf, np.float32),
        state_sums=np.asarray(sum_buf, np.float32),
        col_rounds=col_rounds.copy(),
        col_converged=col_done.copy(),
        convergence_trace=trace_from_push_counts(trace_res, trace_pushed, n=n),
    )
    res.push_stats = {
        "pushed": pushed_total,
        "edges": edges_total,
        "touched": int(touched.sum()),
        "touched_fraction": float(touched.mean()) if n else 0.0,
        "rounds": k,
    }
    return res


def run_push(
    algo: AlgoInstance,
    *,
    x_init: Optional[np.ndarray] = None,
    backend: str = "jax",
    beta: float = 1.0,
    buckets: int = 4,
    max_iters: int = 2000,
) -> RunResult:
    """Thin shim: ``solve(algo, engine="push", ...)`` with the legacy
    keyword style of the other ``run_*`` entry points."""
    o = EngineOptions(x_init=x_init, backend=backend, beta=beta,
                      buckets=buckets, max_iters=max_iters)
    validate_options("push", o, algo)
    return _solve(algo, o)
