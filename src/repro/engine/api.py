"""The one validated entry path to the iterative engines: :func:`solve`.

The engines accreted four ``run_*`` entry points with copy-pasted,
partially-incompatible keyword surfaces; each validated its own corner of
the option space (``sweeps_per_call > 1`` on ``backend="jax"`` was rejected
in two places with two messages, ``extrapolate_every`` in three). This
module replaces that with a single frozen :class:`EngineOptions` record and
a single :func:`validate_options` pass, so every invalid combination is
rejected exactly once, with one exception family:

* :class:`EngineOptionsError` (a ``ValueError``) — the option combination
  is malformed or not meaningful (unknown engine/backend, non-positive
  budgets, pallas-only knobs on the pure-JAX backend).
* :class:`EngineUnsupportedError` (both an :class:`EngineOptionsError` and
  a ``NotImplementedError``) — the combination is meaningful but this build
  does not implement it (Aitken extrapolation on a nonlinear lattice
  semiring, extrapolation under sweep batching).

``except EngineOptionsError`` therefore catches *every* rejection the entry
path can raise, while pre-existing callers that caught ``ValueError`` or
``NotImplementedError`` keep working unchanged.

The legacy entry points — ``run_sync`` / ``run_async_block`` /
``run_distributed`` — survive as thin shims over :func:`solve` with their
old signatures, and ``run_incremental``'s engine routing goes through
:func:`solve` too, so there is exactly one dispatch table and one
validation pass in the package.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Optional

import numpy as np

if TYPE_CHECKING:  # avoid a module cycle: the engines import this module
    from repro.engine.algorithms import AlgoInstance
    from repro.engine.convergence import RunResult

ENGINES = ("sync", "async_block", "distributed", "push")
BACKENDS = ("jax", "pallas")


class EngineOptionsError(ValueError):
    """An :class:`EngineOptions` combination the engines reject.

    The single exception family for the entry path: every malformed or
    unsupported option combination raises this (or the
    :class:`EngineUnsupportedError` subclass), so callers can guard one
    ``except EngineOptionsError`` instead of enumerating ValueError /
    NotImplementedError / KeyError per entry point.
    """


class EngineUnsupportedError(EngineOptionsError, NotImplementedError):
    """A meaningful option combination this build does not implement.

    Subclasses both :class:`EngineOptionsError` (the family) and
    ``NotImplementedError`` (what the pre-`solve` entry points raised for
    these cases), so both old and new handling styles catch it.
    """


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Every knob the iterative engines accept, validated in one place.

    x_init : resume/warm-start state overlaid on the algorithm's ``x0``
        (``(n,)``, ``(n, 1)`` or ``(n, d)`` — see `harness.init_state`).
    extrapolate_every : Aitken acceleration period for linear sum-semiring
        systems; 0 = off, otherwise >= 2 (see `harness.loop`).
    backend : ``"jax"`` (gather/segment-reduce sweeps) or ``"pallas"``
        (fused flat-BSR kernel; ``engine="async_block"`` only).
    bs : block size of the processing order (block engines; ignored by
        ``engine="sync"``, which runs whole-graph Jacobi rounds).
    inner : per-block refinement sweeps (block engines, jax backend).
    sweeps_per_call : sweeps batched into one persistent megakernel launch
        (pallas backend only; > 1 enables in-kernel convergence and
        active-frontier block skipping).
    frontier : bool[n] dirty-vertex seed for the megakernel's frontier
        (pallas backend with ``sweeps_per_call > 1``; None = all dirty).
    max_iters : round budget.
    mesh / axis : device mesh for ``engine="distributed"`` (None = one
        mesh axis over every visible device).
    transfer_guard : device->host transfer sanitizer for the whole solve
        (None = jax default, or one of ``"allow"`` / ``"log"`` /
        ``"disallow"``); ``"disallow"`` turns any unaudited implicit
        device->host readback inside the engines into a hard fault.
    push_threshold : frontier-fraction cutoff for ``engine="auto"``: route
        to the vertex-granular push engine when
        `engine.push.estimate_frontier_fraction` estimates fewer than this
        fraction of vertices start pending, else to the block sweep. 0
        never routes to push, 1 always does (when the semiring supports it).
    beta : push engine only — per-vertex threshold exponent, ``eps_vec =
        eps * outdeg**(1 - beta)`` (sum semirings; 1.0 = the sweep engines'
        uniform eps, < 1 = InstantGNN-style degree-normalized early stop).
    buckets : push engine, pallas backend only — priority buckets per
        round (bucket 0 = best priority settles first: delta-stepping for
        min_plus, largest-residual-first for sums).
    rank : optional processing order (``rank[v]`` = ordinal position of v,
        e.g. a `core.gograph.gograph_order` / `extend_rank` result). The
        solve runs relabeled — ``x_init`` / ``frontier`` are permuted in and
        the returned state is permuted back — so callers stay in the
        instance's id space while the engine sweeps blocks in rank order.
    trace : optional `repro.obs.trace.Tracer` — span tracing for the solve
        (``solve`` / ``pack`` / ``sweep_call`` spans; see `repro.obs`).
        None or a disabled tracer costs nothing; an enabled one records at
        batch granularity or coarser, never per round, so a traced solve
        stays green under ``transfer_guard="disallow"`` and returns results
        bitwise identical to an untraced one.
    """

    x_init: Optional[np.ndarray] = None
    extrapolate_every: int = 0
    backend: str = "jax"
    bs: int = 256
    inner: int = 1
    sweeps_per_call: int = 1
    frontier: Optional[np.ndarray] = None
    max_iters: int = 2000
    mesh: Any = None
    axis: str = "data"
    transfer_guard: Optional[str] = None
    push_threshold: float = 0.05
    beta: float = 1.0
    buckets: int = 4
    rank: Optional[np.ndarray] = None
    trace: Any = None


def validate_options(
    engine: str, o: EngineOptions, algo: "AlgoInstance | None" = None
) -> None:
    """Reject every invalid (engine, options[, algorithm]) combination.

    The one validation pass behind :func:`solve`, the ``run_*`` shims, and
    `AsyncBlockSession`. ``algo`` enables the algorithm-dependent checks
    (extrapolation requires a linear sum semiring); pass None to validate
    options whose algorithm is not known yet.
    """
    if engine not in ENGINES:
        raise EngineOptionsError(
            f"unknown engine {engine!r}; one of {sorted(ENGINES)}"
        )
    if o.backend not in BACKENDS:
        raise EngineOptionsError(
            f"unknown backend {o.backend!r}; one of {sorted(BACKENDS)}"
        )
    if o.bs < 1:
        raise EngineOptionsError(f"bs must be >= 1, got {o.bs}")
    if o.inner < 1:
        raise EngineOptionsError(f"inner must be >= 1, got {o.inner}")
    if o.max_iters < 1:
        raise EngineOptionsError(f"max_iters must be >= 1, got {o.max_iters}")
    if o.sweeps_per_call < 1:
        raise EngineOptionsError(
            f"sweeps_per_call must be >= 1, got {o.sweeps_per_call}"
        )
    if o.x_init is not None and np.ndim(o.x_init) not in (1, 2):
        raise EngineOptionsError(
            f"x_init must be (n,), (n, 1) or (n, d), "
            f"got ndim={np.ndim(o.x_init)}"
        )
    if not isinstance(o.axis, str) or not o.axis:
        raise EngineOptionsError(
            f"axis must be a non-empty mesh-axis name, got {o.axis!r}"
        )
    if o.mesh is not None and engine != "distributed":
        raise EngineOptionsError(
            "mesh names the device mesh for engine='distributed'; "
            f"engine={engine!r} runs on one device"
        )
    if o.transfer_guard not in (None, "allow", "log", "disallow"):
        raise EngineOptionsError(
            f"transfer_guard must be None, 'allow', 'log' or 'disallow', "
            f"got {o.transfer_guard!r}"
        )
    if not 0.0 <= o.push_threshold <= 1.0:
        raise EngineOptionsError(
            f"push_threshold is a frontier fraction in [0, 1], "
            f"got {o.push_threshold}"
        )
    if not 0.0 <= o.beta <= 1.0:
        raise EngineOptionsError(
            f"beta (push threshold exponent) must be in [0, 1], got {o.beta}"
        )
    if o.buckets < 1:
        raise EngineOptionsError(f"buckets must be >= 1, got {o.buckets}")
    if o.rank is not None:
        if np.ndim(o.rank) != 1:
            raise EngineOptionsError(
                f"rank must be a 1-D permutation of 0..n-1 "
                f"(rank[v] = processing position), got ndim={np.ndim(o.rank)}"
            )
        if algo is not None and len(o.rank) != algo.n:
            raise EngineOptionsError(
                f"rank covers {len(o.rank)} vertices, instance has {algo.n}"
            )
    if o.trace is not None:
        from repro.obs.trace import Tracer

        if not isinstance(o.trace, Tracer):
            raise EngineOptionsError(
                f"trace must be None or a repro.obs.trace.Tracer, "
                f"got {type(o.trace).__name__}"
            )
    if o.backend == "pallas":
        if engine not in ("async_block", "push"):
            raise EngineUnsupportedError(
                f"backend='pallas' runs the fused block-GS sweep "
                f"(engine='async_block') or the bucketed residual-push "
                f"scatter (engine='push'); engine={engine!r} has no kernel"
            )
        if o.inner != 1:
            raise EngineOptionsError(
                "backend='pallas' runs the fused sweep; inner must be 1"
            )
    elif engine != "push" and (o.sweeps_per_call != 1 or o.frontier is not None):
        raise EngineOptionsError(
            "sweeps_per_call/frontier amortize kernel launches and DMAs — "
            "pallas-backend knobs; backend='jax' supports neither"
        )
    if engine == "push":
        if o.sweeps_per_call != 1 or o.frontier is not None:
            raise EngineOptionsError(
                "engine='push' schedules its own per-round frontier; "
                "sweeps_per_call/frontier are sweep-engine knobs"
            )
        if o.inner != 1:
            raise EngineOptionsError(
                "engine='push' settles one vertex at a time; inner is a "
                "block-engine knob"
            )
        if o.extrapolate_every:
            raise EngineUnsupportedError(
                "engine='push' is itself the sparse acceleration; Aitken "
                "extrapolation applies to the dense sweep engines only"
            )
    if engine == "sync" and o.inner != 1:
        raise EngineOptionsError(
            "engine='sync' runs whole-graph Jacobi rounds; inner is a "
            "block-engine knob"
        )
    if o.extrapolate_every:
        if algo is not None and algo.semiring.reduce != "sum":
            raise EngineUnsupportedError(
                f"extrapolate_every is only valid for linear sum-semiring "
                f"systems; {algo.name!r} uses reduce={algo.semiring.reduce!r}"
            )
        if not o.extrapolate_every >= 2:
            # a period of 1 jumps every round off a rho estimated from the
            # previous jump's own step — the amplifications compound with no
            # contraction rounds between and the iteration diverges to NaN
            raise EngineOptionsError(
                f"extrapolate_every must be 0 (off) or >= 2, "
                f"got {o.extrapolate_every}"
            )
        if o.sweeps_per_call > 1 or o.frontier is not None:
            # both knobs route through the megakernel's batched driver
            raise EngineUnsupportedError(
                "extrapolate_every needs per-sweep host control; "
                "use sweeps_per_call=1"
            )


def solve(
    algo: "AlgoInstance",
    engine: str = "async_block",
    options: Optional[EngineOptions] = None,
    **overrides,
) -> "RunResult":
    """Converge ``algo`` with the chosen engine — the single entry path.

    ``engine``: ``"sync"`` (Jacobi rounds, paper Eq. 1), ``"async_block"``
    (block Gauss–Seidel, the TPU adaptation of Eq. 2), ``"distributed"``
    (shard_map supersteps: synchronous across shards, Gauss–Seidel within),
    ``"push"`` (vertex-granular residual push — the ultra-sparse regime),
    or ``"auto"`` (the frontier-size router: estimate the initial pending
    fraction via `engine.push.estimate_frontier_fraction` and pick
    ``"push"`` below ``options.push_threshold``, ``"async_block"`` above —
    or whenever the semiring has no push formulation).

    ``options`` is an :class:`EngineOptions`; keyword ``overrides`` are
    applied on top (``solve(algo, "async_block", bs=64)`` is shorthand for
    ``solve(algo, "async_block", options=EngineOptions(bs=64))``). All
    validation happens here, in :func:`validate_options`, before any engine
    code runs; the legacy ``run_*`` entry points are shims over this
    function, parity-tested bitwise for the min/max semirings.
    """
    o = options if options is not None else EngineOptions()
    if overrides:
        try:
            o = dataclasses.replace(o, **overrides)
        except TypeError:
            bad = sorted(set(overrides) - {f.name for f in dataclasses.fields(o)})
            raise EngineOptionsError(
                f"unknown EngineOptions field(s) {bad}; valid fields: "
                f"{[f.name for f in dataclasses.fields(o)]}"
            ) from None
    if engine == "auto":
        # the frontier-size router — resolved before validation so the
        # chosen engine's constraints (and only those) apply. Sweep-only
        # knobs are dropped when push wins: the router's contract is "same
        # answer, work proportional to the touched neighborhood", and a
        # caller-seeded frontier/sweep batch has no push meaning.
        from repro.engine import push as _push

        try:
            frac = _push.estimate_frontier_fraction(algo, o.x_init)
            use_push = frac < o.push_threshold
        except NotImplementedError:
            use_push = False
        if use_push:
            engine = "push"
            o = dataclasses.replace(
                o, sweeps_per_call=1, frontier=None, extrapolate_every=0,
            )
        else:
            engine = "async_block"
    validate_options(engine, o, algo)
    rank: Optional[np.ndarray] = None
    if o.rank is not None:
        # run relabeled: the engines sweep blocks of consecutive ids, so the
        # order becomes real by renaming vertex v to id rank[v]; the caller's
        # id-space vectors permute in and the result permutes back out
        from repro.engine.harness import permute_state
        from repro.graphs.graph import check_permutation

        rank = np.asarray(o.rank)
        check_permutation(rank, algo.n)
        algo = algo.relabel(rank)
        o = dataclasses.replace(
            o,
            rank=None,
            x_init=None if o.x_init is None
            else permute_state(np.asarray(o.x_init), rank),
            frontier=None if o.frontier is None
            else permute_state(np.asarray(o.frontier), rank),
        )
    # lazy imports: the engine modules import this module for the error
    # family and the shims, so the dispatch edge must not exist at import time
    from repro.engine import async_block, distributed, push, sync

    impl = {
        "sync": sync._solve,
        "async_block": async_block._solve,
        "distributed": distributed._solve,
        "push": push._solve,
    }[engine]
    from repro.obs.trace import tspan

    with tspan(o.trace, "solve", algo=algo.name, engine=engine,
               backend=o.backend, n=algo.n, d=algo.d) as sp:
        if o.transfer_guard is not None:
            import jax

            # direction-scoped on purpose: host->device staging of inputs is
            # normal engine behavior; unaudited device->host readback is the
            # bug class this sanitizer exists to catch (audited readouts go
            # through jax.device_get, which the guard always permits)
            with jax.transfer_guard_device_to_host(o.transfer_guard):
                res = impl(algo, o)
        else:
            res = impl(algo, o)
        sp.set(rounds=res.rounds, converged=bool(res.converged))
    if rank is not None:
        x = np.asarray(res.x).reshape(algo.n, -1)[rank]
        if algo.d == 1:
            x = x[:, 0]
        res = dataclasses.replace(res, x=x)
    return res
