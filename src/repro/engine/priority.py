"""Priority-scheduled block engine (Priter [52] adapted to blocks).

The paper's related work notes that *prioritized* asynchronous execution —
updating only the vertices whose state is farthest from convergence — avoids
wasted work. At block granularity this becomes: per scheduling round, select
the top-k blocks by accumulated priority and update only those.

Priority bookkeeping is done on the block dependency graph (derived from the
same block structure the kernels use): when block i's state moves by
|delta_i|, every dependent block j (one with edges i -> j) inherits priority
mass |delta_i|. The dependency graph is the O(nnz_blocks) block-CSR
skeleton from `graphs.blocked.block_dependency_structure` — one
scatter-add over its (dst block, src block) pairs per scheduling round —
replacing the old dense (nb, nb) indicator whose memory and per-round
matmul work were both quadratic in nb.

States are batched ``f32[n, d]`` like the other engines (shared pack path in
`engine.harness`); a block's priority is its state motion summed over all d
query columns, so the scheduler chases whichever query still has work left.
The round driver stays bespoke — priority rounds touch k blocks, not the
whole edge set, so the shared full-sweep driver does not apply.

Work is measured in *block updates*; a full sweep costs nb. The benchmark
(`benchmarks/priority_sched.py`) shows priority scheduling reaches the same
fixpoint in a fraction of the edge-work of full sweeps, and composes with
the GoGraph ordering (fresher selected blocks) — extending the paper's
scheduling story beyond its own experiments.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import harness
from repro.engine import jax_ops as J


def _block_dependency(
    algo: AlgoInstance, bs: int, nb: int
) -> tuple[np.ndarray, np.ndarray]:
    """Unique (dst block, src block) dependency pairs: ``dep_dst[t]``
    depends on ``dep_src[t]`` (an edge runs src-block -> dst-block). The
    block-CSR skeleton shared with the kernel packers — O(nnz_blocks), not
    the dense O(nb^2) indicator."""
    from repro.graphs.blocked import block_dependency_structure

    _, dep_dst, dep_src = block_dependency_structure(
        algo.src, algo.dst, algo.n, bs
    )
    return dep_dst, dep_src


@partial(
    jax.jit,
    static_argnames=("bs", "nb", "k_sel", "n_real", "sem_reduce", "sem_edge",
                     "comb", "res_kind", "max_rounds"),
)
def _run(
    esrc, edst, ew, emask, x0, c, fixed, dep_dst, dep_src,
    bs: int, nb: int, k_sel: int, n_real: int,
    sem_reduce: str, sem_edge: str, comb: str, res_kind: str,
    eps: float, max_rounds: int, identity: float,
):
    d = x0.shape[1]
    c_blk = c.reshape(nb, bs, d)
    fixed_blk = fixed.reshape(nb, bs, d)
    x0_blk = x0.reshape(nb, bs, d)

    def block_update(i, x):
        msgs = J.edge_op(sem_edge, x[esrc[i]], ew[i])
        msgs = jnp.where(emask[i][:, None], msgs, identity)
        agg = J.segment_reduce(sem_reduce, msgs, edst[i], bs, identity)
        old = jax.lax.dynamic_slice(x, (i * bs, 0), (bs, d))
        new = J.combine(comb, agg, c_blk[i], old, fixed_blk[i], x0_blk[i])
        delta = jnp.sum(jnp.abs(jnp.where(jnp.abs(new) < 1e30, new, 0)
                                - jnp.where(jnp.abs(old) < 1e30, old, 0)))
        return jax.lax.dynamic_update_slice(x, new, (i * bs, 0)), delta

    def round_fn(state):
        x, prio, k, res, tot_updates = state
        _, sel = jax.lax.top_k(prio, k_sel)

        def body(t, carry):
            x, deltas = carry
            i = sel[t]
            x, dlt = block_update(i, x)
            return x, deltas.at[t].set(dlt)

        x_new, deltas = jax.lax.fori_loop(
            0, k_sel, body, (x, jnp.zeros((k_sel,), jnp.float32))
        )
        # processed blocks hand their priority to dependents: one
        # scatter-add over the O(nnz_blocks) dependency pairs (delta_vec is
        # nonzero only at the selected blocks, so untouched pairs add 0)
        delta_vec = jnp.zeros((nb,), jnp.float32).at[sel].set(deltas)
        prio = prio.at[sel].set(0.0)
        prio = prio.at[dep_dst].add(delta_vec[dep_src])
        # stop only when this round moved nothing AND no pending priority
        # remains anywhere (selected-quiet != converged)
        res = jnp.maximum(jnp.sum(delta_vec), jnp.max(prio))
        return x_new, prio, k + 1, res, tot_updates + k_sel

    def cond(state):
        _, _, k, res, _ = state
        return jnp.logical_and(k < max_rounds, res > eps)

    init = (x0, jnp.full((nb,), 1e30, jnp.float32), jnp.int32(0),
            jnp.float32(jnp.inf), jnp.int32(0))
    x, prio, k, res, tot = jax.lax.while_loop(cond, round_fn, init)
    return x, k, res, tot


def run_priority_block(
    algo: AlgoInstance, bs: int = 128, select_frac: float = 0.25,
    max_rounds: int = 20000,
) -> RunResult:
    """Returns a RunResult whose `rounds` is *equivalent full sweeps*
    (total block updates / nb) — directly comparable to the other engines'
    round counts in work terms.

    Per-column bookkeeping: the scheduler stops on the *total* priority mass
    across all d columns, which bounds every column's mass, so
    ``col_converged`` is filled (all columns share the aggregate verdict).
    ``col_rounds`` stays None — work-proportional scheduling has no
    per-query round count."""
    be, x0, c, fixed, npad = harness.pack(algo, bs)
    nb = be.nb
    k_sel = max(1, int(round(nb * select_frac)))
    dep_dst, dep_src = _block_dependency(algo, bs, nb)
    # priority scheduling needs an accumulated-change signal; for "changed"
    # algorithms (SSSP/BFS/CC) the L1 delta works identically. The threshold
    # is NOT scaled by d: total mass <= eps bounds every column's mass, so a
    # batched run is at least as converged per query as a scalar run.
    eps = algo.eps if algo.residual != "linf" else algo.eps * max(1, algo.n) * 0.01
    x, k, res, tot = _run(
        jnp.asarray(be.esrc), jnp.asarray(be.edst), jnp.asarray(be.ew),
        jnp.asarray(be.emask), jnp.asarray(x0), jnp.asarray(c),
        jnp.asarray(fixed), jnp.asarray(dep_dst), jnp.asarray(dep_src),
        bs=bs, nb=nb, k_sel=k_sel, n_real=algo.n,
        sem_reduce=algo.semiring.reduce, sem_edge=algo.semiring.edge_op,
        comb=algo.combine, res_kind=algo.residual,
        eps=float(eps), max_rounds=max_rounds,
        identity=algo.semiring.identity,
    )
    xr = np.asarray(x)[: algo.n]
    if algo.d == 1:
        xr = xr[:, 0]
    finite = xr[np.abs(xr) < 1e30]
    converged = bool(res <= eps)
    return RunResult(
        x=xr,
        rounds=float(tot) / nb,
        converged=converged,
        residuals=np.asarray([float(res)]),
        state_sums=np.asarray([float(finite.sum()) if len(finite) else 0.0]),
        col_converged=np.full((algo.d,), converged),
    )
