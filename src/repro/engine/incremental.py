"""Incremental serving engine for evolving graphs (delta-based warm-start).

Production serving re-answers the same queries on graphs that change under
them; recomputing from ``x0`` after every edge batch throws away exactly the
rounds the GoGraph ordering saved. This engine absorbs a
:class:`~repro.graphs.delta.GraphDelta` into an already-converged
:class:`RunResult` instead, iterating only on what the delta perturbed. Two
regimes, chosen by the algorithm's semiring:

**Sum semirings** (pagerank / katz / ppr / adsorption / php) are linear:
``x* = c + W x*``. After a mutation (W, c) -> (W', c'), the correction
``delta* = x'* - x_warm`` solves the *same* linear system with the dense
residual ``r = c' + W' x_warm - x_warm`` as its constant term (Maiter's
delta-based accumulative iteration). We build that delta instance and drive
it through the ordinary engines — the shared round driver `harness.loop` —
from ``delta = 0``. Because the delta system is linear with an arbitrary
sign pattern (deletions make ``r`` signed), the paper's monotone-semiring
restrictions don't bind it, and the driver's Aitken extrapolation
(``extrapolate_every``) legally accelerates it: the iteration matrix W' is
entrywise nonnegative, so the dominant (Perron) mode is real and the
geometric-tail jump ``step * rho / (1 - rho)`` is well conditioned.

**Min/max semirings** (sssp / bfs / cc / sswp) are lattice fixpoints.
*Tightening* deltas — insertions, plus reweights that move edges in the
reduce direction — can only move the fixpoint further along the monotone
direction, so the converged state is a valid bound and the engines'
``min_old`` / ``max_old`` combine re-lowers (re-raises) it directly via
``x_init``. *Loosening* deltas (deletions; reweights against the reduce
direction) can invalidate converged values, and a min-fixpoint can never be
raised by iteration — so the affected *region* (everything reachable from
the loosened edges' destinations in the mutated graph) is masked back to
``x0`` and recomputed, while the untouched remainder keeps serving its warm
values. Every warm value outside the region is witnessed by a surviving
path, so the masked state stays a valid bound and the iteration converges to
the exact new fixpoint (bitwise — the per-edge relaxations are the same f32
programs a cold run executes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine.harness import permute_state
from repro.graphs.delta import out_closure
from repro.graphs.graph import Graph

__all__ = [
    "EdgeDiff", "instance_edge_diff", "warm_state", "dense_residual",
    "affected_region", "run_incremental", "permute_state",
]

# Aitken period for the linear delta systems: frequent enough to matter on
# short warm runs, spaced enough that modes re-mix between jumps.
DEFAULT_EXTRAPOLATE_EVERY = 4


@dataclasses.dataclass(frozen=True)
class EdgeDiff:
    """Instance-level edge diff (on the *transformed* weights, so implicit
    reweights like PageRank's out-degree renormalization are included)."""

    added_dst: np.ndarray      # int32 — dsts of edges only in the new instance
    removed_dst: np.ndarray    # int32 — dsts of edges only in the old instance
    tightened_dst: np.ndarray  # int32 — surviving edges moved along the reduce dir
    loosened_dst: np.ndarray   # int32 — surviving edges moved against it

    @property
    def loosening(self) -> bool:
        """True when the delta can move the fixpoint *against* the monotone
        direction (requires the masked regional recompute for min/max)."""
        return len(self.removed_dst) > 0 or len(self.loosened_dst) > 0


def instance_edge_diff(old: AlgoInstance, new: AlgoInstance) -> EdgeDiff:
    """Diff two min/max-semiring instances of the same algorithm over
    (possibly) different graphs. Parallel edges are collapsed to their
    effective weight under the instance's reduce (min for min-semirings,
    max for max). Sum semirings never need a diff — their incremental path
    works off the dense residual — and tighter/looser has no meaning for
    them, so they are rejected."""
    if new.semiring.reduce not in ("min", "max"):
        raise ValueError(
            f"edge diffs classify tightening/loosening for min/max "
            f"semirings only, not reduce={new.semiring.reduce!r}"
        )
    n = max(old.n, new.n)

    def eff(algo: AlgoInstance) -> tuple[np.ndarray, np.ndarray]:
        key = algo.src.astype(np.int64) * n + algo.dst
        uniq, inv = np.unique(key, return_inverse=True)
        if algo.semiring.reduce == "min":
            w = np.full(len(uniq), np.inf)
            np.minimum.at(w, inv, algo.w.astype(np.float64))
        else:
            w = np.full(len(uniq), -np.inf)
            np.maximum.at(w, inv, algo.w.astype(np.float64))
        return uniq, w

    ko, wo = eff(old)
    kn, wn = eff(new)
    added = np.setdiff1d(kn, ko, assume_unique=True)
    removed = np.setdiff1d(ko, kn, assume_unique=True)
    common, io_, in_ = np.intersect1d(ko, kn, assume_unique=True,
                                      return_indices=True)
    dw = wn[in_] - wo[io_]
    # "tighter" moves the fixpoint along the monotone direction: lower
    # weights for min-reduce (shorter paths), higher for max-reduce (wider).
    if new.semiring.reduce == "min":
        tightened, loosened = common[dw < 0], common[dw > 0]
    else:
        tightened, loosened = common[dw > 0], common[dw < 0]

    def dsts(keys: np.ndarray) -> np.ndarray:
        return (keys % n).astype(np.int32)

    return EdgeDiff(dsts(added), dsts(removed), dsts(tightened), dsts(loosened))


def warm_state(algo_new: AlgoInstance, algo_old: AlgoInstance,
               prior: Union[RunResult, np.ndarray]) -> np.ndarray:
    """Overlay a prior converged state onto the new instance's ``x0``:
    surviving vertices keep their values, appended vertices start cold."""
    x_prior = np.asarray(getattr(prior, "x", prior), np.float32)
    x_prior = x_prior.reshape(algo_old.n, -1)
    if x_prior.shape[1] != algo_new.d:
        raise ValueError(
            f"prior state has {x_prior.shape[1]} query columns, "
            f"new instance has {algo_new.d}"
        )
    x = algo_new.x0.astype(np.float32).copy()
    x[: algo_old.n] = x_prior
    # pinned vertices always serve their pin value, not a stale prior
    x = np.where(algo_new.fixed, algo_new.x0, x)
    return x


def dense_residual(algo: AlgoInstance, x: np.ndarray) -> np.ndarray:
    """``F(x) - x`` for a sum-semiring instance (f64 accumulate, f32 out);
    zero at pinned vertices."""
    assert algo.combine == "replace" and algo.semiring.reduce == "sum"
    assert algo.semiring.edge_op == "mul", algo.semiring
    x = np.asarray(x, np.float64).reshape(algo.n, -1)
    msgs = x[algo.src] * algo.w.astype(np.float64)[:, None]
    agg = np.zeros_like(x)
    np.add.at(agg, algo.dst, msgs)
    r = algo.c.astype(np.float64) + agg - x
    return np.where(algo.fixed, 0.0, r).astype(np.float32)


def affected_region(algo: AlgoInstance, seeds: np.ndarray) -> np.ndarray:
    """bool[n] — vertices reachable from ``seeds`` along the instance's
    out-edges. Anything whose converged value could have depended on a
    loosened edge lies downstream of that edge's destination; paths through
    *other* removed edges are covered because their destinations seed too."""
    n = algo.n
    reach = np.zeros(n, bool)
    seeds = np.unique(np.asarray(seeds, np.int64))
    if len(seeds) == 0:
        return reach
    indptr, nbrs, _ = Graph(n, algo.src, algo.dst, algo.w).csr()
    reach[seeds] = True
    frontier = seeds
    while len(frontier):
        counts = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
        total = int(counts.sum())
        if total == 0:
            break
        starts = np.repeat(indptr[frontier], counts)
        offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
        nxt = np.unique(nbrs[starts + offs])
        nxt = nxt[~reach[nxt]]
        reach[nxt] = True
        frontier = nxt
    return reach


def _dispatch(engine: str, algo: AlgoInstance, *,
              x_init: Optional[np.ndarray] = None,
              extrapolate_every: int = 0, **kw: Any) -> RunResult:
    # the engine string table IS solve()'s dispatch now: one validation
    # pass, one set of error messages, for direct and incremental runs alike
    from repro.engine.api import solve

    return solve(algo, engine=engine, x_init=x_init,
                 extrapolate_every=extrapolate_every, **kw)


def run_incremental(
    algo_new: AlgoInstance,
    algo_old: AlgoInstance,
    prior: Union[RunResult, np.ndarray],
    *,
    engine: str = "async_block",
    extrapolate_every: Optional[int] = None,
    rank: Optional[np.ndarray] = None,
    **engine_kw: Any,
) -> RunResult:
    """Converge ``algo_new`` warm-started from ``prior`` (converged on
    ``algo_old``); both instances must come from the same constructor in the
    same id space with old ids a prefix of the new (see
    :func:`repro.engine.algorithms.remake`).

    ``rank`` optionally supplies a processing order (e.g. from
    `core.gograph.extend_rank`); the iteration runs relabeled but the
    returned state is always in the instances' id space, so serving code
    never sees the ordering.

    Extra ``engine_kw`` are forwarded to the engine, so
    ``engine="async_block", backend="pallas"`` serves the warm re-run through
    the fused flat-BSR `gs_sweep` kernel: the sum path's delta system packs
    like any other "replace" instance (its residual constant rides the ``c``
    operand), and the min/max paths' warm states enter the kernel through
    ``x_init`` — including the max-semiring workloads (sswp/reachability) the
    kernels now implement. Adding ``sweeps_per_call=R`` batches R sweeps per
    persistent megakernel launch, and this function then also seeds the
    kernel's active frontier with exactly the delta-touched blocks: for sum
    semirings the rows where the dense residual is nonzero (everything else
    solves the delta system at its 0 start bitwise), for min/max the
    destinations of mutated edges, the masked recompute region, and the
    appended vertices (every other block's warm value stays self-consistent
    under the monotone combine, so skipping it until a neighbor moves is a
    bitwise no-op). An explicit ``frontier=`` in ``engine_kw`` overrides the
    seeding.

    Returns an ordinary :class:`RunResult` whose ``x`` is the new fixpoint
    and whose ``rounds`` / traces are those of the *incremental* run only —
    for sum semirings they describe the delta system, whose per-round changes
    equal the full system's by linearity.
    """
    if algo_old.name != algo_new.name or algo_old.d != algo_new.d:
        raise ValueError(
            f"instance mismatch: {algo_old.name}/d={algo_old.d} vs "
            f"{algo_new.name}/d={algo_new.d}"
        )
    x_warm = warm_state(algo_new, algo_old, prior)

    # seed the megakernel's active frontier from the delta-touched blocks
    # when the caller asked for sweep batching and didn't pin one themselves
    seed_frontier = (
        engine == "async_block"
        and engine_kw.get("backend") == "pallas"
        and int(engine_kw.get("sweeps_per_call", 1)) > 1
        and "frontier" not in engine_kw
    )

    def _run_relabeled(
        algo: AlgoInstance, x_init: Optional[np.ndarray]
    ) -> RunResult:
        """Run `algo` under `rank` (or directly), returning id-space x.

        All the relabel mechanics — permuting x_init/frontier in and the
        result back out — live in ``solve(rank=...)`` now; this wrapper only
        threads the order through."""
        return _dispatch(engine, algo, x_init=x_init, rank=rank, **run_kw)

    if algo_new.semiring.reduce == "sum":
        if extrapolate_every is None:
            # Aitken needs per-sweep host control; the sweep-batched driver
            # only syncs per batch, so it runs unaccelerated — and the push
            # engine is itself the sparse acceleration ("auto" drops the
            # period in solve() if and when it routes to push)
            extrapolate_every = (
                0 if (engine == "push"
                      or int(engine_kw.get("sweeps_per_call", 1)) > 1)
                else DEFAULT_EXTRAPOLATE_EVERY
            )
        run_kw = dict(engine_kw, extrapolate_every=extrapolate_every)
        r = dense_residual(algo_new, x_warm)
        if seed_frontier:
            # the delta system starts at 0: any block with an all-zero
            # residual already satisfies its equation bitwise at that start
            run_kw["frontier"] = np.any(r != 0, axis=1)
        delta_algo = dataclasses.replace(
            algo_new,
            x0=np.zeros_like(x_warm),
            c=r,
            fixed=algo_new.fixed.copy(),
            exact_fn=None,
        )
        res = _run_relabeled(delta_algo, None)
        delta = np.asarray(res.x, np.float32).reshape(x_warm.shape)
        x_full = x_warm + delta
        if algo_new.d == 1:
            x_full = x_full[:, 0]
        return dataclasses.replace(res, x=x_full)

    # min/max semirings: monotone re-lowering / re-raising, with a masked
    # regional recompute when the delta loosens the fixpoint. An explicit
    # extrapolation request is an error here, same as at the engines.
    from repro.engine.harness import check_extrapolation

    check_extrapolation(algo_new, extrapolate_every or 0)
    run_kw = dict(engine_kw, extrapolate_every=0)
    diff = instance_edge_diff(algo_old, algo_new)
    region = None
    if diff.loosening:
        seeds = np.concatenate([diff.removed_dst, diff.loosened_dst])
        region = affected_region(algo_new, seeds)
        x_warm = np.where(region[:, None], algo_new.x0, x_warm)
    if seed_frontier:
        # every warm block outside this set is the old fixpoint fed unchanged
        # in-edges, so its recompute is a bitwise no-op until a neighbor moves
        verts = out_closure(
            algo_new.src, algo_new.dst,
            np.concatenate([diff.added_dst, diff.removed_dst,
                            diff.tightened_dst, diff.loosened_dst]),
            algo_new.n, depth=0,
        )
        verts[algo_old.n:] = True  # appended vertices start at x0
        if region is not None:
            verts |= region
        run_kw["frontier"] = verts
    return _run_relabeled(algo_new, x_warm)
