"""Synchronous (Jacobi) engine — paper Eq. 1.

Every round recomputes all vertices from the *previous* round's states:
one full segment-reduce over the edge set inside the shared round driver
(`engine.harness.loop`). This is the paper's "Sync" baseline mode.

States are batched ``f32[n, d]`` (column j = independent query j, e.g. one
personalized-PageRank seed); convergence is per column — a converged column
freezes and stops contributing to the residual, so each query reports its
own round count. ``d = 1`` is the scalar mode and matches the paper's runs.

``x_init`` warm-starts the loop from a prior state (checkpointed
macro-stepping or the incremental serving engine) while ``x0`` keeps pinning
fixed vertices; ``extrapolate_every`` turns on the shared driver's Aitken
acceleration (linear sum-semiring systems only — see `harness.loop`).
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import harness
from repro.engine import jax_ops as J


@partial(jax.jit, static_argnames=("n", "sem_reduce", "sem_edge", "comb", "res_kind",
                                   "max_iters", "extrapolate_every"))
def _run(
    src, dst, w, x_start, x0, c, fixed,
    n: int, sem_reduce: str, sem_edge: str, comb: str, res_kind: str,
    eps: float, max_iters: int, identity: float, extrapolate_every: int,
):
    def round_fn(x):
        msgs = J.edge_op(sem_edge, x[src], w)
        agg = J.segment_reduce(sem_reduce, msgs, dst, n, identity)
        return J.combine(comb, agg, c, x, fixed, x0)

    return harness.loop(
        round_fn, x_start, res_kind=res_kind, eps=eps, max_iters=max_iters,
        extrapolate_every=extrapolate_every,
    )


def _solve(algo: AlgoInstance, o) -> RunResult:
    """Engine body behind ``solve(algo, engine="sync", ...)``; options are
    already validated (`engine.api.validate_options`)."""
    arrs = J.device_arrays(algo)
    x_start = harness.init_state(np.asarray(algo.x0), o.x_init, algo.n)
    out = _run(
        arrs["src"], arrs["dst"], arrs["w"],
        jax.numpy.asarray(x_start), arrs["x0"], arrs["c"], arrs["fixed"],
        n=algo.n,
        sem_reduce=algo.semiring.reduce,
        sem_edge=algo.semiring.edge_op,
        comb=algo.combine,
        res_kind=algo.residual,
        eps=algo.eps,
        max_iters=o.max_iters,
        identity=algo.semiring.identity,
        extrapolate_every=o.extrapolate_every,
    )
    return harness.finalize(algo, *out)


def run_sync(
    algo: AlgoInstance, max_iters: int = 2000,
    x_init: np.ndarray | None = None, extrapolate_every: int = 0,
) -> RunResult:
    """Thin shim over ``solve(algo, engine="sync")`` — kept for the legacy
    keyword spelling; parity-tested bitwise against `engine.api.solve`."""
    from repro.engine.api import EngineOptions, solve

    return solve(algo, engine="sync", options=EngineOptions(
        max_iters=max_iters, x_init=x_init,
        extrapolate_every=extrapolate_every,
    ))
