"""Synchronous (Jacobi) engine — paper Eq. 1.

Every round recomputes all vertices from the *previous* round's states:
one full segment-reduce over the edge set inside a ``lax.while_loop``.
This is the paper's "Sync" baseline mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import jax_ops as J


@partial(jax.jit, static_argnames=("n", "sem_reduce", "sem_edge", "comb", "res_kind", "max_iters"))
def _run(
    src, dst, w, x0, c, fixed,
    n: int, sem_reduce: str, sem_edge: str, comb: str, res_kind: str,
    eps: float, max_iters: int, identity: float,
):
    res_buf = jnp.zeros((max_iters,), jnp.float32)
    sum_buf = jnp.zeros((max_iters,), jnp.float32)

    def round_fn(x):
        msgs = J.edge_op(sem_edge, x[src], w)
        agg = J.segment_reduce(sem_reduce, msgs, dst, n, identity)
        return J.combine(comb, agg, c, x, fixed, x0)

    def cond(state):
        _, k, res, _, _ = state
        return jnp.logical_and(k < max_iters, res > eps)

    def body(state):
        x, k, _, res_buf, sum_buf = state
        x_new = round_fn(x)
        res = J.residual(res_kind, x_new, x)
        res_buf = res_buf.at[k].set(res)
        sum_buf = sum_buf.at[k].set(jnp.sum(jnp.where(jnp.abs(x_new) < 1e30, x_new, 0.0)))
        return x_new, k + 1, res, res_buf, sum_buf

    init = (x0, jnp.int32(0), jnp.float32(jnp.inf), res_buf, sum_buf)
    x, k, res, res_buf, sum_buf = jax.lax.while_loop(cond, body, init)
    return x, k, res, res_buf, sum_buf


def run_sync(algo: AlgoInstance, max_iters: int = 2000) -> RunResult:
    arrs = J.device_arrays(algo)
    x, k, res, res_buf, sum_buf = _run(
        arrs["src"], arrs["dst"], arrs["w"], arrs["x0"], arrs["c"], arrs["fixed"],
        n=algo.n,
        sem_reduce=algo.semiring.reduce,
        sem_edge=algo.semiring.edge_op,
        comb=algo.combine,
        res_kind=algo.residual,
        eps=algo.eps,
        max_iters=max_iters,
        identity=algo.semiring.identity,
    )
    k = int(k)
    return RunResult(
        x=np.asarray(x),
        rounds=k,
        converged=bool(res <= algo.eps),
        residuals=np.asarray(res_buf)[:k],
        state_sums=np.asarray(sum_buf)[:k],
    )
