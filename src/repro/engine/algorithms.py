"""Iterative graph algorithms as monotonic update-function specs (paper §II/III).

Every algorithm is normalized to the template

    x_v  <-  combine( c_v ,  REDUCE_{(u,v) in E}  edge_op(x_u, w'_uv) ,  x_v )

with a *monotonic* update function F (paper Eq. 3), which is what licenses the
asynchronous mode: consuming fresher in-neighbor states can only move a vertex
closer to its converged value (Lemma 1 / Theorem 1).

Instances carry their own edge arrays (CC symmetrizes; PageRank-style
algorithms bake d/|OUT(u)| into the edge weight), so engines only ever see an
:class:`AlgoInstance` and never touch the Graph again.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.graphs.graph import Graph

BIG = np.float32(3.0e38)  # stand-in for +inf that survives f32 arithmetic


@dataclasses.dataclass(frozen=True)
class Semiring:
    reduce: str   # "sum" | "min" | "max"
    edge_op: str  # "mul" | "add" | "min"

    @property
    def identity(self) -> float:
        return {"sum": 0.0, "min": float(BIG), "max": float(-BIG)}[self.reduce]


@dataclasses.dataclass
class AlgoInstance:
    """A concrete algorithm bound to a concrete graph.

    State is *batched*: ``x0``, ``c``, ``fixed`` are ``(n, d)`` where column j
    is an independent query (e.g. one personalized-PageRank seed or one SSSP
    source). Scalar constructors pass 1-D arrays and are normalized to
    ``d = 1`` here; every engine runs all columns in lockstep with per-column
    convergence, so ``d = 1`` reproduces the scalar behavior exactly.
    """

    name: str
    n: int
    src: np.ndarray        # int32[m]      edge sources
    dst: np.ndarray        # int32[m]      edge destinations
    w: np.ndarray          # float32[m]    transformed edge weights w'
    x0: np.ndarray         # float32[n, d] initial states
    c: np.ndarray          # float32[n, d] per-vertex constants
    fixed: np.ndarray      # bool[n, d]    vertices pinned at x0 (e.g. PHP target)
    semiring: Semiring
    combine: str           # "replace" (c + agg) | "min_old" | "max_old"
    residual: str          # "linf" | "l1" | "changed"
    eps: float
    monotone_dir: int      # +1 increasing toward fixpoint, -1 decreasing
    exact_fn: Optional[Callable[[], np.ndarray]] = None
    # constructor keyword args, recorded so `remake` can rebuild the same
    # algorithm on a mutated graph (incremental serving). Vertex-id-valued
    # params (source/seeds/target) are in the constructor's id space, so
    # `remake` is only valid before any `relabel`.
    params: Optional[dict] = None

    def __post_init__(self):
        for f in ("x0", "c", "fixed"):
            a = np.asarray(getattr(self, f))
            if a.ndim == 1:
                a = a.reshape(self.n, 1)
            setattr(self, f, a)
        if not (self.x0.shape == self.c.shape == self.fixed.shape):
            raise ValueError(
                f"x0/c/fixed shapes disagree: {self.x0.shape} "
                f"{self.c.shape} {self.fixed.shape}"
            )

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def d(self) -> int:
        """Number of queries batched in the state columns."""
        return int(self.x0.shape[1])

    @property
    def c_pad_fill(self) -> float:
        """Padding fill for the constant vector `c`: additive 0.0 under
        "replace" combine, the reduce identity otherwise (0.0 is absorbing
        under min/max and would corrupt padding rows)."""
        return 0.0 if self.combine == "replace" else self.semiring.identity

    def exact(self) -> np.ndarray:
        assert self.exact_fn is not None
        return self.exact_fn()

    def relabel(self, rank: np.ndarray) -> "AlgoInstance":
        """Apply a processing order: vertex v becomes id rank[v]."""
        rank = np.asarray(rank)
        inv = np.empty_like(rank)
        inv[rank] = np.arange(len(rank))
        return dataclasses.replace(
            self,
            src=rank[self.src].astype(np.int32),
            dst=rank[self.dst].astype(np.int32),
            w=self.w.copy(),
            x0=self.x0[inv].copy(),
            c=self.c[inv].copy(),
            fixed=self.fixed[inv].copy(),
            exact_fn=(lambda: self.exact()[inv]) if self.exact_fn is not None else None,
            # id-valued params (source/seeds/target) are now stale; dropping
            # them makes `remake` on a relabeled instance fail loudly
            params=None,
        )


# --------------------------------------------------------------------------
# constructors
# --------------------------------------------------------------------------

def make_pagerank(g: Graph, damping: float = 0.85, eps: float = 1e-6) -> AlgoInstance:
    """x_v = (1-d) + d * sum_{u in IN(v)} x_u / |OUT(u)|  (unnormalized PR).

    Started from x0 = 0 the iterates increase monotonically toward the
    fixpoint, which is the monotone form the paper's theory needs.
    """
    outdeg = np.maximum(g.out_degrees(), 1).astype(np.float32)
    w = (damping * g.weights / outdeg[g.src]).astype(np.float32)
    return AlgoInstance(
        name="pagerank", n=g.n, src=g.src.copy(), dst=g.dst.copy(), w=w,
        x0=np.zeros(g.n, np.float32),
        c=np.full(g.n, 1.0 - damping, np.float32),
        fixed=np.zeros(g.n, bool),
        semiring=Semiring("sum", "mul"), combine="replace",
        residual="linf", eps=eps, monotone_dir=+1,
        exact_fn=lambda: _exact_linear_sum(g.n, g.src, g.dst, w,
                                           np.full(g.n, 1.0 - damping, np.float32)),
        params={"damping": damping, "eps": eps},
    )


def make_katz(g: Graph, alpha: float = 0.05, beta: float = 1.0, eps: float = 1e-6) -> AlgoInstance:
    w = np.full(g.m, alpha, np.float32) * g.weights
    return AlgoInstance(
        name="katz", n=g.n, src=g.src.copy(), dst=g.dst.copy(), w=w,
        x0=np.zeros(g.n, np.float32), c=np.full(g.n, beta, np.float32),
        fixed=np.zeros(g.n, bool),
        semiring=Semiring("sum", "mul"), combine="replace",
        residual="linf", eps=eps, monotone_dir=+1,
        exact_fn=lambda: _exact_linear_sum(g.n, g.src, g.dst, w,
                                           np.full(g.n, beta, np.float32)),
        params={"alpha": alpha, "beta": beta, "eps": eps},
    )


def make_php(g: Graph, target: int = 0, penalty: float = 0.8, eps: float = 1e-6) -> AlgoInstance:
    """Penalized hitting probability toward `target` (paper workload PHP):
    x_t = 1 pinned; x_v = p * sum_{u in IN(v)} x_u / |OUT(u)|."""
    outdeg = np.maximum(g.out_degrees(), 1).astype(np.float32)
    w = (penalty * g.weights / outdeg[g.src]).astype(np.float32)
    x0 = np.zeros(g.n, np.float32)
    x0[target] = 1.0
    fixed = np.zeros(g.n, bool)
    fixed[target] = True
    return AlgoInstance(
        name="php", n=g.n, src=g.src.copy(), dst=g.dst.copy(), w=w,
        x0=x0, c=np.zeros(g.n, np.float32), fixed=fixed,
        semiring=Semiring("sum", "mul"), combine="replace",
        residual="linf", eps=eps, monotone_dir=+1,
        exact_fn=lambda: _exact_linear_sum(g.n, g.src, g.dst, w,
                                           np.zeros(g.n, np.float32),
                                           fixed=fixed, x_fixed=x0),
        params={"target": target, "penalty": penalty, "eps": eps},
    )


def make_adsorption(
    g: Graph, seeds: Optional[np.ndarray] = None,
    p_inj: float = 0.25, p_cont: float = 0.75, eps: float = 1e-6,
) -> AlgoInstance:
    """Scalar-label Adsorption [18]: x_v = p_inj*I_v + p_cont * mean_in x_u."""
    indeg = np.maximum(g.in_degrees(), 1).astype(np.float32)
    w = (p_cont * g.weights / indeg[g.dst]).astype(np.float32)
    seeds = np.asarray(seeds if seeds is not None else [0])
    c = np.zeros(g.n, np.float32)
    c[seeds] = p_inj
    return AlgoInstance(
        name="adsorption", n=g.n, src=g.src.copy(), dst=g.dst.copy(), w=w,
        x0=np.zeros(g.n, np.float32), c=c, fixed=np.zeros(g.n, bool),
        semiring=Semiring("sum", "mul"), combine="replace",
        residual="linf", eps=eps, monotone_dir=+1,
        exact_fn=lambda: _exact_linear_sum(g.n, g.src, g.dst, w, c),
        params={"seeds": seeds, "p_inj": p_inj, "p_cont": p_cont, "eps": eps},
    )


def make_sssp(g: Graph, source: int = 0, eps: float = 0.5) -> AlgoInstance:
    """x_v = min(x_v, min_u x_u + w_uv); converged when nothing changes.

    ``eps`` thresholds the "changed" residual (#state entries that moved this
    round); the 0.5 default means "stop when nothing changes".
    """
    x0 = np.full(g.n, BIG, np.float32)
    x0[source] = 0.0
    return AlgoInstance(
        name="sssp", n=g.n, src=g.src.copy(), dst=g.dst.copy(),
        w=g.weights.copy(), x0=x0, c=np.full(g.n, BIG, np.float32),
        fixed=np.zeros(g.n, bool),
        semiring=Semiring("min", "add"), combine="min_old",
        residual="changed", eps=eps, monotone_dir=-1,
        exact_fn=lambda: _exact_dijkstra(g, source),
        params={"source": source, "eps": eps},
    )


def make_bfs(g: Graph, source: int = 0, eps: float = 0.5) -> AlgoInstance:
    """Hop counts = SSSP with unit weights."""
    inst = make_sssp(Graph(g.n, g.src.copy(), g.dst.copy(), None), source, eps=eps)
    return dataclasses.replace(
        inst, name="bfs", w=np.ones(g.m, np.float32),
        params={"source": source, "eps": eps},
    )


def make_cc(g: Graph) -> AlgoInstance:
    """Connected components by min-label propagation over symmetrized edges."""
    src = np.concatenate([g.src, g.dst])
    dst = np.concatenate([g.dst, g.src])
    x0 = np.arange(g.n, dtype=np.float32)

    def _exact() -> np.ndarray:
        parent = np.arange(g.n)

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for u, v in zip(g.src, g.dst, strict=True):
            ra, rb = find(int(u)), find(int(v))
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)
        roots = np.array([find(v) for v in range(g.n)])
        # min label within each component
        out = np.full(g.n, np.inf)
        np.minimum.at(out, roots, np.arange(g.n, dtype=np.float64))
        return out[roots].astype(np.float32)

    return AlgoInstance(
        name="cc", n=g.n, src=src.astype(np.int32), dst=dst.astype(np.int32),
        w=np.zeros(len(src), np.float32), x0=x0, c=np.full(g.n, BIG, np.float32),
        fixed=np.zeros(g.n, bool),
        semiring=Semiring("min", "add"), combine="min_old",
        residual="changed", eps=0.5, monotone_dir=-1,
        exact_fn=_exact,
        params={},
    )


def make_sswp(g: Graph, source: int = 0) -> AlgoInstance:
    """Single-source widest path: x_v = max(x_v, max_u min(x_u, w_uv))."""
    if g.w is None:
        raise ValueError("SSWP needs edge weights")
    x0 = np.zeros(g.n, np.float32)
    x0[source] = BIG

    def _exact() -> np.ndarray:
        import heapq

        width = np.zeros(g.n, np.float32)
        width[source] = BIG
        indptr, nbrs, eid = g.csr()
        w = g.weights
        heap = [(-float(BIG), source)]
        done = np.zeros(g.n, bool)
        while heap:
            negw, v = heapq.heappop(heap)
            if done[v]:
                continue
            done[v] = True
            for j in range(indptr[v], indptr[v + 1]):
                u = nbrs[j]
                cand = min(-negw, float(w[eid[j]]))
                if cand > width[u]:
                    width[u] = cand
                    heapq.heappush(heap, (-cand, int(u)))
        return width

    return AlgoInstance(
        name="sswp", n=g.n, src=g.src.copy(), dst=g.dst.copy(),
        w=g.weights.copy(), x0=x0, c=np.full(g.n, -BIG, np.float32),
        fixed=np.zeros(g.n, bool),
        semiring=Semiring("max", "min"), combine="max_old",
        residual="changed", eps=0.5, monotone_dir=+1,
        exact_fn=_exact,
        params={"source": source},
    )


def make_reachability(g: Graph, source: int = 0) -> AlgoInstance:
    """0/1 reachability from ``source`` as a max-times fixpoint:
    x_v = max(x_v, max_{u in IN(v)} x_u * 1) with x_source = 1.

    The (max, mul) semiring is the second max-reduce pair the fused kernels
    implement (`max_times`); states stay in {0, 1}, so the nonnegative-state
    contract that semiring's 0-fill relies on holds by construction.
    """
    x0 = np.zeros(g.n, np.float32)
    x0[source] = 1.0

    def _exact() -> np.ndarray:
        reach = np.zeros(g.n, bool)
        reach[source] = True
        indptr, nbrs, _ = g.csr()
        frontier = [source]
        while frontier:
            v = frontier.pop()
            for j in range(indptr[v], indptr[v + 1]):
                u = int(nbrs[j])
                if not reach[u]:
                    reach[u] = True
                    frontier.append(u)
        return reach.astype(np.float32)

    return AlgoInstance(
        name="reachability", n=g.n, src=g.src.copy(), dst=g.dst.copy(),
        w=np.ones(g.m, np.float32), x0=x0,
        c=np.full(g.n, -BIG, np.float32), fixed=np.zeros(g.n, bool),
        semiring=Semiring("max", "mul"), combine="max_old",
        residual="changed", eps=0.5, monotone_dir=+1,
        exact_fn=_exact,
        params={"source": source},
    )


# --------------------------------------------------------------------------
# batched multi-query constructors
# --------------------------------------------------------------------------

def make_personalized_pagerank(
    g: Graph, seeds=None, damping: float = 0.85, eps: float = 1e-6,
) -> AlgoInstance:
    """Personalized PageRank from ``d = len(seeds)`` seeds at once.

    Column j solves  x_v = (1-damping)*1[v == seeds[j]] + damping * sum_in
    x_u / |OUT(u)| — the same linear system as :func:`make_pagerank` with a
    one-hot restart vector, so all columns share the edge arrays and one
    batched run answers every query.
    """
    seeds = np.asarray(seeds if seeds is not None else [0], dtype=np.int64)
    if len(seeds) == 0:
        raise ValueError("personalized_pagerank needs at least one seed")
    d = len(seeds)
    outdeg = np.maximum(g.out_degrees(), 1).astype(np.float32)
    w = (damping * g.weights / outdeg[g.src]).astype(np.float32)
    c = np.zeros((g.n, d), np.float32)
    c[seeds, np.arange(d)] = 1.0 - damping
    return AlgoInstance(
        name="ppr", n=g.n, src=g.src.copy(), dst=g.dst.copy(), w=w,
        x0=np.zeros((g.n, d), np.float32), c=c,
        fixed=np.zeros((g.n, d), bool),
        semiring=Semiring("sum", "mul"), combine="replace",
        residual="linf", eps=eps, monotone_dir=+1,
        exact_fn=lambda: _exact_linear_sum(g.n, g.src, g.dst, w, c),
        params={"seeds": seeds, "damping": damping, "eps": eps},
    )


def make_multi_source_sssp(g: Graph, sources=None, eps: float = 0.5) -> AlgoInstance:
    """Single-source shortest paths from ``d = len(sources)`` sources at once;
    column j is the distance field of source j."""
    sources = np.asarray(sources if sources is not None else [0], dtype=np.int64)
    if len(sources) == 0:
        raise ValueError("multi_source_sssp needs at least one source")
    d = len(sources)
    x0 = np.full((g.n, d), BIG, np.float32)
    x0[sources, np.arange(d)] = 0.0

    def _exact() -> np.ndarray:
        return np.stack([_exact_dijkstra(g, int(s)) for s in sources], axis=1)

    return AlgoInstance(
        name="ms_sssp", n=g.n, src=g.src.copy(), dst=g.dst.copy(),
        w=g.weights.copy(), x0=x0, c=np.full((g.n, d), BIG, np.float32),
        fixed=np.zeros((g.n, d), bool),
        semiring=Semiring("min", "add"), combine="min_old",
        residual="changed", eps=eps, monotone_dir=-1,
        exact_fn=_exact,
        params={"sources": sources, "eps": eps},
    )


# short aliases matching the README / benchmark vocabulary
personalized_pagerank = make_personalized_pagerank
multi_source_sssp = make_multi_source_sssp


# --------------------------------------------------------------------------
# exact references
# --------------------------------------------------------------------------

def _exact_linear_sum(
    n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray, c: np.ndarray,
    fixed: Optional[np.ndarray] = None, x_fixed: Optional[np.ndarray] = None,
    iters: int = 10_000, tol: float = 1e-12,
) -> np.ndarray:
    """Jacobi to machine precision in float64 (reference for sum semirings).

    ``c`` may be (n,) or (n, d); the result matches its shape (columns are
    independent restart vectors).
    """
    c64 = np.asarray(c, np.float64)
    x = np.zeros_like(c64)
    if fixed is not None:
        x = np.where(fixed, np.asarray(x_fixed, np.float64), x)
    w64 = w.astype(np.float64)
    wv = w64 if c64.ndim == 1 else w64[:, None]
    for _ in range(iters):
        agg = np.zeros_like(x)
        np.add.at(agg, dst, x[src] * wv)
        x_new = c64 + agg
        if fixed is not None:
            x_new = np.where(fixed, np.asarray(x_fixed, np.float64), x_new)
        if np.max(np.abs(x_new - x)) < tol:
            x = x_new
            break
        x = x_new
    return x.astype(np.float32)


def _exact_dijkstra(g: Graph, source: int) -> np.ndarray:
    import heapq

    dist = np.full(g.n, np.float64(BIG))
    dist[source] = 0.0
    indptr, nbrs, eid = g.csr()
    w = g.weights
    heap = [(0.0, source)]
    done = np.zeros(g.n, bool)
    while heap:
        d, v = heapq.heappop(heap)
        if done[v]:
            continue
        done[v] = True
        for j in range(indptr[v], indptr[v + 1]):
            u = nbrs[j]
            nd = d + float(w[eid[j]])
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(heap, (nd, int(u)))
    return dist.astype(np.float32)


ALGORITHMS: dict[str, Callable[..., AlgoInstance]] = {
    "pagerank": make_pagerank,
    "katz": make_katz,
    "php": make_php,
    "adsorption": make_adsorption,
    "sssp": make_sssp,
    "bfs": make_bfs,
    "cc": make_cc,
    "sswp": make_sswp,
    "reachability": make_reachability,
    "ppr": make_personalized_pagerank,
    "ms_sssp": make_multi_source_sssp,
}


def get_algorithm(name: str, g: Graph, **kw) -> AlgoInstance:
    return ALGORITHMS[name](g, **kw)


def remake(algo: AlgoInstance, g: Graph) -> AlgoInstance:
    """Rebuild ``algo`` (same constructor, same parameters) on a mutated
    graph — the delta constructor of the incremental serving engine.

    This re-runs the weight transform (e.g. PageRank's d/|OUT(u)| scaling),
    so edges whose weight changed only *implicitly* — an insertion into u's
    out-set rescales every existing u-edge — are picked up. ``algo`` must be
    in its original (pre-`relabel`) id space and ``g`` must keep the old
    vertex ids (new vertices appended at the end).
    """
    if algo.params is None:
        raise ValueError(
            f"algorithm {algo.name!r} has no recorded constructor params; "
            "build it via the make_* constructors / get_algorithm"
        )
    if g.n < algo.n:
        raise ValueError(
            f"mutated graph has {g.n} vertices < instance's {algo.n}; "
            "vertex removal is not supported (mask edges instead)"
        )
    return ALGORITHMS[algo.name](g, **algo.params)
