"""Shared jittable primitives for the iterative engines.

All state-carrying operands are batched ``(n, d)`` matrices (column j =
query j); per-edge operands (``w``, masks) stay 1-D and broadcast across the
batch dimension. ``d = 1`` reproduces the scalar engines bit-for-bit.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.engine.algorithms import AlgoInstance


def _bcast_edge(a: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Lift a per-edge 1-D array to broadcast against (e, d) messages."""
    if like.ndim == a.ndim + 1:
        return a[..., None]
    return a


def edge_op(kind: str, x_src: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    w = _bcast_edge(w, x_src)
    if kind == "mul":
        return x_src * w
    if kind == "add":
        return x_src + w
    if kind == "min":
        return jnp.minimum(x_src, w)
    raise ValueError(kind)


def segment_reduce(
    kind: str, msgs: jnp.ndarray, dst: jnp.ndarray, n: int, identity: float
) -> jnp.ndarray:
    out = jnp.full((n,) + msgs.shape[1:], identity, dtype=msgs.dtype)
    if kind == "sum":
        return out.at[dst].add(msgs)
    if kind == "min":
        return out.at[dst].min(msgs)
    if kind == "max":
        return out.at[dst].max(msgs)
    raise ValueError(kind)


def combine(
    kind: str, agg: jnp.ndarray, c: jnp.ndarray, x_old: jnp.ndarray,
    fixed: jnp.ndarray, x0: jnp.ndarray,
) -> jnp.ndarray:
    if kind == "replace":
        x_new = c + agg
    elif kind == "min_old":
        x_new = jnp.minimum(x_old, jnp.minimum(c, agg))
    elif kind == "max_old":
        x_new = jnp.maximum(x_old, jnp.maximum(c, agg))
    else:
        raise ValueError(kind)
    return jnp.where(fixed, x0, x_new)


def residual(kind: str, x_new: jnp.ndarray, x_old: jnp.ndarray) -> jnp.ndarray:
    """Scalar residual over the whole state (all columns together)."""
    if kind == "linf":
        return jnp.max(jnp.abs(x_new - x_old))
    if kind == "l1":
        return jnp.sum(jnp.abs(x_new - x_old))
    if kind == "changed":
        return jnp.sum((x_new != x_old).astype(jnp.float32))
    raise ValueError(kind)


def residual_cols(kind: str, x_new: jnp.ndarray, x_old: jnp.ndarray) -> jnp.ndarray:
    """Per-column residual f32[d] for (n, d) states — the convergence unit of
    the batched engines: a column (query) that drops below eps is frozen and
    stops contributing to the stopping test. Delegates to the shared metric
    definition (`kernels.semirings.delta_cols`) so the host drivers, the
    multisweep megakernel, and the numpy oracle can never disagree."""
    from repro.kernels.semirings import delta_cols

    return delta_cols(kind, x_new, x_old, xp=jnp)


def device_arrays(algo: AlgoInstance) -> dict[str, jnp.ndarray]:
    return {
        "src": jnp.asarray(algo.src),
        "dst": jnp.asarray(algo.dst),
        "w": jnp.asarray(algo.w),
        "x0": jnp.asarray(algo.x0),
        "c": jnp.asarray(algo.c),
        "fixed": jnp.asarray(algo.fixed),
    }
