from repro.engine.algorithms import (
    ALGORITHMS,
    AlgoInstance,
    get_algorithm,
    make_multi_source_sssp,
    make_personalized_pagerank,
    multi_source_sssp,
    personalized_pagerank,
    remake,
)
from repro.engine.api import (
    EngineOptions,
    EngineOptionsError,
    EngineUnsupportedError,
    solve,
)
from repro.engine.async_block import AsyncBlockSession, run_async_block
from repro.engine.distributed import run_distributed
from repro.engine.incremental import permute_state, run_incremental, warm_state
from repro.engine.priority import run_priority_block
from repro.engine.push import estimate_frontier_fraction, run_push
from repro.engine.sync import run_sync

__all__ = [
    "solve",
    "EngineOptions",
    "EngineOptionsError",
    "EngineUnsupportedError",
    "get_algorithm",
    "ALGORITHMS",
    "AlgoInstance",
    "personalized_pagerank",
    "multi_source_sssp",
    "make_personalized_pagerank",
    "make_multi_source_sssp",
    "remake",
    "run_sync",
    "run_async_block",
    "AsyncBlockSession",
    "run_distributed",
    "run_priority_block",
    "run_push",
    "estimate_frontier_fraction",
    "run_incremental",
    "warm_state",
    "permute_state",
]
