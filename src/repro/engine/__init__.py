from repro.engine.algorithms import (
    ALGORITHMS,
    AlgoInstance,
    get_algorithm,
    make_multi_source_sssp,
    make_personalized_pagerank,
    multi_source_sssp,
    personalized_pagerank,
)
from repro.engine.async_block import run_async_block
from repro.engine.distributed import run_distributed
from repro.engine.priority import run_priority_block
from repro.engine.sync import run_sync

__all__ = [
    "get_algorithm",
    "ALGORITHMS",
    "AlgoInstance",
    "personalized_pagerank",
    "multi_source_sssp",
    "make_personalized_pagerank",
    "make_multi_source_sssp",
    "run_sync",
    "run_async_block",
    "run_distributed",
    "run_priority_block",
]
