from repro.engine.algorithms import get_algorithm, ALGORITHMS, AlgoInstance
from repro.engine.sync import run_sync
from repro.engine.async_block import run_async_block
from repro.engine.distributed import run_distributed

__all__ = [
    "get_algorithm",
    "ALGORITHMS",
    "AlgoInstance",
    "run_sync",
    "run_async_block",
    "run_distributed",
]
