"""Convergence bookkeeping shared by the engines."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class RunResult:
    """Outcome of an iterative run.

    rounds counts *full passes over the edge set* (one synchronous round or
    one asynchronous sweep both count 1), which is the unit the paper plots
    in Fig. 6 — it makes sync and async modes directly comparable.

    Batched (d > 1) runs set ``x`` to the (n, d) state matrix and fill the
    per-column fields: ``col_rounds[j]`` is the round at which query j first
    met eps (columns freeze there, so each query gets exactly its scalar
    round count), ``col_converged[j]`` whether it did within the budget.
    ``rounds`` is then the number of rounds the batch executed =
    ``max(col_rounds)``. Scalar (d = 1) runs keep the legacy contract:
    ``x`` is 1-D and the per-column fields have length 1.

    Exception: ``run_priority_block`` schedules work-proportionally, so it
    has no per-query round counts — it fills ``col_converged`` (aggregate
    verdict, valid for every column) but leaves ``col_rounds`` None.
    """

    x: np.ndarray
    rounds: int
    converged: bool
    residuals: np.ndarray  # per-round residual trace
    state_sums: np.ndarray  # per-round sum(x) (for Fig. 7 convergence plots)
    col_rounds: Optional[np.ndarray] = None    # int32[d]
    col_converged: Optional[np.ndarray] = None  # bool[d]
    # sweep-batched megakernel runs only (run_async_block(backend="pallas",
    # sweeps_per_call>1)): fraction of row-blocks actually updated per sweep
    # — the frontier-skipping win (1.0 = full sweep, 0.0 = everything clean)
    active_block_fraction: Optional[np.ndarray] = None  # f32[rounds]

    @property
    def d(self) -> int:
        """Number of batched queries in this result."""
        return int(self.x.shape[1]) if self.x.ndim == 2 else 1

    def distance_trace(self, x_star_sum: float) -> np.ndarray:
        """dist_t = |sum x* - sum x_t| (paper §V-C)."""
        return np.abs(x_star_sum - self.state_sums[: self.rounds])


def trim_trace(residuals, sums, rounds: int) -> tuple[np.ndarray, np.ndarray]:
    residuals = np.asarray(residuals)[:rounds]
    sums = np.asarray(sums)[:rounds]
    return residuals, sums
