"""Convergence bookkeeping shared by the engines."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RunResult:
    """Outcome of an iterative run.

    rounds counts *full passes over the edge set* (one synchronous round or
    one asynchronous sweep both count 1), which is the unit the paper plots
    in Fig. 6 — it makes sync and async modes directly comparable.
    """

    x: np.ndarray
    rounds: int
    converged: bool
    residuals: np.ndarray  # per-round residual trace
    state_sums: np.ndarray  # per-round sum(x) (for Fig. 7 convergence plots)

    def distance_trace(self, x_star_sum: float) -> np.ndarray:
        """dist_t = |sum x* - sum x_t| (paper §V-C)."""
        return np.abs(x_star_sum - self.state_sums[: self.rounds])


def trim_trace(residuals, sums, rounds: int) -> tuple[np.ndarray, np.ndarray]:
    residuals = np.asarray(residuals)[:rounds]
    sums = np.asarray(sums)[:rounds]
    return residuals, sums
