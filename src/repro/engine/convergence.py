"""Convergence bookkeeping shared by the engines.

Besides the :class:`RunResult` container this module holds the one
implementation of the engines' *per-column* convergence accounting —
previously duplicated (inline, slightly divergently) between the traced
round driver ``harness.loop`` and the host-side megakernel driver
``harness.sweep_batched_loop``. The functions are array-namespace agnostic:
they use only operators numpy and traced jax arrays share, so the same code
runs inside ``lax.while_loop`` bodies and on host numpy bookkeeping.

``reinit_columns`` is the *inverse* of the freeze: the serving layer
(`repro.serving`) swaps a finished query out of a state-matrix column and a
queued query in mid-run, which means un-converging exactly that column's
bookkeeping while every other column keeps its progress.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.obs.telemetry import ConvergenceTrace


@dataclasses.dataclass
class RunResult:
    """Outcome of an iterative run.

    rounds counts *full passes over the edge set* (one synchronous round or
    one asynchronous sweep both count 1), which is the unit the paper plots
    in Fig. 6 — it makes sync and async modes directly comparable.

    Batched (d > 1) runs set ``x`` to the (n, d) state matrix and fill the
    per-column fields: ``col_rounds[j]`` is the round at which query j first
    met eps (columns freeze there, so each query gets exactly its scalar
    round count), ``col_converged[j]`` whether it did within the budget.
    ``rounds`` is then the number of rounds the batch executed =
    ``max(col_rounds)``. Scalar (d = 1) runs keep the legacy contract:
    ``x`` is 1-D and the per-column fields have length 1.

    Exception: ``run_priority_block`` schedules work-proportionally, so it
    has no per-query round counts — it fills ``col_converged`` (aggregate
    verdict, valid for every column) but leaves ``col_rounds`` None.
    """

    x: np.ndarray
    rounds: int
    converged: bool
    residuals: np.ndarray  # per-round residual trace
    state_sums: np.ndarray  # per-round sum(x) (for Fig. 7 convergence plots)
    col_rounds: Optional[np.ndarray] = None    # int32[d]
    col_converged: Optional[np.ndarray] = None  # bool[d]
    # sweep-batched megakernel runs only (run_async_block(backend="pallas",
    # sweeps_per_call>1)): fraction of row-blocks actually updated per sweep
    # — the frontier-skipping win (1.0 = full sweep, 0.0 = everything clean)
    active_block_fraction: Optional[np.ndarray] = None  # f32[rounds]
    # push-engine runs only (engine="push"): work accounting — "pushed"
    # (vertex settles, summed over rounds), "edges" (scatter messages),
    # "touched" / "touched_fraction" (distinct vertices ever active), and
    # "rounds". The sparse-delta benchmark compares these against the sweep
    # engines' rounds * n swept vertices.
    push_stats: Optional[dict] = None
    # uniform per-round telemetry (residual / active fraction / work) filled
    # by every first-class engine (sync / async_block / distributed / push);
    # built from already-transferred host data at the existing sync points,
    # so it costs zero extra device->host transfers (repro.obs.telemetry)
    convergence_trace: Optional[ConvergenceTrace] = None

    @property
    def d(self) -> int:
        """Number of batched queries in this result."""
        return int(self.x.shape[1]) if self.x.ndim == 2 else 1

    def distance_trace(self, x_star_sum: float) -> np.ndarray:
        """dist_t = |sum x* - sum x_t| (paper §V-C)."""
        return np.abs(x_star_sum - self.state_sums[: self.rounds])


def trim_trace(residuals, sums, rounds: int) -> tuple[np.ndarray, np.ndarray]:
    residuals = np.asarray(residuals)[:rounds]
    sums = np.asarray(sums)[:rounds]
    return residuals, sums


def converge_step(res_col, eps: float, col_done, col_rounds):
    """One round of per-column convergence accounting.

    ``res_col`` is this round's per-column residual, ``col_done`` /
    ``col_rounds`` the running bookkeeping. Returns ``(newly_done, active,
    col_done, col_rounds)``: a column is *active* while not yet converged
    (it pays this round, so ``col_rounds`` advances), and *newly done* the
    first round its residual drops to eps. Works on numpy host arrays and
    on traced jax arrays (pure operators, no namespace-specific calls) —
    the single implementation behind both round drivers, so the serving
    layer's swap-in hook has one semantics to invert.
    """
    active = ~col_done
    newly_done = active & (res_col <= eps)
    return (
        newly_done,
        active,
        col_done | newly_done,
        col_rounds + active.astype(col_rounds.dtype),
    )


def freeze_columns(x_cand, x_prev, active, newly_done):
    """Per-column state freezing for the traced round driver.

    Active, not-yet-converged columns advance to the candidate state;
    columns converging *this* round keep their pre-sweep state (the sweep
    that measured residual <= eps is a verification sweep — see
    ``harness.loop``); already-frozen columns stay put bitwise.
    """
    import jax.numpy as jnp

    advance = active & ~newly_done
    return jnp.where(advance[None, :], x_cand, x_prev)


def reinit_columns(col_done, col_rounds, cols) -> tuple[np.ndarray, np.ndarray]:
    """Mid-run per-column re-initialization — the inverse of the freeze.

    Swapping a new query into column j of a resident state matrix
    (`repro.serving`) resets exactly that column's convergence bookkeeping:
    done flag cleared, round count zeroed; every other column keeps its
    progress. Accepts host numpy (returns fresh numpy arrays) or device jax
    arrays (returns functional `.at[].set` updates, so a device-resident
    session's accounting never round-trips to host). Inputs are not mutated.
    """
    cols = np.asarray(cols, dtype=np.int64).reshape(-1)
    if hasattr(col_done, "at"):  # jax arrays: stay on device
        return col_done.at[cols].set(False), col_rounds.at[cols].set(0)
    col_done = np.asarray(col_done).copy()
    col_rounds = np.asarray(col_rounds).copy()
    col_done[cols] = False
    col_rounds[cols] = 0
    return col_done, col_rounds
