"""Distributed engine: vertex blocks sharded across devices (shard_map).

Execution model (DESIGN.md §3): *synchronous across shards, Gauss–Seidel
within a shard*. Each device owns a contiguous range of blocks of the
processing order. Per superstep every device sweeps its own blocks
sequentially against a device-local copy of the full state matrix (so its own
earlier blocks contribute this-round values), then shards are re-assembled —
one all-gather of the state matrix per superstep.

GoGraph's partition-locality objective minimizes cross-shard edges, which is
exactly what keeps this hybrid close to fully-asynchronous Gauss–Seidel in
rounds; the paper's single-machine claim transfers because intra-shard edges
dominate after community-aware reordering.

States are batched ``f32[N, d]`` like every other engine — column j is an
independent query riding the same supersteps with per-column convergence
freezing in the shared round driver. The per-superstep collective volume is
|V|·d·4 bytes (the gathered state matrix), vs. the edge set held
shard-local — the same design large-scale systems (Gemini, Gluon) use for
power-law graphs.

:class:`DistContext` packs one algorithm *structure* (edges + block layout +
mesh) into device operands plus a jitted superstep driver. `run_distributed`
builds a throwaway context per call; `engine.async_block.AsyncBlockSession`
(``backend="distributed"``) keeps one alive as the resident backing of a
serving family whose state spans devices.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import make_mesh, pvary, set_mesh, shard_map

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import harness
from repro.engine import jax_ops as J


def _pad_blocks(arr: np.ndarray, nb_target: int, fill) -> np.ndarray:
    nb = arr.shape[0]
    if nb == nb_target:
        return arr
    pad = np.full((nb_target - nb,) + arr.shape[1:], fill, dtype=arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def make_superstep(
    mesh, axis: str, nb: int, bs: int,
    sem_reduce: str, sem_edge: str, comb: str,
    identity: float, inner: int = 1,
):
    """Build the jittable one-superstep function over ``(N, d)`` states."""
    ndev = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    assert nb % ndev == 0
    nb_local = nb // ndev
    axis_name = axis

    def superstep(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk):
        # everything below sees the *local* shard of the blocked arrays and a
        # replicated copy of the state matrix
        def inner_fn(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk):
            dev = jax.lax.axis_index(axis_name)
            d = x_full.shape[1]
            # the carry becomes device-varying after the first block update;
            # mark the replicated input as varying up-front
            x_full = pvary(x_full, (axis_name,))

            def block_update(j, x_work):
                gi = dev * nb_local + j  # global block id
                msgs = J.edge_op(sem_edge, x_work[esrc[j]], ew[j])
                msgs = jnp.where(emask[j][:, None], msgs, identity)
                agg = J.segment_reduce(sem_reduce, msgs, edst[j], bs, identity)
                old = jax.lax.dynamic_slice(x_work, (gi * bs, 0), (bs, d))
                new = J.combine(comb, agg, c_blk[j], old, fixed_blk[j], x0_blk[j])
                return jax.lax.dynamic_update_slice(x_work, new, (gi * bs, 0))

            def block_body(j, x_work):
                def one(_, xx):
                    return block_update(j, xx)
                return jax.lax.fori_loop(0, inner, one, x_work)

            x_work = jax.lax.fori_loop(0, nb_local, block_body, x_full)
            # each device contributes its own refreshed slice
            dev0 = dev * nb_local * bs
            return jax.lax.dynamic_slice(x_work, (dev0, 0), (nb_local * bs, d))

        return shard_map(
            inner_fn,
            mesh,
            (P(None), P(axis_name), P(axis_name), P(axis_name),
             P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
            P(axis_name),
            check_vma=False,
        )(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk)

    return superstep, nb_local


class DistContext:
    """Packed shard_map operands + jitted round driver for one structure.

    Owns what is constant across runs of one algorithm family: the mesh, the
    device-resident blocked edge arrays (padded to a whole number of blocks
    per device), the padded ``(npad2, d)`` host operand templates, and the
    compiled driver. :meth:`run` then converges any ``(npad2, d)`` state
    against any (same-shape) operand columns — which is exactly what lets a
    serving session mutate operand columns on device between batches and
    keep calling the same compiled superstep loop.
    """

    def __init__(self, algo: AlgoInstance, bs: int, mesh=None,
                 axis: str = "data", inner: int = 1):
        if mesh is None:
            mesh = make_mesh((len(jax.devices()),), (axis,))
        self.mesh, self.axis, self.bs = mesh, axis, bs
        ndev = mesh.shape[axis]
        be, x0, c, fixed, npad = harness.pack(algo, bs)
        self.nb = ((be.nb + ndev - 1) // ndev) * ndev
        self.npad2 = self.nb * bs
        self._edges = tuple(jnp.asarray(a) for a in (
            _pad_blocks(be.esrc, self.nb, 0),
            _pad_blocks(be.edst, self.nb, 0),
            _pad_blocks(be.ew, self.nb, 0.0),
            _pad_blocks(be.emask, self.nb, False),
        ))

        def padm(a, fill):
            out = np.full((self.npad2,) + a.shape[1:], fill, dtype=a.dtype)
            out[: a.shape[0]] = a
            return out

        # host templates; callers device-transfer (sessions keep them there)
        self.x0 = padm(x0, np.asarray(algo.semiring.identity, x0.dtype))
        self.c = padm(c, np.asarray(algo.c_pad_fill, c.dtype))
        self.fixed = padm(fixed, True)
        real_mask = np.zeros(self.npad2, bool)
        real_mask[: algo.n] = True
        self._real_mask = jnp.asarray(real_mask)

        superstep, _ = make_superstep(
            mesh, axis, self.nb, bs,
            algo.semiring.reduce, algo.semiring.edge_op, algo.combine,
            algo.semiring.identity, inner=inner,
        )
        nb, res_kind, eps = self.nb, algo.residual, algo.eps

        @partial(jax.jit, static_argnames=("max_iters", "extrapolate_every"))
        def _run(x_start, esrc, edst, ew, emask, x0v, cv, fxv, real_mask,
                 max_iters: int, extrapolate_every: int):
            d = x_start.shape[1]
            c_blk = cv.reshape(nb, bs, d)
            fixed_blk = fxv.reshape(nb, bs, d)
            x0_blk = x0v.reshape(nb, bs, d)  # pins stay x0 when warm-started

            def round_fn(x):
                return superstep(x, esrc, edst, ew, emask, c_blk,
                                 fixed_blk, x0_blk)

            return harness.loop(
                round_fn, x_start, res_kind=res_kind, eps=eps,
                max_iters=max_iters, real_mask=real_mask,
                extrapolate_every=extrapolate_every,
            )

        self._run = _run

    def run(self, x_start, x0, c, fixed, *, max_iters: int,
            extrapolate_every: int = 0):
        """Drive supersteps to convergence; the `harness.loop` tuple."""
        with set_mesh(self.mesh):
            return self._run(
                jnp.asarray(x_start), *self._edges, jnp.asarray(x0),
                jnp.asarray(c), jnp.asarray(fixed), self._real_mask,
                max_iters=max_iters, extrapolate_every=extrapolate_every,
            )


def _solve(algo: AlgoInstance, o) -> RunResult:
    """Engine body behind ``solve(algo, engine="distributed", ...)``; options
    are already validated (`engine.api.validate_options`)."""
    ctx = DistContext(algo, o.bs, mesh=o.mesh, axis=o.axis, inner=o.inner)
    x_start = harness.init_state(ctx.x0, o.x_init, algo.n)
    out = ctx.run(
        x_start, ctx.x0, ctx.c, ctx.fixed,
        max_iters=o.max_iters, extrapolate_every=o.extrapolate_every,
    )
    return harness.finalize(algo, *out)


def run_distributed(
    algo: AlgoInstance,
    mesh=None,
    axis: str = "data",
    bs: int = 256,
    max_iters: int = 2000,
    inner: int = 1,
    x_init: np.ndarray | None = None,
    extrapolate_every: int = 0,
) -> RunResult:
    """Thin shim over ``solve(algo, engine="distributed")`` — the legacy
    keyword spelling, parity-tested against `engine.api.solve`.

    ``x_init`` warm-starts from a prior state (incremental serving);
    ``extrapolate_every`` enables Aitken acceleration for linear systems
    (see `harness.loop`)."""
    from repro.engine.api import EngineOptions, solve

    return solve(algo, engine="distributed", options=EngineOptions(
        x_init=x_init, extrapolate_every=extrapolate_every, bs=bs,
        inner=inner, max_iters=max_iters, mesh=mesh, axis=axis,
    ))
