"""Distributed engine: vertex blocks sharded across devices (shard_map).

Execution model (DESIGN.md §3): *synchronous across shards, Gauss–Seidel
within a shard*. Each device owns a contiguous range of blocks of the
processing order. Per superstep every device sweeps its own blocks
sequentially against a device-local copy of the full state vector (so its own
earlier blocks contribute this-round values), then shards are re-assembled —
one all-gather of the state vector per superstep.

GoGraph's partition-locality objective minimizes cross-shard edges, which is
exactly what keeps this hybrid close to fully-asynchronous Gauss–Seidel in
rounds; the paper's single-machine claim transfers because intra-shard edges
dominate after community-aware reordering.

The per-superstep collective volume is |V|·4 bytes (the gathered state), vs.
the edge set held shard-local — the same design large-scale systems (Gemini,
Gluon) use for power-law graphs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import make_mesh, pvary, set_mesh, shard_map

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import harness
from repro.engine import jax_ops as J


def _pad_blocks(arr: np.ndarray, nb_target: int, fill) -> np.ndarray:
    nb = arr.shape[0]
    if nb == nb_target:
        return arr
    pad = np.full((nb_target - nb,) + arr.shape[1:], fill, dtype=arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def make_superstep(
    mesh, axis: str, nb: int, bs: int,
    sem_reduce: str, sem_edge: str, comb: str,
    identity: float, inner: int = 1,
):
    """Build the jittable one-superstep function (also used by the dry-run)."""
    ndev = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    assert nb % ndev == 0
    nb_local = nb // ndev
    axis_name = axis

    def superstep(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk):
        # everything below sees the *local* shard of the blocked arrays and a
        # replicated copy of the state vector
        def inner_fn(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk):
            dev = jax.lax.axis_index(axis_name)
            # the carry becomes device-varying after the first block update;
            # mark the replicated input as varying up-front
            x_full = pvary(x_full, (axis_name,))

            def block_update(j, x_work):
                gi = dev * nb_local + j  # global block id
                msgs = J.edge_op(sem_edge, x_work[esrc[j]], ew[j])
                msgs = jnp.where(emask[j], msgs, identity)
                agg = J.segment_reduce(sem_reduce, msgs, edst[j], bs, identity)
                old = jax.lax.dynamic_slice(x_work, (gi * bs,), (bs,))
                new = J.combine(comb, agg, c_blk[j], old, fixed_blk[j], x0_blk[j])
                return jax.lax.dynamic_update_slice(x_work, new, (gi * bs,))

            def block_body(j, x_work):
                def one(_, xx):
                    return block_update(j, xx)
                return jax.lax.fori_loop(0, inner, one, x_work)

            x_work = jax.lax.fori_loop(0, nb_local, block_body, x_full)
            # each device contributes its own refreshed slice
            dev0 = dev * nb_local * bs
            return jax.lax.dynamic_slice(x_work, (dev0,), (nb_local * bs,))

        return shard_map(
            inner_fn,
            mesh,
            (P(None), P(axis_name), P(axis_name), P(axis_name),
             P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
            P(axis_name),
            check_vma=False,
        )(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk)

    return superstep, nb_local


def run_distributed(
    algo: AlgoInstance,
    mesh=None,
    axis: str = "data",
    bs: int = 256,
    max_iters: int = 2000,
    inner: int = 1,
    x_init: np.ndarray | None = None,
    extrapolate_every: int = 0,
) -> RunResult:
    """``x_init`` warm-starts from a prior state (incremental serving);
    ``extrapolate_every`` enables Aitken acceleration for linear systems
    (see `harness.loop`)."""
    harness.check_extrapolation(algo, extrapolate_every)
    if mesh is None:
        mesh = make_mesh((len(jax.devices()),), (axis,))
    ndev = mesh.shape[axis]

    if algo.d != 1:
        raise NotImplementedError(
            "run_distributed is single-query for now; use run_sync/"
            "run_async_block for batched (d > 1) states"
        )
    be, x0, c, fixed, npad = harness.pack(algo, bs)
    x0, c, fixed = x0[:, 0], c[:, 0], fixed[:, 0]
    nb = ((be.nb + ndev - 1) // ndev) * ndev
    esrc = _pad_blocks(be.esrc, nb, 0)
    edst = _pad_blocks(be.edst, nb, 0)
    ew = _pad_blocks(be.ew, nb, 0.0)
    emask = _pad_blocks(be.emask, nb, False)
    npad2 = nb * bs

    def padv(a, fill):
        out = np.full((npad2,), fill, dtype=a.dtype)
        out[: len(a)] = a
        return out

    x0 = padv(x0, algo.semiring.identity)
    c = padv(c, algo.c_pad_fill)
    fx = np.ones(npad2, bool)
    fx[: npad] = fixed
    c_blk = c.reshape(nb, bs)
    fixed_blk = fx.reshape(nb, bs)
    x0_blk = x0.reshape(nb, bs)  # pin source stays x0 even when warm-started
    x_start = harness.init_state(x0[:, None], x_init, algo.n)[:, 0]

    superstep, _ = make_superstep(
        mesh, axis, nb, bs,
        algo.semiring.reduce, algo.semiring.edge_op, algo.combine,
        algo.semiring.identity, inner=inner,
    )

    real_mask = np.zeros(npad2, bool)
    real_mask[: algo.n] = True
    res_kind = algo.residual
    eps = algo.eps

    @partial(jax.jit, static_argnames=("max_iters", "extrapolate_every"))
    def _run(x0v, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk, real_mask,
             max_iters: int, extrapolate_every: int):
        # the shard_map superstep is written over 1-D state vectors; lift it
        # to the (N, 1) batched contract of the shared round driver
        def round_fn(x2d):
            x_new = superstep(x2d[:, 0], esrc, edst, ew, emask, c_blk,
                              fixed_blk, x0_blk)
            return x_new[:, None]

        return harness.loop(
            round_fn, x0v[:, None], res_kind=res_kind, eps=eps,
            max_iters=max_iters, real_mask=real_mask,
            extrapolate_every=extrapolate_every,
        )

    with set_mesh(mesh):
        out = _run(
            jnp.asarray(x_start), jnp.asarray(esrc), jnp.asarray(edst),
            jnp.asarray(ew), jnp.asarray(emask), jnp.asarray(c_blk),
            jnp.asarray(fixed_blk), jnp.asarray(x0_blk),
            jnp.asarray(real_mask), max_iters=max_iters,
            extrapolate_every=extrapolate_every,
        )
    return harness.finalize(algo, *out)
