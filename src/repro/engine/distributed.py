"""Distributed engine: vertex blocks sharded across devices (shard_map).

Execution model (DESIGN.md §3): *synchronous across shards, Gauss–Seidel
within a shard*. Each device owns a contiguous range of blocks of the
processing order. Per superstep every device sweeps its own blocks
sequentially against a device-local copy of the full state vector (so its own
earlier blocks contribute this-round values), then shards are re-assembled —
one all-gather of the state vector per superstep.

GoGraph's partition-locality objective minimizes cross-shard edges, which is
exactly what keeps this hybrid close to fully-asynchronous Gauss–Seidel in
rounds; the paper's single-machine claim transfers because intra-shard edges
dominate after community-aware reordering.

The per-superstep collective volume is |V|·4 bytes (the gathered state), vs.
the edge set held shard-local — the same design large-scale systems (Gemini,
Gluon) use for power-law graphs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.engine import jax_ops as J
from repro.engine.async_block import _pack


def _pad_blocks(arr: np.ndarray, nb_target: int, fill) -> np.ndarray:
    nb = arr.shape[0]
    if nb == nb_target:
        return arr
    pad = np.full((nb_target - nb,) + arr.shape[1:], fill, dtype=arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def make_superstep(
    mesh, axis: str, nb: int, bs: int,
    sem_reduce: str, sem_edge: str, comb: str,
    identity: float, inner: int = 1,
):
    """Build the jittable one-superstep function (also used by the dry-run)."""
    ndev = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    assert nb % ndev == 0
    nb_local = nb // ndev
    axis_name = axis

    def superstep(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk):
        # everything below sees the *local* shard of the blocked arrays and a
        # replicated copy of the state vector
        def inner_fn(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk):
            dev = jax.lax.axis_index(axis_name)
            # the carry becomes device-varying after the first block update;
            # mark the replicated input as varying up-front
            x_full = jax.lax.pvary(x_full, (axis_name,))

            def block_update(j, x_work):
                gi = dev * nb_local + j  # global block id
                msgs = J.edge_op(sem_edge, x_work[esrc[j]], ew[j])
                msgs = jnp.where(emask[j], msgs, identity)
                agg = J.segment_reduce(sem_reduce, msgs, edst[j], bs, identity)
                old = jax.lax.dynamic_slice(x_work, (gi * bs,), (bs,))
                new = J.combine(comb, agg, c_blk[j], old, fixed_blk[j], x0_blk[j])
                return jax.lax.dynamic_update_slice(x_work, new, (gi * bs,))

            def block_body(j, x_work):
                def one(_, xx):
                    return block_update(j, xx)
                return jax.lax.fori_loop(0, inner, one, x_work)

            x_work = jax.lax.fori_loop(0, nb_local, block_body, x_full)
            # each device contributes its own refreshed slice
            dev0 = dev * nb_local * bs
            return jax.lax.dynamic_slice(x_work, (dev0,), (nb_local * bs,))

        return jax.shard_map(
            inner_fn,
            mesh=mesh,
            in_specs=(P(None), P(axis_name), P(axis_name), P(axis_name),
                      P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
            out_specs=P(axis_name),
        )(x_full, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk)

    return superstep, nb_local


def run_distributed(
    algo: AlgoInstance,
    mesh=None,
    axis: str = "data",
    bs: int = 256,
    max_iters: int = 2000,
    inner: int = 1,
) -> RunResult:
    if mesh is None:
        ndev = len(jax.devices())
        mesh = jax.make_mesh(
            (ndev,), (axis,), axis_types=(jax.sharding.AxisType.Auto,)
        )
    ndev = mesh.shape[axis]

    be, x0, c, fixed, npad = _pack(algo, bs)
    nb = ((be.nb + ndev - 1) // ndev) * ndev
    esrc = _pad_blocks(be.esrc, nb, 0)
    edst = _pad_blocks(be.edst, nb, 0)
    ew = _pad_blocks(be.ew, nb, 0.0)
    emask = _pad_blocks(be.emask, nb, False)
    npad2 = nb * bs

    def padv(a, fill):
        out = np.full((npad2,), fill, dtype=a.dtype)
        out[: len(a)] = a
        return out

    x0 = padv(x0, algo.semiring.identity)
    c = padv(c, 0.0)
    fx = np.ones(npad2, bool)
    fx[: npad] = fixed
    c_blk = c.reshape(nb, bs)
    fixed_blk = fx.reshape(nb, bs)
    x0_blk = x0.reshape(nb, bs)

    superstep, _ = make_superstep(
        mesh, axis, nb, bs,
        algo.semiring.reduce, algo.semiring.edge_op, algo.combine,
        algo.semiring.identity, inner=inner,
    )

    real_mask = np.zeros(npad2, bool)
    real_mask[: algo.n] = True
    res_kind = algo.residual
    eps = algo.eps

    @partial(jax.jit, static_argnames=("max_iters",))
    def _run(x0v, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk, real_mask, max_iters: int):
        res_buf = jnp.zeros((max_iters,), jnp.float32)
        sum_buf = jnp.zeros((max_iters,), jnp.float32)

        def cond(state):
            _, k, res, _, _ = state
            return jnp.logical_and(k < max_iters, res > eps)

        def body(state):
            x, k, _, res_buf, sum_buf = state
            x_new = superstep(x, esrc, edst, ew, emask, c_blk, fixed_blk, x0_blk)
            res = J.residual(res_kind, jnp.where(real_mask, x_new, 0), jnp.where(real_mask, x, 0))
            res_buf = res_buf.at[k].set(res)
            sum_buf = sum_buf.at[k].set(
                jnp.sum(jnp.where(real_mask & (jnp.abs(x_new) < 1e30), x_new, 0.0))
            )
            return x_new, k + 1, res, res_buf, sum_buf

        init = (x0v, jnp.int32(0), jnp.float32(jnp.inf), res_buf, sum_buf)
        return jax.lax.while_loop(cond, body, init)

    with jax.set_mesh(mesh):
        x, k, res, res_buf, sum_buf = _run(
            jnp.asarray(x0), jnp.asarray(esrc), jnp.asarray(edst), jnp.asarray(ew),
            jnp.asarray(emask), jnp.asarray(c_blk), jnp.asarray(fixed_blk),
            jnp.asarray(x0_blk), jnp.asarray(real_mask), max_iters=max_iters,
        )
    k = int(k)
    return RunResult(
        x=np.asarray(x)[: algo.n],
        rounds=k,
        converged=bool(res <= algo.eps),
        residuals=np.asarray(res_buf)[:k],
        state_sums=np.asarray(sum_buf)[:k],
    )
