"""Three-term roofline from compiled XLA artifacts (no hardware needed).

    T_compute    = HLO_FLOPs(per device)      / peak_FLOP/s
    T_memory     = HLO_bytes(per device)      / HBM_bw
    T_collective = collective_bytes(per dev)  / link_bw

HLO_FLOPs and HLO_bytes come from ``compiled.cost_analysis()`` (already
per-device under SPMD). collective_bytes is parsed from the compiled HLO
text: the summed operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async *-start variants
counted once).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e, per chip
HW_V5E = {
    "peak_flops": 197e12,     # bf16 FLOP/s
    "hbm_bw": 819e9,          # bytes/s
    "link_bw": 50e9,          # bytes/s/link ICI
    "hbm_bytes": 16e9,        # capacity
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind. Returns {kind: bytes, total}."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind, args = m.group(1), m.group(2)
        b = 0
        for sm in _SHAPE_RE.finditer(args):
            dtype, dims = sm.group(1), sm.group(2)
            if dtype in _DTYPE_BYTES:
                b += _shape_bytes(dtype, dims)
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return {"bytes": out, "counts": count}


@dataclasses.dataclass
class RooflineTerms:
    t_compute: float
    t_memory: float
    t_collective: float
    flops: float
    bytes_accessed: float
    collective_bytes: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collective_bytes,
        }


def roofline_terms(flops: float, bytes_accessed: float, collective_bytes: float,
                   hw: dict = HW_V5E) -> RooflineTerms:
    return RooflineTerms(
        t_compute=flops / hw["peak_flops"],
        t_memory=bytes_accessed / hw["hbm_bw"],
        t_collective=collective_bytes / hw["link_bw"],
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=collective_bytes,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N_active for MoE."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def mfu_fraction(terms: RooflineTerms, useful_flops_per_device: float,
                 hw: dict = HW_V5E) -> float:
    """Model-FLOPs utilization implied by the roofline bound: the fraction of
    peak compute the step achieves if it runs exactly at its binding term."""
    if terms.bound_time <= 0:
        return 0.0
    return (useful_flops_per_device / hw["peak_flops"]) / terms.bound_time
