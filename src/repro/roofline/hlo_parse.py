"""Structural analysis of compiled HLO text.

XLA's ``HloCostAnalysis`` (exposed as ``compiled.cost_analysis()``) visits
every computation ONCE — a ``lax.scan`` over 16 layers contributes its body
cost a single time, under-counting FLOPs, HBM traffic and collective bytes
by the trip count. The dry-run programs lean heavily on scan (layer cycles,
online-softmax KV chunks, mLSTM chunks, microbatch accumulation), so this
module re-derives the three roofline inputs from the HLO text itself:

  1. parse computations + ops (+ operand symbol tables),
  2. build the call graph (calls= / to_apply= / body= / condition=),
  3. infer while trip counts from the loop-condition's integer constant,
  4. propagate multipliers: a computation's cost counts once per dynamic
     execution,
  5. sum dot FLOPs, collective bytes, and an HBM-traffic proxy, each scaled
     by its computation's multiplier.

Traffic proxy: for every op outside fused subcomputations, bytes(result) +
bytes(operands) — i.e. each op reads inputs and writes outputs to HBM;
internals of fusions are skipped (counted once at the fusion call site),
which is exactly the locality XLA's fusion gives you on hardware.

Collective byte convention (per device, per execution):
  all-reduce          result bytes        (ring sends ~2x; reported raw)
  all-gather          result bytes        (the full gathered tensor moves)
  reduce-scatter      operand bytes       (the full tensor is reduced)
  all-to-all          result bytes
  collective-permute  result bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] token in `text`."""
    total = 0
    for m in _SHAPE_TOKEN.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_TOKEN.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class Op:
    name: str
    shape: str          # raw result-shape text
    opcode: str
    args: str           # raw text inside the top-level parens
    attrs: str          # raw text after the closing paren
    operands: list      # %names referenced in args


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict       # op name -> result shape text


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")


def _split_args_attrs(rest: str) -> tuple[str, str]:
    """rest starts right after the opcode's '('; split at its matching ')'."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def _balanced(s: str, open_ch: str, close_ch: str) -> int:
    """Index one past the matching close for s[0] == open_ch."""
    depth = 0
    for i, ch in enumerate(s):
        if ch == open_ch:
            depth += 1
        elif ch == close_ch:
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_op_line(line: str):
    """Manual tokenizer: handles tuple result shapes containing layout braces
    and /*index=N*/ comments, which defeat any single regex."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple-shaped result
        cut = _balanced(rest, "(", ")")
        shape, rest = rest[:cut], rest[cut:]
    else:  # single shape token: dtype[dims]{layout}? — no spaces inside
        sm = re.match(r"\s*(\w+\[[^\]]*\](?:\{[^ ]*\})?)", rest)
        if not sm:
            return None
        shape, rest = sm.group(1), rest[sm.end():]
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    args, attrs = _split_args_attrs(rest[om.end():])
    operands = re.findall(r"%[\w\.\-]+", args)
    return Op(name=name, shape=shape, opcode=opcode, args=args,
              attrs=attrs, operands=operands)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _is_header(line: str) -> str | None:
    """Computation headers look like '[ENTRY ]%name (params...) -> ret {'.

    Op lines start with '%name = ...'; headers have no '=' in the name part
    (before the first '('), once /*...*/ comments are stripped.
    """
    stripped = _COMMENT_RE.sub("", line).strip()
    if not stripped.endswith("{"):
        return None
    head = stripped.split("(", 1)[0]
    if "=" in head:
        return None
    toks = head.split()
    if not toks:
        return None
    name = toks[-1] if toks[0] == "ENTRY" and len(toks) > 1 else toks[0]
    if not re.fullmatch(r"%?[\w\.\-]+", name):
        return None
    return name.lstrip("%")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            name = _is_header(line)
            if name:
                cur = Computation(name, [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op_line(line)
        if op is None:
            continue
        cur.ops.append(op)
        cur.symbols[op.name] = op.shape
    return comps


def _callee_names(op: Op) -> list[tuple[str, str]]:
    """[(kind, computation_name)] referenced by this op's attributes."""
    out = []
    for kind in ("calls", "to_apply", "body", "condition"):
        m = re.search(kind + r"=(%?[\w\.\-]+)", op.attrs)
        if m:
            out.append((kind, m.group(1).lstrip("%")))
    return out


def _while_trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    ints = []
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.fullmatch(r"\s*(\d+)\s*", op.args)
            if m:
                ints.append(int(m.group(1)))
    return max(ints) if ints else 1


def computation_multipliers(comps: dict[str, Computation]) -> tuple[dict, set]:
    """Returns ({computation: dynamic execution count}, fused_internal set).

    Roots are entry computations (no callers). Multipliers propagate along
    call edges; while bodies/conditions get x trip_count. Computations called
    via calls=/to_apply= are marked fused-internal for the traffic proxy.
    """
    callers: dict[str, list] = defaultdict(list)
    fused_internal: set[str] = set()
    for cname, comp in comps.items():
        for op in comp.ops:
            for kind, callee in _callee_names(op):
                if callee not in comps:
                    continue
                trip = 1
                if kind == "body":
                    trip = _while_trip_count(
                        comps, dict(_callee_names(op)).get("condition", "")
                    )
                if kind in ("calls", "to_apply"):
                    fused_internal.add(callee)
                callers[callee].append((cname, trip))

    mult: dict[str, float] = {}

    def resolve(name: str, stack=()):
        if name in mult:
            return mult[name]
        if name in stack:  # recursion guard
            return 1.0
        if not callers[name]:
            mult[name] = 1.0
            return 1.0
        total = 0.0
        for caller, trip in callers[name]:
            total += resolve(caller, stack + (name,)) * trip
        mult[name] = max(total, 1.0)
        return mult[name]

    for name in comps:
        resolve(name)
    return mult, fused_internal


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 x numel(result) x contracted size (from lhs shape + contracting dims)."""
    res = _shape_dims(op.shape)
    if not res:
        return 0.0
    numel = 1
    for d in res[0][1]:
        numel *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1
    if m and op.operands:
        lhs_shape = comp.symbols.get(op.operands[0])
        if lhs_shape:
            dims = _shape_dims(lhs_shape)
            if dims:
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(dims[0][1]):
                        contract *= dims[0][1][idx]
    return 2.0 * numel * contract


_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "call", "after-all", "iota"}


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    mult, fused = computation_multipliers(comps)
    called: set = set(fused)
    for comp in comps.values():
        for op in comp.ops:
            for _, callee in _callee_names(op):
                called.add(callee)
    entry_comps = [c for c in comps if c not in called]

    flops = 0.0
    traffic_all = 0.0     # upper bound: every op reads/writes HBM
    traffic_dot = 0.0     # TPU-fusion model: matmuls + state updates + colls
    traffic_by_op: dict[str, float] = defaultdict(float)
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, float] = defaultdict(float)
    while_trips: list = []
    # XLA:CPU's FloatNormalization materializes f32 copies of large bf16
    # buffers (while carries, params) because the host has no native bf16.
    # These buffers DO NOT EXIST in a TPU executable; their total is reported
    # so memory_analysis() can be corrected (see dryrun.py).
    cpu_upcast = 0.0
    _UPCAST_MIN = 64 * 1024 * 1024
    comp_reads: dict[str, set] = {}

    _DOT_TRAFFIC_OPS = {"dot", "convolution", "dynamic-update-slice",
                        "scatter", "gather"}
    # `copy` is excluded: XLA:CPU materializes while-carry copies that TPU
    # elides via buffer aliasing/donation — counting them triples the
    # apparent traffic with ops that do not exist in the TPU executable.

    def _is_upcast_wrapped(comp: Computation, op: Op) -> bool:
        """XLA:CPU bf16 legalization: bf16 dots/collectives run as f32 with
        converts hoisted/sunk around them (the CPU has no native bf16). If an
        f32 collective's operand chain originates from bf16 values within a
        few hops, its TPU intent dtype is bf16 — count half the bytes."""
        if not op.shape.startswith("f32"):
            return False
        by_name = {o.name: o for o in comp.ops}

        def origin_bf16(name: str, depth: int) -> bool:
            d = by_name.get(name)
            if d is None:
                return False
            if any(comp.symbols.get(o, "").startswith("bf16") for o in d.operands):
                return True
            if depth <= 0:
                return False
            return any(origin_bf16(o, depth - 1) for o in d.operands)

        return any(origin_bf16(o, 3) for o in op.operands)

    for cname, comp in comps.items():
        k = mult.get(cname, 1.0)
        for op in comp.ops:
            base = op.opcode.replace("-start", "").replace("-done", "")
            if op.opcode in ("dot", "convolution"):
                flops += k * _dot_flops(op, comp)
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                if base == "reduce-scatter":
                    b = sum(_shape_list_bytes(comp.symbols.get(o, ""))
                            for o in op.operands)
                else:
                    b = _shape_list_bytes(op.shape)
                if _is_upcast_wrapped(comp, op):
                    b //= 2
                coll_bytes[base] += k * b
                coll_counts[base] += k
                traffic_dot += k * b
            op_io = None
            if op.opcode not in _SKIP_TRAFFIC and not op.opcode.endswith("-done"):
                if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
                    # read-modify-write of the *slice*, not the whole buffer
                    op_io = 2 * _shape_list_bytes(
                        comp.symbols.get(op.operands[1], "")
                    )
                else:
                    op_io = _shape_list_bytes(op.shape)
                    for o in op.operands:
                        op_io += _shape_list_bytes(comp.symbols.get(o, ""))
            if cname not in fused and op_io is not None:
                traffic_all += k * op_io
            if op.opcode in _DOT_TRAFFIC_OPS and op_io is not None:
                # TPU-fusion view: elementwise chains live in VMEM; HBM
                # traffic happens at matmul boundaries and explicit state
                # updates (KV caches, optimizer writes), wherever they sit
                # (incl. inside fusions). Reads are DEDUPED per computation
                # execution below (an operand feeding several dots in one
                # body crosses HBM once); only writes counted here.
                if op.opcode == "dynamic-update-slice":
                    traffic_dot += k * op_io
                    traffic_by_op[op.opcode] += k * op_io
                else:
                    w = _shape_list_bytes(op.shape)
                    traffic_dot += k * w
                    traffic_by_op[op.opcode] += k * w
                    comp_reads.setdefault(cname, set()).update(op.operands)
            if op.opcode == "while":
                cond = dict(_callee_names(op)).get("condition", "")
                while_trips.append((cname, _while_trip_count(comps, cond)))
                # f32 carry entries with a same-dims bf16 twin in the same
                # tuple are FloatNormalization artifacts of the CPU backend:
                # the TPU executable carries the bf16 buffer only.
                entries = _shape_dims(op.shape)
                bf16_dims = [tuple(d) for dt, d in entries if dt == "bf16"]
                for dt, d in entries:
                    if dt != "f32":
                        continue
                    b = 4
                    for x in d:
                        b *= x
                    if b >= _UPCAST_MIN and tuple(d) in bf16_dims:
                        cpu_upcast += b
            if cname in entry_comps and op.opcode in ("convert", "fusion"):
                # hoisted loop-invariant bf16->f32 conversions at the entry:
                # distinct f32 buffers on CPU, absent on TPU
                is_conv = op.opcode == "convert" or (
                    "convert" in dict(_callee_names(op)).get("calls", "")
                )
                if is_conv and op.shape.startswith("f32") and op.operands:
                    src = comp.symbols.get(op.operands[0], "")
                    b = _shape_list_bytes(op.shape)
                    if src.startswith("bf16") and b >= _UPCAST_MIN:
                        cpu_upcast += b

    # deduped dot-operand reads: each distinct buffer feeding the matmuls of
    # one computation crosses HBM once per execution of that computation
    for cname, names in comp_reads.items():
        k = mult.get(cname, 1.0)
        comp = comps[cname]
        b = sum(_shape_list_bytes(comp.symbols.get(n, "")) for n in names)
        traffic_dot += k * b
        traffic_by_op["dot_reads_deduped"] += k * b

    total = sum(coll_bytes.values())
    return {
        "flops_scaled": flops,
        "traffic_bytes_scaled": traffic_all,
        "traffic_dot_bytes_scaled": traffic_dot,
        "traffic_by_opcode": dict(traffic_by_op),
        "collective_bytes": dict(coll_bytes) | {"total": total},
        "collective_counts": dict(coll_counts),
        "while_trip_counts": while_trips,
        "cpu_bf16_upcast_bytes": cpu_upcast,
        "n_computations": len(comps),
    }
