from repro.roofline.analysis import (
    HW_V5E,
    collective_bytes_from_hlo,
    roofline_terms,
    model_flops,
)

__all__ = ["HW_V5E", "collective_bytes_from_hlo", "roofline_terms", "model_flops"]
