"""The single source of truth for kernel-semiring constants.

Every consumer of the fused semirings — both Pallas kernels, the numpy
oracles, and `ops.pack_algorithm` — reads these tables instead of restating
them, so a new semiring (or a corrected identity) cannot leave a kernel and
its oracle agreeing to disagree about what "empty" reduces to.

ACC_IDENTITY[s]  — the value a reduction accumulator starts from (and what an
                   in-edge-less vertex aggregates to).
TILE_FILL[s]     — the value absent edges *inside* a nonzero tile carry; the
                   semiring's absorbing element under its edge op, except
                   max_times, whose multiplicative fill 0 is only harmless
                   for nonnegative states (documented at the constructors).
"""
from __future__ import annotations

from repro.engine.algorithms import BIG

ACC_IDENTITY: dict[str, float] = {
    "plus_times": 0.0,
    "min_plus": float(BIG),
    "max_min": float(-BIG),
    "max_times": float(-BIG),
}

TILE_FILL: dict[str, float] = {
    "plus_times": 0.0,
    "min_plus": float(BIG),
    "max_min": float(-BIG),
    "max_times": 0.0,
}
