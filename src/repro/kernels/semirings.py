"""The single source of truth for kernel-semiring constants.

Every consumer of the fused semirings — both Pallas kernels, the numpy
oracles, and `ops.pack_algorithm` — reads these tables instead of restating
them, so a new semiring (or a corrected identity) cannot leave a kernel and
its oracle agreeing to disagree about what "empty" reduces to.

ACC_IDENTITY[s]  — the value a reduction accumulator starts from (and what an
                   in-edge-less vertex aggregates to).
TILE_FILL[s]     — the value absent edges *inside* a nonzero tile carry; the
                   semiring's absorbing element under its edge op, except
                   max_times, whose multiplicative fill 0 is only harmless
                   for nonnegative states (documented at the constructors).
DELTA_METRIC[s]  — the in-kernel per-sweep convergence metric the multisweep
                   megakernel accumulates for this semiring when the caller
                   does not pin one: the lattice (min/max) semirings move in
                   discrete steps, so "changed" (count of entries that moved,
                   an absolute did-anything-change signal) is exact; the
                   plus semiring contracts continuously, so the metric is the
                   max-|residual| ("linf") the sum-algorithm engines
                   threshold against eps. These match the `residual` kinds
                   `engine.algorithms` assigns, so in-kernel convergence
                   decisions agree with the host drivers' sweep-at-a-time
                   decisions (asserted in tests).
"""
from __future__ import annotations

from repro.engine.algorithms import BIG

ACC_IDENTITY: dict[str, float] = {
    "plus_times": 0.0,
    "min_plus": float(BIG),
    "max_min": float(-BIG),
    "max_times": float(-BIG),
}

TILE_FILL: dict[str, float] = {
    "plus_times": 0.0,
    "min_plus": float(BIG),
    "max_min": float(-BIG),
    "max_times": 0.0,
}

DELTA_METRIC: dict[str, str] = {
    "plus_times": "linf",
    "min_plus": "changed",
    "max_min": "changed",
    "max_times": "changed",
}


def pending_cols(semiring: str, p, r, xp, keepdims: bool = False):
    """Per-column pending-work metric of a push-engine ``(p, r)`` state —
    THE definition, shared by the push round driver (`engine.push`, xp=jnp),
    its vectorized jax backend, and the numpy oracle (`kernels.ref`, xp=np).

    For the sum semiring the residual *is* the distance still to be folded
    in, so the metric is its per-column max-|r| — the same linf quantity the
    sweep engines threshold against eps. For the lattice semirings ``r``
    holds the best pending candidate; a row is pending when that candidate
    beats ``p`` under the combine, and the metric is the per-column count of
    such rows (the same absolute "changed" signal as `DELTA_METRIC`).
    """
    if semiring == "plus_times":
        return xp.max(xp.abs(r), axis=0, keepdims=keepdims)
    if semiring == "min_plus":
        moved = xp.minimum(p, r) != p
    elif semiring in ("max_min", "max_times"):
        moved = xp.maximum(p, r) != p
    else:
        raise ValueError(semiring)
    return xp.sum(moved.astype(xp.float32), axis=0, keepdims=keepdims)


def delta_cols(res_kind: str, new, old, xp, keepdims: bool = False):
    """Per-column convergence metric over the row axis — THE definition.

    One function serves every consumer so the metrics can never drift apart:
    the engines' host drivers (`engine.jax_ops.residual_cols`, xp=jnp over
    full (n, d) states), the multisweep megakernel (xp=jnp over one (bs, d)
    block, keepdims=True for the (1, d) VMEM accumulator), and the numpy
    oracle (`kernels.ref`, xp=np). ``xp`` is the array namespace (numpy or
    jax.numpy — identical APIs for everything used here).
    """
    if res_kind == "linf":
        return xp.max(xp.abs(new - old), axis=0, keepdims=keepdims)
    if res_kind == "l1":
        return xp.sum(xp.abs(new - old), axis=0, keepdims=keepdims)
    if res_kind == "changed":
        return xp.sum((new != old).astype(xp.float32), axis=0,
                      keepdims=keepdims)
    raise ValueError(res_kind)
