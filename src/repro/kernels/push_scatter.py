"""Bucketed vertex-granular residual-push scatter kernel.

The block engines (and the persistent megakernel's frontier) skip work at
``bs``-block granularity; when a serving delta or a personalized query
touches a handful of vertices, whole blocks still sweep. This kernel is the
ultra-sparse regime: the push engine (`engine.push`) maintains ``(p, r)``
state per column — ``p`` the settled estimate, ``r`` the pending residual —
and each launch processes one *round* of active vertices, binned by the
host into priority buckets.

Grid = ``(buckets, cap)``: TPU grids run sequentially with the bucket
dimension outermost, so bucket 0's vertices (best priority — smallest
tentative distance for min_plus, largest pending residual for the sum
semiring) settle before bucket 1 reads them. That ordering is exactly
delta-stepping for SSSP, and largest-residual-first push for PageRank — and
because every slot reads ``(p, r)`` through the *aliased outputs*, each
vertex sees every earlier scatter of the same launch (Gauss–Seidel
freshness at vertex granularity).

Per slot ``k = b * cap + j`` with vertex ``u = vid[k]`` (``-1`` pads):

    sum (plus_times):      push = r[u];  p[u] += push;  r[u] = 0
                           r[v] += w_uv * push              (out-edges u->v)
    lattice (min/max):     push = combine(p[u], r[u]);  p[u] = push
                           r[u] = ACC_IDENTITY
                           r[v] = reduce(r[v], edge_op(push, w_uv))

``u``'s rows are settled *before* the scatter, so a self-loop lands its
message on the emptied residual row (the sum invariant ``r = c + Wp - p``
survives self-loops).

The CSR out-neighbor segment ``nbrs[seg_start[k] : +seg_len[k]]`` is walked
in chunks of a static ``ecap``: each chunk is one DMA of neighbor ids and
weights into SMEM scratch (scalar-indexable), then per-edge (1, d) residual
rows are gather/scatter-DMA'd through VMEM. Hub vertices of any degree cost
``ceil(deg/ecap)`` chunk DMAs; ``nbrs``/``ew`` must be tail-padded by
``ecap`` entries so the final static-size chunk DMA cannot overrun.

VMEM per step: four (1, d) rows + two (1, 1) counters; SMEM: the two
(ecap,) edge buffers — independent of n, m, and d beyond the rows
(budgeted in `kernels.budgets` as ``push_scatter_pallas``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.semirings import ACC_IDENTITY

# semirings the scatter body implements; mirror pack_algorithm's guard so
# direct callers fail loudly instead of pushing with a wrong identity
_SUPPORTED = ("plus_times", "min_plus", "max_min", "max_times")


def _check_semiring(semiring: str) -> None:
    if semiring not in _SUPPORTED:
        raise NotImplementedError(
            f"push_scatter: unsupported semiring {semiring!r}; "
            f"supported: {sorted(_SUPPORTED)}"
        )


def _make_kernel(semiring: str, buckets: int, cap: int, ecap: int):
    ident = ACC_IDENTITY[semiring]

    def kernel(vid_ref, seg_ref, len_ref, nbrs_hbm, ew_hbm, p_hbm, r_hbm,
               p_out, r_out, pushed_out, edges_out,
               urow, rrow, vrow, push, cnt, ecnt, ebuf, wbuf,
               sem_u, sem_r, sem_v, sem_e):
        b = pl.program_id(0)
        j = pl.program_id(1)
        k = b * cap + j

        # bucket start: zero this bucket's work counters
        @pl.when(j == 0)
        def _bucket_reset():
            cnt[...] = jnp.zeros_like(cnt)
            ecnt[...] = jnp.zeros_like(ecnt)

        u = vid_ref[k]

        @pl.when(u >= 0)
        def _push_vertex():
            # u's (p, r) rows, read through the aliased outputs so every
            # earlier slot's settle/scatter this launch is already visible
            cp_u = pltpu.make_async_copy(p_out.at[pl.ds(u, 1)], urow, sem_u)
            cp_r = pltpu.make_async_copy(r_out.at[pl.ds(u, 1)], rrow, sem_r)
            cp_u.start()
            cp_r.start()
            cp_u.wait()
            cp_r.wait()

            if semiring == "plus_times":
                push[...] = rrow[...]
                urow[...] = urow[...] + rrow[...]
            elif semiring == "min_plus":
                push[...] = jnp.minimum(urow[...], rrow[...])
                urow[...] = push[...]
            else:  # max_min / max_times
                push[...] = jnp.maximum(urow[...], rrow[...])
                urow[...] = push[...]
            rrow[...] = jnp.full_like(rrow, ident)

            # settle u BEFORE scattering: a self-loop u->u must land its
            # message on the emptied residual row, not the pre-push one
            wb_u = pltpu.make_async_copy(urow, p_out.at[pl.ds(u, 1)], sem_u)
            wb_u.start()
            wb_u.wait()
            wb_r = pltpu.make_async_copy(rrow, r_out.at[pl.ds(u, 1)], sem_r)
            wb_r.start()
            wb_r.wait()

            lo = seg_ref[k]
            deg = len_ref[k]

            def chunk(ci, _):
                # one static-size DMA per ecap edges (tail padding makes the
                # overrun slots harmless; the inner bound ignores them)
                off = lo + ci * ecap
                cp_n = pltpu.make_async_copy(
                    nbrs_hbm.at[pl.ds(off, ecap)], ebuf, sem_e.at[0]
                )
                cp_w = pltpu.make_async_copy(
                    ew_hbm.at[pl.ds(off, ecap)], wbuf, sem_e.at[1]
                )
                cp_n.start()
                cp_w.start()
                cp_n.wait()
                cp_w.wait()
                m_here = jnp.minimum(deg - ci * ecap, ecap)

                def edge(t, _):
                    v = ebuf[t]
                    w = wbuf[t]
                    cp_v = pltpu.make_async_copy(
                        r_out.at[pl.ds(v, 1)], vrow, sem_v
                    )
                    cp_v.start()
                    cp_v.wait()
                    if semiring == "plus_times":
                        vrow[...] = vrow[...] + w * push[...]
                    elif semiring == "min_plus":
                        vrow[...] = jnp.minimum(vrow[...], push[...] + w)
                    elif semiring == "max_min":
                        vrow[...] = jnp.maximum(
                            vrow[...], jnp.minimum(push[...], w)
                        )
                    else:  # max_times
                        vrow[...] = jnp.maximum(vrow[...], push[...] * w)
                    wb_v = pltpu.make_async_copy(
                        vrow, r_out.at[pl.ds(v, 1)], sem_v
                    )
                    wb_v.start()
                    wb_v.wait()
                    return 0

                jax.lax.fori_loop(0, m_here, edge, 0)
                return 0

            nchunks = (deg + ecap - 1) // ecap
            jax.lax.fori_loop(0, nchunks, chunk, 0)

            cnt[...] += 1.0
            ecnt[...] += deg.astype(jnp.float32)

        pushed_out[...] = cnt[...]
        edges_out[...] = ecnt[...]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("semiring", "buckets", "cap", "ecap", "interpret"),
)
def push_scatter_pallas(
    vid: jnp.ndarray,        # int32[buckets*cap]  vertex per slot, -1 = pad
    seg_start: jnp.ndarray,  # int32[buckets*cap]  CSR out-segment start
    seg_len: jnp.ndarray,    # int32[buckets*cap]  CSR out-segment length
    nbrs: jnp.ndarray,       # int32[m + ecap]     CSR out-neighbors (padded)
    ew: jnp.ndarray,         # f32[m + ecap]       edge weights (padded)
    p: jnp.ndarray,          # f32[n, d]           settled state (aliased)
    r: jnp.ndarray,          # f32[n, d]           pending residual (aliased)
    *,
    semiring: str = "plus_times",
    buckets: int,
    cap: int,
    ecap: int = 128,
    interpret: bool = True,
):
    """One bucketed push round. Returns ``(p, r, pushed, edges)``:

    * ``p``, ``r``    f32[n, d] — state after the round (inputs aliased)
    * ``pushed``      f32[buckets, 1] — vertices settled per bucket
    * ``edges``       f32[buckets, 1] — edge messages scattered per bucket

    Slots run in flat ``b * cap + j`` order; the host places the best
    priority bucket first. Padding slots (``vid < 0``) are predicated
    no-ops: zero DMAs, zero messages.
    """
    _check_semiring(semiring)
    if buckets < 1 or cap < 1 or ecap < 1:
        raise ValueError(f"buckets/cap/ecap must be >= 1, got "
                         f"{(buckets, cap, ecap)}")
    n, d = p.shape
    assert r.shape == (n, d), (r.shape, p.shape)
    assert vid.shape == (buckets * cap,), (vid.shape, buckets, cap)
    assert seg_start.shape == vid.shape and seg_len.shape == vid.shape
    assert nbrs.shape == ew.shape and nbrs.ndim == 1
    kernel = _make_kernel(semiring, buckets, cap, ecap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(buckets, cap),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # nbrs, chunk-DMA'd manually
            pl.BlockSpec(memory_space=pl.ANY),  # ew
            pl.BlockSpec(memory_space=pl.ANY),  # p (aliased)
            pl.BlockSpec(memory_space=pl.ANY),  # r (aliased)
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pl.ANY),              # p (aliased)
            pl.BlockSpec(memory_space=pl.ANY),              # r (aliased)
            pl.BlockSpec((1, 1), lambda b, j, *_: (b, 0)),  # pushed/bucket
            pl.BlockSpec((1, 1), lambda b, j, *_: (b, 0)),  # edges/bucket
        ),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),   # urow: u's settled row
            pltpu.VMEM((1, d), jnp.float32),   # rrow: u's residual row
            pltpu.VMEM((1, d), jnp.float32),   # vrow: neighbor residual row
            pltpu.VMEM((1, d), jnp.float32),   # push: the scattered message
            pltpu.VMEM((1, 1), jnp.float32),   # cnt: pushes this bucket
            pltpu.VMEM((1, 1), jnp.float32),   # ecnt: edges this bucket
            pltpu.SMEM((ecap,), jnp.int32),    # ebuf: neighbor-id chunk
            pltpu.SMEM((ecap,), jnp.float32),  # wbuf: weight chunk
            pltpu.SemaphoreType.DMA,           # sem_u (p row)
            pltpu.SemaphoreType.DMA,           # sem_r (r row)
            pltpu.SemaphoreType.DMA,           # sem_v (neighbor row)
            pltpu.SemaphoreType.DMA((2,)),     # sem_e (edge chunk pair)
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n, d), p.dtype),
            jax.ShapeDtypeStruct((n, d), r.dtype),
            jax.ShapeDtypeStruct((buckets, 1), jnp.float32),
            jax.ShapeDtypeStruct((buckets, 1), jnp.float32),
        ),
        # p, r (after the 3 prefetch args + nbrs + ew) -> outputs 0, 1
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(vid, seg_start, seg_len, nbrs, ew, p, r)
