"""Block-sparse (BSR) x dense SpMM Pallas kernel — one synchronous round.

The reordered + community-partitioned adjacency is block-concentrated
(DESIGN.md §3), so each row-block touches few column-blocks. The kernel walks
``grid = (nb, dj, k_max)`` with the column-block index scalar-prefetched from
``cols`` so the BlockSpec index_map can DMA exactly the source-state tile the
current adjacency tile needs — the data movement the paper's cache argument
becomes on TPU.

Semirings:
  plus_times — y[i] = sum_k  tiles[i,k] @ x[cols[i,k]]          (MXU matmuls)
  min_plus   — y[i] = min_k  min_c (tiles[i,k][r,c] + x[cols[i,k]][c, :])
               (VPU broadcast; SSSP/BFS-style relaxations)

Padding contract: unused k-slots carry ``cols = 0`` and tiles filled with the
semiring identity (0 for plus_times, +BIG for min_plus), so no masks are
needed inside the kernel.

VMEM budget per grid step: tile (bs x bs) + x block (bs x dj) + out block
(bs x dj), all fp32 — with bs=128, dj=128 that's 192 KiB, comfortably inside
the ~16 MiB v5e VMEM even with double buffering. min_plus materializes a
(bs, bs, dj) broadcast, so it is built with a narrower dj (see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.engine.algorithms import BIG


def _plus_times_kernel(cols_ref, tiles_ref, x_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        tiles_ref[0, 0], x_ref[...], preferred_element_type=o_ref.dtype
    )


def _min_plus_kernel(cols_ref, tiles_ref, x_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, BIG)

    # (bs, bs, 1) + (1, bs, dj) -> min over the source axis
    part = jnp.min(tiles_ref[0, 0][:, :, None] + x_ref[...][None, :, :], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], part)


@functools.partial(
    jax.jit, static_argnames=("semiring", "bs", "dj", "interpret")
)
def bsr_spmm_pallas(
    cols: jnp.ndarray,   # int32[nb, k_max]
    tiles: jnp.ndarray,  # f32[nb, k_max, bs, bs]
    x: jnp.ndarray,      # f32[nb*bs, d]
    *,
    semiring: str = "plus_times",
    bs: int,
    dj: int,
    interpret: bool = True,
) -> jnp.ndarray:
    nb, k_max = cols.shape
    n, d = x.shape
    assert d % dj == 0 and n == nb * bs
    kernel = {"plus_times": _plus_times_kernel, "min_plus": _min_plus_kernel}[semiring]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, d // dj, k_max),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda i, j, k, cols_ref: (i, k, 0, 0)),
            pl.BlockSpec((bs, dj), lambda i, j, k, cols_ref: (cols_ref[i, k], j)),
        ],
        out_specs=pl.BlockSpec((bs, dj), lambda i, j, k, cols_ref: (i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(cols, tiles, x)
