"""Block-sparse (BSR) x dense SpMM Pallas kernel — one synchronous round.

Walks the ragged flat layout (`graphs.blocked.FlatBSRMatrix`): the grid is
``(d // dj, nnz_blocks)`` — one step per *real* tile, not per ``(row, k_max)``
slot — with ``rowptr`` / ``tilerows`` / ``tilecols`` scalar-prefetched so the
BlockSpec index maps can DMA exactly the source-state tile and output block
each adjacency tile needs. Tiles are sorted by destination row, so all grid
steps writing one output block are consecutive: the block stays resident in
VMEM, is initialized at its row's first tile (``t == rowptr[row]``), and is
flushed when the row changes. Work and data movement are O(nnz_blocks); the
old dense-padded layout ran ``nb * k_max`` steps, paying the densest
(hub) row-block's tile count in every row.

Semirings (identities in kernels.semirings.ACC_IDENTITY):
  plus_times — y[i] = sum_t  tiles[t] @ x[tilecols[t]]            (MXU matmuls)
  min_plus   — y[i] = min_t  min_c (tiles[t][r,c] + x[tilecols[t]][c, :])
  max_min    — y[i] = max_t  max_c min(tiles[t][r,c], x[..][c, :])  (SSWP)
  max_times  — y[i] = max_t  max_c (tiles[t][r,c] * x[..][c, :])  (reachability;
               nonnegative states — absent in-tile edges contribute 0 products)

Padding contract: there are no padding tiles. Absent edges *inside* a real
tile carry the semiring's absorbing fill (0 / +BIG / -BIG / 0); row-blocks
with no tiles at all never appear in the grid, so the wrapper writes the
reduce identity into their output rows afterwards.

VMEM budget per grid step: tile (bs x bs) + x block (bs x dj) + out block
(bs x dj), all fp32 — with bs=128, dj=128 that's 192 KiB, comfortably inside
the ~16 MiB v5e VMEM even with double buffering. min_plus/max_* materialize a
(bs, bs, dj) broadcast, so they are built with a narrower dj (see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.semirings import ACC_IDENTITY


def _make_kernel(semiring: str):
    def kernel(rowptr_ref, tilerows_ref, tilecols_ref, tiles_ref, x_ref, o_ref):
        t = pl.program_id(1)
        row = tilerows_ref[t]

        @pl.when(t == rowptr_ref[row])
        def _init():
            o_ref[...] = jnp.full_like(o_ref, ACC_IDENTITY[semiring])

        tile = tiles_ref[0]
        if semiring == "plus_times":
            o_ref[...] += jnp.dot(
                tile, x_ref[...], preferred_element_type=o_ref.dtype
            )
        elif semiring == "min_plus":
            part = jnp.min(tile[:, :, None] + x_ref[...][None, :, :], axis=1)
            o_ref[...] = jnp.minimum(o_ref[...], part)
        elif semiring == "max_min":
            part = jnp.max(
                jnp.minimum(tile[:, :, None], x_ref[...][None, :, :]), axis=1
            )
            o_ref[...] = jnp.maximum(o_ref[...], part)
        elif semiring == "max_times":
            part = jnp.max(tile[:, :, None] * x_ref[...][None, :, :], axis=1)
            o_ref[...] = jnp.maximum(o_ref[...], part)
        else:
            raise ValueError(semiring)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("semiring", "bs", "dj", "interpret")
)
def bsr_spmm_pallas(
    rowptr: jnp.ndarray,    # int32[nb + 1]
    tilerows: jnp.ndarray,  # int32[nnz_blocks]
    tilecols: jnp.ndarray,  # int32[nnz_blocks]
    tiles: jnp.ndarray,     # f32[nnz_blocks, bs, bs]
    x: jnp.ndarray,         # f32[nb*bs, d]
    *,
    semiring: str = "plus_times",
    bs: int,
    dj: int,
    interpret: bool = True,
) -> jnp.ndarray:
    if semiring not in ACC_IDENTITY:
        raise NotImplementedError(
            f"bsr_spmm_pallas: unknown semiring {semiring!r}; "
            f"supported: {sorted(ACC_IDENTITY)}"
        )
    nb = rowptr.shape[0] - 1
    nnz = tiles.shape[0]
    n, d = x.shape
    assert d % dj == 0 and n == nb * bs
    assert tilerows.shape[0] == tilecols.shape[0] == nnz
    ident = jnp.float32(ACC_IDENTITY[semiring])
    # empty row-blocks own no grid steps, so the kernel never writes their
    # output rows: overwrite them with the reduce identity afterwards. This
    # also covers the empty-graph pack (one never-referenced pad tile with
    # rowptr all zero): every row is empty, so every row is overwritten.
    empty_row = jnp.repeat(rowptr[1:] == rowptr[:-1], bs)[:, None]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(d // dj, nnz),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda j, t, rp, tr, tc: (t, 0, 0)),
            pl.BlockSpec((bs, dj), lambda j, t, rp, tr, tc: (tc[t], j)),
        ],
        out_specs=pl.BlockSpec((bs, dj), lambda j, t, rp, tr, tc: (tr[t], j)),
    )
    y = pl.pallas_call(
        _make_kernel(semiring),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(rowptr, tilerows, tilecols, tiles, x)
    return jnp.where(empty_row, ident.astype(x.dtype), y)
