# Pallas TPU kernels for the paper's compute hot-spot: the per-round vertex
# update sweep. Both walk the ragged flat-BSR layout (graphs.blocked.
# FlatBSRMatrix: tiles[nnz_blocks, bs, bs] + rowptr/tilecols) so memory, DMA
# count, and semiring work are O(nnz_blocks), not O(nb * k_max). Two kernels:
#   bsr_spmm  — one synchronous round as block-sparse-matrix x dense-states
#               (plus_times on the MXU; min_plus/max_min/max_times on the VPU)
#   gs_sweep  — one *asynchronous* block Gauss-Seidel sweep as a single fused
#               kernel, exploiting the TPU's sequential grid execution so
#               later blocks consume earlier blocks' freshly written states
#               (the paper's Eq. 2 at tile granularity), with double-buffered
#               gather DMAs hiding fetch latency behind the tile reduction
# ops.py holds the jit'd wrappers, ref.py the pure-numpy oracles.
from repro.kernels.ops import bsr_spmm, gs_sweep

__all__ = ["bsr_spmm", "gs_sweep"]
