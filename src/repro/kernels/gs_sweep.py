"""Fused block Gauss–Seidel sweep — the paper's async mode as ONE kernel.

TPU Pallas grids execute sequentially, which is exactly the ordering
guarantee the paper's Eq. 2 needs: grid step i updates destination block i and
*writes it back to the state buffer before step i+1 runs*. The state lives in
HBM (`pl.ANY`) and is aliased input->output, so column-block gathers issued by
later steps (explicit `make_async_copy` DMAs) observe every earlier block's
current-round value — positive edges (p(src) < p(dst)) deliver fresh state,
negative edges deliver last-round state, with zero host round-trips for the
whole sweep.

This is the kernel the GoGraph ordering exists to feed: the reordering
maximizes (a) the number of src-block < dst-block edges (freshness) and
(b) block-diagonal concentration (fewer DMAs per step; `BSRMatrix.stats()`).

Update rule per destination block i (semiring & combine as in the engines):

    agg  = REDUCE_k  tiles[i,k] (x) x[cols[i,k]]
    newb = combine(c[i], agg, oldb);  newb = fixed ? x0 : newb
    x[i] <- newb

VMEM per step: k_max adjacency tiles are streamed via BlockSpec; the gather
buffer, accumulator, and const/x0/fixed blocks are (bs, d) scratch/inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.engine.algorithms import BIG

# semiring/combine pairs the kernel body actually implements: sum-reduce
# rounds (PageRank family, combine c + agg) and min-plus relaxations
# (SSSP/BFS/CC, combine min(old, c, agg)).
_SUPPORTED = {("plus_times", "replace"), ("min_plus", "min_old")}


def _make_kernel(semiring: str, combine: str, k_max: int, bs: int):
    def kernel(cols_ref, tiles_ref, c_ref, x0_ref, fixed_ref, x_hbm, x_out,
               xblk, acc, sem):
        i = pl.program_id(0)

        if semiring == "plus_times":
            acc[...] = jnp.zeros_like(acc)
        else:
            acc[...] = jnp.full_like(acc, BIG)

        def body(k, _):
            c = cols_ref[i, k]
            cp = pltpu.make_async_copy(x_out.at[pl.ds(c * bs, bs)], xblk, sem)
            cp.start()
            cp.wait()
            if semiring == "plus_times":
                acc[...] += jnp.dot(
                    tiles_ref[0, k], xblk[...], preferred_element_type=acc.dtype
                )
            else:  # min_plus
                part = jnp.min(
                    tiles_ref[0, k][:, :, None] + xblk[...][None, :, :], axis=1
                )
                acc[...] = jnp.minimum(acc[...], part)
            return 0

        jax.lax.fori_loop(0, k_max, body, 0)

        # fetch the destination block's previous-round value
        cp = pltpu.make_async_copy(x_out.at[pl.ds(i * bs, bs)], xblk, sem)
        cp.start()
        cp.wait()
        old = xblk[...]
        if combine == "replace":
            new = c_ref[...] + acc[...]
        elif combine == "min_old":
            new = jnp.minimum(old, jnp.minimum(c_ref[...], acc[...]))
        elif combine == "max_old":
            new = jnp.maximum(old, jnp.maximum(c_ref[...], acc[...]))
        else:
            raise ValueError(combine)
        new = jnp.where(fixed_ref[...] != 0, x0_ref[...], new)
        acc[...] = new.astype(acc.dtype)
        cp = pltpu.make_async_copy(acc, x_out.at[pl.ds(i * bs, bs)], sem)
        cp.start()
        cp.wait()

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("semiring", "combine", "bs", "interpret"),
)
def gs_sweep_pallas(
    cols: jnp.ndarray,    # int32[nb, k_max]
    tiles: jnp.ndarray,   # f32[nb, k_max, bs, bs]
    c: jnp.ndarray,       # f32[nb*bs, d]   per-vertex const (broadcast over d)
    x0: jnp.ndarray,      # f32[nb*bs, d]
    fixed: jnp.ndarray,   # f32[nb*bs, d]   1.0 where pinned
    x: jnp.ndarray,       # f32[nb*bs, d]   state (donated; aliased to output)
    *,
    semiring: str = "plus_times",
    combine: str = "replace",
    bs: int,
    interpret: bool = True,
) -> jnp.ndarray:
    # the accumulator init and tile reduction are only implemented for these
    # pairs; anything else (e.g. max-semiring "max_old" for SSWP) would start
    # the accumulator at +BIG — the *min*-semiring identity — and silently
    # compute garbage. Mirror pack_algorithm's guard (kernels/ops.py) here so
    # direct kernel callers fail loudly too.
    if (semiring, combine) not in _SUPPORTED:
        raise NotImplementedError(
            f"gs_sweep_pallas: unsupported semiring/combine pair "
            f"({semiring!r}, {combine!r}); supported: {sorted(_SUPPORTED)}"
        )
    nb, k_max = cols.shape
    n, d = x.shape
    assert n == nb * bs
    # the batched engine (run_async_block(backend="pallas")) feeds real
    # multi-query columns here; all per-vertex operands must carry them
    assert c.shape == x0.shape == fixed.shape == (n, d), (
        c.shape, x0.shape, fixed.shape, (n, d)
    )
    kernel = _make_kernel(semiring, combine, k_max, bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, k_max, bs, bs), lambda i, cols_ref: (i, 0, 0, 0)),
            pl.BlockSpec((bs, d), lambda i, cols_ref: (i, 0)),
            pl.BlockSpec((bs, d), lambda i, cols_ref: (i, 0)),
            pl.BlockSpec((bs, d), lambda i, cols_ref: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((bs, d), x.dtype),
            pltpu.VMEM((bs, d), x.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        input_output_aliases={5: 0},  # x (after the prefetch arg) -> output
        interpret=interpret,
    )(cols, tiles, c, x0, fixed, x)
