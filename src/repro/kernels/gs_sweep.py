"""Persistent multi-sweep block Gauss–Seidel megakernel.

The paper's reordering cuts *rounds*; this kernel removes the fixed
per-round tax that reordering cannot touch. One ``pallas_call`` now executes
up to ``sweeps`` Gauss–Seidel sweeps over a 2-D grid ``(sweeps, nb)`` — TPU
grids run sequentially with the sweep dimension outermost, so the state stays
resident in HBM (aliased input->output) across the whole batch and the host
checks convergence once per *batch* instead of once per sweep. Three fused
mechanisms make per-round cost proportional to remaining work:

* **In-kernel convergence.** Every block update folds its per-column delta
  (``kernels.semirings.DELTA_METRIC``: max-|residual| for the plus semiring,
  changed-entry count for the lattice semirings — the same metrics the host
  drivers threshold) into a VMEM accumulator; the end of each sweep writes
  the accumulated ``(1, d)`` row into the ``deltas[sweeps, d]`` output and
  sets an SMEM ``done`` flag once all columns drop to ``eps``.

* **Early-out.** Once ``done`` is set, the remaining grid steps are
  predicated no-ops: no gather DMAs, no tile DMAs, no reduction — the
  leftover sweeps of the batch cost grid bookkeeping only, and their delta
  rows report 0.

* **Active-frontier block skipping.** A per-row-block dirty bitmap (SMEM,
  seeded from the ``dirty`` input, exported to the ``dirty_out`` output so
  the next batch resumes the frontier) gates each block update behind
  ``@pl.when``: a block whose in-neighbor blocks all held still since its
  last update is skipped with zero HBM traffic. When an update *changes* a
  block (bitwise — any entry, any column), its dependents — read from the
  block reverse-dependency CSR ``revptr``/``revrows``
  (`graphs.blocked.FlatBSRMatrix.reverse_deps`) — are re-marked dirty:
  blocks later in this sweep see the mark immediately (Gauss–Seidel
  freshness at frontier granularity), earlier blocks next sweep. Because a
  clean block's recompute is bitwise a no-op by construction, frontier
  execution is **bitwise-equivalent** to full sweeps, per sweep, per column.

The frontier contract: a clean (``dirty == 0``) block asserts that its
current state already satisfies its update equation. Cold starts must
therefore seed all-dirty (``graphs.blocked.frontier_blocks(None, ...)``);
warm starts may seed only the delta-touched blocks (see
``engine.incremental``) because monotone combines keep every untouched
block self-consistent.

Data layout is the ragged flat BSR of `graphs.blocked.FlatBSRMatrix`
(tiles[nnz_blocks, bs, bs] + scalar-prefetched rowptr/tilecols), walked with
the double-buffered gather+tile DMA pipeline: tile t+1's adjacency tile and
gathered source block stream into the opposite scratch slot while tile t
reduces, and the destination block's previous-round fetch overlaps the whole
reduction.

Update rule per destination block i (semiring & combine as in the engines):

    agg  = REDUCE_t  tiles[t] (x) x[tilecols[t]],  t in [rowptr[i], rowptr[i+1])
    newb = combine(c[i], agg, oldb);  newb = fixed ? x0 : newb
    x[i] <- newb

VMEM per step: 2 adjacency tiles (bs, bs) + 7 state blocks (bs, d) + the
(1, d) delta row and (1, 1) active counter — independent of both k_max and
``sweeps``. SMEM holds the nb dirty flags and the done bit.

Supported (semiring, combine) pairs and their accumulator identities:

    plus_times / replace   acc 0     (PageRank family: c + sum w*x)
    min_plus   / min_old   acc +BIG  (SSSP/BFS/CC: min(old, c, min x+w))
    max_min    / max_old   acc -BIG  (SSWP: max(old, c, max min(x, w)))
    max_times  / max_old   acc -BIG  (reachability: max(old, c, max w*x);
                                      requires nonnegative states — absent
                                      in-tile edges contribute w=0 products)

``gs_sweep_pallas`` (the legacy single-sweep entry point) is the same kernel
with ``sweeps=1``, an all-dirty frontier, and the delta/frontier outputs
discarded — one body, one set of semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.semirings import ACC_IDENTITY, DELTA_METRIC, delta_cols


def or_dirty_blocks(dirty, vertex_mask, n: int, bs: int) -> np.ndarray:
    """OR a vertex-level support mask into a per-row-block dirty bitmap.

    This is the frontier seeding for a *column subset*: when the serving
    layer swaps a new query into one column of a resident state matrix, only
    the blocks whose update equations the newcomer's injection invalidates —
    its support (seeds/sources/pinned vertices, `engine.harness.
    column_support`) plus the vertices the support's out-edges feed — stop
    being self-consistent; OR-ing them into the carried bitmap makes the
    next megakernel batch re-touch exactly what the newcomer needs, while
    blocks that are clean for every other in-flight column stay skipped.
    Sound because the clean contract is per-block over *all* columns and an
    unsupported vertex of the fresh column whose in-neighbors are all
    unsupported holds its inert fill, whose update is a bitwise no-op until
    an in-neighbor moves (and the kernel re-marks dependents when one does).

    ``dirty`` may be host numpy or a device jax array; a jax bitmap is OR-ed
    functionally and stays on device (the serving session carries it across
    batches without host sync — the vertex mask itself is tiny, host-built
    from the newcomer query's own host-side vectors).
    """
    from repro.graphs.blocked import frontier_blocks

    add = frontier_blocks(np.asarray(vertex_mask), n, bs)
    if hasattr(dirty, "at"):  # jax array: stays device-resident
        return jnp.maximum(dirty, jnp.asarray(add)).astype(jnp.int32)
    return np.maximum(np.asarray(dirty, np.int32), add).astype(np.int32)

# semiring/combine pairs the kernel body implements, with the accumulator
# identity (kernels.semirings.ACC_IDENTITY) each reduction starts from.
# Anything else must fail loudly — a wrong identity silently computes
# garbage shaped like an answer.
_SUPPORTED = {
    ("plus_times", "replace"),
    ("min_plus", "min_old"),
    ("max_min", "max_old"),
    ("max_times", "max_old"),
}


def _reduce_tile(semiring: str, acc_ref, tile, xs):
    """acc <- acc (reduce) tile (x) xs for one (bs, bs) tile and (bs, d)
    source block."""
    if semiring == "plus_times":
        acc_ref[...] += jnp.dot(tile, xs, preferred_element_type=acc_ref.dtype)
    elif semiring == "min_plus":
        part = jnp.min(tile[:, :, None] + xs[None, :, :], axis=1)
        acc_ref[...] = jnp.minimum(acc_ref[...], part)
    elif semiring == "max_min":
        part = jnp.max(jnp.minimum(tile[:, :, None], xs[None, :, :]), axis=1)
        acc_ref[...] = jnp.maximum(acc_ref[...], part)
    elif semiring == "max_times":
        part = jnp.max(tile[:, :, None] * xs[None, :, :], axis=1)
        acc_ref[...] = jnp.maximum(acc_ref[...], part)
    else:
        raise ValueError(semiring)


def _make_kernel(semiring: str, combine: str, res_kind: str, bs: int,
                 nb: int, sweeps: int, eps: float):
    def kernel(rowptr_ref, tilecols_ref, revptr_ref, revrows_ref,
               dirty_init_ref, tiles_hbm, c_ref, x0_ref, fixed_ref, x_hbm,
               x_out, deltas_out, active_out, dirty_out,
               xblk, tblk, oldblk, acc, dacc, cnt, dirty_s, done_s,
               sem_x, sem_t, sem_o):
        s = pl.program_id(0)
        i = pl.program_id(1)

        # batch start: load the caller's frontier, clear the done bit
        @pl.when(jnp.logical_and(s == 0, i == 0))
        def _seed_frontier():
            done_s[0] = 0

            def cp(j, _):
                dirty_s[j] = dirty_init_ref[j]
                return 0

            jax.lax.fori_loop(0, nb, cp, 0)

        # sweep start: zero this sweep's delta row and active counter, so
        # early-outed sweeps report 0 movement / 0 blocks touched
        @pl.when(i == 0)
        def _sweep_reset():
            dacc[...] = jnp.zeros_like(dacc)
            cnt[...] = jnp.zeros_like(cnt)

        work = jnp.logical_and(done_s[0] == 0, dirty_s[i] != 0)

        @pl.when(work)
        def _update():
            dirty_s[i] = 0
            lo = rowptr_ref[i]
            hi = rowptr_ref[i + 1]

            acc[...] = jnp.full_like(acc, ACC_IDENTITY[semiring])

            def gather(t, slot):
                # source block for tile t, read from the *aliased output* so
                # earlier grid steps' writes (this sweep) are visible
                c = tilecols_ref[t]
                return pltpu.make_async_copy(
                    x_out.at[pl.ds(c * bs, bs)], xblk.at[slot], sem_x.at[slot]
                )

            def fetch_tile(t, slot):
                return pltpu.make_async_copy(
                    tiles_hbm.at[t], tblk.at[slot], sem_t.at[slot]
                )

            # the destination block's previous value: fetched once, its DMA
            # overlaps the whole tile reduction below
            old_cp = pltpu.make_async_copy(
                x_out.at[pl.ds(i * bs, bs)], oldblk, sem_o
            )
            old_cp.start()

            # double-buffer warm-up: tile lo's DMAs go into slot 0
            @pl.when(lo < hi)
            def _warmup():
                gather(lo, 0).start()
                fetch_tile(lo, 0).start()

            def body(t, _):
                slot = jax.lax.rem(t - lo, 2)
                nxt = 1 - slot

                # start tile t+1's fetches before blocking on tile t's
                @pl.when(t + 1 < hi)
                def _prefetch():
                    gather(t + 1, nxt).start()
                    fetch_tile(t + 1, nxt).start()

                gather(t, slot).wait()
                fetch_tile(t, slot).wait()
                _reduce_tile(semiring, acc, tblk[slot], xblk[slot])
                return 0

            jax.lax.fori_loop(lo, hi, body, 0)

            old_cp.wait()
            old = oldblk[...]
            if combine == "replace":
                new = c_ref[...] + acc[...]
            elif combine == "min_old":
                new = jnp.minimum(old, jnp.minimum(c_ref[...], acc[...]))
            elif combine == "max_old":
                new = jnp.maximum(old, jnp.maximum(c_ref[...], acc[...]))
            else:
                raise ValueError(combine)
            new = jnp.where(fixed_ref[...] != 0, x0_ref[...], new)

            # per-column delta in the engines' residual metric — the shared
            # definition, so in-kernel and host convergence always agree
            dblk = delta_cols(res_kind, new, old, xp=jnp,
                              keepdims=True).astype(dacc.dtype)
            if res_kind == "linf":
                dacc[...] = jnp.maximum(dacc[...], dblk)
            else:
                dacc[...] += dblk
            cnt[...] += 1.0
            changed = jnp.any(new != old)

            acc[...] = new.astype(acc.dtype)
            cp = pltpu.make_async_copy(acc, x_out.at[pl.ds(i * bs, bs)], sem_o)
            cp.start()
            cp.wait()

            # this block moved (bitwise): every dependent's cached "my inputs
            # held still" claim is void — re-mark them via the reverse CSR.
            # A diagonal tile re-marks i itself, which is exactly right: its
            # own state is one of its inputs then.
            @pl.when(changed)
            def _mark_dependents():
                def mk(t, _):
                    dirty_s[revrows_ref[t]] = 1
                    return 0

                jax.lax.fori_loop(revptr_ref[i], revptr_ref[i + 1], mk, 0)

        deltas_out[...] = dacc[...]
        active_out[...] = cnt[...]

        # sweep end: all columns at or below eps -> predicate the remaining
        # sweeps of this batch away (sticky; zeroed deltas keep it set)
        @pl.when(i == nb - 1)
        def _sweep_end():
            done_now = jnp.where(jnp.all(dacc[...] <= eps), 1, 0)
            done_s[0] = jnp.maximum(done_s[0], done_now.astype(done_s.dtype))

        # batch end: export the frontier so the next batch resumes it
        @pl.when(jnp.logical_and(s == sweeps - 1, i == nb - 1))
        def _export_frontier():
            def wr(j, _):
                dirty_out[j] = dirty_s[j]
                return 0

            jax.lax.fori_loop(0, nb, wr, 0)

    return kernel


def _check_pair(semiring: str, combine: str):
    # each pair needs its own accumulator identity and reduction; an unknown
    # pair would start from the wrong identity and silently compute garbage.
    # Mirror pack_algorithm's guard (kernels/ops.py) here so direct kernel
    # callers fail loudly too.
    if (semiring, combine) not in _SUPPORTED:
        raise NotImplementedError(
            f"gs_sweep: unsupported semiring/combine pair "
            f"({semiring!r}, {combine!r}); supported: {sorted(_SUPPORTED)}"
        )


@functools.partial(
    jax.jit,
    static_argnames=("semiring", "combine", "res_kind", "bs", "sweeps",
                     "eps", "interpret"),
)
def gs_multisweep_pallas(
    rowptr: jnp.ndarray,    # int32[nb + 1]      scalar-prefetched
    tilecols: jnp.ndarray,  # int32[nnz_blocks]  scalar-prefetched
    revptr: jnp.ndarray,    # int32[nb + 1]      reverse-dep CSR, prefetched
    revrows: jnp.ndarray,   # int32[nnz_blocks]  dependents of each src block
    dirty: jnp.ndarray,     # int32[nb]          frontier bitmap (1 = dirty)
    tiles: jnp.ndarray,     # f32[nnz_blocks, bs, bs]  ragged flat tiles
    c: jnp.ndarray,         # f32[nb*bs, d]   per-vertex const
    x0: jnp.ndarray,        # f32[nb*bs, d]
    fixed: jnp.ndarray,     # f32[nb*bs, d]   1.0 where pinned
    x: jnp.ndarray,         # f32[nb*bs, d]   state (aliased to output)
    *,
    semiring: str = "plus_times",
    combine: str = "replace",
    res_kind: str | None = None,
    bs: int,
    sweeps: int = 1,
    eps: float = -1.0,
    interpret: bool = True,
):
    """Run up to ``sweeps`` Gauss–Seidel sweeps in one persistent kernel.

    Returns ``(x, deltas, active, dirty_out)``:

    * ``x``        f32[n, d]  — state after the batch (input aliased)
    * ``deltas``   f32[sweeps, d] — per-sweep per-column convergence metric
      (``res_kind``; defaults to ``DELTA_METRIC[semiring]``). Early-outed
      sweeps report 0, so the host reconstructs exact per-column round
      counts from this trace.
    * ``active``   f32[sweeps, 1] — blocks actually updated per sweep (the
      ``active_block_fraction`` numerator; early-outed/skipped sweeps: 0)
    * ``dirty_out`` int32[nb] — the frontier after the batch; feed it back
      as ``dirty`` to resume, or all-ones to force a full sweep.

    ``deltas`` and ``active`` are also the megakernel's telemetry feed:
    the engine turns them (after its existing once-per-batch readout) into
    ``RunResult.convergence_trace`` — per-round residual and
    ``active_block_fraction`` in ``swept_block_cells`` units
    (`repro.obs.telemetry.trace_from_block_activity`) — so enabling
    observability never adds a device->host transfer.

    ``eps`` is the in-kernel early-out threshold (static): once a sweep's
    deltas are all <= eps, the batch's remaining sweeps are predicated
    no-ops. ``eps=-1.0`` disables the early-out (metrics are >= 0).
    """
    _check_pair(semiring, combine)
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    if res_kind is None:
        res_kind = DELTA_METRIC[semiring]
    nb = rowptr.shape[0] - 1
    n, d = x.shape
    assert n == nb * bs
    assert tiles.ndim == 3 and tiles.shape[1:] == (bs, bs)
    assert tilecols.shape[0] == tiles.shape[0]
    assert revptr.shape == rowptr.shape and dirty.shape == (nb,)
    # the batched engine (run_async_block(backend="pallas")) feeds real
    # multi-query columns here; all per-vertex operands must carry them
    assert c.shape == x0.shape == fixed.shape == (n, d), (
        c.shape, x0.shape, fixed.shape, (n, d)
    )
    kernel = _make_kernel(semiring, combine, res_kind, bs, nb, sweeps,
                          float(eps))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(sweeps, nb),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # ragged tiles, DMA'd manually
            pl.BlockSpec((bs, d), lambda s, i, *_: (i, 0)),
            pl.BlockSpec((bs, d), lambda s, i, *_: (i, 0)),
            pl.BlockSpec((bs, d), lambda s, i, *_: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pl.ANY),              # x (aliased)
            pl.BlockSpec((1, d), lambda s, i, *_: (s, 0)),  # deltas
            pl.BlockSpec((1, 1), lambda s, i, *_: (s, 0)),  # active counts
            pl.BlockSpec(memory_space=pltpu.VMEM),          # dirty_out
        ),
        scratch_shapes=[
            pltpu.VMEM((2, bs, d), x.dtype),   # xblk: double-buffered gathers
            pltpu.VMEM((2, bs, bs), x.dtype),  # tblk: double-buffered tiles
            pltpu.VMEM((bs, d), x.dtype),      # oldblk
            pltpu.VMEM((bs, d), x.dtype),      # acc
            pltpu.VMEM((1, d), jnp.float32),   # dacc: sweep delta per column
            pltpu.VMEM((1, 1), jnp.float32),   # cnt: active blocks this sweep
            pltpu.SMEM((nb,), jnp.int32),      # dirty flags (the frontier)
            pltpu.SMEM((1,), jnp.int32),       # done bit (early-out)
            pltpu.SemaphoreType.DMA((2,)),     # sem_x
            pltpu.SemaphoreType.DMA((2,)),     # sem_t
            pltpu.SemaphoreType.DMA,           # sem_o (old fetch + writeback)
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((sweeps, d), jnp.float32),
            jax.ShapeDtypeStruct((sweeps, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.int32),
        ),
        # x (after the 5 prefetch args) -> output 0
        input_output_aliases={9: 0},
        interpret=interpret,
    )(rowptr, tilecols, revptr, revrows, dirty, tiles, c, x0, fixed, x)


@functools.partial(
    jax.jit,
    static_argnames=("semiring", "combine", "bs", "interpret"),
)
def gs_sweep_pallas(
    rowptr: jnp.ndarray,    # int32[nb + 1]      scalar-prefetched
    tilecols: jnp.ndarray,  # int32[nnz_blocks]  scalar-prefetched
    tiles: jnp.ndarray,     # f32[nnz_blocks, bs, bs]  ragged flat tiles
    c: jnp.ndarray,         # f32[nb*bs, d]   per-vertex const
    x0: jnp.ndarray,        # f32[nb*bs, d]
    fixed: jnp.ndarray,     # f32[nb*bs, d]   1.0 where pinned
    x: jnp.ndarray,         # f32[nb*bs, d]   state (donated; aliased to output)
    *,
    semiring: str = "plus_times",
    combine: str = "replace",
    bs: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """One full sweep, state in / state out — the legacy per-sweep entry
    point, now the ``sweeps=1`` megakernel with an all-dirty frontier and the
    delta/frontier outputs discarded (an empty reverse-dep CSR makes the
    dirty bookkeeping a no-op). Bitwise-identical to the dedicated
    single-sweep kernel it replaces: every block updates, in the same order,
    with the same tile walk."""
    _check_pair(semiring, combine)
    nb = rowptr.shape[0] - 1
    x_new, _, _, _ = gs_multisweep_pallas(
        rowptr, tilecols,
        jnp.zeros((nb + 1,), jnp.int32), jnp.zeros((1,), jnp.int32),
        jnp.ones((nb,), jnp.int32),
        tiles, c, x0, fixed, x,
        semiring=semiring, combine=combine, bs=bs, sweeps=1, eps=-1.0,
        interpret=interpret,
    )
    return x_new
