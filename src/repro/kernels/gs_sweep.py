"""Fused block Gauss–Seidel sweep — the paper's async mode as ONE kernel.

TPU Pallas grids execute sequentially, which is exactly the ordering
guarantee the paper's Eq. 2 needs: grid step i updates destination block i and
*writes it back to the state buffer before step i+1 runs*. The state lives in
HBM (`pl.ANY`) and is aliased input->output, so column-block gathers issued by
later steps (explicit `make_async_copy` DMAs) observe every earlier block's
current-round value — positive edges (p(src) < p(dst)) deliver fresh state,
negative edges deliver last-round state, with zero host round-trips for the
whole sweep.

Data layout (ragged flat BSR, `graphs.blocked.FlatBSRMatrix`): destination
block i owns tiles ``rowptr[i]..rowptr[i+1]`` of ``tiles[nnz_blocks, bs, bs]``,
tile t reading source block ``tilecols[t]``. ``rowptr``/``tilecols`` are
scalar-prefetched so the kernel can compute DMA addresses before compute
starts. Per-sweep work is O(nnz_blocks) tiles — the hub row-blocks the
GoGraph HD phase concentrates (paper §IV-A) cost their own row only, instead
of inflating a global ``k_max`` every row pays for as the old dense-padded
layout did.

Double buffering: the adjacency tile *and* the gathered source block for tile
t+1 are DMA'd into the opposite scratch slot while tile t is being reduced,
so the semiring work hides the gather latency instead of serializing
``start(); wait()`` per tile. The destination block's previous-round value is
fetched once at step start and overlaps the whole reduction.

Update rule per destination block i (semiring & combine as in the engines):

    agg  = REDUCE_t  tiles[t] (x) x[tilecols[t]],  t in [rowptr[i], rowptr[i+1])
    newb = combine(c[i], agg, oldb);  newb = fixed ? x0 : newb
    x[i] <- newb

VMEM per step: 2 adjacency tiles (bs, bs) + 7 state blocks (bs, d) — the 2
double-buffered gathers, the old-block buffer, the accumulator, and the
const/x0/fixed input blocks. With bs = d = 128 that is 2*64 KiB tiles +
7*64 KiB state = 576 KiB, independent of k_max (the old layout streamed
k_max tiles per step, so the hub row set every step's footprint).

Supported (semiring, combine) pairs and their accumulator identities:

    plus_times / replace   acc 0     (PageRank family: c + sum w*x)
    min_plus   / min_old   acc +BIG  (SSSP/BFS/CC: min(old, c, min x+w))
    max_min    / max_old   acc -BIG  (SSWP: max(old, c, max min(x, w)))
    max_times  / max_old   acc -BIG  (reachability: max(old, c, max w*x);
                                      requires nonnegative states — absent
                                      in-tile edges contribute w=0 products)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.semirings import ACC_IDENTITY

# semiring/combine pairs the kernel body implements, with the accumulator
# identity (kernels.semirings.ACC_IDENTITY) each reduction starts from.
# Anything else must fail loudly — a wrong identity silently computes
# garbage shaped like an answer.
_SUPPORTED = {
    ("plus_times", "replace"),
    ("min_plus", "min_old"),
    ("max_min", "max_old"),
    ("max_times", "max_old"),
}


def _reduce_tile(semiring: str, acc_ref, tile, xs):
    """acc <- acc (reduce) tile (x) xs for one (bs, bs) tile and (bs, d)
    source block."""
    if semiring == "plus_times":
        acc_ref[...] += jnp.dot(tile, xs, preferred_element_type=acc_ref.dtype)
    elif semiring == "min_plus":
        part = jnp.min(tile[:, :, None] + xs[None, :, :], axis=1)
        acc_ref[...] = jnp.minimum(acc_ref[...], part)
    elif semiring == "max_min":
        part = jnp.max(jnp.minimum(tile[:, :, None], xs[None, :, :]), axis=1)
        acc_ref[...] = jnp.maximum(acc_ref[...], part)
    elif semiring == "max_times":
        part = jnp.max(tile[:, :, None] * xs[None, :, :], axis=1)
        acc_ref[...] = jnp.maximum(acc_ref[...], part)
    else:
        raise ValueError(semiring)


def _make_kernel(semiring: str, combine: str, bs: int):
    def kernel(rowptr_ref, tilecols_ref, tiles_hbm, c_ref, x0_ref, fixed_ref,
               x_hbm, x_out, xblk, tblk, oldblk, acc, sem_x, sem_t, sem_o):
        i = pl.program_id(0)
        lo = rowptr_ref[i]
        hi = rowptr_ref[i + 1]

        acc[...] = jnp.full_like(acc, ACC_IDENTITY[semiring])

        def gather(t, slot):
            # source block for tile t, read from the *aliased output* so
            # earlier grid steps' writes (this sweep) are visible
            c = tilecols_ref[t]
            return pltpu.make_async_copy(
                x_out.at[pl.ds(c * bs, bs)], xblk.at[slot], sem_x.at[slot]
            )

        def fetch_tile(t, slot):
            return pltpu.make_async_copy(
                tiles_hbm.at[t], tblk.at[slot], sem_t.at[slot]
            )

        # the destination block's previous-round value: fetched once, its DMA
        # overlaps the whole tile reduction below
        old_cp = pltpu.make_async_copy(
            x_out.at[pl.ds(i * bs, bs)], oldblk, sem_o
        )
        old_cp.start()

        # double-buffer warm-up: tile lo's DMAs go into slot 0
        @pl.when(lo < hi)
        def _warmup():
            gather(lo, 0).start()
            fetch_tile(lo, 0).start()

        def body(t, _):
            slot = jax.lax.rem(t - lo, 2)
            nxt = 1 - slot

            # start tile t+1's fetches before blocking on tile t's
            @pl.when(t + 1 < hi)
            def _prefetch():
                gather(t + 1, nxt).start()
                fetch_tile(t + 1, nxt).start()

            gather(t, slot).wait()
            fetch_tile(t, slot).wait()
            _reduce_tile(semiring, acc, tblk[slot], xblk[slot])
            return 0

        jax.lax.fori_loop(lo, hi, body, 0)

        old_cp.wait()
        old = oldblk[...]
        if combine == "replace":
            new = c_ref[...] + acc[...]
        elif combine == "min_old":
            new = jnp.minimum(old, jnp.minimum(c_ref[...], acc[...]))
        elif combine == "max_old":
            new = jnp.maximum(old, jnp.maximum(c_ref[...], acc[...]))
        else:
            raise ValueError(combine)
        new = jnp.where(fixed_ref[...] != 0, x0_ref[...], new)
        acc[...] = new.astype(acc.dtype)
        cp = pltpu.make_async_copy(acc, x_out.at[pl.ds(i * bs, bs)], sem_o)
        cp.start()
        cp.wait()

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("semiring", "combine", "bs", "interpret"),
)
def gs_sweep_pallas(
    rowptr: jnp.ndarray,    # int32[nb + 1]      scalar-prefetched
    tilecols: jnp.ndarray,  # int32[nnz_blocks]  scalar-prefetched
    tiles: jnp.ndarray,     # f32[nnz_blocks, bs, bs]  ragged flat tiles
    c: jnp.ndarray,         # f32[nb*bs, d]   per-vertex const
    x0: jnp.ndarray,        # f32[nb*bs, d]
    fixed: jnp.ndarray,     # f32[nb*bs, d]   1.0 where pinned
    x: jnp.ndarray,         # f32[nb*bs, d]   state (donated; aliased to output)
    *,
    semiring: str = "plus_times",
    combine: str = "replace",
    bs: int,
    interpret: bool = True,
) -> jnp.ndarray:
    # each pair needs its own accumulator identity and reduction; an unknown
    # pair would start from the wrong identity and silently compute garbage.
    # Mirror pack_algorithm's guard (kernels/ops.py) here so direct kernel
    # callers fail loudly too.
    if (semiring, combine) not in _SUPPORTED:
        raise NotImplementedError(
            f"gs_sweep_pallas: unsupported semiring/combine pair "
            f"({semiring!r}, {combine!r}); supported: {sorted(_SUPPORTED)}"
        )
    nb = rowptr.shape[0] - 1
    n, d = x.shape
    assert n == nb * bs
    assert tiles.ndim == 3 and tiles.shape[1:] == (bs, bs)
    assert tilecols.shape[0] == tiles.shape[0]
    # the batched engine (run_async_block(backend="pallas")) feeds real
    # multi-query columns here; all per-vertex operands must carry them
    assert c.shape == x0.shape == fixed.shape == (n, d), (
        c.shape, x0.shape, fixed.shape, (n, d)
    )
    kernel = _make_kernel(semiring, combine, bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # ragged tiles, DMA'd manually
            pl.BlockSpec((bs, d), lambda i, rowptr_ref, tilecols_ref: (i, 0)),
            pl.BlockSpec((bs, d), lambda i, rowptr_ref, tilecols_ref: (i, 0)),
            pl.BlockSpec((bs, d), lambda i, rowptr_ref, tilecols_ref: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((2, bs, d), x.dtype),   # xblk: double-buffered gathers
            pltpu.VMEM((2, bs, bs), x.dtype),  # tblk: double-buffered tiles
            pltpu.VMEM((bs, d), x.dtype),      # oldblk
            pltpu.VMEM((bs, d), x.dtype),      # acc
            pltpu.SemaphoreType.DMA((2,)),     # sem_x
            pltpu.SemaphoreType.DMA((2,)),     # sem_t
            pltpu.SemaphoreType.DMA,           # sem_o (old fetch + writeback)
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        input_output_aliases={6: 0},  # x (after the 2 prefetch args) -> output
        interpret=interpret,
    )(rowptr, tilecols, tiles, c, x0, fixed, x)
