"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp



def ref_bsr_spmm(
    cols: jnp.ndarray,   # int32[nb, k_max]
    tiles: jnp.ndarray,  # f32[nb, k_max, bs, bs]
    x: jnp.ndarray,      # f32[nb*bs, d]
    semiring: str = "plus_times",
) -> jnp.ndarray:
    nb, k_max, bs, _ = tiles.shape
    d = x.shape[1]
    xb = x.reshape(nb, bs, d)
    gathered = xb[cols]  # (nb, k_max, bs, d)
    if semiring == "plus_times":
        return jnp.einsum("nkrc,nkcd->nrd", tiles, gathered).reshape(nb * bs, d)
    if semiring == "min_plus":
        # min over k and over source columns of tile[r, c] + x[c, d]
        expanded = tiles[..., None] + gathered[:, :, None, :, :]  # (nb,k,bs_r,bs_c,d)
        return jnp.min(jnp.min(expanded, axis=3), axis=1).reshape(nb * bs, d)
    raise ValueError(semiring)


def _combine(kind: str, agg, c, old, fixed, x0):
    if kind == "replace":
        new = c + agg
    elif kind == "min_old":
        new = jnp.minimum(old, jnp.minimum(c, agg))
    elif kind == "max_old":
        new = jnp.maximum(old, jnp.maximum(c, agg))
    else:
        raise ValueError(kind)
    return jnp.where(fixed != 0, x0, new)


def ref_gs_sweep(
    cols: jnp.ndarray,
    tiles: jnp.ndarray,
    c: jnp.ndarray,
    x0: jnp.ndarray,
    fixed: jnp.ndarray,
    x: jnp.ndarray,
    semiring: str = "plus_times",
    combine: str = "replace",
) -> jnp.ndarray:
    """Sequential block sweep with an evolving state vector (pure jnp)."""
    nb, k_max, bs, _ = tiles.shape
    d = x.shape[1]

    def body(i, xcur):
        xb = xcur.reshape(nb, bs, d)
        gathered = xb[cols[i]]  # (k_max, bs, d)
        if semiring == "plus_times":
            agg = jnp.einsum("krc,kcd->rd", tiles[i], gathered)
        else:
            expanded = tiles[i][..., None] + gathered[:, None, :, :]
            agg = jnp.min(jnp.min(expanded, axis=2), axis=0)
        old = jax.lax.dynamic_slice(xcur, (i * bs, 0), (bs, d))
        cb = jax.lax.dynamic_slice(c, (i * bs, 0), (bs, d))
        x0b = jax.lax.dynamic_slice(x0, (i * bs, 0), (bs, d))
        fb = jax.lax.dynamic_slice(fixed, (i * bs, 0), (bs, d))
        new = _combine(combine, agg, cb, old, fb, x0b)
        return jax.lax.dynamic_update_slice(xcur, new.astype(xcur.dtype), (i * bs, 0))

    return jax.lax.fori_loop(0, nb, body, x)
