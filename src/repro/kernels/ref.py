"""Pure-numpy oracles for the Pallas kernels (the allclose ground truth).

The oracles walk the same ragged flat-BSR layout as the kernels
(`graphs.blocked.FlatBSRMatrix`) with plain Python loops over row-blocks —
deliberately the dumbest possible implementation, so tests compare the
kernels against code whose correctness is visible at a glance. Reductions
run in the kernels' tile order, which makes min/max semirings bitwise
comparable (order-free reductions) and plus_times comparable to float
accumulation-order noise.

`ref_gs_multisweep` models the megakernel's sweep-batched frontier
semantics exactly: per-sweep delta accumulation in the engines' residual
metric, the all-columns-below-eps early-out, the dirty bitmap gating each
block update, and reverse-dependency re-marking *during* the sweep (a block
changed by an earlier block this sweep is visible to later blocks
immediately, next sweep otherwise).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.semirings import ACC_IDENTITY, DELTA_METRIC, delta_cols


def ref_push_round(
    order, indptr, nbrs, ew, p, r, semiring: str = "plus_times"
):
    """Sequential residual push over ``order`` — the `push_scatter_pallas`
    oracle. Each vertex u folds its pending residual into its settled state,
    empties the residual row, then scatters one semiring message per
    out-edge onto its neighbors' residual rows; vertex u+1 sees every
    scatter of vertices <= u (the kernel's Gauss–Seidel freshness).

    Returns ``(p, r, pushed, edges)`` with fresh arrays (inputs untouched).
    All arithmetic stays f32, in the kernel's exact order, so lattice
    semirings compare bitwise and plus_times to accumulation-order noise.
    """
    indptr = np.asarray(indptr)
    nbrs = np.asarray(nbrs)
    ew = np.asarray(ew, np.float32)
    p = np.array(p, np.float32, copy=True)
    r = np.array(r, np.float32, copy=True)
    ident = np.float32(ACC_IDENTITY[semiring])
    pushed = 0
    edges = 0
    for u in np.asarray(order):
        if u < 0:
            continue
        if semiring == "plus_times":
            push = r[u].copy()
            p[u] = p[u] + push
        elif semiring == "min_plus":
            push = np.minimum(p[u], r[u])
            p[u] = push
        elif semiring in ("max_min", "max_times"):
            push = np.maximum(p[u], r[u])
            p[u] = push
        else:
            raise ValueError(semiring)
        r[u] = ident  # before the scatter: self-loops land on the empty row
        for t in range(int(indptr[u]), int(indptr[u + 1])):
            v = nbrs[t]
            w = ew[t]
            if semiring == "plus_times":
                r[v] = r[v] + w * push
            elif semiring == "min_plus":
                with np.errstate(over="ignore"):
                    r[v] = np.minimum(r[v], push + w)
            elif semiring == "max_min":
                r[v] = np.maximum(r[v], np.minimum(push, w))
            else:
                r[v] = np.maximum(r[v], push * w)
        pushed += 1
        edges += int(indptr[u + 1] - indptr[u])
    return p, r, pushed, edges


def _tile_op(semiring: str, tile: np.ndarray, xs: np.ndarray) -> np.ndarray:
    """One tile's contribution: (bs, bs) tile (x) (bs, d) source block."""
    if semiring == "plus_times":
        return tile @ xs
    if semiring == "min_plus":
        # BIG + BIG overflows f32 to +inf — exactly what the kernel computes,
        # and still the min identity, so the overflow is the correct answer
        with np.errstate(over="ignore"):
            return np.min(tile[:, :, None] + xs[None, :, :], axis=1)
    if semiring == "max_min":
        return np.max(np.minimum(tile[:, :, None], xs[None, :, :]), axis=1)
    if semiring == "max_times":
        return np.max(tile[:, :, None] * xs[None, :, :], axis=1)
    raise ValueError(semiring)


def _reduce(semiring: str, acc: np.ndarray, part: np.ndarray) -> np.ndarray:
    if semiring == "plus_times":
        return acc + part
    if semiring == "min_plus":
        return np.minimum(acc, part)
    return np.maximum(acc, part)


def _combine(kind: str, agg, c, old, fixed, x0):
    if kind == "replace":
        new = c + agg
    elif kind == "min_old":
        new = np.minimum(old, np.minimum(c, agg))
    elif kind == "max_old":
        new = np.maximum(old, np.maximum(c, agg))
    else:
        raise ValueError(kind)
    return np.where(np.asarray(fixed) != 0, x0, new)


def ref_bsr_spmm(
    rowptr, tilecols, tiles, x, semiring: str = "plus_times"
) -> np.ndarray:
    """y_blk[i] = REDUCE_{t in [rowptr[i], rowptr[i+1])} tiles[t] (x)
    x_blk[tilecols[t]]; empty row-blocks yield the reduce identity."""
    rowptr = np.asarray(rowptr)
    tilecols = np.asarray(tilecols)
    tiles = np.asarray(tiles, np.float32)
    x = np.asarray(x, np.float32)
    nb = len(rowptr) - 1
    bs = tiles.shape[-1]
    d = x.shape[1]
    y = np.full((nb * bs, d), ACC_IDENTITY[semiring], np.float32)
    for i in range(nb):
        acc = np.full((bs, d), ACC_IDENTITY[semiring], np.float32)
        for t in range(rowptr[i], rowptr[i + 1]):
            cblk = tilecols[t]
            xs = x[cblk * bs:(cblk + 1) * bs]
            acc = _reduce(semiring, acc, _tile_op(semiring, tiles[t], xs))
        y[i * bs:(i + 1) * bs] = acc
    return y


def ref_gs_sweep(
    rowptr, tilecols, tiles, c, x0, fixed, x,
    semiring: str = "plus_times", combine: str = "replace",
) -> np.ndarray:
    """Sequential block sweep with an evolving state vector: block i's gathers
    see blocks < i at their THIS-sweep values (Eq. 2 at tile granularity)."""
    rowptr = np.asarray(rowptr)
    tilecols = np.asarray(tilecols)
    tiles = np.asarray(tiles, np.float32)
    c = np.asarray(c, np.float32)
    x0 = np.asarray(x0, np.float32)
    fixed = np.asarray(fixed)
    xcur = np.array(x, np.float32, copy=True)
    nb = len(rowptr) - 1
    bs = tiles.shape[-1]
    d = xcur.shape[1]
    for i in range(nb):
        acc = np.full((bs, d), ACC_IDENTITY[semiring], np.float32)
        for t in range(rowptr[i], rowptr[i + 1]):
            cblk = tilecols[t]
            xs = xcur[cblk * bs:(cblk + 1) * bs]
            acc = _reduce(semiring, acc, _tile_op(semiring, tiles[t], xs))
        sl = slice(i * bs, (i + 1) * bs)
        xcur[sl] = _combine(combine, acc, c[sl], xcur[sl], fixed[sl], x0[sl])
    return xcur


def ref_gs_multisweep(
    rowptr, tilecols, revptr, revrows, dirty, tiles, c, x0, fixed, x,
    semiring: str = "plus_times", combine: str = "replace",
    res_kind: str | None = None, eps: float = -1.0, sweeps: int = 1,
):
    """Numpy mirror of `gs_sweep.gs_multisweep_pallas`: up to ``sweeps``
    frontier-gated Gauss–Seidel sweeps with in-oracle convergence.

    Returns ``(x, deltas[sweeps, d], active[sweeps], dirty_out[nb])`` with
    the megakernel's exact semantics: a clean block is skipped (its state
    untouched), a changed block re-marks its reverse-dependency rows
    mid-sweep, a sweep whose deltas all drop to ``eps`` early-outs the rest
    of the batch (their delta/active rows report 0)."""
    if res_kind is None:
        res_kind = DELTA_METRIC[semiring]
    rowptr = np.asarray(rowptr)
    tilecols = np.asarray(tilecols)
    revptr = np.asarray(revptr)
    revrows = np.asarray(revrows)
    tiles = np.asarray(tiles, np.float32)
    c = np.asarray(c, np.float32)
    x0 = np.asarray(x0, np.float32)
    fixed = np.asarray(fixed)
    xcur = np.array(x, np.float32, copy=True)
    nb = len(rowptr) - 1
    bs = tiles.shape[-1]
    d = xcur.shape[1]
    dirty_s = np.asarray(dirty, np.int32).copy()
    deltas = np.zeros((sweeps, d), np.float32)
    active = np.zeros((sweeps,), np.float32)
    done = False
    for s in range(sweeps):
        if done:
            continue
        dacc = np.zeros((d,), np.float32)
        for i in range(nb):
            if not dirty_s[i]:
                continue
            dirty_s[i] = 0
            acc = np.full((bs, d), ACC_IDENTITY[semiring], np.float32)
            for t in range(rowptr[i], rowptr[i + 1]):
                cblk = tilecols[t]
                xs = xcur[cblk * bs:(cblk + 1) * bs]
                acc = _reduce(semiring, acc, _tile_op(semiring, tiles[t], xs))
            sl = slice(i * bs, (i + 1) * bs)
            old = xcur[sl].copy()
            new = _combine(combine, acc, c[sl], old, fixed[sl], x0[sl])
            dblk = delta_cols(res_kind, new, old, xp=np)
            if res_kind == "linf":
                dacc = np.maximum(dacc, dblk)
            else:
                dacc = dacc + dblk
            active[s] += 1.0
            xcur[sl] = new
            if np.any(new != old):
                for t in range(revptr[i], revptr[i + 1]):
                    dirty_s[revrows[t]] = 1
        deltas[s] = dacc
        if np.all(dacc <= eps):
            done = True
    return xcur, deltas, active, dirty_s
