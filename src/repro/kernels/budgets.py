"""Declared per-kernel VMEM/SMEM budgets — the contract repro-lint enforces.

Every ``pl.pallas_call`` in `repro.kernels` must have an entry here; the
static checker (`tools.check.pallas_resources`) re-derives each kernel's
VMEM/SMEM footprint from its BlockSpecs, scratch_shapes, and grid at the
representative points below and fails the build when a footprint crosses its
declared budget. The budgets are deliberately far below the ~16 MiB/core
TPU VMEM: the pipeline double-buffers every windowed operand on top of what
we count, and headroom is what lets a future PR widen ``d`` or ``bs``
without renegotiating the kernel's memory story.

Footprint model (all operands are 4-byte f32/int32):

* scratch ``pltpu.VMEM`` / ``pltpu.SMEM`` shapes count at face value;
* windowed BlockSpecs (shape + index map) count twice — Pallas
  double-buffers pipelined windows;
* ``memory_space=ANY`` operands live in HBM and count zero (their VMEM cost
  is whatever scratch the kernel DMAs them into, already counted);
* broadcast temporaries the kernel body materializes (the min/max
  semirings' ``(bs, bs, dj)`` intermediate in `bsr_spmm`) are declared per
  point as ``temp_bytes`` — the checker cannot see inside the traced body.

Points carry every dimension name the kernel's shape expressions use
(``bs``/``d``/``nb``/``sweeps``/``nnz``/``dj``; ``n`` derives as
``nb * bs``). They are chosen to bracket real usage: the serving default
(bs=64..256, d=8..64 slots), the kernel-bench sweep, and the SMEM-heavy
many-blocks regime (the dirty bitmap scales with ``nb``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KernelBudget:
    """Declared resource ceiling for one pallas_call wrapper."""

    vmem_limit_bytes: int
    smem_limit_bytes: int
    points: tuple[dict, ...]    # representative dims (+ optional temp_bytes)
    notes: str = ""


KiB = 1024
MiB = 1024 * 1024

KERNEL_BUDGETS: dict[str, KernelBudget] = {
    "gs_multisweep_pallas": KernelBudget(
        # measured at the widest point below: ~1.13 MiB VMEM, ~16 KiB SMEM
        vmem_limit_bytes=2 * MiB,
        smem_limit_bytes=32 * KiB,
        points=(
            # serving default: bs=256 blocks, 64 query columns
            {"bs": 256, "d": 64, "nb": 32, "sweeps": 16, "nnz": 256},
            # kernel-bench sweep shape
            {"bs": 128, "d": 128, "nb": 64, "sweeps": 16, "nnz": 1024},
            # many-blocks regime: the SMEM dirty bitmap scales with nb
            {"bs": 16, "d": 8, "nb": 4096, "sweeps": 8, "nnz": 16384},
        ),
        notes="scratch holds 2 gather + 2 tile buffers (double-buffered "
              "DMA), old/acc blocks, the (1, d) delta row; SMEM holds the "
              "nb dirty flags + done bit",
    ),
    "push_scatter_pallas": KernelBudget(
        # measured at the widest point below: ~1.1 KiB VMEM, 4 KiB SMEM —
        # the push kernel streams (1, d) rows, so VMEM is independent of n,
        # m, buckets, and cap
        vmem_limit_bytes=64 * KiB,
        smem_limit_bytes=8 * KiB,
        points=(
            # serving default: 64 query columns, hub-chunking at ecap=256
            {"ecap": 256, "d": 64, "buckets": 8, "cap": 512, "n": 4096},
            # delta absorption: few columns, small rounds
            {"ecap": 128, "d": 8, "buckets": 4, "cap": 64, "n": 1024},
            # scalar delta-stepping SSSP on a big graph, wide edge chunks
            {"ecap": 512, "d": 1, "buckets": 16, "cap": 1024, "n": 65536},
        ),
        notes="scratch holds four (1, d) residual/state rows + two (1, 1) "
              "work counters; SMEM holds the two (ecap,) edge-chunk "
              "buffers (neighbor ids + weights)",
    ),
    "bsr_spmm_pallas": KernelBudget(
        # measured: ~0.38 MiB (plus_times), ~0.64 MiB (min family w/ temp)
        vmem_limit_bytes=2 * MiB,
        smem_limit_bytes=4 * KiB,
        points=(
            # plus_times runs full-width dj = d on the MXU (no broadcast temp)
            {"bs": 128, "d": 128, "dj": 128, "nb": 64, "nnz": 512,
             "temp_bytes": 0},
            # broadcast semirings: ops.bsr_spmm narrows dj so the
            # (bs, bs, dj) intermediate stays <= 512 KiB — declare it
            {"bs": 128, "d": 64, "dj": 8, "nb": 64, "nnz": 512,
             "temp_bytes": 128 * 128 * 8 * 4},
            {"bs": 16, "d": 64, "dj": 64, "nb": 256, "nnz": 4096,
             "temp_bytes": 16 * 16 * 64 * 4},
        ),
        notes="per step: one (1, bs, bs) tile window + (bs, dj) x/out "
              "windows; min/max semirings add the declared (bs, bs, dj) "
              "broadcast temporary (see ops.bsr_spmm's dj narrowing)",
    ),
}
