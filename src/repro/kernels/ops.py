"""jit'd public wrappers for the Pallas kernels.

`interpret=None` auto-selects: real kernel lowering on TPU, interpret mode on
CPU (this container), so the same call sites work in both worlds. The
wrappers also provide `pack_algorithm`, which turns an `AlgoInstance` (with
its transformed edge weights) into kernel-ready **ragged flat BSR** operands
(`graphs.blocked.FlatBSRMatrix`: tiles[nnz_blocks, bs, bs] + rowptr +
tilecols), and `run_async_block_pallas`, a full async engine whose per-sweep
work is the fused gs_sweep kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance
from repro.engine.convergence import RunResult
from repro.graphs.blocked import pack_bsr_flat, pad_state, padded_n
from repro.graphs.graph import Graph
from repro.kernels.bsr_spmm import bsr_spmm_pallas
from repro.kernels.gs_sweep import gs_sweep_pallas
from repro.kernels.semirings import TILE_FILL


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def bsr_spmm(rowptr, tilerows, tilecols, tiles, x, *, semiring="plus_times",
             dj=None, interpret=None):
    bs = tiles.shape[-1]
    d = x.shape[1]
    if dj is None:
        # the broadcast semirings materialize (bs, bs, dj); keep within ~2 MiB
        dj = d if semiring == "plus_times" else max(
            1, min(d, (512 * 1024) // (bs * bs * 4))
        )
        while d % dj:
            dj -= 1
    return bsr_spmm_pallas(
        rowptr, tilerows, tilecols, tiles, x, semiring=semiring, bs=bs, dj=dj,
        interpret=_auto_interpret(interpret),
    )


def gs_sweep(rowptr, tilecols, tiles, c, x0, fixed, x, *,
             semiring="plus_times", combine="replace", interpret=None):
    bs = tiles.shape[-1]
    return gs_sweep_pallas(
        rowptr, tilecols, tiles, c, x0, fixed, x, semiring=semiring,
        combine=combine, bs=bs, interpret=_auto_interpret(interpret),
    )


# ---------------------------------------------------------------------------
# AlgoInstance -> kernel operands
# ---------------------------------------------------------------------------

# (reduce, edge_op) -> kernel semiring; the in-tile fill for absent edges is
# the shared kernels.semirings.TILE_FILL table (max_times relies on states
# being nonnegative: a 0-weight product is then never above a real max_old
# combine's old/c floor).
_KERNEL_SEMIRING = {
    ("sum", "mul"): "plus_times",
    ("min", "add"): "min_plus",
    ("max", "min"): "max_min",
    ("max", "mul"): "max_times",
}


def pack_algorithm(algo: AlgoInstance, bs: int, d: int | None = None) -> dict:
    """Pack an algorithm's graph + vectors into flat-BSR kernel operands.

    The state is (n_padded, d). ``d`` defaults to the algorithm's own batch
    width ``algo.d`` (batched constructors carry real per-column vectors); a
    larger ``d`` broadcasts a scalar (``algo.d == 1``) instance across the
    batch — the kernel-bench path for filling TPU lanes with copies.
    """
    key = (algo.semiring.reduce, algo.semiring.edge_op)
    if key not in _KERNEL_SEMIRING:
        raise NotImplementedError(
            f"no kernel semiring for reduce={key[0]!r} edge_op={key[1]!r}; "
            f"supported: {sorted(_KERNEL_SEMIRING)}"
        )
    semiring = _KERNEL_SEMIRING[key]
    g = Graph(algo.n, algo.src, algo.dst, algo.w)
    bsr = pack_bsr_flat(g, bs, fill=TILE_FILL[semiring])
    npad = padded_n(algo.n, bs)
    d = algo.d if d is None else d
    if d != algo.d and algo.d != 1:
        raise ValueError(f"cannot broadcast a d={algo.d} instance to d={d}")

    # same padding primitive + fill rules as engine.harness.pack
    def padm(a, fillv):
        out = pad_state(np.asarray(a, np.float32), bs, fill=fillv)
        if d != algo.d:
            out = np.repeat(out, d, axis=1)
        return out

    ident = algo.semiring.identity
    x0pad = padm(algo.x0, ident)
    revptr, revrows = bsr.reverse_deps()
    return {
        "rowptr": jnp.asarray(bsr.rowptr),
        "tilecols": jnp.asarray(bsr.tilecols),
        "tilerows": jnp.asarray(bsr.tilerows),
        "revptr": jnp.asarray(revptr),
        "revrows": jnp.asarray(revrows),
        "tiles": jnp.asarray(bsr.tiles),
        "c": jnp.asarray(padm(algo.c, algo.c_pad_fill)),
        "x0": jnp.asarray(x0pad),
        "x0_host": x0pad,  # host copy kept so warm-starts never read back x0
        "fixed": jnp.asarray(padm(algo.fixed, 1.0)),  # pads pinned
        "x": jnp.asarray(x0pad.copy()),
        "semiring": semiring,
        "combine": algo.combine,
        "bsr_stats": bsr.stats(),
        "npad": npad,
    }


def run_async_block_pallas(
    algo: AlgoInstance, bs: int = 128, max_iters: int = 500, interpret=None,
    x_init: np.ndarray | None = None, sweeps_per_call: int = 1,
    frontier: np.ndarray | None = None,
) -> RunResult:
    """Async engine with the fused gs_sweep kernel doing each sweep.

    Back-compat shim: the convergence loop now lives in the engine layer —
    this is ``run_async_block(algo, backend="pallas")`` with an explicit
    interpret override. ``sweeps_per_call > 1`` batches that many sweeps
    into one persistent megakernel launch (in-kernel convergence +
    active-frontier block skipping); ``frontier`` optionally seeds the dirty
    bitmap from a vertex-level bool[n] mask (see `engine.async_block`).
    """
    from repro.engine.async_block import _run_async_block_pallas

    return _run_async_block_pallas(
        algo, bs, max_iters, 1, x_init, interpret=interpret,
        sweeps_per_call=sweeps_per_call, frontier=frontier,
    )
