"""jit'd public wrappers for the Pallas kernels.

`interpret=None` auto-selects: real kernel lowering on TPU, interpret mode on
CPU (this container), so the same call sites work in both worlds. The
wrappers also provide `pack_algorithm`, which turns an `AlgoInstance` (with
its transformed edge weights) into kernel-ready BSR operands, and
`run_async_block_pallas`, a full async engine whose per-sweep work is the
fused gs_sweep kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.algorithms import AlgoInstance, BIG
from repro.engine.convergence import RunResult
from repro.graphs.blocked import pack_bsr, padded_n
from repro.graphs.graph import Graph
from repro.kernels.bsr_spmm import bsr_spmm_pallas
from repro.kernels.gs_sweep import gs_sweep_pallas


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def bsr_spmm(cols, tiles, x, *, semiring="plus_times", dj=None, interpret=None):
    bs = tiles.shape[-1]
    d = x.shape[1]
    if dj is None:
        # min_plus materializes (bs, bs, dj); keep it within ~2 MiB fp32
        dj = d if semiring == "plus_times" else max(1, min(d, (512 * 1024) // (bs * bs * 4)))
        while d % dj:
            dj -= 1
    return bsr_spmm_pallas(
        cols, tiles, x, semiring=semiring, bs=bs, dj=dj,
        interpret=_auto_interpret(interpret),
    )


def gs_sweep(cols, tiles, c, x0, fixed, x, *, semiring="plus_times",
             combine="replace", interpret=None):
    bs = tiles.shape[-1]
    return gs_sweep_pallas(
        cols, tiles, c, x0, fixed, x, semiring=semiring, combine=combine,
        bs=bs, interpret=_auto_interpret(interpret),
    )


# ---------------------------------------------------------------------------
# AlgoInstance -> kernel operands
# ---------------------------------------------------------------------------

def pack_algorithm(algo: AlgoInstance, bs: int, d: int = 1) -> dict:
    """Pack an algorithm's graph + vectors into BSR kernel operands.

    The state is (n_padded, d); scalar algorithms use d=1 (interpret mode) —
    on a real TPU you'd batch d>=128 sources per sweep to fill the lanes.
    """
    semiring = "plus_times" if algo.semiring.reduce == "sum" else "min_plus"
    if algo.semiring.reduce == "max":
        raise NotImplementedError("max-semirings: negate and use min_plus")
    fill = 0.0 if semiring == "plus_times" else float(BIG)
    g = Graph(algo.n, algo.src, algo.dst, algo.w)
    bsr = pack_bsr(g, bs, fill=fill)
    npad = padded_n(algo.n, bs)

    def padv(a, fillv):
        out = np.full((npad,), fillv, dtype=np.float32)
        out[: algo.n] = a
        return np.repeat(out[:, None], d, axis=1)

    fixed = np.zeros(npad, np.float32)
    fixed[: algo.n] = algo.fixed.astype(np.float32)
    fixed[algo.n:] = 1.0  # pads pinned
    x0pad = padv(algo.x0, algo.semiring.identity)
    return {
        "cols": jnp.asarray(bsr.cols),
        "tiles": jnp.asarray(bsr.tiles),
        "c": jnp.asarray(padv(algo.c, 0.0)),
        "x0": jnp.asarray(x0pad),
        "fixed": jnp.asarray(np.repeat(fixed[:, None], d, axis=1)),
        "x": jnp.asarray(x0pad.copy()),
        "semiring": semiring,
        "combine": algo.combine,
        "bsr_stats": bsr.stats(),
        "npad": npad,
    }


def run_async_block_pallas(
    algo: AlgoInstance, bs: int = 128, max_iters: int = 500, interpret=None,
    x_init: np.ndarray | None = None,
) -> RunResult:
    """Async engine with the fused gs_sweep kernel doing each sweep.

    The convergence loop stays at the JAX level (python loop; each sweep is
    one device call) — interpret mode is slow, so benchmarks use modest
    sizes; on TPU each sweep is a single kernel launch.
    """
    ops = pack_algorithm(algo, bs)
    x = ops["x"]
    if x_init is not None:
        x = x.at[: algo.n, 0].set(jnp.asarray(x_init))
    residuals, sums = [], []
    k = 0
    converged = False
    for k in range(1, max_iters + 1):
        x_new = gs_sweep(
            ops["cols"], ops["tiles"], ops["c"], ops["x0"], ops["fixed"], x,
            semiring=ops["semiring"], combine=ops["combine"], interpret=interpret,
        )
        xo = np.asarray(x_new)[: algo.n, 0]
        xprev = np.asarray(x)[: algo.n, 0]
        if algo.residual == "changed":
            res = float(np.sum(xo != xprev))
        elif algo.residual == "l1":
            res = float(np.sum(np.abs(xo - xprev)))
        else:
            res = float(np.max(np.abs(xo - xprev)))
        residuals.append(res)
        sums.append(float(np.sum(xo[np.abs(xo) < 1e30])))
        x = x_new
        if res <= algo.eps:
            converged = True
            break
    return RunResult(
        x=np.asarray(x)[: algo.n, 0],
        rounds=k,
        converged=converged,
        residuals=np.asarray(residuals),
        state_sums=np.asarray(sums),
    )
