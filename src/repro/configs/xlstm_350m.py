"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0: projections live inside the xLSTM blocks (mLSTM up-projects 2x,
sLSTM 4/3x). Linear recurrence -> long_500k eligible. No attention -> no KV
cache; decode carries (C, n, m) / (h, c, n, m) states."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        vocab=50304, d_model=1024, n_layers=24, n_heads=4, n_kv=4,
        d_ff=0, head_dim=256,
        pattern=("mlstm", "slstm"), norm_kind="rms",
        rnn_chunk=256,
        subquadratic=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-reduced",
        vocab=512, d_model=64, n_layers=4, n_heads=4, n_kv=4,
        d_ff=0, head_dim=16,
        pattern=("mlstm", "slstm"), norm_kind="rms",
        rnn_chunk=8, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=2, zero1=True)
