"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
— 5:1 local:global sliding-window attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].

kv=4 does not divide the 16-way model axis, so KV projections replicate and
decode uses the sequence-sharded split-KV path (decode_seq_shard)."""
from repro.models.model import ModelConfig

PATTERN = ("local+mlp",) * 5 + ("attn+mlp",)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        vocab=262144, d_model=2560, n_layers=34, n_heads=8, n_kv=4,
        d_ff=10240, head_dim=256,
        pattern=PATTERN, mlp_kind="geglu", norm_kind="rms",
        window=1024, rope_theta=1_000_000.0,
        subquadratic=True,        # 5:1 local:global -> long_500k eligible
        decode_seq_shard=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-reduced",
        vocab=512, d_model=64, n_layers=7, n_heads=4, n_kv=2,
        d_ff=128, head_dim=16,
        pattern=PATTERN, mlp_kind="geglu", norm_kind="rms",
        window=8, kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=4, zero1=True)
