"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + (Llama-3-70B-style) LM backbone
[arXiv:2404.16821; unverified].

The vision frontend (InternViT-6B) is a STUB per the assignment:
input_specs() supplies 256 precomputed patch embeddings per example that are
prepended to the token embeddings. kv=8 < 16-way model axis -> KV replicated;
decode uses the sequence-sharded split-KV path."""
from repro.models.model import ModelConfig

PREFIX_LEN = 256  # vision patch tokens per image


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        vocab=128256, d_model=8192, n_layers=80, n_heads=64, n_kv=8,
        d_ff=28672, head_dim=128,
        pattern=("attn+mlp",), mlp_kind="swiglu", norm_kind="rms",
        prefix_len=PREFIX_LEN,
        decode_seq_shard=True,
        rope_theta=500_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-reduced",
        vocab=512, d_model=64, n_layers=3, n_heads=8, n_kv=2,
        d_ff=224, head_dim=8,
        pattern=("attn+mlp",), mlp_kind="swiglu", norm_kind="rms",
        prefix_len=4, kv_chunk=32, remat="none", dtype="float32",
    )


# 76B on 16 GB v5e chips: shard optimizer state and the f32 grad accumulator
# over DP, and keep per-microbatch activations to one sequence per device.
TRAIN_OVERRIDES = dict(microbatches=16, zero1=True, zero2_grads=True)
