"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

Experts are padded 60 -> 64 for clean 16-way expert parallelism (pad experts
receive -inf router logits; gate renormalizes over real experts)."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        vocab=151936, d_model=2048, n_layers=24, n_heads=16, n_kv=16,
        d_ff=1408, head_dim=128,
        pattern=("attn+moe",), mlp_kind="swiglu", norm_kind="rms",
        moe_experts=60, moe_top_k=4, moe_d_expert=1408, moe_shared=4,
        moe_pad_to=64,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-reduced",
        vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv=4,
        d_ff=48, head_dim=16,
        pattern=("attn+moe",), mlp_kind="swiglu", norm_kind="rms",
        moe_experts=6, moe_top_k=4, moe_d_expert=48, moe_shared=2,
        moe_pad_to=8, kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=4, zero1=True)
