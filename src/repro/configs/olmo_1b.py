"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304
— non-parametric LN [arXiv:2402.00838; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        vocab=50304, d_model=2048, n_layers=16, n_heads=16, n_kv=16,
        d_ff=8192, head_dim=128,
        pattern=("attn+mlp",), mlp_kind="swiglu", norm_kind="nonparam",
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-reduced",
        vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv=4,
        d_ff=256, head_dim=16,
        pattern=("attn+mlp",), mlp_kind="swiglu", norm_kind="nonparam",
        kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=2, zero1=True)
