"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

kv=8 does not divide the 16-way model axis -> KV replicated, decode via the
sequence-sharded split-KV path."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        vocab=49155, d_model=1024, n_layers=24, n_heads=16, n_kv=8,
        d_ff=512, head_dim=64,
        pattern=("attn+moe",), mlp_kind="swiglu", norm_kind="rms",
        moe_experts=32, moe_top_k=8, moe_d_expert=512, moe_shared=0,
        decode_seq_shard=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-reduced",
        vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv=2,
        d_ff=32, head_dim=16,
        pattern=("attn+moe",), mlp_kind="swiglu", norm_kind="rms",
        moe_experts=8, moe_top_k=4, moe_d_expert=32, moe_shared=0,
        kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=4, zero1=True)
