"""Architecture registry: one module per assigned arch (+ the paper's own
graph workload). Each module exposes

    config()   -> ModelConfig   (exact published dims)
    reduced()  -> ModelConfig   (same family, tiny dims — CPU smoke tests)

`get_config(name)` / `get_reduced(name)` / `ALL_ARCHS` are the front door.
"""
from __future__ import annotations

import importlib

ALL_ARCHS = [
    "olmo-1b",
    "deepseek-7b",
    "gemma3-4b",
    "gemma-7b",
    "qwen2-moe-a2.7b",
    "granite-moe-1b-a400m",
    "internvl2-76b",
    "xlstm-350m",
    "whisper-tiny",
    "recurrentgemma-2b",
]

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_")
            for name in ALL_ARCHS}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_ARCHS}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str):
    return _mod(name).config()


def get_reduced(name: str):
    return _mod(name).reduced()


def get_train_overrides(name: str) -> dict:
    """Per-arch TrainConfig field overrides (microbatching / ZeRO tiers).

    Big models need the production memory tricks to fit a 16 GB v5e:
    ZeRO-1 optimizer-state sharding, ZeRO-2 gradient-accumulator sharding,
    and enough microbatches that saved activations stay bounded.
    """
    mod = _mod(name)
    return getattr(mod, "TRAIN_OVERRIDES", {})
