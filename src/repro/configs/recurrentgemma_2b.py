"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1 attention per 2 recurrent blocks
[arXiv:2402.19427; hf].

Pattern (rglru, rglru, local-attn) x 8 + 2 remainder rglru = 26 layers.
RG-LRU recurrence + 2048-window local attention -> long_500k eligible.
MQA kv=1 -> KV replicated; decode via split-KV over the window cache."""
from repro.models.model import ModelConfig

PATTERN = ("rglru+mlp", "rglru+mlp", "local+mlp")


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        vocab=256000, d_model=2560, n_layers=26, n_heads=10, n_kv=1,
        d_ff=7680, head_dim=256,
        pattern=PATTERN, mlp_kind="geglu", norm_kind="rms",
        window=2048,
        subquadratic=True,
        decode_seq_shard=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-reduced",
        vocab=512, d_model=64, n_layers=8, n_heads=4, n_kv=1,
        d_ff=128, head_dim=16,
        pattern=PATTERN, mlp_kind="geglu", norm_kind="rms",
        window=8, kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=2, zero1=True)
