"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400 — llama-arch [arXiv:2401.02954; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        vocab=102400, d_model=4096, n_layers=30, n_heads=32, n_kv=32,
        d_ff=11008, head_dim=128,
        pattern=("attn+mlp",), mlp_kind="swiglu", norm_kind="rms",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-reduced",
        vocab=512, d_model=64, n_layers=3, n_heads=4, n_kv=4,
        d_ff=172, head_dim=16,
        pattern=("attn+mlp",), mlp_kind="swiglu", norm_kind="rms",
        kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=4, zero1=True, zero2_grads=True)


# decode_32k @ batch 128 with MHA (kv=32) KV caches is capacity-bound:
# int8 KV quantization halves cache bytes (see ModelConfig.kv_cache_dtype)
