"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865 — enc-dec, conv frontend (STUB) [arXiv:2212.04356; unverified].

The log-mel + strided-conv frontend is a stub: input_specs() supplies
precomputed frame embeddings (B, T, 384). Full attention -> long_500k
skipped. kv=6 does not divide 16 -> KV replicated; decode via split-KV."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        vocab=51865, d_model=384, n_layers=8, n_heads=6, n_kv=6,
        d_ff=1536, head_dim=64,
        arch_type="encdec", enc_layers=4, dec_layers=4,
        mlp_kind="gelu", norm_kind="layernorm",
        decode_seq_shard=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-reduced",
        vocab=512, d_model=64, n_layers=4, n_heads=4, n_kv=4,
        d_ff=128, head_dim=16,
        arch_type="encdec", enc_layers=2, dec_layers=2,
        mlp_kind="gelu", norm_kind="layernorm",
        kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=4)
