"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000
— GeGLU, head_dim=256 (q-dim 4096 != d_model) [arXiv:2403.08295; hf]."""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        vocab=256000, d_model=3072, n_layers=28, n_heads=16, n_kv=16,
        d_ff=24576, head_dim=256,
        pattern=("attn+mlp",), mlp_kind="geglu", norm_kind="rms",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-reduced",
        vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv=4,
        d_ff=384, head_dim=32,   # head_dim * n_heads != d_model, as in gemma
        pattern=("attn+mlp",), mlp_kind="geglu", norm_kind="rms",
        kv_chunk=32, remat="none", dtype="float32",
    )


TRAIN_OVERRIDES = dict(microbatches=4, zero1=True, zero2_grads=True)
