from repro.runtime.fault import (
    FaultTolerantRunner,
    StragglerMonitor,
    PreemptionGuard,
)

__all__ = ["FaultTolerantRunner", "StragglerMonitor", "PreemptionGuard"]
