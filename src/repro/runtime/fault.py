"""Fault tolerance: checkpoint/restart, preemption, straggler mitigation.

On a 1000+-node cluster the failure model is: (a) hard node loss -> the job
controller restarts the process group and we must resume from the last
checkpoint with zero manual steps; (b) preemption notice (SIGTERM) -> save
NOW and exit cleanly; (c) stragglers -> detect persistent slow steps and
surface/act (re-shard, swap pod) rather than silently losing throughput.

This module implements all three against the single-process simulator:
failures are injected by tests via `inject`, SIGTERM is registered for real,
and the straggler monitor is wall-clock based — the logic is exactly what a
multi-host deployment runs; only the restart transport differs.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag the training loop checks each step."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._orig = {}
        if install:
            for sig in (signal.SIGTERM,):
                try:
                    self._orig[sig] = signal.signal(sig, self._handler)
                except ValueError:  # non-main thread
                    pass

    def _handler(self, signum, frame):
        self.preempted = True

    def restore(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class StragglerEvent:
    step: int
    dt: float
    median: float
    ratio: float


class StragglerMonitor:
    """Flags steps slower than `threshold` x the running median.

    On real hardware the actionable signal is per-host: the monitor would be
    fed per-host step times (from jax.process_index() heartbeats) and the
    policy hook decides demote/evict/re-shard. Here the policy hook receives
    the event; the default action is to record it.
    """

    def __init__(self, threshold: float = 2.0, window: int = 50,
                 policy: Optional[Callable[[StragglerEvent], None]] = None):
        self.threshold = threshold
        self.window = window
        self.policy = policy
        self.times: list[float] = []
        self.events: list[StragglerEvent] = []

    def observe(self, step: int, dt: float):
        import statistics

        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window:])
            if dt > self.threshold * med:
                ev = StragglerEvent(step=step, dt=dt, median=med, ratio=dt / med)
                self.events.append(ev)
                if self.policy:
                    self.policy(ev)
        self.times.append(dt)


class FaultTolerantRunner:
    """Checkpoint/restart wrapper around a step loop.

    run() executes `step_fn(state, step) -> state` for `steps` steps,
    checkpointing every `ckpt_every` via save_fn(step, state) and restoring
    with restore_fn() -> (state, start_step) after a failure. Failures are
    retried up to `max_failures` times; each recovery resumes from the last
    durable checkpoint (losing at most ckpt_every-1 steps of work).
    """

    def __init__(
        self,
        step_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        ckpt_every: int = 10,
        max_failures: int = 3,
        straggler: Optional[StragglerMonitor] = None,
        preemption: Optional[PreemptionGuard] = None,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_failures = max_failures
        self.straggler = straggler or StragglerMonitor()
        self.preemption = preemption
        self.failures = 0
        self.log: list[str] = []

    def run(self, state, steps: int, start_step: int = 0):
        step = start_step
        while step < steps:
            try:
                t0 = time.perf_counter()
                state = self.step_fn(state, step)
                dt = time.perf_counter() - t0
                self.straggler.observe(step, dt)
                step += 1
                if self.ckpt_every and step % self.ckpt_every == 0:
                    self.save_fn(step, state)
                if self.preemption is not None and self.preemption.preempted:
                    self.save_fn(step, state)
                    self.log.append(f"preempted at step {step}; checkpointed")
                    return state, step
            except Exception as e:  # noqa: BLE001 — any step failure
                self.failures += 1
                self.log.append(f"step {step} failed ({type(e).__name__}: {e}); "
                                f"failure {self.failures}/{self.max_failures}")
                if self.failures > self.max_failures:
                    raise
                state, step = self.restore_fn()
                self.log.append(f"restored; resuming at step {step}")
        return state, step
