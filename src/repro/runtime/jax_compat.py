"""Version bridge to the jax >= 0.6 sharding API.

The production stack is written against the modern surface — ``jax.set_mesh``
(current-mesh context), ``jax.shard_map`` (with ``axis_names`` /
``check_vma``), ``jax.make_mesh(..., axis_types=...)``, ``jax.lax.pvary`` —
but CI and the pinned container run the 0.4.x line, where those live under
different names with slightly different knobs:

    new (>= 0.6)                       old (0.4.x)
    ------------------------------     ----------------------------------
    jax.set_mesh(mesh)                 with mesh:  (Mesh context manager)
    jax.shard_map(axis_names=S)        shard_map(auto=all_axes - S)
    jax.shard_map(check_vma=False)     shard_map(check_rep=False)
    jax.make_mesh(..., axis_types=..)  jax.make_mesh(shape, names)
    jax.lax.pvary(x, axes)             (no-op: no varying-axis tracking)

Import these wrappers instead of the jax names anywhere a mesh is built or a
shard_map is issued; they are pass-throughs on new jax.
"""
from __future__ import annotations

import contextlib

import jax


def make_mesh(shape, axis_names, *, explicit: bool = False):
    """jax.make_mesh with Auto axis_types where supported, plain otherwise."""
    if not hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(tuple(shape), tuple(axis_names))
    kind = (jax.sharding.AxisType.Explicit if explicit
            else jax.sharding.AxisType.Auto)
    return jax.make_mesh(
        tuple(shape), tuple(axis_names), axis_types=(kind,) * len(axis_names)
    )


def set_mesh(mesh):
    """Context manager making ``mesh`` current for implicit sharding."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # 0.4.x: Mesh is itself the resource-env context manager
    return mesh if hasattr(mesh, "__enter__") else contextlib.nullcontext()


def shard_map(f, mesh, in_specs, out_specs, *, axis_names=None,
              check_vma: bool | None = None):
    """jax.shard_map / jax.experimental.shard_map.shard_map bridge.

    ``axis_names``: mesh axes the function is manual over (new-API meaning);
    on old jax this becomes ``auto = all_axes - axis_names``.
    ``check_vma``: new name for replication checking (old ``check_rep``).
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm

    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    # old check_rep cannot track device-varying carries the new API expresses
    # with pvary; disable it whenever the caller opted out of vma checking
    if check_vma is False:
        kw["check_rep"] = False
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def pvary(x, axis_names):
    """Mark ``x`` as varying over ``axis_names`` (no-op before jax 0.5)."""
    pv = getattr(jax.lax, "pvary", None)
    return pv(x, tuple(axis_names)) if pv is not None else x
