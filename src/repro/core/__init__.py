# The paper's primary contribution: the M(.) metric over vertex processing
# orders, the GoGraph divide-and-conquer reordering algorithm, and the
# competitor reordering baselines it is evaluated against.
from repro.core.metric import (
    metric_m,
    metric_m_jax,
    positive_edge_fraction,
    edge_span,
    block_fresh_fraction,
)
from repro.core.gograph import gograph_order, GoGraphConfig
from repro.core import baselines, partition

__all__ = [
    "metric_m",
    "metric_m_jax",
    "positive_edge_fraction",
    "edge_span",
    "block_fresh_fraction",
    "gograph_order",
    "GoGraphConfig",
    "baselines",
    "partition",
]
