"""The paper's metric function M(.) and related order-quality measures.

M(O_V) = #{(u,v) in E : p(u) < p(v)}   (Eq. 7) — the number of *positive*
edges, i.e. edges whose source is processed before its destination, so the
destination sees the source's state from the *current* round (Eq. 2).

:class:`MetricTracker` maintains M (and per-region M) incrementally as
:class:`~repro.graphs.delta.GraphDelta` batches mutate the graph — O(|delta|)
per batch instead of the O(m) `metric_m` recompute — which is what lets the
serving layer watch the order decay and trigger regional re-ranks online.
"""
from __future__ import annotations

from collections import Counter
from typing import TYPE_CHECKING, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.graphs.graph import Graph, check_permutation, rank_to_order

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (delta imports graph)
    from repro.graphs.delta import GraphDelta


def metric_m(g: Graph, rank: np.ndarray) -> int:
    """Count positive edges of order `rank` (rank[v] = ordinal p(v))."""
    rank = np.asarray(rank)
    return int(np.count_nonzero(rank[g.src] < rank[g.dst]))


def positive_edge_fraction(g: Graph, rank: np.ndarray) -> float:
    """M / |E| — the normalized column of paper Table II."""
    return metric_m(g, rank) / max(1, g.m)


# M counts at most |E| edges; int32 accumulation is exact only up to here.
METRIC_EDGE_BOUND = 2**31 - 1


def metric_m_jax(src: jnp.ndarray, dst: jnp.ndarray, rank: jnp.ndarray) -> jnp.ndarray:
    """JAX version (used inside jitted evaluation sweeps).

    Accumulates in int64 when ``jax_enable_x64`` is on. With x64 disabled
    (the default) an int64 request would silently downcast to int32, so the
    dtype is spelled out and edge counts past ``METRIC_EDGE_BOUND`` raise
    instead of silently wrapping.
    """
    m = int(src.shape[0])
    x64 = bool(jax.config.jax_enable_x64)
    if m > METRIC_EDGE_BOUND and not x64:
        raise OverflowError(
            f"metric_m_jax: {m} edges exceeds the int32 accumulation bound "
            f"({METRIC_EDGE_BOUND}); enable jax_enable_x64 for int64 counts"
        )
    acc = jnp.int64 if x64 else jnp.int32
    return jnp.sum((rank[src] < rank[dst]).astype(acc), dtype=acc)


def _pair_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Endpoint-pair keys (ids are int32, so ``s << 32 | d`` never collides).

    Stable under vertex appends — unlike the ``src * n + dst`` arithmetic in
    `GraphDelta.apply`, which re-keys every delta — so the tracker's edge
    multiset survives graph growth without rebuilding."""
    return (np.asarray(src).astype(np.int64) << 32) | np.asarray(dst).astype(np.int64)


class MetricTracker:
    """Incremental maintenance of M under `GraphDelta` mutations.

    Holds the graph's edge multiset (keyed by endpoint pair), the current
    rank, and per-region positive/total edge counts, where a vertex's
    *region* is the ``regions``-way contiguous span of rank positions it
    occupied at the last (re)base — the unit at which the serving layer
    triggers regional re-ranks. ``apply_delta`` is O(|delta|) edge work:

    * insertions/deletions adjust the multiset and the counts using the
      current rank (deletions remove every copy of a pair, mirroring
      ``GraphDelta.apply``);
    * reweights never change M;
    * appended vertices require the extended rank (``extend_rank`` output —
      any update that *preserves the relative order* of tracked vertices is
      exact, because old edges' positivity only depends on relative order).
      New vertices inherit the region of their predecessor in the new order.

    After an arbitrary reorder (e.g. `regional_rerank`) relative order is
    *not* preserved — call :meth:`rebase` with the new rank instead.

    ``tracker.M == metric_m(g_current, rank_current)`` holds exactly at
    every step (property-tested in tests/test_reorder.py).
    """

    def __init__(self, g: Graph, rank: np.ndarray, *, regions: int = 16) -> None:
        if regions < 1:
            raise ValueError(f"regions must be >= 1, got {regions}")
        self.regions = int(regions)
        self._base(g, rank)

    def _base(self, g: Graph, rank: np.ndarray) -> None:
        rank = np.asarray(rank, dtype=np.int64)
        if rank.shape != (g.n,):
            raise ValueError(f"rank must have shape ({g.n},), got {rank.shape}")
        check_permutation(rank, g.n)
        self.n = g.n
        self._rank = rank.copy()
        # region = contiguous span of rank positions, frozen at (re)base time
        self._region_of = (rank * self.regions) // max(1, g.n)
        uk, cnt = np.unique(_pair_keys(g.src, g.dst), return_counts=True)
        self._edges: Counter[int] = Counter(dict(zip(uk.tolist(), cnt.tolist())))
        self.m_edges = g.m
        pos = rank[g.src] < rank[g.dst]
        self.M = int(np.count_nonzero(pos))
        reg = self._region_of[g.dst]
        self.region_m = np.bincount(reg[pos], minlength=self.regions).astype(np.int64)
        self.region_edges = np.bincount(reg, minlength=self.regions).astype(np.int64)
        self.baseline_fraction = self.fractions()

    def rebase(self, g: Graph, rank: np.ndarray, *, regions: Optional[int] = None) -> None:
        """Full O(m) recount against a new rank (after an arbitrary reorder)."""
        if regions is not None:
            if regions < 1:
                raise ValueError(f"regions must be >= 1, got {regions}")
            self.regions = int(regions)
        self._base(g, rank)

    # -- queries -----------------------------------------------------------
    @property
    def rank(self) -> np.ndarray:
        return self._rank

    @property
    def region_of(self) -> np.ndarray:
        return self._region_of

    @property
    def m_frac(self) -> float:
        """M / |E| — the tracked `positive_edge_fraction`."""
        return self.M / max(1, self.m_edges)

    def fractions(self) -> np.ndarray:
        """Per-region M fraction; empty regions report 1.0 (nothing to decay)."""
        frac = self.region_m / np.maximum(self.region_edges, 1)
        return np.where(self.region_edges > 0, frac, 1.0)

    def decayed_regions(self, threshold: float, *, min_edges: int = 8) -> np.ndarray:
        """Regions whose M fraction fell below ``threshold`` *and* below their
        fraction at the last (re)base — the regional re-rank trigger set.
        Regions with fewer than ``min_edges`` edges never trigger (a handful
        of inverted edges is not worth a re-rank)."""
        frac = self.fractions()
        hit = (self.region_edges >= min_edges) & (frac < threshold)
        hit &= frac < self.baseline_fraction
        return np.nonzero(hit)[0].astype(np.int64)

    def region_members(self, region_ids: np.ndarray) -> np.ndarray:
        """Vertex ids assigned to the given regions (at the last rebase)."""
        return np.nonzero(np.isin(self._region_of, region_ids))[0].astype(np.int64)

    # -- the O(|delta|) update ---------------------------------------------
    def apply_delta(self, delta: "GraphDelta", rank_new: Optional[np.ndarray] = None) -> None:
        """Fold one `GraphDelta` into the tracked counts.

        Mirrors ``GraphDelta.apply`` semantics (deletions first, addressed by
        endpoint pair and removing every copy; reweights are M-neutral; then
        insertions). When ``delta.n_add > 0`` the extended rank over all
        ``n + n_add`` vertices is required and must preserve the relative
        order of the existing vertices (``extend_rank`` guarantees this)."""
        if delta.n_add:
            if rank_new is None:
                raise ValueError(
                    "apply_delta: delta appends vertices; pass the extended "
                    "rank (extend_rank output) as rank_new"
                )
            self._extend(np.asarray(rank_new, dtype=np.int64), delta.n_add)
        if len(delta.del_src):
            dk = _pair_keys(delta.del_src, delta.del_dst)
            _, first = np.unique(dk, return_index=True)
            s = delta.del_src[first].astype(np.int64)
            d = delta.del_dst[first].astype(np.int64)
            counts = np.fromiter(
                (self._edges.pop(int(k), 0) for k in dk[first]),
                dtype=np.int64, count=len(first),
            )
            pos = self._rank[s] < self._rank[d]
            reg = self._region_of[d]
            self.m_edges -= int(counts.sum())
            self.M -= int(counts[pos].sum())
            np.subtract.at(self.region_edges, reg, counts)
            np.subtract.at(self.region_m, reg, counts * pos)
        if len(delta.add_src):
            s = delta.add_src.astype(np.int64)
            d = delta.add_dst.astype(np.int64)
            for k in _pair_keys(s, d).tolist():
                self._edges[k] += 1
            pos = self._rank[s] < self._rank[d]
            reg = self._region_of[d]
            self.m_edges += len(s)
            self.M += int(np.count_nonzero(pos))
            np.add.at(self.region_edges, reg, 1)
            np.add.at(self.region_m, reg, pos.astype(np.int64))

    def _extend(self, rank_new: np.ndarray, n_add: int) -> None:
        n_new = self.n + n_add
        if rank_new.shape != (n_new,):
            raise ValueError(
                f"rank_new must cover all {n_new} vertices, got {rank_new.shape}"
            )
        check_permutation(rank_new, n_new)
        # region forward-fill: a new vertex inherits the region of the nearest
        # *old* vertex preceding it in the new order (head-of-order -> region 0)
        order = rank_to_order(rank_new)
        if self.n == 0:
            self._region_of = np.zeros(n_new, dtype=np.int64)
        else:
            old_pos = np.where(order < self.n, np.arange(n_new), -1)
            last_old = np.maximum.accumulate(old_pos)
            # gather ids are old vertices wherever last_old >= 0; the clip only
            # sanitizes lanes the where() masks out (a new vertex ranked first)
            gather = np.minimum(order[np.maximum(last_old, 0)], self.n - 1)
            reg_by_pos = np.where(last_old >= 0, self._region_of[gather], 0)
            region_new = np.empty(n_new, dtype=np.int64)
            region_new[order] = reg_by_pos
            self._region_of = region_new
        self._rank = rank_new.copy()
        self.n = n_new


def edge_span(g: Graph, rank: np.ndarray) -> float:
    """Mean |p(u) - p(v)| over edges.

    Locality proxy: small spans mean a vertex and its neighbors are close in
    the processing order, the property the paper links to CPU cache hits
    (§IV-A "Divide other vertices") and that on TPU controls how many distinct
    state tiles a block update touches.
    """
    rank = np.asarray(rank, dtype=np.int64)
    if g.m == 0:
        return 0.0
    return float(np.abs(rank[g.src] - rank[g.dst]).mean())


def block_fresh_fraction(g: Graph, rank: np.ndarray, bs: int) -> dict:
    """Edge freshness at *block* granularity (the TPU execution model).

    In a block Gauss–Seidel sweep over blocks of `bs` consecutive positions,
    an edge delivers a current-round ("fresh") state iff its source's block
    precedes its destination's block. Intra-block edges see the previous
    round (the block updates jointly), so GoGraph's positive edges translate
    to fresh edges only across blocks — this function quantifies how much of
    the vertex-level M(.) survives blocking.
    """
    rank = np.asarray(rank, dtype=np.int64)
    sb = rank[g.src] // bs
    db = rank[g.dst] // bs
    m = max(1, g.m)
    return {
        "fresh": float(np.count_nonzero(sb < db) / m),
        "intra": float(np.count_nonzero(sb == db) / m),
        "stale": float(np.count_nonzero(sb > db) / m),
    }


def metric_table(g: Graph, ranks: dict[str, np.ndarray], bs: int = 256) -> dict[str, dict]:
    """Convenience: per-order quality summary (Table II style)."""
    out = {}
    for name, rank in ranks.items():
        m_val = metric_m(g, rank)
        row = {
            "M": m_val,
            "M_over_E": m_val / max(1, g.m),
            "edge_span": edge_span(g, rank),
        }
        row.update({f"block_{k}": v for k, v in block_fresh_fraction(g, rank, bs).items()})
        out[name] = row
    return out
