"""The paper's metric function M(.) and related order-quality measures.

M(O_V) = #{(u,v) in E : p(u) < p(v)}   (Eq. 7) — the number of *positive*
edges, i.e. edges whose source is processed before its destination, so the
destination sees the source's state from the *current* round (Eq. 2).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.graphs.graph import Graph


def metric_m(g: Graph, rank: np.ndarray) -> int:
    """Count positive edges of order `rank` (rank[v] = ordinal p(v))."""
    rank = np.asarray(rank)
    return int(np.count_nonzero(rank[g.src] < rank[g.dst]))


def positive_edge_fraction(g: Graph, rank: np.ndarray) -> float:
    """M / |E| — the normalized column of paper Table II."""
    return metric_m(g, rank) / max(1, g.m)


def metric_m_jax(src: jnp.ndarray, dst: jnp.ndarray, rank: jnp.ndarray) -> jnp.ndarray:
    """JAX version (used inside jitted evaluation sweeps).

    Accumulates in int32 explicitly: an int64 request silently downcasts to
    int32 when x64 is disabled (the default), so spelling int32 out makes the
    result independent of ``jax_enable_x64``. M counts at most |E| edges, so
    int32 is exact up to 2**31 - 1 (~2.1e9) edges — beyond any graph the
    single-host engines can hold.
    """
    return jnp.sum((rank[src] < rank[dst]).astype(jnp.int32), dtype=jnp.int32)


def edge_span(g: Graph, rank: np.ndarray) -> float:
    """Mean |p(u) - p(v)| over edges.

    Locality proxy: small spans mean a vertex and its neighbors are close in
    the processing order, the property the paper links to CPU cache hits
    (§IV-A "Divide other vertices") and that on TPU controls how many distinct
    state tiles a block update touches.
    """
    rank = np.asarray(rank, dtype=np.int64)
    if g.m == 0:
        return 0.0
    return float(np.abs(rank[g.src] - rank[g.dst]).mean())


def block_fresh_fraction(g: Graph, rank: np.ndarray, bs: int) -> dict:
    """Edge freshness at *block* granularity (the TPU execution model).

    In a block Gauss–Seidel sweep over blocks of `bs` consecutive positions,
    an edge delivers a current-round ("fresh") state iff its source's block
    precedes its destination's block. Intra-block edges see the previous
    round (the block updates jointly), so GoGraph's positive edges translate
    to fresh edges only across blocks — this function quantifies how much of
    the vertex-level M(.) survives blocking.
    """
    rank = np.asarray(rank, dtype=np.int64)
    sb = rank[g.src] // bs
    db = rank[g.dst] // bs
    m = max(1, g.m)
    return {
        "fresh": float(np.count_nonzero(sb < db) / m),
        "intra": float(np.count_nonzero(sb == db) / m),
        "stale": float(np.count_nonzero(sb > db) / m),
    }


def metric_table(g: Graph, ranks: dict[str, np.ndarray], bs: int = 256) -> dict[str, dict]:
    """Convenience: per-order quality summary (Table II style)."""
    out = {}
    for name, rank in ranks.items():
        m_val = metric_m(g, rank)
        row = {
            "M": m_val,
            "M_over_E": m_val / max(1, g.m),
            "edge_span": edge_span(g, rank),
        }
        row.update({f"block_{k}": v for k, v in block_fresh_fraction(g, rank, bs).items()})
        out[name] = row
    return out
