"""GoGraph — the paper's divide-and-conquer vertex reordering (Algorithm 1).

Pipeline (paper §IV-A, Fig. 3):
  1. extract high-degree vertices (top ``hd_fraction``, default 0.2%) and the
     vertices their removal isolates;
  2. partition the remaining core into locality-preserving subgraphs;
  3. order vertices inside each subgraph by BFS-driven insertion, placing each
     candidate at the position maximizing the metric M(.) via the incremental
     ``GetOptVal`` scan over its already-placed neighbors;
  4. order the subgraphs themselves the same way, treating each as a
     super-vertex with weighted edges (weight = #edges between subgraphs);
  5. re-insert high-degree vertices, then isolated vertices, again via
     ``GetOptVal`` against the assembled order.

Ordinal numbers are represented by floating ``val``s exactly as in the paper's
implementation section (§IV-C): inserting between two placed vertices assigns
the mean of their vals, so no reindexing is needed; the final processing order
is the stable argsort of vals. A renormalization guard keeps midpoint
bisection away from float-precision exhaustion.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph, order_to_rank, rank_to_order
from repro.core import partition as part_mod


@dataclasses.dataclass
class GoGraphConfig:
    hd_fraction: float = 0.002      # paper: "top 0.2% vertices with highest degree"
    min_n_for_hd: int = 64          # tiny graphs skip the HD phase
    partition_method: str = "labelprop"  # labelprop | louvain | fennel | bfs
    max_subgraph: int = 4096
    seed: int = 0


def _scan_best_gap(pe_head: float, delta_per: np.ndarray) -> int:
    """The ``GetOptVal`` gap scan, vectorized: the paper's per-gap loop
    walks the candidate's placed neighbors in val order, accumulating the
    positive-edge count ``pe`` (+w past an in-neighbor, -w past an
    out-neighbor) and keeping the first gap that *strictly* improves on the
    head position (paper line 18). The running ``pe`` after each neighbor is
    a sequential prefix sum seeded with ``pe_head`` — ``np.cumsum`` performs
    the identical left-to-right f64 additions, so seeding the cumsum with
    ``pe_head`` reproduces the loop's rounding bitwise — and "first strict
    improvement over everything before it" is ``argmax`` (first occurrence
    of the max) guarded by ``max > pe_head``. Returns the best gap index, or
    -1 for the head position."""
    cum = np.cumsum(np.concatenate(([pe_head], delta_per)))[1:]
    best = cum.max()
    return int(np.argmax(cum)) if best > pe_head else -1


class _Inserter:
    """Incremental M-maximizing insertion (the paper's ``GetOptVal``).

    Maintains float vals for placed vertices of an id universe of size n.
    ``insert`` scans the candidate's placed neighbors in ascending val order,
    updating the positive-edge count pe incrementally (+w when passing an
    in-neighbor, -w when passing an out-neighbor), and assigns the candidate
    the val of the best gap. Head/tail positions use global min-1 / max+1.
    The scan itself is the vectorized `_scan_best_gap` prefix sum
    (bitwise-identical to the sequential loop it replaced), so insertion
    cost is sort-dominated O(deg log deg) numpy work, not a Python loop
    per gap.
    """

    def __init__(self, n: int) -> None:
        self.val = np.full(n, np.nan, dtype=np.float64)
        self.placed: list[int] = []
        self._min = 0.0
        self._max = 0.0

    def grow(self, n_new: int) -> None:
        """Extend the id universe (appended vertices arrive unplaced)."""
        if n_new < len(self.val):
            raise ValueError(f"cannot shrink inserter from {len(self.val)} to {n_new}")
        pad = np.full(n_new - len(self.val), np.nan, dtype=np.float64)
        self.val = np.concatenate([self.val, pad])

    # -- helpers ---------------------------------------------------------
    def seed_sequence(self, ids: np.ndarray) -> None:
        """Pre-place `ids` at consecutive integer vals (assembled core order)."""
        ids = np.asarray(ids, dtype=np.int64)
        self.val[ids] = np.arange(len(ids), dtype=np.float64)
        self.placed = [int(i) for i in ids]
        if len(ids):
            self._min, self._max = 0.0, float(len(ids) - 1)

    def _renormalize(self) -> None:
        ids = np.asarray(self.placed, dtype=np.int64)
        order = ids[np.argsort(self.val[ids], kind="stable")]
        self.val[order] = np.arange(len(order), dtype=np.float64)
        self._min, self._max = 0.0, float(max(0, len(order) - 1))

    def is_placed(self, v: int) -> bool:
        return not np.isnan(self.val[v])

    # -- the core routine --------------------------------------------------
    def insert(
        self,
        v: int,
        in_nbrs: np.ndarray,
        in_w: np.ndarray,
        out_nbrs: np.ndarray,
        out_w: np.ndarray,
    ) -> float:
        """Place v optimally w.r.t. its placed neighbors; returns the val."""
        if not self.placed:
            self.val[v] = 0.0
            self._min = self._max = 0.0
            self.placed.append(int(v))
            return 0.0

        in_nbrs = np.asarray(in_nbrs, dtype=np.int64)
        out_nbrs = np.asarray(out_nbrs, dtype=np.int64)
        in_w = np.asarray(in_w, dtype=np.float64)
        out_w = np.asarray(out_w, dtype=np.float64)
        pin = in_nbrs[~np.isnan(self.val[in_nbrs])] if len(in_nbrs) else in_nbrs
        win = in_w[~np.isnan(self.val[in_nbrs])] if len(in_nbrs) else in_w
        pout = out_nbrs[~np.isnan(self.val[out_nbrs])] if len(out_nbrs) else out_nbrs
        wout = out_w[~np.isnan(self.val[out_nbrs])] if len(out_nbrs) else out_w

        if len(pin) == 0 and len(pout) == 0:
            # no placed neighbors: append at tail (keeps BFS locality)
            self._max += 1.0
            self.val[v] = self._max
            self.placed.append(int(v))
            return self.val[v]

        # net pe change when the candidate moves past each distinct neighbor:
        # passing an in-neighbor u (edge u->v) makes it positive (+w);
        # passing an out-neighbor w_ (edge v->w_) makes it negative (-w).
        nbrs = np.concatenate([pin, pout])
        deltas = np.concatenate([win, -wout])
        uniq, inv = np.unique(nbrs, return_inverse=True)
        delta_per = np.zeros(len(uniq), dtype=np.float64)
        np.add.at(delta_per, inv, deltas)
        order = np.argsort(self.val[uniq], kind="stable")
        uniq = uniq[order]
        delta_per = delta_per[order]

        pe = float(wout.sum())  # head position: all out-edges positive
        best_idx = _scan_best_gap(pe, delta_per)  # -1 = before first neighbor

        if best_idx == -1:
            self._min -= 1.0
            new_val = self._min
        elif best_idx == len(uniq) - 1:
            self._max += 1.0
            new_val = self._max
        else:
            lo = self.val[uniq[best_idx]]
            hi = self.val[uniq[best_idx + 1]]
            new_val = 0.5 * (lo + hi)
            if not (lo < new_val < hi):  # float bisection exhausted
                self._renormalize()
                lo = self.val[uniq[best_idx]]
                hi = self.val[uniq[best_idx + 1]]
                new_val = 0.5 * (lo + hi)

        self.val[v] = new_val
        self._min = min(self._min, new_val)
        self._max = max(self._max, new_val)
        self.placed.append(int(v))
        return new_val


def _insert_all(
    ins: _Inserter, g: Graph, ids: np.ndarray, *, by_degree: bool = True
) -> None:
    """Insert ``ids`` into ``ins`` against ``g``'s full (unit-weight)
    neighborhoods — THE shared insertion loop behind phase 5, `extend_rank`,
    and `regional_rerank` (previously re-spelled at each site).

    ``by_degree=True`` inserts hubs first (descending degree, stable by id —
    the HD-phase convention, so later arrivals can position against them);
    ``by_degree=False`` preserves the given order (BFS-candidate sequences).
    """
    ids = np.asarray(ids, dtype=np.int64)
    if not len(ids):
        return
    if by_degree:
        deg = g.degrees()
        ids = ids[np.argsort(-deg[ids], kind="stable")]
    csc_indptr, csc_src, _ = g.csc()
    csr_indptr, csr_dst, _ = g.csr()
    for v in ids:
        inn = csc_src[csc_indptr[v]:csc_indptr[v + 1]]
        outn = csr_dst[csr_indptr[v]:csr_indptr[v + 1]]
        ins.insert(int(v), inn, np.ones(len(inn)), outn, np.ones(len(outn)))


def _community_bfs_order(
    members: np.ndarray,
    indptr: np.ndarray,
    nbrs: np.ndarray,
    in_deg: np.ndarray,
) -> np.ndarray:
    """BFS over the community's internal (undirected) edges, seeded at the
    min in-degree member (paper: "the initial vertex always has the smallest
    in-degree"), restarting for disconnected pieces."""
    from collections import deque

    member_set = np.zeros(int(indptr.shape[0] - 1), dtype=bool)
    member_set[members] = True
    visited = np.zeros_like(member_set)
    by_indeg = members[np.argsort(in_deg[members], kind="stable")]
    order = np.empty(len(members), dtype=np.int64)
    pos = 0
    ptr = 0
    q: deque[int] = deque()
    while pos < len(members):
        if not q:
            while ptr < len(by_indeg) and visited[by_indeg[ptr]]:
                ptr += 1
            if ptr >= len(by_indeg):
                break
            s = int(by_indeg[ptr])
            visited[s] = True
            q.append(s)
        u = q.popleft()
        order[pos] = u
        pos += 1
        for w in nbrs[indptr[u]:indptr[u + 1]]:
            if member_set[w] and not visited[w]:
                visited[w] = True
                q.append(int(w))
    return order[:pos]


def gograph_order(
    g: Graph,
    config: GoGraphConfig | None = None,
    return_info: bool = False,
) -> np.ndarray | tuple[np.ndarray, dict]:
    """Run GoGraph; returns rank (rank[v] = ordinal p(v)).

    With ``return_info=True`` also returns a dict of phase artifacts used by
    tests and benchmarks (hd set, isolated set, community labels, vals).
    """
    cfg = config or GoGraphConfig()
    n = g.n
    if n == 0:
        rank = np.empty(0, dtype=np.int64)
        return (rank, {}) if return_info else rank

    ones = np.ones(g.m, dtype=np.float64)

    # ---- phase 1: extract high-degree vertices -------------------------
    deg = g.degrees()
    n_hd = int(round(n * cfg.hd_fraction)) if n >= cfg.min_n_for_hd else 0
    if n_hd > 0:
        # deterministic top-k by (degree desc, id asc)
        order_by_deg = np.lexsort((np.arange(n), -deg))
        hd = order_by_deg[:n_hd]
    else:
        hd = np.empty(0, dtype=np.int64)
    is_hd = np.zeros(n, dtype=bool)
    is_hd[hd] = True

    # ---- isolated after HD removal (incl. genuinely isolated vertices) --
    keep_edge = ~(is_hd[g.src] | is_hd[g.dst])
    deg_rest = np.bincount(g.src[keep_edge], minlength=n) + np.bincount(
        g.dst[keep_edge], minlength=n
    )
    is_iso = (~is_hd) & (deg_rest == 0)
    core_ids = np.where(~is_hd & ~is_iso)[0].astype(np.int32)

    info: dict = {"hd": hd, "iso": np.where(is_iso)[0], "core": core_ids}

    # ---- phase 2: partition the core ------------------------------------
    core_order_global: np.ndarray
    if len(core_ids):
        g_core, old_ids = g.subgraph(core_ids)
        labels = part_mod.partition(
            g_core, method=cfg.partition_method, max_size=cfg.max_subgraph, seed=cfg.seed
        )
        info["labels"] = labels
        k = int(labels.max()) + 1 if len(labels) else 0

        sym_indptr, sym_nbrs = part_mod._sym_csr(g_core)
        in_deg_core = g_core.in_degrees()
        csc_indptr, csc_src, csc_eid = g_core.csc()
        csr_indptr, csr_dst, csr_eid = g_core.csr()

        # ---- phase 3: order vertices within each subgraph ---------------
        local_pos = np.empty(g_core.n, dtype=np.int64)  # position inside community
        comm_members: list[np.ndarray] = []
        for c in range(k):
            members = np.where(labels == c)[0]
            comm_members.append(members)
            cand = _community_bfs_order(members, sym_indptr, sym_nbrs, in_deg_core)
            ins = _Inserter(g_core.n)
            lab_c = labels
            for v in cand:
                inn = csc_src[csc_indptr[v]:csc_indptr[v + 1]]
                inn = inn[lab_c[inn] == c]
                outn = csr_dst[csr_indptr[v]:csr_indptr[v + 1]]
                outn = outn[lab_c[outn] == c]
                ins.insert(int(v), inn, np.ones(len(inn)), outn, np.ones(len(outn)))
            mvals = ins.val[members]
            local_pos[members] = np.argsort(np.argsort(mvals, kind="stable"), kind="stable")

        # ---- phase 4: order the subgraphs (super-vertices) --------------
        cs, cd = labels[g_core.src], labels[g_core.dst]
        inter = cs != cd
        if k > 1 and inter.any():
            key = cs[inter].astype(np.int64) * k + cd[inter]
            uniq, cnt = np.unique(key, return_counts=True)
            s_src = (uniq // k).astype(np.int32)
            s_dst = (uniq % k).astype(np.int32)
            g_sup = Graph(k, s_src, s_dst, cnt.astype(np.float32))
        else:
            g_sup = Graph(k, np.empty(0, np.int32), np.empty(0, np.int32))
        sup_sym_indptr, sup_sym_nbrs = part_mod._sym_csr(g_sup)
        sup_in_deg = g_sup.in_degrees()
        s_csc_indptr, s_csc_src, s_csc_eid = g_sup.csc()
        s_csr_indptr, s_csr_dst, s_csr_eid = g_sup.csr()
        sup_cand = _community_bfs_order(
            np.arange(k, dtype=np.int64), sup_sym_indptr, sup_sym_nbrs, sup_in_deg
        )
        sup_ins = _Inserter(k)
        sup_w = g_sup.weights
        for svx in sup_cand:
            inn = s_csc_src[s_csc_indptr[svx]:s_csc_indptr[svx + 1]]
            win = sup_w[s_csc_eid[s_csc_indptr[svx]:s_csc_indptr[svx + 1]]]
            outn = s_csr_dst[s_csr_indptr[svx]:s_csr_indptr[svx + 1]]
            wout = sup_w[s_csr_eid[s_csr_indptr[svx]:s_csr_indptr[svx + 1]]]
            sup_ins.insert(int(svx), inn, win, outn, wout)
        sup_rank = np.argsort(np.argsort(sup_ins.val[:k], kind="stable"), kind="stable")
        info["sup_rank"] = sup_rank

        # ---- decompress: global core order ------------------------------
        comm_sizes = np.array([len(m) for m in comm_members], dtype=np.int64)
        comm_by_pos = np.argsort(sup_rank, kind="stable")  # community at each slot
        offsets = np.zeros(k, dtype=np.int64)
        running = 0
        for cpos in comm_by_pos:
            offsets[cpos] = running
            running += comm_sizes[cpos]
        core_pos = offsets[labels] + local_pos  # position of each core vertex
        core_order_local = np.argsort(core_pos, kind="stable")
        core_order_global = old_ids[core_order_local]
    else:
        core_order_global = np.empty(0, dtype=np.int64)

    # ---- phase 5: insert high-degree then isolated vertices -------------
    glob = _Inserter(n)
    glob.seed_sequence(core_order_global)
    _insert_all(glob, g, hd, by_degree=True)
    _insert_all(glob, g, np.where(is_iso)[0], by_degree=False)

    order = np.argsort(glob.val, kind="stable")
    rank = order_to_rank(order)
    info["val"] = glob.val
    return (rank, info) if return_info else rank


class RankMaintainer:
    """Persistent incremental order maintenance for evolving graphs.

    Wraps one `_Inserter` whose float vals survive across delta batches:
    ``extend_rank`` used to re-seed (an O(n) renormalization) on *every*
    batch, so a tenant absorbing a delta stream paid O(n) per batch even
    when only a handful of vertices arrived. The maintainer seeds once and
    only renormalizes when midpoint bisection exhausts float precision
    (the `_Inserter` guard), making steady-state extension O(|new| · deg).

    Placed vertices keep their relative order exactly (their vals are only
    bisected between), so already-packed blocks and served warm states stay
    aligned. After an arbitrary reorder (e.g. `regional_rerank`) build a
    fresh maintainer from the new rank.
    """

    def __init__(self, rank: np.ndarray) -> None:
        rank = np.asarray(rank)
        self.n = len(rank)
        self._ins = _Inserter(self.n)
        self._ins.seed_sequence(rank_to_order(rank))

    def extend(self, g: Graph) -> np.ndarray:
        """Place ``g``'s appended vertices (ids >= current n) and return the
        extended rank over all ``g.n`` vertices. New vertices insert in
        descending degree order (hubs first, the HD-phase convention)."""
        if self.n > g.n:
            raise ValueError(f"maintained rank covers {self.n} vertices, graph has {g.n}")
        if g.n > self.n:
            self._ins.grow(g.n)
            _insert_all(self._ins, g, np.arange(self.n, g.n, dtype=np.int64),
                        by_degree=True)
            self.n = g.n
        return self.rank()

    def rank(self) -> np.ndarray:
        return order_to_rank(np.argsort(self._ins.val[:self.n], kind="stable"))


def extend_rank(g: Graph, rank_old: np.ndarray) -> np.ndarray:
    """Incremental order maintenance for evolving graphs.

    ``g`` is a mutated graph whose first ``len(rank_old)`` vertices keep
    their ids; the rest are newly appended. Instead of re-running the full
    divide-and-conquer pipeline, each new vertex is placed into the existing
    order at its M-maximizing position via the same ``GetOptVal`` scan
    (`_Inserter.insert`) that phase 5 uses for high-degree vertices —
    O(deg(v) log deg(v)) per arrival, no global reorder.

    One-shot convenience over :class:`RankMaintainer` — callers extending
    repeatedly (the serving loop) should hold a maintainer instead, which
    amortizes the O(n) seeding this wrapper pays per call.
    """
    return RankMaintainer(rank_old).extend(g)


def regional_rerank(g: Graph, rank: np.ndarray, members: np.ndarray) -> np.ndarray:
    """Re-run the divide-and-conquer insertion over ``members`` only and
    splice the result into the global rank.

    Non-members keep their relative order exactly; members are removed and
    re-inserted at their M-maximizing positions via the same ``GetOptVal``
    machinery as phase 3 (BFS candidate order over the members' internal
    undirected edges, seeded at the min in-degree member) with the
    vectorized `_scan_best_gap` prefix scan. Cross-region edges participate
    through each member's full neighborhood, so a member can land anywhere
    in the global order, not just inside its old span.

    This is the online-reordering repair step: when a region's M fraction
    decays (tracked by `MetricTracker`), re-ranking just that region
    recovers most of the lost metric at O(|region| · deg) cost instead of
    the full O(n) pipeline. Returns the new rank over all vertices.
    """
    rank = np.asarray(rank, dtype=np.int64)
    if rank.shape != (g.n,):
        raise ValueError(f"rank must have shape ({g.n},), got {rank.shape}")
    members = np.asarray(members, dtype=np.int64)
    if not len(members):
        return rank.copy()
    is_member = np.zeros(g.n, dtype=bool)
    is_member[members] = True
    rest = rank_to_order(rank)
    rest = rest[~is_member[rest]]  # non-members, in current order
    ins = _Inserter(g.n)
    ins.seed_sequence(rest)
    sym_indptr, sym_nbrs = part_mod._sym_csr(g)
    # Seed each BFS component at a *boundary* member (one with a non-member
    # neighbor), min in-degree among those. Phase 3's plain min-in-degree
    # seed is right when nothing is placed yet, but here the non-members
    # are already placed: an interior seed has no placed neighbor, so its
    # GetOptVal scan degenerates to the tail-append fallback and drags the
    # whole spliced component away from its cross-region anchors.
    csum = np.concatenate([[0], np.cumsum(~is_member[sym_nbrs])])
    ext_nbrs = csum[sym_indptr[1:]] - csum[sym_indptr[:-1]]
    deg = g.in_degrees().astype(np.int64)
    prio = deg + (int(deg.max(initial=0)) + 1) * (ext_nbrs == 0)
    cand = _community_bfs_order(members, sym_indptr, sym_nbrs, prio)
    _insert_all(ins, g, cand, by_degree=False)
    # BFS can only miss members with no internal edges; place them by degree
    missed = members[np.isnan(ins.val[members])]
    _insert_all(ins, g, missed, by_degree=True)
    return order_to_rank(np.argsort(ins.val, kind="stable"))
