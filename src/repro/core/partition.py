"""Graph partitioning / community detection for GoGraph's divide phase.

The paper uses Rabbit-Partition by default and shows Metis/Louvain perform
similarly while stream-based Fennel lags (Fig. 13). We implement:

* ``label_propagation`` — synchronous LP over the symmetrized graph,
  vectorized with numpy (the default; community-quality close to Louvain on
  the power-law graphs the paper targets, and fast).
* ``louvain_like`` — one-level greedy modularity via repeated LP + community
  contraction (a light-weight stand-in for Louvain/Rabbit's merge hierarchy).
* ``fennel_like`` — streaming balanced partitioner (the paper's weakest
  competitor, reproduced for the Fig. 13 ablation).
* ``bfs_blocks`` — plain BFS chunking (no community structure; ablation).

All partitioners return integer labels, then ``enforce_max_size`` splits
oversized parts (BFS chunks) so the conquer phase's insertion cost stays
bounded, and ``compact_labels`` renumbers labels densely.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.graphs.graph import Graph


def compact_labels(labels: np.ndarray) -> np.ndarray:
    _, inv = np.unique(labels, return_inverse=True)
    return inv.astype(np.int32)


def _sym_csr(g: Graph) -> tuple[np.ndarray, np.ndarray]:
    """CSR of the symmetrized (undirected) graph."""
    a = np.concatenate([g.src, g.dst])
    b = np.concatenate([g.dst, g.src])
    order = np.argsort(a, kind="stable")
    indptr = np.zeros(g.n + 1, dtype=np.int64)
    np.cumsum(np.bincount(a, minlength=g.n), out=indptr[1:])
    return indptr, b[order]


def label_propagation(g: Graph, rounds: int = 8, seed: int = 0) -> np.ndarray:
    """Synchronous label propagation, numpy-vectorized.

    Each round every vertex adopts the plurality label among its (undirected)
    neighbors; ties break toward the smaller label for determinism. A small
    random tie-noise on the first round avoids the all-labels-identical
    collapse LP is prone to on star-like graphs.
    """
    rng = np.random.default_rng(seed)
    labels = np.arange(g.n, dtype=np.int64)
    verts = np.arange(g.n, dtype=g.src.dtype)
    # self-vote breaks the synchronous-LP bipartite oscillation
    a = np.concatenate([g.dst, g.src, verts])  # receiver
    b = np.concatenate([g.src, g.dst, verts])  # sender
    if len(a) == 0:
        return labels.astype(np.int32)
    for r in range(rounds):
        lab_b = labels[b]
        # count votes per (receiver, label) pair
        key = a.astype(np.int64) * (g.n + 1) + lab_b
        uniq, counts = np.unique(key, return_counts=True)
        recv = uniq // (g.n + 1)
        lab = uniq % (g.n + 1)
        if r == 0:
            counts = counts.astype(np.float64) + rng.random(len(counts)) * 0.5
        # plurality with smaller-label tie-break: sort by (recv, -count, lab)
        order = np.lexsort((lab, -counts, recv))
        recv_s = recv[order]
        first = np.ones(len(recv_s), dtype=bool)
        first[1:] = recv_s[1:] != recv_s[:-1]
        winners_recv = recv_s[first]
        winners_lab = lab[order][first]
        new_labels = labels.copy()
        new_labels[winners_recv] = winners_lab
        if np.array_equal(new_labels, labels):
            break
        labels = new_labels
    return compact_labels(labels)


def louvain_like(g: Graph, levels: int = 2, rounds: int = 5, seed: int = 0) -> np.ndarray:
    """Multi-level LP: propagate, contract communities, propagate again.

    Approximates the Louvain/Rabbit merge hierarchy: the second level merges
    small communities that are densely interconnected.
    """
    labels = label_propagation(g, rounds=rounds, seed=seed)
    for lvl in range(1, levels):
        k = int(labels.max()) + 1 if g.n else 0
        if k <= 1:
            break
        # contracted multigraph between communities
        cs, cd = labels[g.src], labels[g.dst]
        keep = cs != cd
        if not keep.any():
            break
        gc = Graph(k, cs[keep].astype(np.int32), cd[keep].astype(np.int32))
        sup = label_propagation(gc, rounds=rounds, seed=seed + lvl)
        labels = sup[labels]
    return compact_labels(labels)


def fennel_like(g: Graph, k: int, gamma: float = 1.5, seed: int = 0) -> np.ndarray:
    """Streaming Fennel partitioner (paper Fig. 13's weak baseline).

    Vertices arrive in id order; each goes to the part maximizing
    |neighbors already in part| − alpha * gamma/2 * |part|^(gamma-1).
    """
    n = max(1, g.n)
    m = max(1, g.m)
    alpha = m * (k ** (gamma - 1)) / (n ** gamma)
    indptr, nbrs = _sym_csr(g)
    labels = -np.ones(g.n, dtype=np.int64)
    sizes = np.zeros(k, dtype=np.int64)
    scores = np.empty(k, dtype=np.float64)
    for v in range(g.n):
        scores[:] = -alpha * gamma / 2.0 * np.power(np.maximum(sizes, 1), gamma - 1)
        nb = nbrs[indptr[v]:indptr[v + 1]]
        placed = labels[nb]
        placed = placed[placed >= 0]
        if len(placed):
            np.add.at(scores, placed, 1.0)
        best = int(np.argmax(scores))
        labels[v] = best
        sizes[best] += 1
    return compact_labels(labels)


def bfs_blocks(g: Graph, block_size: int) -> np.ndarray:
    """Chunk a BFS traversal into fixed-size parts (no community signal)."""
    order = bfs_order(g)
    labels = np.empty(g.n, dtype=np.int32)
    labels[order] = np.arange(g.n, dtype=np.int32) // max(1, block_size)
    return compact_labels(labels)


def bfs_order(g: Graph, start: int | None = None) -> np.ndarray:
    """Undirected BFS visiting order, restarting at unvisited min-degree."""
    indptr, nbrs = _sym_csr(g)
    visited = np.zeros(g.n, dtype=bool)
    deg = indptr[1:] - indptr[:-1]
    by_deg = np.argsort(deg, kind="stable")
    order = np.empty(g.n, dtype=np.int64)
    pos = 0
    ptr = 0
    q: deque[int] = deque()
    if start is not None and g.n:
        q.append(start)
        visited[start] = True
    while pos < g.n:
        if not q:
            while ptr < g.n and visited[by_deg[ptr]]:
                ptr += 1
            if ptr >= g.n:
                break
            s = int(by_deg[ptr])
            visited[s] = True
            q.append(s)
        v = q.popleft()
        order[pos] = v
        pos += 1
        for u in nbrs[indptr[v]:indptr[v + 1]]:
            if not visited[u]:
                visited[u] = True
                q.append(int(u))
    return order[:pos]


def enforce_max_size(g: Graph, labels: np.ndarray, max_size: int, seed: int = 0) -> np.ndarray:
    """Split any community larger than max_size into BFS chunks."""
    labels = labels.astype(np.int64).copy()
    next_label = int(labels.max()) + 1 if g.n else 0
    sizes = np.bincount(labels)
    for c in np.where(sizes > max_size)[0]:
        members = np.where(labels == c)[0].astype(np.int32)
        sub, old_ids = g.subgraph(members)
        sub_order = bfs_order(sub)
        for chunk_start in range(0, len(sub_order), max_size):
            chunk = sub_order[chunk_start:chunk_start + max_size]
            if chunk_start == 0:
                continue  # first chunk keeps label c
            labels[old_ids[chunk]] = next_label
            next_label += 1
    return compact_labels(labels)


def partition(
    g: Graph,
    method: str = "labelprop",
    max_size: int = 4096,
    seed: int = 0,
    k_hint: int | None = None,
) -> np.ndarray:
    """Front door used by GoGraph. Returns dense community labels."""
    if method == "labelprop":
        labels = label_propagation(g, seed=seed)
    elif method == "louvain":
        labels = louvain_like(g, seed=seed)
    elif method == "fennel":
        k = k_hint or max(1, g.n // max(1, max_size))
        labels = fennel_like(g, k=k, seed=seed)
    elif method == "bfs":
        labels = bfs_blocks(g, block_size=max_size)
    else:
        raise ValueError(f"unknown partition method: {method}")
    return enforce_max_size(g, labels, max_size, seed=seed)
