"""Competitor reordering methods from the paper's evaluation (§V-A).

All functions return a rank array (rank[v] = ordinal p(v)).

* ``default_order``   — original ids (the paper's baseline of unit runtime).
* ``random_order``    — random permutation; M is |E|/2 in expectation, the
                         paper's effectiveness yardstick (§IV-B).
* ``degree_sort``     — descending-degree relabeling.
* ``hub_sort``        — Hub Sorting [48]: hubs (deg > avg) sorted descending at
                         the front; non-hub relative order preserved.
* ``hub_cluster``     — Hub Clustering [49]: hubs clustered contiguously at the
                         front in original relative order.
* ``rabbit_like``     — Rabbit [44]: community detection + community-major
                         layout, BFS within community (locality only).
* ``gorder_like``     — Gorder [41]: greedy sliding-window neighbor-affinity
                         maximization (priority-queue implementation).
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.graphs.graph import Graph, order_to_rank
from repro.core import partition as part_mod


def default_order(g: Graph) -> np.ndarray:
    return np.arange(g.n, dtype=np.int64)


def random_order(g: Graph, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(g.n).astype(np.int64)


def degree_sort(g: Graph) -> np.ndarray:
    deg = g.degrees()
    order = np.lexsort((np.arange(g.n), -deg))
    return order_to_rank(order)


def hub_sort(g: Graph) -> np.ndarray:
    deg = g.degrees()
    avg = deg.mean() if g.n else 0.0
    hubs = np.where(deg > avg)[0]
    non = np.where(deg <= avg)[0]
    hubs = hubs[np.argsort(-deg[hubs], kind="stable")]
    order = np.concatenate([hubs, non])
    return order_to_rank(order)


def hub_cluster(g: Graph) -> np.ndarray:
    deg = g.degrees()
    avg = deg.mean() if g.n else 0.0
    hubs = np.where(deg > avg)[0]
    non = np.where(deg <= avg)[0]
    order = np.concatenate([hubs, non])  # original relative order both sides
    return order_to_rank(order)


def rabbit_like(g: Graph, seed: int = 0) -> np.ndarray:
    """Community-major layout: communities ordered by size desc, members in
    BFS order. Captures Rabbit's cache goal (locality) but — unlike GoGraph —
    is direction-blind, so it does not optimize M(.)."""
    labels = part_mod.louvain_like(g, seed=seed)
    k = int(labels.max()) + 1 if g.n else 0
    sym_indptr, sym_nbrs = part_mod._sym_csr(g)
    in_deg = g.in_degrees()
    sizes = np.bincount(labels, minlength=k)
    comm_order = np.argsort(-sizes, kind="stable")
    chunks = []
    for c in comm_order:
        members = np.where(labels == c)[0]
        from repro.core.gograph import _community_bfs_order

        chunks.append(_community_bfs_order(members, sym_indptr, sym_nbrs, in_deg))
    order = np.concatenate(chunks) if chunks else np.empty(0, dtype=np.int64)
    return order_to_rank(order)


def gorder_like(g: Graph, window: int = 5) -> np.ndarray:
    """Greedy Gorder: repeatedly append the vertex with the highest affinity
    (shared edges) to the last `window` placed vertices. Lazy max-heap with
    stale-entry skipping; O((n + m·w) log n)."""
    n = g.n
    sym_indptr, sym_nbrs = part_mod._sym_csr(g)
    score = np.zeros(n, dtype=np.int64)
    placed = np.zeros(n, dtype=bool)
    heap: list[tuple[int, int]] = [(0, v) for v in range(n)]
    heapq.heapify(heap)
    recent: list[int] = []
    order = np.empty(n, dtype=np.int64)

    def bump(v: int, d: int) -> None:
        score[v] += d
        if not placed[v] and d > 0:
            heapq.heappush(heap, (-int(score[v]), v))

    for pos in range(n):
        while heap:
            neg_s, v = heap[0]
            if placed[v] or -neg_s != score[v]:
                heapq.heappop(heap)
                continue
            break
        if not heap:  # all stale: pick any unplaced
            v = int(np.where(~placed)[0][0])
        else:
            _, v = heapq.heappop(heap)
        placed[v] = True
        order[pos] = v
        recent.append(v)
        for u in sym_nbrs[sym_indptr[v]:sym_indptr[v + 1]]:
            if not placed[u]:
                bump(int(u), 1)
        if len(recent) > window:
            old = recent.pop(0)
            for u in sym_nbrs[sym_indptr[old]:sym_indptr[old + 1]]:
                if not placed[u]:
                    score[u] -= 1  # lazy: heap entry goes stale
    return order_to_rank(order)


# Registry used by benchmarks (paper Fig. 5/6 competitor set + GoGraph).
def all_reorderers(seed: int = 0) -> dict:
    from repro.core.gograph import gograph_order

    return {
        "Default": lambda g: default_order(g),
        "Random": lambda g: random_order(g, seed=seed),
        "DegSort": degree_sort,
        "HubSort": hub_sort,
        "HubCluster": hub_cluster,
        "Rabbit": lambda g: rabbit_like(g, seed=seed),
        "Gorder": gorder_like,
        "GoGraph": lambda g: gograph_order(g),
    }
