"""Public API for the repro package.

The stable, documented surface (see README "API"):

* :func:`repro.solve` / :class:`repro.EngineOptions` — the one entry point
  to every engine (``sync`` | ``async_block`` | ``distributed``), with all
  option validation in one place (:class:`repro.EngineOptionsError`).
* Algorithm constructors — :func:`repro.get_algorithm` and the named
  builders (``personalized_pagerank``, ``multi_source_sssp``, ...).
* :func:`repro.run_incremental` — delta-driven recompute over an evolving
  graph.
* :class:`repro.GraphServer` / :class:`repro.Ticket` — the multi-tenant
  continuous-batching serving layer; :class:`repro.GraphDelta` for live
  graph mutations.

Everything else (``repro.engine``, ``repro.kernels``, ``repro.serving``,
...) is importable but considered internal; its layout may shift between
PRs. Attributes here resolve lazily (PEP 562) so ``import repro`` stays
cheap and subpackages that don't need the engine stack don't pay for it.
"""
from __future__ import annotations

__all__ = [
    # unified engine entry point
    "solve",
    "EngineOptions",
    "EngineOptionsError",
    "EngineUnsupportedError",
    # algorithms
    "get_algorithm",
    "ALGORITHMS",
    "AlgoInstance",
    "personalized_pagerank",
    "multi_source_sssp",
    "make_personalized_pagerank",
    "make_multi_source_sssp",
    "remake",
    # engine shims (legacy spellings; thin wrappers over solve())
    "run_sync",
    "run_async_block",
    "run_distributed",
    "run_push",
    "estimate_frontier_fraction",
    # incremental + serving
    "run_incremental",
    "GraphDelta",
    "Graph",
    "GraphServer",
    "Ticket",
]

_ENGINE = {
    "solve", "EngineOptions", "EngineOptionsError", "EngineUnsupportedError",
    "get_algorithm", "ALGORITHMS", "AlgoInstance", "personalized_pagerank",
    "multi_source_sssp", "make_personalized_pagerank",
    "make_multi_source_sssp", "remake", "run_sync", "run_async_block",
    "run_distributed", "run_push", "estimate_frontier_fraction",
    "run_incremental",
}
_SERVING = {"GraphServer", "Ticket"}
_GRAPHS = {"GraphDelta": "repro.graphs.delta", "Graph": "repro.graphs.graph"}


def __getattr__(name: str):
    import importlib

    if name in _ENGINE:
        return getattr(importlib.import_module("repro.engine"), name)
    if name in _SERVING:
        return getattr(importlib.import_module("repro.serving"), name)
    if name in _GRAPHS:
        return getattr(importlib.import_module(_GRAPHS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
