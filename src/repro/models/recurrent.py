"""Recurrent blocks: RG-LRU (Griffin / RecurrentGemma) and xLSTM cells.

* RG-LRU runs as a `jax.lax.associative_scan` over (decay, input) pairs —
  O(log S) depth, the TPU-native form of a linear recurrence.
* mLSTM (matrix-memory) uses the chunkwise-parallel form: intra-chunk
  attention-like compute on the MXU + an inter-chunk scan over the (C, n)
  running state, the standard sub-quadratic realization.
* sLSTM has hidden-to-hidden recurrence and is genuinely sequential (xLSTM
  paper §2.3); it runs as a per-step `lax.scan` with a small state.

All blocks expose (train/prefill) `apply` over full sequences and a
single-step `step` for decode, carrying explicit state pytrees.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear


# =====================================================================
# RG-LRU (Griffin)
# =====================================================================

@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int           # recurrence width (Griffin: ~4/3 d_model; we use d_model)
    conv_width: int = 4
    c_const: float = 8.0


def init_rglru(key, cfg: RGLRUConfig, dtype):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    params, specs = {}, {}
    params["wx"], specs["wx"] = init_linear(k1, cfg.d_model, (cfg.d_rnn,), ("embed", "ffn"), dtype)
    params["wy"], specs["wy"] = init_linear(k2, cfg.d_model, (cfg.d_rnn,), ("embed", "ffn"), dtype)
    params["wo"], specs["wo"] = init_linear(k3, cfg.d_rnn, (cfg.d_model,), ("ffn", "embed"), dtype)
    # depthwise causal conv over the rnn channel
    params["conv"] = (jax.random.normal(k4, (cfg.conv_width, cfg.d_rnn), jnp.float32) * 0.1).astype(dtype)
    specs["conv"] = (None, "ffn")
    # recurrence gates: a (recurrent weight via Lambda), input gate
    params["w_a"], specs["w_a"] = init_linear(k5, cfg.d_rnn, (cfg.d_rnn,), ("ffn", None), dtype)
    params["w_i"], specs["w_i"] = init_linear(k6, cfg.d_rnn, (cfg.d_rnn,), ("ffn", None), dtype)
    # Lambda parametrizes the per-channel decay in (0, 1)
    lam = jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, cfg.d_rnn)))  # softplus^-1
    params["lambda"] = lam.astype(jnp.float32)
    specs["lambda"] = ("ffn",)
    return params, specs


def _causal_depthwise_conv(x, w, state=None):
    """x: (B, S, C), w: (W, C). Returns (y, new_state (B, W-1, C))."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else state
    return y, new_state


def _rglru_scan(a, bx, h0=None):
    """h_t = a_t * h_{t-1} + bx_t via associative scan; a, bx: (B, S, C)."""
    if h0 is not None:
        # fold the carried state into the first step
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def apply_rglru(cfg: RGLRUConfig, params, x, state=None):
    """x: (B, S, D). Returns (y, new_state dict)."""
    gate_y = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, params["wy"]))
    u = jnp.einsum("bsd,dr->bsr", x, params["wx"])
    conv_state = None if state is None else state["conv"]
    u, conv_state = _causal_depthwise_conv(u, params["conv"], conv_state)

    r = jax.nn.sigmoid(jnp.einsum("bsr,rc->bsc", u, params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rc->bsc", u, params["w_i"]).astype(jnp.float32))
    log_a = -cfg.c_const * r * jax.nn.softplus(params["lambda"])
    a = jnp.exp(log_a)
    # input normalization keeps |h| bounded (Griffin eq. 4)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    bx = beta * (i * u.astype(jnp.float32))

    h0 = None if state is None else state["h"]
    h = _rglru_scan(a, bx, h0)
    y = (h.astype(x.dtype) * gate_y)
    y = jnp.einsum("bsr,rd->bsd", y, params["wo"])
    new_state = {"h": h[:, -1], "conv": conv_state}
    return y, new_state


def rglru_state(cfg: RGLRUConfig, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
    }


# =====================================================================
# mLSTM (xLSTM matrix memory) — chunkwise-parallel
# =====================================================================

@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def init_mlstm(key, cfg: MLSTMConfig, dtype):
    ks = jax.random.split(key, 8)
    di = cfg.d_inner
    params, specs = {}, {}
    params["w_up"], specs["w_up"] = init_linear(ks[0], cfg.d_model, (di,), ("embed", "ffn"), dtype)
    params["w_gate"], specs["w_gate"] = init_linear(ks[1], cfg.d_model, (di,), ("embed", "ffn"), dtype)
    params["wq"], specs["wq"] = init_linear(ks[2], di, (di,), ("ffn", None), dtype)
    params["wk"], specs["wk"] = init_linear(ks[3], di, (di,), ("ffn", None), dtype)
    params["wv"], specs["wv"] = init_linear(ks[4], di, (di,), ("ffn", None), dtype)
    params["w_if"], specs["w_if"] = init_linear(ks[5], di, (2 * cfg.n_heads,), ("ffn", None), dtype)
    params["w_down"], specs["w_down"] = init_linear(ks[6], di, (cfg.d_model,), ("ffn", "embed"), dtype)
    return params, specs


def _mlstm_chunk_scan(q, k, v, log_i, log_f, state=None):
    """Chunkwise mLSTM. q,k,v: (B,H,S,hd); log_i/log_f: (B,H,S).

    State: C (B,H,hd,hd), n (B,H,hd), m (B,H) running stabilizer.
    Returns h (B,H,S,hd) and final state.
    """
    b, h, s, hd = q.shape
    if state is None:
        C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    F = jnp.cumsum(log_f, axis=-1)                      # (B,H,S) cumulative decay

    # intra-chunk decay matrix D[t, s'] = F_t - F_s' + log_i_s'  (s' <= t)
    Dmask = jnp.tril(jnp.ones((s, s), bool))
    D = F[..., :, None] - F[..., None, :] + log_i[..., None, :]
    D = jnp.where(Dmask, D, -1e30)

    # stabilizers: running max of (F_t + m_prev-ish terms)
    m_intra = jnp.max(D, axis=-1)                       # (B,H,S)
    m_inter = F + m0[..., None]                          # carried state weight
    m_t = jnp.maximum(m_intra, m_inter)                  # (B,H,S)

    scale = hd ** -0.5
    att = jnp.einsum("bhtd,bhsd->bhts", q * scale, k).astype(jnp.float32)
    att = att * jnp.exp(D - m_t[..., None])
    h_intra = jnp.einsum("bhts,bhsd->bhtd", att, v.astype(jnp.float32))
    n_intra = jnp.sum(att, axis=-1)                      # (B,H,S) — k-sum proxy
    # inter-chunk contribution from carried C0, n0
    w_inter = jnp.exp(m_inter - m_t)                     # (B,H,S)
    h_inter = jnp.einsum("bhtd,bhde->bhte", q.astype(jnp.float32) * scale, C0) * w_inter[..., None]
    n_inter = jnp.einsum("bhtd,bhd->bht", q.astype(jnp.float32) * scale, n0) * w_inter

    denom = jnp.maximum(jnp.abs(n_intra + n_inter), jnp.exp(-m_t))
    h_out = (h_intra + h_inter) / denom[..., None]

    # state update to end of chunk
    F_end = F[..., -1:]                                  # (B,H,1)
    m_new = jnp.maximum(F_end[..., 0] + m0, jnp.max(F_end - F + log_i, axis=-1))
    wk = jnp.exp(F_end - F + log_i - m_new[..., None])   # (B,H,S)
    C_new = jnp.exp(F_end[..., 0] + m0 - m_new)[..., None, None] * C0 + jnp.einsum(
        "bhs,bhsd,bhse->bhde", wk, k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n_new = jnp.exp(F_end[..., 0] + m0 - m_new)[..., None] * n0 + jnp.einsum(
        "bhs,bhsd->bhd", wk, k.astype(jnp.float32)
    )
    return h_out, {"C": C_new, "n": n_new, "m": m_new}


def apply_mlstm(cfg: MLSTMConfig, params, x, state=None):
    """x: (B, S, D) -> (y, state). Sequence is processed in chunks."""
    b, s, _ = x.shape
    up = jnp.einsum("bsd,di->bsi", x, params["w_up"])
    gate = jax.nn.silu(jnp.einsum("bsd,di->bsi", x, params["w_gate"]))
    q = jnp.einsum("bsi,ij->bsj", up, params["wq"])
    k = jnp.einsum("bsi,ij->bsj", up, params["wk"])
    v = jnp.einsum("bsi,ij->bsj", up, params["wv"])
    gates = jnp.einsum("bsi,ig->bsg", up, params["w_if"]).astype(jnp.float32)
    log_i = jax.nn.log_sigmoid(gates[..., : cfg.n_heads])       # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., cfg.n_heads :])

    hd = cfg.head_dim

    def heads(t):  # (B,S,di) -> (B,H,S,hd)
        return t.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    log_i = log_i.transpose(0, 2, 1)
    log_f = log_f.transpose(0, 2, 1)

    ck = min(cfg.chunk, s)
    pad = (-s) % ck
    if pad:
        # pad with identity steps: i=0 (no write), f=1 (no decay) — the final
        # state is unaffected and padded outputs are trimmed below
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v = zpad(q), zpad(k), zpad(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)), constant_values=0.0)
    s_pad = s + pad
    nc = s_pad // ck

    def chunk_step(carry, inp):
        qc, kc, vc, lic, lfc = inp
        h, new_state = _mlstm_chunk_scan(qc, kc, vc, lic, lfc, carry)
        return new_state, h

    def to_chunks(t):  # (B,H,S,...) -> (nc, B,H,ck,...)
        shp = t.shape
        return t.reshape(shp[0], shp[1], nc, ck, *shp[3:]).transpose(
            2, 0, 1, 3, *range(4, t.ndim + 1)
        )

    if state is None:
        state = mlstm_state(cfg, b)
    final_state, hs = jax.lax.scan(
        chunk_step, state,
        (to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(log_i), to_chunks(log_f)),
    )
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, cfg.n_heads, s_pad, hd)[:, :, :s]
    h = h.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = jnp.einsum("bsi,id->bsd", h * gate, params["w_down"])
    return y, final_state


def mlstm_state(cfg: MLSTMConfig, batch: int):
    hd = cfg.head_dim
    return {
        "C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
    }


def step_mlstm(cfg: MLSTMConfig, params, x1, state):
    """Decode step: x1 (B, 1, D)."""
    y, new_state = apply_mlstm(
        dataclasses.replace(cfg, chunk=1), params, x1, state
    )
    return y, new_state


# =====================================================================
# sLSTM (xLSTM scalar memory) — sequential scan
# =====================================================================

@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor: float = 4.0 / 3.0
    time_chunk: int = 16
    # time_chunk: steps executed inside ONE scan iteration (unrolled).
    # The hidden->gates weight matrix is then fetched from HBM once per
    # chunk instead of once per step — the recurrence itself stays exactly
    # sequential, but weight re-streaming traffic drops by the chunk factor
    # (the FlashRNN/Haste trick at the XLA level; see EXPERIMENTS §Perf).

    @property
    def d_inner(self) -> int:
        # rounded UP to a multiple of lcm(n_heads, 64): hardware-aligned and
        # evenly shardable over a 16-way model axis (a non-divisible width
        # forces replicated recurrence weights, whose per-timestep gradient
        # all-reduces dominated the xlstm train cell — EXPERIMENTS §Perf)
        import math

        di = int(self.d_model * self.proj_factor)
        align = math.lcm(self.n_heads, 64)
        return ((di + align - 1) // align) * align


def init_slstm(key, cfg: SLSTMConfig, dtype):
    ks = jax.random.split(key, 6)
    di = cfg.d_inner
    hd = di // cfg.n_heads
    params, specs = {}, {}
    params["w_up"], specs["w_up"] = init_linear(ks[0], cfg.d_model, (di,), ("embed", "ffn"), dtype)
    # input-to-gates: z, i, f, o stacked
    params["w_gates"], specs["w_gates"] = init_linear(ks[1], di, (4 * di,), ("ffn", None), dtype)
    # hidden-to-gates recurrence: BLOCK-DIAGONAL per head (xLSTM §2.3 —
    # "multiple heads ... recurrent connections only within each head").
    # 4x fewer recurrence FLOPs/bytes than a dense di x 4di matrix, and the
    # per-timestep weight-gradient all-reduce shrinks accordingly
    # (EXPERIMENTS §Perf, xlstm cell).
    params["r_gates"] = (
        jax.random.normal(ks[2], (cfg.n_heads, hd, 4 * hd), jnp.float32)
        * (hd ** -0.5 * 0.5)
    ).astype(dtype)
    specs["r_gates"] = (None, None, None)
    params["w_down"], specs["w_down"] = init_linear(ks[3], di, (cfg.d_model,), ("ffn", "embed"), dtype)
    return params, specs


def _slstm_cell(params, di, xg, carry):
    """One timestep. xg: (B, 4*di) pre-computed input gates; carry: dict."""
    h, c, n, m = carry["h"], carry["c"], carry["n"], carry["m"]
    nh, hd, _ = params["r_gates"].shape
    b = h.shape[0]
    # per-head recurrence: (B,H,hd) x (H,hd,4hd) -> (B,H,4hd) -> (B,4di) in
    # the (z,i,f,o)-stacked layout
    rec = jnp.einsum("bhd,hdg->bhg", h.reshape(b, nh, hd),
                     params["r_gates"].astype(h.dtype))
    rec = rec.reshape(b, nh, 4, hd).transpose(0, 2, 1, 3).reshape(b, 4 * di)
    gates = xg + rec.astype(jnp.float32)
    z, i, f, o = jnp.split(gates, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_i = i  # exponential input gate (log-space value is the pre-activation)
    log_f = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * (c_new / jnp.maximum(jnp.abs(n_new), 1.0))
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def apply_slstm(cfg: SLSTMConfig, params, x, state=None):
    b, s, _ = x.shape
    di = cfg.d_inner
    up = jnp.einsum("bsd,di->bsi", x, params["w_up"])
    xg = jnp.einsum("bsi,ig->bsg", up, params["w_gates"]).astype(jnp.float32)
    if state is None:
        state = slstm_state(cfg, b)

    # exact chunking: the largest divisor of s not exceeding time_chunk, so
    # no padded pseudo-steps ever touch the recurrent state
    tc = 1
    for cand in range(min(cfg.time_chunk, s), 0, -1):
        if s % cand == 0:
            tc = cand
            break
    xg_c = xg.transpose(1, 0, 2).reshape(s // tc, tc, b, 4 * di)

    def chunk_step(carry, xg_chunk):
        hs = []
        st = carry
        for t in range(tc):  # unrolled: w_gates/r_gates read once per chunk
            st = _slstm_cell(params, di, xg_chunk[t], st)
            hs.append(st["h"])
        return st, jnp.stack(hs)

    final, hs = jax.lax.scan(chunk_step, state, xg_c)
    h = hs.reshape(s, b, di).transpose(1, 0, 2).astype(x.dtype)
    y = jnp.einsum("bsi,id->bsd", h, params["w_down"])
    return y, final


def slstm_state(cfg: SLSTMConfig, batch: int):
    di = cfg.d_inner
    z = jnp.zeros((batch, di), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, di), -1e30, jnp.float32)}
