"""Whisper-style encoder–decoder backbone.

Per the assignment, the audio frontend (log-mel + strided convs) is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, T, d_model); a
single linear ``frontend_proj`` stands in for the conv stack (documented in
DESIGN.md §4). Encoder layers are bidirectional; decoder layers are
causal self-attention + cross-attention over the encoder output + MLP.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import blocks as B
from repro.models.layers import (
    MLPConfig, apply_mlp, apply_norm, init_embedding, init_linear, init_mlp, init_norm,
)
from repro.models.transformer import embed_tokens, logits_from


def _self_cfg(cfg, causal):
    return A.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=None, causal=causal, kv_chunk=cfg.kv_chunk,
    )


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params, specs = {}, {}
    params["norm1"], specs["norm1"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)
    params["self"], specs["self"] = A.init_attention(k1, _self_cfg(cfg, True), dtype)
    params["norm_x"], specs["norm_x"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)
    params["cross"], specs["cross"] = A.init_attention(k2, _self_cfg(cfg, False), dtype)
    params["norm2"], specs["norm2"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)
    params["mlp"], specs["mlp"] = init_mlp(k3, MLPConfig(cfg.mlp_kind, cfg.d_model, cfg.d_ff), dtype)
    return params, specs


def init_params(cfg, key):
    dtype = cfg.jnp_dtype
    keys = jax.random.split(key, 6)
    params, specs = {}, {}
    params["emb"], specs["emb"] = init_embedding(keys[0], cfg.vocab, cfg.d_model, dtype)
    params["frontend_proj"], specs["frontend_proj"] = init_linear(
        keys[1], cfg.d_model, (cfg.d_model,), ("embed", "embed_out"), dtype
    )
    params["final_norm"], specs["final_norm"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)

    enc_keys = jax.random.split(keys[2], cfg.enc_layers)
    _, enc_spec1 = B.block_init("enc+mlp", enc_keys[0], cfg, dtype)
    params["enc"] = jax.vmap(lambda k: B.block_init("enc+mlp", k, cfg, dtype)[0])(enc_keys)
    specs["enc"] = jax.tree.map(lambda ax: (None,) + tuple(ax), enc_spec1,
                                is_leaf=lambda x: isinstance(x, tuple))

    dec_keys = jax.random.split(keys[3], cfg.dec_layers)
    _, dec_spec1 = _dec_layer_init(dec_keys[0], cfg, dtype)
    params["dec"] = jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype)[0])(dec_keys)
    specs["dec"] = jax.tree.map(lambda ax: (None,) + tuple(ax), dec_spec1,
                                is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


def _maybe_remat(cfg, fn):
    from repro.models.transformer import _maybe_remat as _mr

    return _mr(cfg, fn)


def encode(cfg, params, frames):
    """frames: (B, T, d_model) stub embeddings -> encoder states."""
    x = jnp.einsum("btd,de->bte", frames.astype(cfg.jnp_dtype), params["frontend_proj"])
    positions = jnp.arange(x.shape[1])

    def body(carry, p):
        x, = carry
        x, _, _ = B.block_apply("enc+mlp", cfg, p, x, positions)
        return (x,), None

    (x,), _ = jax.lax.scan(_maybe_remat(cfg, body), (x,), params["enc"])
    return x


def _dec_layer(cfg, p, x, enc_kv, positions, cache=None, decode=False):
    scfg = _self_cfg(cfg, True)
    xcfg = _self_cfg(cfg, False)
    h = apply_norm(cfg.norm_kind, p["norm1"], x)
    if decode:
        q, k1, v1 = A.project_qkv(scfg, p["self"], h, positions[:, None])
        cache = B._append_kv_cache(cache, k1, v1, positions)
        kd, vd = B._cache_kv_views(cfg, cache)
        attn = A.decode_attention(scfg, q, kd, vd, positions, cache["slot_pos"])
    else:
        q, k, v = A.project_qkv(scfg, p["self"], h, positions[None, :])
        if x.shape[1] > cfg.kv_chunk:
            attn = A.attention_chunked(scfg, q, k, v, positions, positions)
        else:
            attn = A.attention_full(scfg, q, k, v, positions, positions)
        if cache is not None:
            cache = B._fill_kv_cache(cache, k, v, positions)
    x = x + A.output_proj(scfg, p["self"], attn)

    # cross attention over (precomputed) encoder keys/values — chunked
    # online-softmax when the decoder side is long (train_4k: sq=4096)
    h = apply_norm(cfg.norm_kind, p["norm_x"], x)
    qx = jnp.einsum("bsd,dhe->bshe", h, p["cross"]["wq"])
    ek, ev = enc_kv
    sq = h.shape[1]
    enc_pos = jnp.arange(ek.shape[1])
    q_pos = positions if (decode and positions.ndim == 1) else jnp.arange(sq)
    if sq * ek.shape[1] > cfg.kv_chunk * cfg.kv_chunk:
        xout = A.attention_chunked(xcfg, qx, ek, ev, q_pos, enc_pos)
    else:
        xout = A.attention_full(xcfg, qx, ek, ev, q_pos, enc_pos)
    x = x + A.output_proj(xcfg, p["cross"], xout)

    h = apply_norm(cfg.norm_kind, p["norm2"], x)
    x = x + apply_mlp(MLPConfig(cfg.mlp_kind, cfg.d_model, cfg.d_ff), p["mlp"], h)
    return x, cache


def _enc_kv(cfg, params, enc_out):
    """Precompute per-decoder-layer cross K/V (stacked over layers)."""
    xcfg = _self_cfg(cfg, False)

    def one(p):
        k = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wk"])
        v = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wv"])
        return k, v

    return jax.vmap(one)(params["dec"])  # (L, B, T, Hkv, hd) pair


def decoder_forward(cfg, params, tokens, enc_out, caches=None, decode=False, pos=None):
    x = embed_tokens(cfg, params, tokens)
    positions = pos if decode else jnp.arange(x.shape[1])
    ek, ev = _enc_kv(cfg, params, enc_out)

    have_cache = caches is not None

    def body(carry, xs):
        x, = carry
        if have_cache:
            p, ekl, evl, c = xs
        else:
            p, ekl, evl = xs
            c = None
        x, nc = _dec_layer(cfg, p, x, (ekl, evl), positions, cache=c, decode=decode)
        return (x,), (nc if have_cache else 0)

    xs = (params["dec"], ek, ev) + ((caches,) if have_cache else ())
    scan_body = body if (decode or have_cache) else _maybe_remat(cfg, body)
    (x,), ys = jax.lax.scan(scan_body, (x,), xs)
    x = apply_norm(cfg.norm_kind, params["final_norm"], x)
    return logits_from(cfg, params, x), (ys if have_cache else None)


def loss_fn(cfg, params, batch, mesh=None):
    """batch: frames (B,T,D), tokens (B,S), labels (B,S)."""
    enc_out = encode(cfg, params, batch["frames"])
    logits, _ = decoder_forward(cfg, params, batch["tokens"], enc_out)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce, {"ce": ce, "aux": jnp.zeros(())}


def init_dec_caches(cfg, batch: int, max_seq: int):
    one = {
        "k": jnp.zeros((batch, max_seq, cfg.n_kv, cfg.head_dim), cfg.jnp_dtype),
        "v": jnp.zeros((batch, max_seq, cfg.n_kv, cfg.head_dim), cfg.jnp_dtype),
        "slot_pos": jnp.full((batch, max_seq), -1, jnp.int32),
    }
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.dec_layers,) + a.shape), one)


def prefill(cfg, params, frames, tokens, max_seq: int):
    enc_out = encode(cfg, params, frames)
    caches = init_dec_caches(cfg, tokens.shape[0], max_seq)
    logits, caches = decoder_forward(cfg, params, tokens, enc_out, caches=caches)
    return logits[:, -1:], caches, enc_out


def decode_step(cfg, params, caches, enc_out, tokens1, pos):
    logits, caches = decoder_forward(
        cfg, params, tokens1, enc_out, caches=caches, decode=True, pos=pos
    )
    return logits, caches
