"""GQA attention: projections, chunked online-softmax, KV caches, and the
sequence-sharded split-KV decode combine (FlashDecoding adapted to the mesh).

Memory discipline: prefill/train attention over long sequences uses a
lax.scan over KV chunks with running (max, denom, acc) statistics — exact
softmax with O(S * chunk) live memory instead of O(S^2), which is what lets
the 32k-prefill cells compile within a v5e's HBM without a fused kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import apply_rope, init_linear
from repro.runtime.jax_compat import shard_map as compat_shard_map

NEG_INF = -1.0e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding-window size (None = global)
    causal: bool = True
    q_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    kv_chunk: int = 1024             # online-softmax chunk length


def init_attention(key, cfg: AttnConfig, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    params, specs = {}, {}
    params["wq"], specs["wq"] = init_linear(
        kq, cfg.d_model, (cfg.n_heads, cfg.head_dim), ("embed", "heads", "head_dim"), dtype
    )
    params["wk"], specs["wk"] = init_linear(
        kk, cfg.d_model, (cfg.n_kv, cfg.head_dim), ("embed", "kv_heads", "head_dim"), dtype
    )
    params["wv"], specs["wv"] = init_linear(
        kv, cfg.d_model, (cfg.n_kv, cfg.head_dim), ("embed", "kv_heads", "head_dim"), dtype
    )
    params["wo"], specs["wo"] = init_linear(
        ko, cfg.n_heads * cfg.head_dim, (cfg.d_model,), ("heads_flat", "embed"), dtype,
        scale=(cfg.n_heads * cfg.head_dim) ** -0.5,
    )
    return params, specs


def project_qkv(cfg: AttnConfig, params, x, positions):
    """x: (B, S, D) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd), RoPE applied."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def output_proj(cfg: AttnConfig, params, attn_out):
    b, s = attn_out.shape[:2]
    flat = attn_out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsf,fd->bsd", flat, params["wo"])


# ----------------------------------------------------------- full attention

def _expand_gqa(q, n_kv):
    """(B,S,Hq,hd) -> (B,S,Hkv,G,hd)."""
    b, s, hq, hd = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, hd)


def attention_full(cfg: AttnConfig, q, k, v, q_positions, kv_positions):
    """Materialized-scores attention (short sequences / reference oracle)."""
    scale = cfg.q_scale or cfg.head_dim ** -0.5
    qg = _expand_gqa(q * scale, cfg.n_kv)
    scores = jnp.einsum("bqhge,bkhe->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if cfg.causal:
        mask &= q_positions[:, None] >= kv_positions[None, :]
    if cfg.window is not None:
        mask &= (q_positions[:, None] - kv_positions[None, :]) < cfg.window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhe->bqhge", probs, v)
    b, s = q.shape[:2]
    return out.reshape(b, s, cfg.n_heads, cfg.head_dim)


def attention_chunked(cfg: AttnConfig, q, k, v, q_positions, kv_positions):
    """Exact attention with online softmax over KV chunks (O(S) memory).

    Sliding-window chunks that fall fully outside the causal/window band are
    still scanned (static shapes) but contribute exp(-inf)=0; the HLO is one
    compact scan regardless of sequence length.
    """
    scale = cfg.q_scale or cfg.head_dim ** -0.5
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    ck = min(cfg.kv_chunk, sk)
    n_chunks = (sk + ck - 1) // ck
    pad = n_chunks * ck - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-(10 ** 9))
    kc = k.reshape(b, n_chunks, ck, cfg.n_kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, ck, cfg.n_kv, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(n_chunks, ck)
    qg = _expand_gqa(q * scale, cfg.n_kv)  # (b, sq, hkv, g, hd)

    def step(carry, chunk):
        m, l, acc = carry
        kb, vb, pb = chunk
        s = jnp.einsum("bqhge,bkhe->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((sq, ck), bool)
        if cfg.causal:
            mask &= q_positions[:, None] >= pb[None, :]
        if cfg.window is not None:
            mask &= (q_positions[:, None] - pb[None, :]) < cfg.window
        mask &= pb[None, :] >= 0  # padding chunk entries
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhe->bhgqe", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    g = hq // cfg.n_kv
    m0 = jnp.full((b, cfg.n_kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, cfg.n_kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, cfg.n_kv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, cfg.n_heads, hd)
    return out.astype(q.dtype)


def attention_chunked_q(cfg: AttnConfig, q, k, v, q_positions, kv_positions,
                        q_chunk: int):
    """Doubly-chunked attention: an outer (unrolled) loop over query chunks,
    each attending only the KV range its causal/window band can reach.

    vs attention_chunked (full q x all KV chunks): (a) masked-out (q, kv)
    chunk pairs are STATICALLY skipped — for causal attention that halves
    score FLOPs and KV re-reads; for sliding windows it makes them O(S * W);
    (b) the online-softmax accumulator shrinks from O(S_q * hd) carried
    through every KV step to O(q_chunk * hd), VMEM-resident on TPU.
    """
    b, sq, hq, hd = q.shape
    nq = (sq + q_chunk - 1) // q_chunk
    outs = []
    for i in range(nq):
        lo_q = i * q_chunk
        hi_q = min(sq, (i + 1) * q_chunk)
        # the band of kv positions this q chunk can see (positions are
        # arange in train/prefill, so index == position)
        hi_k = hi_q if cfg.causal else k.shape[1]
        lo_k = 0
        if cfg.window is not None:
            lo_k = max(0, lo_q - cfg.window + 1)
        lo_k = (lo_k // cfg.kv_chunk) * cfg.kv_chunk  # align to kv chunks
        out = attention_chunked(
            cfg, q[:, lo_q:hi_q], k[:, lo_k:hi_k], v[:, lo_k:hi_k],
            q_positions[lo_q:hi_q], kv_positions[lo_k:hi_k],
        )
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


# ------------------------------------------------------------------- decode

def decode_attention(cfg: AttnConfig, q, k_cache, v_cache, pos, slot_positions):
    """Single-token attention over a cache.

    q: (B, 1, Hq, hd); caches: (B, S_cache, Hkv, hd); pos: (B,) current
    position; slot_positions: (B, S_cache) absolute position stored in each
    slot (-1 = empty). Works for both full and rolling (windowed) caches.
    """
    scale = cfg.q_scale or cfg.head_dim ** -0.5
    qg = _expand_gqa(q * scale, cfg.n_kv)[:, 0]  # (B, Hkv, G, hd)
    s = jnp.einsum("bhge,bkhe->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    valid = (slot_positions >= 0) & (slot_positions <= pos[:, None])
    if cfg.window is not None:
        valid &= (pos[:, None] - slot_positions) < cfg.window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhe->bhge", p, v_cache)
    b = q.shape[0]
    return out.reshape(b, 1, cfg.n_heads, cfg.head_dim)


def decode_append_attend_seqsharded(
    cfg: AttnConfig, mesh, axis: str,
    q, k1, v1, k_cache, v_cache, pos, slot_positions,
    batch_axis=None,
):
    """Split-KV decode with in-shard cache append.

    The cache's sequence dim is sharded over `axis`. The new token's K/V is
    written by the one shard that owns its slot (a purely local scatter — a
    global scatter over a sharded dim would make GSPMD all-gather the cache),
    then each shard computes partial (max, denom, weighted-V) statistics and
    the exact softmax is reassembled with pmax/psum — FlashDecoding across
    chips. Per-token collective volume is O(B * Hq * hd), not
    O(S_cache * Hkv * hd). Returns (attn_out, new_k, new_v, new_slot_pos).
    """
    scale = cfg.q_scale or cfg.head_dim ** -0.5
    s_total = k_cache.shape[1]

    def partial_fn(q, k1, v1, k_cache, v_cache, pos, slot_positions):
        s_local = k_cache.shape[1]
        shard = jax.lax.axis_index(axis)
        b = q.shape[0]
        bidx = jnp.arange(b)
        slot = (pos % s_total).astype(jnp.int32)
        local = slot - shard * s_local
        mine = (local >= 0) & (local < s_local)
        local_c = jnp.clip(local, 0, s_local - 1)
        old_k = k_cache[bidx, local_c]
        old_v = v_cache[bidx, local_c]
        old_sp = slot_positions[bidx, local_c]
        k_cache = k_cache.at[bidx, local_c].set(
            jnp.where(mine[:, None, None], k1[:, 0], old_k))
        v_cache = v_cache.at[bidx, local_c].set(
            jnp.where(mine[:, None, None], v1[:, 0], old_v))
        slot_positions = slot_positions.at[bidx, local_c].set(
            jnp.where(mine, pos.astype(jnp.int32), old_sp))

        qg = _expand_gqa(q * scale, cfg.n_kv)[:, 0]
        s = jnp.einsum("bhge,bkhe->bhgk", qg, k_cache,
                       preferred_element_type=jnp.float32)
        valid = (slot_positions >= 0) & (slot_positions <= pos[:, None])
        if cfg.window is not None:
            valid &= (pos[:, None] - slot_positions) < cfg.window
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                       # (B,Hkv,G)
        m_glob = jax.lax.pmax(m_loc, axis)
        p = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bhgk,bkhe->bhge", p.astype(q.dtype), v_cache,
                           preferred_element_type=jnp.float32)
        l_glob = jax.lax.psum(l_loc, axis)
        o_glob = jax.lax.psum(o_loc, axis)
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim).astype(q.dtype)
        return out, k_cache, v_cache, slot_positions

    ba = batch_axis
    return compat_shard_map(
        partial_fn,
        mesh,
        in_specs=(P(ba), P(ba), P(ba), P(ba, axis), P(ba, axis), P(ba),
                  P(ba, axis)),
        out_specs=(P(ba), P(ba, axis), P(ba, axis), P(ba, axis)),
    )(q, k1, v1, k_cache, v_cache, pos, slot_positions)
