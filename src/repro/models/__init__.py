from repro.models.model import ModelConfig, build_model

__all__ = ["ModelConfig", "build_model"]
