"""ModelConfig + the build_model() entry point used by configs/, launch/,
tests and benchmarks."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as E
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv: int
    d_ff: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    arch_type: str = "decoder"              # decoder | encdec
    pattern: tuple = ("attn+mlp",)
    mlp_kind: str = "swiglu"
    norm_kind: str = "rms"
    rope_theta: float = 10000.0
    window: int = 1024                      # sliding-window size for "local+*"
    kv_chunk: int = 1024                    # online-softmax chunk
    q_chunk: int = 2048                     # doubly-chunked attention with
                                            # static causal/window chunk skip
                                            # (0 disables; see §Perf)
    rnn_chunk: int = 256                    # mLSTM chunk
    slstm_tchunk: int = 16                  # sLSTM steps per scan iteration
    dtype: str = "bfloat16"
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_expert: int = 0
    moe_shared: int = 0
    moe_pad_to: Optional[int] = None
    moe_capacity: float = 1.25
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # vision prefix (vlm)
    prefix_len: int = 0
    # sub-quadratic eligibility (long_500k cells)
    subquadratic: bool = False
    # distributed decode
    decode_seq_shard: bool = False
    decode_seq_axis: str = "model"
    decode_batch_axes: Optional[str] = "data"
    # KV-cache quantization: "model" (= model dtype) | "int8" (per-token,
    # per-head symmetric scales; halves at-rest cache bytes — the capacity
    # lever for fat-KV decode cells, see EXPERIMENTS §Dry-run)
    kv_cache_dtype: str = "model"
    # training
    remat: str = "full"                     # none | dots | full

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    def n_params(self) -> int:
        """Analytic parameter count (embedding counted once: tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab * d
        counts = {
            "attn": d * hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * hd * d,
            "mlp": d * self.d_ff * (3 if self.mlp_kind in ("swiglu", "geglu") else 2),
            "moe": (self.moe_pad_to or self.moe_experts) * 3 * d * self.moe_d_expert
                   + d * (self.moe_pad_to or self.moe_experts)
                   + (3 * d * self.moe_shared * self.moe_d_expert if self.moe_shared else 0),
            "rglru": 3 * d * d + 2 * d * d,      # wx, wy, wo + gates
            "mlstm": 2 * d * int(2.0 * d) + 3 * (2 * d) ** 2 + 2 * d * d,
            "slstm": d * int(4 * d / 3) * (1 + 4 + 4) + int(4 * d / 3) * d,
        }
        if self.arch_type == "encdec":
            per = counts["attn"] + counts["mlp"]
            return total + self.enc_layers * per + self.dec_layers * (2 * counts["attn"] + counts["mlp"])
        for i in range(self.n_layers):
            kind = self.pattern[i % len(self.pattern)]
            if kind in ("attn+mlp", "local+mlp", "enc+mlp"):
                total += counts["attn"] + counts["mlp"]
            elif kind == "attn+moe":
                total += counts["attn"] + counts["moe"]
            elif kind == "rglru+mlp":
                total += counts["rglru"] + counts["mlp"]
            elif kind == "mlstm":
                total += counts["mlstm"]
            elif kind == "slstm":
                total += counts["slstm"]
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if not self.moe_experts:
            return self.n_params()
        full = self.n_params()
        e = self.moe_pad_to or self.moe_experts
        moe_layers = sum(
            1 for i in range(self.n_layers)
            if self.pattern[i % len(self.pattern)] == "attn+moe"
        )
        routed_all = moe_layers * e * 3 * self.d_model * self.moe_d_expert
        routed_active = moe_layers * self.moe_top_k * 3 * self.d_model * self.moe_d_expert
        return full - routed_all + routed_active


class Model:
    """Thin dispatcher over the decoder / encdec implementations."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._mod = E if cfg.arch_type == "encdec" else T

    def init(self, key):
        params, specs = self._mod.init_params(self.cfg, key)
        self.param_logical_specs = specs
        return params

    def param_specs(self):
        """(ShapeDtypeStruct pytree, logical-axes pytree) — no allocation."""
        return _trace_specs(self._mod, self.cfg)

    def loss_fn(self, params, batch, mesh=None):
        return self._mod.loss_fn(self.cfg, params, batch, mesh=mesh)

    # decoder-only conveniences
    def forward(self, params, tokens, **kw):
        return T.forward(self.cfg, params, tokens, **kw)

    def prefill(self, params, *args, **kw):
        return self._mod.prefill(self.cfg, params, *args, **kw)

    def decode_step(self, params, *args, **kw):
        return self._mod.decode_step(self.cfg, params, *args, **kw)

    def init_caches(self, batch, max_seq):
        if self.cfg.arch_type == "encdec":
            return E.init_dec_caches(self.cfg, batch, max_seq)
        return T.init_caches(self.cfg, batch, max_seq)


_SPEC_CACHE: dict = {}


def _trace_specs(mod, cfg):
    key = (mod.__name__, cfg.name, cfg.n_layers, cfg.d_model)
    if key not in _SPEC_CACHE:
        # init on the abstract level only: eval_shape avoids allocation, but
        # specs are plain python produced alongside; run init under eval_shape
        # and capture specs via closure.
        holder = {}

        def _init(k):
            p, s = mod.init_params(cfg, k)
            holder["specs"] = s
            return p

        shapes = jax.eval_shape(_init, jax.random.PRNGKey(0))
        _SPEC_CACHE[key] = (shapes, holder["specs"])
    return _SPEC_CACHE[key]


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct pytree, logical-axes pytree) without allocating."""
    mod = E if cfg.arch_type == "encdec" else T
    return _trace_specs(mod, cfg)
