"""Decoder-only LM assembled from a layer-kind pattern, scanned over depth.

Depth is expressed as full *cycles* of the pattern executed under
``jax.lax.scan`` (stacked parameters, compact HLO independent of layer count)
plus an unrolled remainder when ``n_layers % len(pattern) != 0``. This is the
property that keeps 80-layer x 512-device dry-run compiles fast.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.layers import apply_norm, init_embedding, init_norm


def _layer_plan(cfg):
    c = len(cfg.pattern)
    n_cycles = cfg.n_layers // c
    rem = cfg.n_layers - n_cycles * c
    return n_cycles, [cfg.pattern[i] for i in range(rem)]


def init_params(cfg, key):
    dtype = cfg.jnp_dtype
    n_cycles, rem_kinds = _layer_plan(cfg)
    keys = jax.random.split(key, 3 + len(cfg.pattern) + len(rem_kinds))
    params, specs = {}, {}
    params["emb"], specs["emb"] = init_embedding(keys[0], cfg.vocab, cfg.d_model, dtype)
    params["final_norm"], specs["final_norm"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)

    cyc_params, cyc_specs = [], []
    for j, kind in enumerate(cfg.pattern):
        _, spec1 = B.block_init(kind, keys[3 + j], cfg, dtype)
        layer_keys = jax.random.split(keys[3 + j], max(n_cycles, 1))
        if n_cycles > 0:
            stacked = jax.vmap(lambda k: B.block_init(kind, k, cfg, dtype)[0])(layer_keys)
        else:
            stacked = None
        cyc_params.append(stacked)
        cyc_specs.append(jax.tree.map(lambda ax: (None,) + tuple(ax), spec1,
                                      is_leaf=lambda x: isinstance(x, tuple)))
    params["cycles"] = cyc_params
    specs["cycles"] = cyc_specs

    rem_params, rem_specs = [], []
    for i, kind in enumerate(rem_kinds):
        p, s = B.block_init(kind, keys[3 + len(cfg.pattern) + i], cfg, dtype)
        rem_params.append(p)
        rem_specs.append(s)
    params["rem"] = rem_params
    specs["rem"] = rem_specs
    return params, specs


# ---------------------------------------------------------------------------

def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    if cfg.remat == "save_tp":
        # save the post-all-reduce activations: the backward recompute pass
        # then contains ZERO tensor-parallel collectives (1/3 of the TP
        # all-reduce volume under full remat), at +2 saved activations/layer
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "tp_attn_out", "tp_mlp_out"
            ),
        )
    raise ValueError(cfg.remat)


def _run_layers(cfg, params, x, positions, caches=None, decode=False, mesh=None):
    """Shared depth loop. caches: None | {'cycles': [...], 'rem': [...]}"""
    n_cycles, rem_kinds = _layer_plan(cfg)
    aux = jnp.zeros((), jnp.float32)

    if n_cycles > 0:
        have_cache = caches is not None

        def cycle_body(carry, xs):
            x, aux = carry
            cyc_p = xs[0]
            cyc_c = xs[1] if have_cache else [None] * len(cfg.pattern)
            new_caches = []
            for j, kind in enumerate(cfg.pattern):
                x, nc, a = B.block_apply(
                    kind, cfg, cyc_p[j], x, positions,
                    cache=cyc_c[j], decode=decode, mesh=mesh,
                )
                aux = aux + a
                new_caches.append(nc)
            return (x, aux), (tuple(new_caches) if have_cache else 0)

        body = _maybe_remat(cfg, cycle_body) if not decode and caches is None else cycle_body
        xs = (tuple(params["cycles"]),)
        if have_cache:
            xs = xs + (tuple(caches["cycles"]),)
        (x, aux), ys = jax.lax.scan(body, (x, aux), xs)
        new_cycle_caches = list(ys) if have_cache else None
    else:
        new_cycle_caches = caches["cycles"] if caches is not None else None

    new_rem = []
    for i, kind in enumerate(rem_kinds):
        c = caches["rem"][i] if caches is not None else None
        x, nc, a = B.block_apply(
            kind, cfg, params["rem"][i], x, positions, cache=c, decode=decode, mesh=mesh
        )
        aux = aux + a
        new_rem.append(nc)

    new_caches = None
    if caches is not None:
        new_caches = {"cycles": new_cycle_caches, "rem": new_rem}
    return x, new_caches, aux


def embed_tokens(cfg, params, tokens):
    x = jnp.take(params["emb"], tokens, axis=0).astype(cfg.jnp_dtype)
    return x * math.sqrt(cfg.d_model)


def logits_from(cfg, params, x):
    return jnp.einsum("bsd,vd->bsv", x, params["emb"]).astype(jnp.float32)


def forward(cfg, params, tokens, prefix_embeds: Optional[jnp.ndarray] = None,
            caches=None, mesh=None, logits_positions: Optional[str] = None):
    """Full-sequence forward. Returns (logits, new_caches, aux).

    logits_positions="last" computes logits for the final position only —
    the prefill path, where the (B, S, V) logit tensor would otherwise be
    the single largest compute+traffic term.
    """
    x = embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, new_caches, aux = _run_layers(cfg, params, x, positions, caches=caches, mesh=mesh)
    if logits_positions == "last":
        x = x[:, -1:]
    x = apply_norm(cfg.norm_kind, params["final_norm"], x)
    return logits_from(cfg, params, x), new_caches, aux


def loss_fn(cfg, params, batch, mesh=None):
    """Next-token cross entropy (+ MoE aux). batch: tokens, labels[, prefix]."""
    logits, _, aux = forward(
        cfg, params, batch["tokens"], prefix_embeds=batch.get("prefix_embeds"), mesh=mesh
    )
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vision prefix: score text positions only
        logits = logits[:, -labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


def init_caches(cfg, batch: int, max_seq: int):
    n_cycles, rem_kinds = _layer_plan(cfg)
    dtype = cfg.jnp_dtype

    def stack_cache(kind):
        one = B.block_cache(kind, cfg, batch, max_seq, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_cycles,) + a.shape), one)

    cycles = [stack_cache(kind) for kind in cfg.pattern] if n_cycles else []
    rem = [B.block_cache(kind, cfg, batch, max_seq, dtype) for kind in rem_kinds]
    return {"cycles": cycles, "rem": rem}


def prefill(cfg, params, tokens, max_seq: int,
            prefix_embeds: Optional[jnp.ndarray] = None, mesh=None):
    caches = init_caches(cfg, tokens.shape[0], max_seq)
    logits, caches, _ = forward(
        cfg, params, tokens, prefix_embeds=prefix_embeds, caches=caches,
        mesh=mesh, logits_positions="last",
    )
    return logits, caches


def decode_step(cfg, params, caches, tokens1, pos, mesh=None):
    """tokens1: (B, 1) new token ids; pos: (B,) absolute positions."""
    x = embed_tokens(cfg, params, tokens1)
    x, new_caches, _ = _run_layers(cfg, params, x, pos, caches=caches, decode=True, mesh=mesh)
    x = apply_norm(cfg.norm_kind, params["final_norm"], x)
    return logits_from(cfg, params, x), new_caches
