"""Layer-kind blocks: pre-norm residual compositions of the sub-layers.

A model's depth structure is a *pattern* — a tuple of layer kinds cycled over
``n_layers`` (e.g. gemma3's ``("local+mlp",)*5 + ("attn+mlp",)``). Each kind
knows how to init, apply over a full sequence (train/prefill, filling a
cache), and apply a single decode step against its cache.

Block kinds:
  attn+mlp    global causal attention + dense MLP
  local+mlp   sliding-window attention + dense MLP
  enc+mlp     bidirectional attention + dense MLP (encoder layers)
  attn+moe    global causal attention + routed MoE
  rglru+mlp   RG-LRU recurrence + dense MLP (RecurrentGemma)
  mlstm       xLSTM matrix-memory block (self-contained projections)
  slstm       xLSTM scalar-memory block
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.layers import MLPConfig, apply_mlp, apply_norm, init_mlp, init_norm


def _attn_cfg(cfg, window=None) -> A.AttnConfig:
    return A.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=window, causal=True, kv_chunk=cfg.kv_chunk,
    )


def _mlp_cfg(cfg) -> MLPConfig:
    return MLPConfig(cfg.mlp_kind, cfg.d_model, cfg.d_ff)


def _rglru_cfg(cfg) -> R.RGLRUConfig:
    return R.RGLRUConfig(d_model=cfg.d_model, d_rnn=cfg.d_model)


def _mlstm_cfg(cfg) -> R.MLSTMConfig:
    return R.MLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads, chunk=cfg.rnn_chunk)


def _slstm_cfg(cfg) -> R.SLSTMConfig:
    return R.SLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                         time_chunk=cfg.slstm_tchunk)


def _moe_cfg(cfg) -> M.MoEConfig:
    return M.MoEConfig(
        d_model=cfg.d_model, n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
        d_expert=cfg.moe_d_expert, n_shared=cfg.moe_shared,
        pad_experts_to=cfg.moe_pad_to, mlp_kind=cfg.mlp_kind,
        capacity_factor=cfg.moe_capacity,
    )


# ---------------------------------------------------------------------- init

def block_init(kind: str, key, cfg, dtype):
    keys = jax.random.split(key, 4)
    params, specs = {}, {}
    params["norm1"], specs["norm1"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)
    if kind in ("attn+mlp", "local+mlp", "enc+mlp", "attn+moe"):
        window = cfg.window if kind == "local+mlp" else None
        params["attn"], specs["attn"] = A.init_attention(keys[0], _attn_cfg(cfg, window), dtype)
        params["norm2"], specs["norm2"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)
        if kind == "attn+moe":
            params["moe"], specs["moe"] = M.init_moe(keys[1], _moe_cfg(cfg), dtype)
        else:
            params["mlp"], specs["mlp"] = init_mlp(keys[1], _mlp_cfg(cfg), dtype)
    elif kind == "rglru+mlp":
        params["rglru"], specs["rglru"] = R.init_rglru(keys[0], _rglru_cfg(cfg), dtype)
        params["norm2"], specs["norm2"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)
        params["mlp"], specs["mlp"] = init_mlp(keys[1], _mlp_cfg(cfg), dtype)
    elif kind == "mlstm":
        params["mlstm"], specs["mlstm"] = R.init_mlstm(keys[0], _mlstm_cfg(cfg), dtype)
    elif kind == "slstm":
        params["slstm"], specs["slstm"] = R.init_slstm(keys[0], _slstm_cfg(cfg), dtype)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return params, specs


# --------------------------------------------------------------------- cache

def _quantize_kv(t):
    """Per-(token, head) symmetric int8: t (..., hd) -> (int8, f32 scale)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def block_cache(kind: str, cfg, batch: int, max_seq: int, dtype):
    """Allocate an empty decode cache for one layer of this kind."""
    if kind in ("attn+mlp", "attn+moe", "enc+mlp"):
        s_c = max_seq
    elif kind == "local+mlp":
        s_c = min(max_seq, cfg.window)
    elif kind == "rglru+mlp":
        return R.rglru_state(_rglru_cfg(cfg), batch, dtype)
    elif kind == "mlstm":
        return R.mlstm_state(_mlstm_cfg(cfg), batch)
    elif kind == "slstm":
        return R.slstm_state(_slstm_cfg(cfg), batch)
    else:
        raise ValueError(kind)
    cache = {
        "slot_pos": jnp.full((batch, s_c), -1, jnp.int32),
    }
    if cfg.kv_cache_dtype == "int8":
        cache["k"] = jnp.zeros((batch, s_c, cfg.n_kv, cfg.head_dim), jnp.int8)
        cache["v"] = jnp.zeros((batch, s_c, cfg.n_kv, cfg.head_dim), jnp.int8)
        cache["k_scale"] = jnp.zeros((batch, s_c, cfg.n_kv), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, s_c, cfg.n_kv), jnp.float32)
    else:
        cache["k"] = jnp.zeros((batch, s_c, cfg.n_kv, cfg.head_dim), dtype)
        cache["v"] = jnp.zeros((batch, s_c, cfg.n_kv, cfg.head_dim), dtype)
    return cache


def _cache_kv_views(cfg, cache):
    """Dequantized (k, v) views of a cache (no-op for non-quantized)."""
    if "k_scale" in cache:
        dt = cfg.jnp_dtype
        return (_dequantize_kv(cache["k"], cache["k_scale"], dt),
                _dequantize_kv(cache["v"], cache["v_scale"], dt))
    return cache["k"], cache["v"]


def _fill_kv_cache(cache, k, v, positions):
    """Write a full-sequence prefill into a (possibly rolling) cache."""
    quant = "k_scale" in cache
    if quant:
        k, k_s = _quantize_kv(k)
        v, v_s = _quantize_kv(v)
    b, s = k.shape[:2]
    s_c = cache["k"].shape[1]
    out = {}
    if s >= s_c:
        # keep the last s_c entries, placed at slot = pos % s_c
        pos_tail = positions[-s_c:]
        slots = (pos_tail % s_c).astype(jnp.int32)
        out["k"] = jnp.zeros_like(cache["k"]).at[:, slots].set(k[:, -s_c:])
        out["v"] = jnp.zeros_like(cache["v"]).at[:, slots].set(v[:, -s_c:])
        out["slot_pos"] = jnp.full_like(cache["slot_pos"], -1).at[:, slots].set(
            pos_tail[None, :].astype(jnp.int32)
        )
        if quant:
            out["k_scale"] = jnp.zeros_like(cache["k_scale"]).at[:, slots].set(k_s[:, -s_c:])
            out["v_scale"] = jnp.zeros_like(cache["v_scale"]).at[:, slots].set(v_s[:, -s_c:])
    else:
        slots = (positions % s_c).astype(jnp.int32)
        out["k"] = cache["k"].at[:, slots].set(k)
        out["v"] = cache["v"].at[:, slots].set(v)
        out["slot_pos"] = cache["slot_pos"].at[:, slots].set(
            positions[None, :].astype(jnp.int32))
        if quant:
            out["k_scale"] = cache["k_scale"].at[:, slots].set(k_s)
            out["v_scale"] = cache["v_scale"].at[:, slots].set(v_s)
    return out


def _append_kv_cache(cache, k1, v1, pos):
    """Decode-step write. k1/v1: (B,1,Hkv,hd); pos: (B,) absolute position."""
    quant = "k_scale" in cache
    if quant:
        k1, k_s = _quantize_kv(k1)
        v1, v_s = _quantize_kv(v1)
    s_c = cache["k"].shape[1]
    b = k1.shape[0]
    slot = (pos % s_c).astype(jnp.int32)
    bidx = jnp.arange(b)
    out = {
        "k": cache["k"].at[bidx, slot].set(k1[:, 0]),
        "v": cache["v"].at[bidx, slot].set(v1[:, 0]),
        "slot_pos": cache["slot_pos"].at[bidx, slot].set(pos.astype(jnp.int32)),
    }
    if quant:
        out["k_scale"] = cache["k_scale"].at[bidx, slot].set(k_s[:, 0])
        out["v_scale"] = cache["v_scale"].at[bidx, slot].set(v_s[:, 0])
    return out


# --------------------------------------------------------------------- apply

def block_apply(
    kind: str, cfg, params, x, positions,
    cache: Optional[dict] = None, decode: bool = False, mesh=None,
):
    """Returns (y, new_cache, aux_loss).

    Train: cache=None, decode=False. Prefill: cache allocated, decode=False
    (cache is filled). Decode: cache carried, decode=True, x is (B, 1, D) and
    positions is (B,) absolute position of the new token.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if kind in ("attn+mlp", "local+mlp", "enc+mlp", "attn+moe"):
        window = cfg.window if kind == "local+mlp" else None
        acfg = _attn_cfg(cfg, window)
        if kind == "enc+mlp":
            acfg = A.AttnConfig(**{**acfg.__dict__, "causal": False})
        h = apply_norm(cfg.norm_kind, params["norm1"], x)
        if decode:
            q, k1, v1 = A.project_qkv(acfg, params["attn"], h, positions[:, None])
            if cfg.kv_cache_dtype == "int8" and cfg.decode_seq_shard and mesh is not None:
                raise NotImplementedError(
                    "int8 KV + sequence-sharded decode not wired together yet; "
                    "use one or the other (tracked as future work)"
                )
            if cfg.decode_seq_shard and mesh is not None:
                attn_out, kc, vc, sp = A.decode_append_attend_seqsharded(
                    acfg, mesh, cfg.decode_seq_axis, q, k1, v1,
                    cache["k"], cache["v"], positions, cache["slot_pos"],
                    batch_axis=cfg.decode_batch_axes,
                )
                new_cache = {"k": kc, "v": vc, "slot_pos": sp}
            else:
                new_cache = _append_kv_cache(cache, k1, v1, positions)
                kd, vd = _cache_kv_views(cfg, new_cache)
                attn_out = A.decode_attention(
                    acfg, q, kd, vd, positions, new_cache["slot_pos"],
                )
        else:
            q, k, v = A.project_qkv(acfg, params["attn"], h, positions[None, :])
            if cfg.q_chunk and x.shape[1] > cfg.q_chunk:
                attn_out = A.attention_chunked_q(
                    acfg, q, k, v, positions, positions, cfg.q_chunk
                )
            elif x.shape[1] > cfg.kv_chunk:
                attn_out = A.attention_chunked(acfg, q, k, v, positions, positions)
            else:
                attn_out = A.attention_full(acfg, q, k, v, positions, positions)
            if cache is not None:
                new_cache = _fill_kv_cache(cache, k, v, positions)
        from jax.ad_checkpoint import checkpoint_name

        # name the post-TP-collective tensors: the "save_tp" remat policy
        # keeps them so the recompute pass re-runs NO all-reduces
        x = x + checkpoint_name(
            A.output_proj(acfg, params["attn"], attn_out), "tp_attn_out"
        )
        h = apply_norm(cfg.norm_kind, params["norm2"], x)
        if kind == "attn+moe":
            y, aux = M.apply_moe(_moe_cfg(cfg), params["moe"], h)
        else:
            y = apply_mlp(_mlp_cfg(cfg), params["mlp"], h)
        x = x + checkpoint_name(y, "tp_mlp_out")
        return x, new_cache, aux

    if kind == "rglru+mlp":
        h = apply_norm(cfg.norm_kind, params["norm1"], x)
        y, new_cache = R.apply_rglru(_rglru_cfg(cfg), params["rglru"], h, cache)
        x = x + y
        h = apply_norm(cfg.norm_kind, params["norm2"], x)
        x = x + apply_mlp(_mlp_cfg(cfg), params["mlp"], h)
        return x, new_cache, aux

    if kind == "mlstm":
        h = apply_norm(cfg.norm_kind, params["norm1"], x)
        mcfg = _mlstm_cfg(cfg)
        if decode or x.shape[1] < mcfg.chunk:
            import dataclasses as _dc
            mcfg = _dc.replace(mcfg, chunk=x.shape[1])
        y, new_cache = R.apply_mlstm(mcfg, params["mlstm"], h, cache)
        return x + y, new_cache, aux

    if kind == "slstm":
        h = apply_norm(cfg.norm_kind, params["norm1"], x)
        y, new_cache = R.apply_slstm(_slstm_cfg(cfg), params["slstm"], h, cache)
        return x + y, new_cache, aux

    raise ValueError(kind)
