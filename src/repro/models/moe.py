"""Mixture-of-Experts layer: GShard-style top-k routing with capacity,
shared experts (Qwen-MoE style), expert padding for clean expert-parallelism,
and the standard load-balancing auxiliary loss.

Dispatch is the einsum (dense one-hot) formulation: under pjit the expert
dimension is sharded over the "model"/"expert" mesh axis, so the dispatch and
return einsums lower to the canonical all-to-all pair. Capacity keeps the
per-expert buffers static-shaped (dropped tokens fall back to the residual
stream, plus the always-on shared experts).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import MLPConfig, init_mlp, apply_mlp, init_linear


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int          # routed experts (real)
    top_k: int
    d_expert: int           # per-expert FFN width
    n_shared: int = 0       # always-on shared experts (fused into one MLP)
    capacity_factor: float = 1.25
    pad_experts_to: int | None = None   # pad E for divisibility (EP sharding)
    mlp_kind: str = "swiglu"
    aux_weight: float = 0.01
    group_tokens: int = 1024  # dispatch group length: bounds the (g,s,e,c)
                              # one-hot tensors at s*e*cap ~ O(group^2*k/cf)

    @property
    def e_padded(self) -> int:
        return self.pad_experts_to or self.n_experts


def init_moe(key, cfg: MoEConfig, dtype):
    kg, ke1, ke2, ke3, ks = jax.random.split(key, 5)
    e = cfg.e_padded
    params, specs = {}, {}
    params["wg"], specs["wg"] = init_linear(kg, cfg.d_model, (e,), ("embed", None), dtype)
    scale = cfg.d_model ** -0.5
    params["wi"] = (jax.random.normal(ke1, (e, cfg.d_model, cfg.d_expert), jnp.float32) * scale).astype(dtype)
    params["wg_up"] = (jax.random.normal(ke2, (e, cfg.d_model, cfg.d_expert), jnp.float32) * scale).astype(dtype)
    params["wo"] = (jax.random.normal(ke3, (e, cfg.d_expert, cfg.d_model), jnp.float32) * cfg.d_expert ** -0.5).astype(dtype)
    specs["wi"] = ("expert", "embed", "ffn")
    specs["wg_up"] = ("expert", "embed", "ffn")
    specs["wo"] = ("expert", "ffn", "embed")
    if cfg.n_shared:
        shared_cfg = MLPConfig(cfg.mlp_kind, cfg.d_model, cfg.n_shared * cfg.d_expert)
        params["shared"], specs["shared"] = init_mlp(ks, shared_cfg, dtype)
        params["shared_gate"], specs["shared_gate"] = init_linear(
            ks, cfg.d_model, (1,), ("embed", None), dtype
        )
    return params, specs


def apply_moe(cfg: MoEConfig, params, x):
    """x: (B, S, D) -> (y, aux_loss).

    Tokens are dispatched in groups of ~group_tokens (sequences are split,
    GShard-style): the (s, e, capacity) one-hot dispatch/combine tensors then
    stay O(group * e * group*k/e) per group instead of O(S^2 * k) for long
    sequences.
    """
    b_orig, s_orig, d = x.shape
    split = max(1, s_orig // cfg.group_tokens)
    while s_orig % split:
        split -= 1
    x = x.reshape(b_orig * split, s_orig // split, d)
    b, s, _ = x.shape
    e = cfg.e_padded
    capacity = max(1, int(s * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    logits = jnp.einsum("bsd,de->bse", x, params["wg"]).astype(jnp.float32)
    if e > cfg.n_experts:  # mask padded experts out of routing
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)        # (b, s, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )  # renormalize over selected experts (qwen/mixtral convention)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # (b, s, k, e)
    flat = onehot.reshape(b, s * cfg.top_k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, cfg.top_k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)               # (b, s, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch/combine tensors (b, s, e, c) — kept in the compute dtype
    # (bf16): dispatch entries are {0,1}, combine entries are gate values
    pos_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("bske,bskc->bsec", onehot, pos_onehot).astype(x.dtype)
    combine = jnp.einsum(
        "bsk,bske,bskc->bsec", gate_vals, onehot, pos_onehot
    ).astype(x.dtype)

    xe = jnp.einsum("bsec,bsd->becd", dispatch, x)  # (b,e,c,d)
    h = jnp.einsum("becd,edf->becf", xe, params["wi"])
    hg = jnp.einsum("becd,edf->becf", xe, params["wg_up"])
    h = jax.nn.silu(hg) * h if cfg.mlp_kind == "swiglu" else jax.nn.gelu(hg) * h
    ye = jnp.einsum("becf,efd->becd", h, params["wo"])
    y = jnp.einsum("bsec,becd->bsd", combine, ye)

    if cfg.n_shared:
        shared_cfg = MLPConfig(cfg.mlp_kind, cfg.d_model, cfg.n_shared * cfg.d_expert)
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x, params["shared_gate"]).astype(jnp.float32)
        ).astype(x.dtype)
        y = y + gate * apply_mlp(shared_cfg, params["shared"], x)

    # Switch-style load-balance aux loss over the *real* experts
    me = jnp.mean(onehot.sum(axis=2), axis=(0, 1))[: cfg.n_experts]   # fraction routed
    pe = jnp.mean(probs, axis=(0, 1))[: cfg.n_experts]                # mean prob
    aux = cfg.n_experts * jnp.sum(me * pe) * cfg.aux_weight
    return y.reshape(b_orig, s_orig, d), aux
