"""Shared neural layers: norms, rotary embeddings, MLP variants, initializers.

Every ``init_*`` returns ``(params, specs)`` — a parameter pytree and a
matching pytree of *logical axis tuples* (strings or None per dim). The
sharding layer (repro/sharding/rules.py) maps logical axes onto mesh axes, so
models never mention mesh axes directly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------- init

def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, d_in: int, d_out_shape: tuple, axes: tuple, dtype, scale=None):
    """Weight of shape (d_in, *d_out_shape); fan-in scaled init."""
    shape = (d_in,) + tuple(d_out_shape)
    scale = scale if scale is not None else d_in ** -0.5
    return _normal(key, shape, scale, dtype), axes


def init_embedding(key, vocab: int, d: int, dtype):
    # std d^-0.5: with the sqrt(d) input scaling this gives unit-RMS token
    # embeddings AND unit-variance tied logits
    return _normal(key, (vocab, d), d ** -0.5, dtype), ("vocab", "embed")


# ---------------------------------------------------------------------- norm

def init_norm(kind: str, d: int, dtype):
    """kind: rms | layernorm | nonparam  (olmo-style non-parametric LN)."""
    if kind == "rms":
        # gemma convention: stored as zero-centered, applied as (1 + scale)
        return {"scale": jnp.zeros((d,), dtype)}, {"scale": ("embed",)}
    if kind == "layernorm":
        return (
            {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)},
        )
    if kind == "nonparam":
        return {}, {}
    raise ValueError(kind)


def apply_norm(kind: str, params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- mlp

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    kind: str        # swiglu | geglu | gelu
    d_model: int
    d_ff: int


def init_mlp(key, cfg: MLPConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.kind in ("swiglu", "geglu")
    params = {}
    specs = {}
    params["wi"], specs["wi"] = init_linear(k1, cfg.d_model, (cfg.d_ff,), ("embed", "ffn"), dtype)
    if gated:
        params["wg"], specs["wg"] = init_linear(k2, cfg.d_model, (cfg.d_ff,), ("embed", "ffn"), dtype)
    params["wo"], specs["wo"] = init_linear(k3, cfg.d_ff, (cfg.d_model,), ("ffn", "embed"), dtype)
    return params, specs


def apply_mlp(cfg: MLPConfig, params, x):
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    if cfg.kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, params["wg"])) * h
    elif cfg.kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["wg"])) * h
    elif cfg.kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.kind)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ------------------------------------------------------------------- utility

def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))
