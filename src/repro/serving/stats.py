"""Serving telemetry: ticket latency, throughput, slot occupancy.

Two clocks run side by side. The *wall* clock (``time.perf_counter``) feeds
the operational numbers — queries/sec, p50/p99 ticket latency, deadline
misses. The *round* clock (engine rounds actually executed) feeds the
numbers the correctness and benchmark contracts are stated in: per-query
round counts are deterministic (they equal a solo run of the query — see
`repro.serving.server`), so tests and the CI smoke assert on them while the
wall numbers ride along for humans.

`ServerStats` is a *view* over a :class:`repro.obs.MetricsRegistry`: every
counter it used to keep as a plain int is now a labeled metric family
(``repro_queries_submitted_total{tenant=...}`` and friends), and the
latency / wait / rounds sample lists are registry histograms with both
Prometheus bucket series and the bounded recent-sample windows the
percentiles have always been computed from. The legacy attribute surface
(``stats.rounds_total``, ``stats.tenant_batches``, ...) is preserved as
read-only roll-ups so existing tests, benchmarks, and dashboards keep
working unchanged, while ``GraphServer.metrics_text()`` exposes the same
numbers in the Prometheus text format.
"""
from __future__ import annotations

import time
from typing import Any, Optional

from repro.obs.metrics import MetricsRegistry, bounded_append, percentile

__all__ = ["ServerStats", "percentile"]

# Round-count histogram buckets: powers of two out to 1024. Queries converge
# in rounds-units (tens, occasionally hundreds), so the default sub-second
# latency buckets would dump every observation into the +Inf tail.
ROUNDS_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0,
                  512.0, 1024.0)


class ServerStats:
    """Running counters + traces for one :class:`~repro.serving.GraphServer`.

    Per-tenant / per-family slices come from metric labels: ``tenant`` is
    the submitting tenant's name, ``family`` is the batching family's
    algorithm name (queries of one algorithm on one tenant share a family;
    the label deliberately reuses the algo name rather than an opaque
    family id so a Prometheus query groups the way an operator thinks).
    """

    def __init__(self, slots: int, max_samples: int = 100_000,
                 registry: Optional[MetricsRegistry] = None) -> None:
        self.slots = slots
        # sample lists are bounded: when one exceeds max_samples the oldest
        # half is dropped, so percentiles/occupancy reflect the most recent
        # window and a long-running server's telemetry stays O(max_samples)
        self.max_samples = max_samples
        self.registry = registry if registry is not None \
            else MetricsRegistry(max_samples)
        r = self.registry
        self._submitted = r.counter(
            "repro_queries_submitted_total",
            "Queries accepted by submit()", ("tenant",))
        self._resolved = r.counter(
            "repro_queries_resolved_total",
            "Queries resolved (cache hits included)", ("tenant",))
        self._unconverged = r.counter(
            "repro_queries_unconverged_total",
            "Queries resolved without reaching eps", ("tenant",))
        self._failed = r.counter(
            "repro_queries_failed_total",
            "Submissions rejected before running a round", ("tenant",))
        self._cache_hits = r.counter(
            "repro_cache_hits_total",
            "Queries answered from the result cache", ("tenant",))
        self._batches = r.counter(
            "repro_batches_total",
            "Engine batches dispatched", ("tenant",))
        self._rounds = r.counter(
            "repro_rounds_total",
            "Engine rounds executed", ("tenant",))
        self._round_slots = r.counter(
            "repro_round_slots_total",
            "Rounds x occupied slots (useful work)", ("tenant",))
        self._deltas = r.counter(
            "repro_deltas_applied_total",
            "Graph deltas applied", ("tenant",))
        self._deadline_misses = r.counter(
            "repro_deadline_misses_total",
            "Resolved past the ticket deadline", ("tenant", "family"))
        self._reorders = r.counter(
            "repro_reorders_total",
            "Vertex-order swaps applied", ("tenant",))
        self._reorders_disabled = r.gauge(
            "repro_reorders_disabled",
            "1 once the tenant's reorder auto-tuner gave up", ("tenant",))
        self._occupancy = r.gauge(
            "repro_slot_occupancy",
            "Occupied-slot fraction of the most recent batch")
        self._latency_h = r.histogram(
            "repro_latency_seconds",
            "Ticket latency, submit to resolve", ("tenant", "family"))
        self._wait_h = r.histogram(
            "repro_wait_seconds",
            "Ticket queue wait, submit to first round", ("tenant", "family"))
        self._rounds_h = r.histogram(
            "repro_query_rounds",
            "Engine rounds a resolved query consumed", ("tenant", "family"),
            buckets=ROUNDS_BUCKETS)
        self.occupancy_trace: list[float] = []
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

    def now(self) -> float:
        return time.perf_counter()

    @staticmethod
    def _lab(value: Optional[str]) -> str:
        return value if value is not None else ""

    # ---- legacy scalar surface (label-blind roll-ups) -------------------

    @property
    def submitted(self) -> int:
        return int(self._submitted.total())

    @property
    def resolved(self) -> int:
        return int(self._resolved.total())

    @property
    def unconverged(self) -> int:
        return int(self._unconverged.total())

    @property
    def failed(self) -> int:
        """Invalid submissions — never ran a round."""
        return int(self._failed.total())

    @property
    def cache_hits(self) -> int:
        """The cache's own stats() has the full picture."""
        return int(self._cache_hits.total())

    @property
    def batches(self) -> int:
        return int(self._batches.total())

    @property
    def rounds_total(self) -> int:
        """Engine rounds executed, all families."""
        return int(self._rounds.total())

    @property
    def round_slots_total(self) -> int:
        """Rounds x occupied slots (useful work)."""
        return int(self._round_slots.total())

    @property
    def deltas_applied(self) -> int:
        return int(self._deltas.total())

    @property
    def deadline_misses(self) -> int:
        return int(self._deadline_misses.total())

    @property
    def tenant_batches(self) -> dict[str, int]:
        """Per-tenant batch counts — what the cross-tenant fairness gate
        reads (no tenant's share may starve; see benchmarks)."""
        return {k: int(v) for k, v in
                self._batches.per_label("tenant").items()}

    @property
    def tenant_rounds(self) -> dict[str, int]:
        return {k: int(v) for k, v in
                self._rounds.per_label("tenant").items()}

    @property
    def reorders(self) -> dict[str, int]:
        """Order swaps (regional re-rank or explicit swap_order) per tenant."""
        return {k: int(v) for k, v in
                self._reorders.per_label("tenant").items()}

    @property
    def reorders_disabled(self) -> dict[str, bool]:
        """Tenants whose reorder auto-tuner measured no rounds-win and
        gave up."""
        return {k: True for k, v in
                self._reorders_disabled.per_label("tenant").items() if v}

    # ---- recorders ------------------------------------------------------

    def record_submit(self, tenant: Optional[str] = None) -> None:
        self._submitted.inc(tenant=self._lab(tenant))
        if self._t0 is None:
            self._t0 = self.now()

    def record_cache_hit(self, tenant: Optional[str] = None,
                         family: Optional[str] = None) -> None:
        ten, fam = self._lab(tenant), self._lab(family)
        self._cache_hits.inc(tenant=ten)
        self._resolved.inc(tenant=ten)
        self._t_last = self.now()
        # A hit is a real resolve the client experienced: it belongs in the
        # latency/wait/rounds populations as zeros, not outside them —
        # otherwise wait percentiles overstate the served workload.
        self._latency_h.observe(0.0, tenant=ten, family=fam)
        self._wait_h.observe(0.0, tenant=ten, family=fam)
        self._rounds_h.observe(0, tenant=ten, family=fam)

    def record_batch(self, occupied: int, rounds: int,
                     tenant: Optional[str] = None) -> None:
        ten = self._lab(tenant)
        self._batches.inc(tenant=ten)
        self._rounds.inc(rounds, tenant=ten)
        self._round_slots.inc(rounds * occupied, tenant=ten)
        occ = occupied / max(1, self.slots)
        self._occupancy.set(occ)
        bounded_append(self.occupancy_trace, occ, self.max_samples)

    def record_delta(self, tenant: Optional[str] = None) -> None:
        """A graph delta landed on the tenant's device-resident CSR."""
        self._deltas.inc(tenant=self._lab(tenant))

    def record_reorder(self, tenant: str) -> None:
        """An order swap (regional re-rank or explicit swap_order) landed."""
        self._reorders.inc(tenant=tenant)

    def record_reorder_disabled(self, tenant: str) -> None:
        """The tenant's auto-tuner measured no rounds-win and gave up."""
        self._reorders_disabled.set(1, tenant=tenant)

    def record_fail(self, tenant: Optional[str] = None) -> None:
        """A submission rejected before running (bad params); kept out of
        the resolve counters and latency percentiles so parameter errors
        can't masquerade as engine non-convergence or skew p99."""
        self._failed.inc(tenant=self._lab(tenant))
        self._t_last = self.now()

    def record_resolve(self, ticket: Any) -> None:
        ten = self._lab(getattr(ticket, "tenant", None))
        fam = self._lab(getattr(ticket, "algo", None))
        self._resolved.inc(tenant=ten)
        if not ticket.converged:
            self._unconverged.inc(tenant=ten)
        self._t_last = self.now()
        latency = ticket.resolved_at - ticket.submitted_at
        self._latency_h.observe(latency, tenant=ten, family=fam)
        if ticket.started_at is not None:
            self._wait_h.observe(ticket.started_at - ticket.submitted_at,
                                 tenant=ten, family=fam)
        self._rounds_h.observe(ticket.rounds, tenant=ten, family=fam)
        if ticket.deadline is not None and latency > ticket.deadline:
            self._deadline_misses.inc(tenant=ten, family=fam)

    # ---- exporters ------------------------------------------------------

    def metrics_text(self) -> str:
        """Prometheus text exposition of every family in the registry."""
        return self.registry.prometheus_text()

    def summary(self) -> dict[str, Any]:
        """One dict with everything a dashboard (or the benchmark JSON)
        wants; cheap enough to call every tick. All pre-registry keys are
        preserved verbatim; ``per_tenant`` / ``per_family`` add the labeled
        breakdowns (rounds and latency digests, deadline misses)."""
        elapsed = (
            (self._t_last - self._t0)
            if self._t0 is not None and self._t_last is not None
            else 0.0
        )
        occ = self.occupancy_trace
        resolved = self.resolved
        per_tenant: dict[str, Any] = {}
        for ten, samples in self._rounds_h.per_label("tenant").items():
            per_tenant[ten] = {
                "resolved": int(self._resolved.value(tenant=ten)),
                "rounds_p50": percentile(samples, 50),
                "rounds_p99": percentile(samples, 99),
            }
        for ten, samples in self._latency_h.per_label("tenant").items():
            per_tenant.setdefault(ten, {})["latency_p99_s"] = (
                percentile(samples, 99))
        per_family: dict[str, Any] = {}
        for fam, samples in self._rounds_h.per_label("family").items():
            per_family[fam] = {
                "rounds_p50": percentile(samples, 50),
                "rounds_p99": percentile(samples, 99),
                "deadline_misses": int(
                    self._deadline_misses.per_label("family").get(fam, 0)),
            }
        return {
            "submitted": self.submitted,
            "resolved": resolved,
            "unconverged": self.unconverged,
            "failed": self.failed,
            "cache_hits": self.cache_hits,
            "batches": self.batches,
            "rounds_total": self.rounds_total,
            "round_slots_total": self.round_slots_total,
            "deltas_applied": self.deltas_applied,
            "deadline_misses": self.deadline_misses,
            "tenant_batches": self.tenant_batches,
            "tenant_rounds": self.tenant_rounds,
            "reorders": self.reorders,
            "reorders_disabled": self.reorders_disabled,
            "per_tenant": per_tenant,
            "per_family": per_family,
            "elapsed_s": elapsed,
            "throughput_qps": resolved / elapsed if elapsed > 0 else 0.0,
            "latency_p50_s": self._latency_h.percentile(50),
            "latency_p99_s": self._latency_h.percentile(99),
            "wait_p50_s": self._wait_h.percentile(50),
            "wait_p99_s": self._wait_h.percentile(99),
            "rounds_p50": self._rounds_h.percentile(50),
            "rounds_p99": self._rounds_h.percentile(99),
            "occupancy_mean": sum(occ) / len(occ) if occ else 0.0,
        }
