"""Serving telemetry: ticket latency, throughput, slot occupancy.

Two clocks run side by side. The *wall* clock (``time.perf_counter``) feeds
the operational numbers — queries/sec, p50/p99 ticket latency, deadline
misses. The *round* clock (engine rounds actually executed) feeds the
numbers the correctness and benchmark contracts are stated in: per-query
round counts are deterministic (they equal a solo run of the query — see
`repro.serving.server`), so tests and the CI smoke assert on them while the
wall numbers ride along for humans.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input.

    Nearest-rank keeps the answer an *observed* latency — a p99 users
    actually experienced — instead of an interpolated value between two
    observations.
    """
    vals = sorted(float(v) for v in values)
    if not vals:
        return 0.0
    rank = max(1, int(-(-q * len(vals) // 100)))  # ceil without math import
    return vals[min(rank, len(vals)) - 1]


@dataclasses.dataclass
class ServerStats:
    """Running counters + traces for one :class:`~repro.serving.GraphServer`."""

    slots: int
    # sample lists are bounded: when one exceeds max_samples the oldest half
    # is dropped, so percentiles/occupancy reflect the most recent window
    # and a long-running server's telemetry memory stays O(max_samples)
    max_samples: int = 100_000
    submitted: int = 0
    resolved: int = 0
    unconverged: int = 0
    failed: int = 0            # invalid submissions — never ran a round
    cache_hits: int = 0        # the cache's own stats() has the full picture
    batches: int = 0
    rounds_total: int = 0          # engine rounds executed, all families
    round_slots_total: int = 0     # rounds x occupied slots (useful work)
    deltas_applied: int = 0
    deadline_misses: int = 0
    # per-tenant slices of the batch/round counters — what the cross-tenant
    # fairness gate reads (no tenant's share may starve; see benchmarks)
    tenant_batches: dict = dataclasses.field(default_factory=dict)
    tenant_rounds: dict = dataclasses.field(default_factory=dict)
    # online reordering telemetry: order swaps applied per tenant, and the
    # tenants whose auto-tuner measured no rounds-win and gave up
    reorders: dict = dataclasses.field(default_factory=dict)
    reorders_disabled: dict = dataclasses.field(default_factory=dict)
    occupancy_trace: list = dataclasses.field(default_factory=list)
    _latency_s: list = dataclasses.field(default_factory=list)
    _wait_s: list = dataclasses.field(default_factory=list)
    _rounds: list = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None
    _t_last: Optional[float] = None

    def now(self) -> float:
        return time.perf_counter()

    def _append(self, samples: list, value) -> None:
        samples.append(value)
        if len(samples) > self.max_samples:
            del samples[: len(samples) // 2]

    def record_submit(self) -> None:
        self.submitted += 1
        if self._t0 is None:
            self._t0 = self.now()

    def record_cache_hit(self) -> None:
        self.cache_hits += 1
        self.resolved += 1
        self._t_last = self.now()
        self._append(self._latency_s, 0.0)
        self._append(self._rounds, 0)

    def record_batch(self, occupied: int, rounds: int,
                     tenant: str | None = None) -> None:
        self.batches += 1
        self.rounds_total += rounds
        self.round_slots_total += rounds * occupied
        if tenant is not None:
            self.tenant_batches[tenant] = self.tenant_batches.get(tenant, 0) + 1
            self.tenant_rounds[tenant] = (
                self.tenant_rounds.get(tenant, 0) + rounds
            )
        self._append(self.occupancy_trace, occupied / max(1, self.slots))

    def record_reorder(self, tenant: str) -> None:
        """An order swap (regional re-rank or explicit swap_order) landed."""
        self.reorders[tenant] = self.reorders.get(tenant, 0) + 1

    def record_reorder_disabled(self, tenant: str) -> None:
        """The tenant's auto-tuner measured no rounds-win and gave up."""
        self.reorders_disabled[tenant] = True

    def record_fail(self) -> None:
        """A submission rejected before running (bad params); kept out of
        the resolve counters and latency percentiles so parameter errors
        can't masquerade as engine non-convergence or skew p99."""
        self.failed += 1
        self._t_last = self.now()

    def record_resolve(self, ticket) -> None:
        self.resolved += 1
        if not ticket.converged:
            self.unconverged += 1
        self._t_last = self.now()
        self._append(self._latency_s, ticket.resolved_at - ticket.submitted_at)
        if ticket.started_at is not None:
            self._append(self._wait_s, ticket.started_at - ticket.submitted_at)
        self._append(self._rounds, ticket.rounds)
        if ticket.deadline is not None and (
            ticket.resolved_at - ticket.submitted_at > ticket.deadline
        ):
            self.deadline_misses += 1

    def summary(self) -> dict:
        """One dict with everything a dashboard (or the benchmark JSON)
        wants; cheap enough to call every tick."""
        elapsed = (
            (self._t_last - self._t0)
            if self._t0 is not None and self._t_last is not None
            else 0.0
        )
        occ = self.occupancy_trace
        return {
            "submitted": self.submitted,
            "resolved": self.resolved,
            "unconverged": self.unconverged,
            "failed": self.failed,
            "cache_hits": self.cache_hits,
            "batches": self.batches,
            "rounds_total": self.rounds_total,
            "round_slots_total": self.round_slots_total,
            "deltas_applied": self.deltas_applied,
            "deadline_misses": self.deadline_misses,
            "tenant_batches": dict(self.tenant_batches),
            "tenant_rounds": dict(self.tenant_rounds),
            "reorders": dict(self.reorders),
            "reorders_disabled": dict(self.reorders_disabled),
            "elapsed_s": elapsed,
            "throughput_qps": self.resolved / elapsed if elapsed > 0 else 0.0,
            "latency_p50_s": percentile(self._latency_s, 50),
            "latency_p99_s": percentile(self._latency_s, 99),
            "wait_p50_s": percentile(self._wait_s, 50),
            "wait_p99_s": percentile(self._wait_s, 99),
            "rounds_p50": percentile(self._rounds, 50),
            "rounds_p99": percentile(self._rounds, 99),
            "occupancy_mean": sum(occ) / len(occ) if occ else 0.0,
        }
