"""Byte-budgeted LRU result cache with graph-version region invalidation.

Entries are keyed by ``(tenant, algo, canonical params)`` and carry the
``graph_version`` they were computed at; a lookup hits only when the entry's
version matches the owning tenant's current one. The point of the design is
what happens when a :class:`~repro.graphs.delta.GraphDelta` lands: instead
of flushing everything, :meth:`ResultCache.apply_delta` *promotes* to the
new version every entry whose cached **support blocks** miss the
delta-touched blocks, and drops the rest.

Why that rule is sound (and not just a heuristic): an entry's support is the
block set where its answer or its inputs deviate from the workload's inert
fill (`engine.harness.column_support` with the finished state folded in —
reached vertices, seeds, pinned targets). A delta edge can only change the
query's fixpoint by injecting or removing influence along a path from the
query's inputs; the *first* delta edge on any such path leaves a supported
vertex, so its endpoint block intersects the support and the entry is
dropped. Mutations entirely among unsupported (inert-valued) vertices
contribute the semiring's absorbing fill exactly as before and cannot move
any supported value. Appended vertices that survive promotion are
unreachable from the entry's inputs by the same argument, so the promoted
state extends with the workload's inert fill. Global-support workloads
(pagerank: ``c > 0`` everywhere) have every block in their support and are
invalidated by any edge delta — the correct, conservative outcome.

Block granularity matches the serving engine's ``bs``: coarser than vertex
granularity, so strictly more conservative, never less sound.

Two bounds keep a long-running multi-tenant server honest:

* ``max_bytes`` — a byte budget over the cached ``(n,)`` states. The cache
  is an LRU (ordered dict, recency = get/put): inserting past the budget
  evicts least-recently-used entries until it fits; an entry larger than
  the whole budget is simply not retained.
* Per-tenant invalidation — :meth:`apply_delta` takes a ``select``
  predicate over keys, so one tenant's graph delta can never touch another
  tenant's entries (their versions advance independently).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Iterable, Optional

import numpy as np


@dataclasses.dataclass
class CacheEntry:
    x: np.ndarray               # finished (n,) state of the query
    rounds: int                 # rounds the computing run took
    support_blocks: frozenset   # block ids the answer/inputs touch
    graph_version: int
    x0_fill: float              # inert fill — extends x when n grows
    hits: int = 0


# accounting overhead charged per entry on top of the state bytes (key
# tuple, support set, dataclass) — keeps a budget of tiny states from
# admitting an unbounded entry count
_ENTRY_OVERHEAD = 256


def _entry_bytes(e: CacheEntry) -> int:
    return int(e.x.nbytes) + _ENTRY_OVERHEAD


class ResultCache:
    """(tenant, algo, params)-keyed LRU results, region-invalidated.

    ``max_bytes`` bounds the resident bytes (None = unbounded, the pre-LRU
    behavior). Recency order: :meth:`get` hits and :meth:`put` inserts both
    refresh an entry; eviction pops the least recently used.
    """

    def __init__(self, max_bytes: Optional[int] = None) -> None:
        if max_bytes is not None and max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
        self.max_bytes = max_bytes
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.invalidated = 0
        self.promoted = 0
        self.evicted = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple, graph_version: int) -> Optional[CacheEntry]:
        """The cached entry for ``key`` at ``graph_version``, else None."""
        e = self._entries.get(key)
        if e is None or e.graph_version != graph_version:
            self.misses += 1
            return None
        self._entries.move_to_end(key)  # LRU refresh
        self.hits += 1
        e.hits += 1
        return e

    def put(
        self, key: tuple, x: np.ndarray, rounds: int,
        support_blocks: Iterable[int], graph_version: int, x0_fill: float,
    ) -> None:
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes -= _entry_bytes(old)
        e = CacheEntry(
            x=np.asarray(x).copy(), rounds=int(rounds),
            support_blocks=frozenset(int(b) for b in support_blocks),
            graph_version=graph_version, x0_fill=float(x0_fill),
        )
        self._entries[key] = e
        self.bytes += _entry_bytes(e)
        self._evict_to_budget()

    def _evict_to_budget(self) -> None:
        if self.max_bytes is None:
            return
        while self.bytes > self.max_bytes and self._entries:
            _, old = self._entries.popitem(last=False)  # least recently used
            self.bytes -= _entry_bytes(old)
            self.evicted += 1

    def apply_delta(
        self, touched_blocks: Iterable[int], new_version: int,
        n_new: int | None = None,
        select: Optional[Callable[[tuple], bool]] = None,
    ) -> None:
        """Promote entries untouched by the delta; drop the rest.

        ``touched_blocks`` — block ids containing any endpoint of a
        mutated (added/deleted/reweighted) edge. ``n_new`` extends promoted
        states with their inert fill when the delta appended vertices.
        ``select`` scopes the pass to one tenant's keys — unselected
        entries are left untouched (their tenant's version didn't move).
        """
        touched = frozenset(int(b) for b in touched_blocks)
        for key in list(self._entries):
            if select is not None and not select(key):
                continue
            e = self._entries[key]
            if e.graph_version != new_version - 1 or (e.support_blocks & touched):
                del self._entries[key]
                self.bytes -= _entry_bytes(e)
                self.invalidated += 1
                continue
            e.graph_version = new_version
            if n_new is not None and n_new > len(e.x):
                self.bytes -= _entry_bytes(e)
                e.x = np.concatenate([
                    e.x,
                    np.full(n_new - len(e.x), e.x0_fill, e.x.dtype),
                ])
                self.bytes += _entry_bytes(e)
            self.promoted += 1
        self._evict_to_budget()  # promotion growth can overshoot the budget

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "bytes": self.bytes,
            "max_bytes": self.max_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "invalidated": self.invalidated,
            "promoted": self.promoted,
            "evicted": self.evicted,
        }
