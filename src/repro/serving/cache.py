"""Graph-version result cache with delta-region invalidation.

Entries are keyed by ``(algo, canonical params)`` and carry the
``graph_version`` they were computed at; a lookup hits only when the entry's
version matches the server's current one. The point of the design is what
happens when a :class:`~repro.graphs.delta.GraphDelta` lands: instead of
flushing everything, :meth:`ResultCache.apply_delta` *promotes* to the new
version every entry whose cached **support blocks** miss the delta-touched
blocks, and drops the rest.

Why that rule is sound (and not just a heuristic): an entry's support is the
block set where its answer or its inputs deviate from the workload's inert
fill (`engine.harness.column_support` with the finished state folded in —
reached vertices, seeds, pinned targets). A delta edge can only change the
query's fixpoint by injecting or removing influence along a path from the
query's inputs; the *first* delta edge on any such path leaves a supported
vertex, so its endpoint block intersects the support and the entry is
dropped. Mutations entirely among unsupported (inert-valued) vertices
contribute the semiring's absorbing fill exactly as before and cannot move
any supported value. Appended vertices that survive promotion are
unreachable from the entry's inputs by the same argument, so the promoted
state extends with the workload's inert fill. Global-support workloads
(pagerank: ``c > 0`` everywhere) have every block in their support and are
invalidated by any edge delta — the correct, conservative outcome.

Block granularity matches the serving engine's ``bs``: coarser than vertex
granularity, so strictly more conservative, never less sound.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CacheEntry:
    x: np.ndarray               # finished (n,) state of the query
    rounds: int                 # rounds the computing run took
    support_blocks: frozenset   # block ids the answer/inputs touch
    graph_version: int
    x0_fill: float              # inert fill — extends x when n grows
    hits: int = 0


class ResultCache:
    """(algo, params, graph_version)-keyed results, region-invalidated."""

    def __init__(self):
        self._entries: dict[tuple, CacheEntry] = {}
        self.hits = 0
        self.misses = 0
        self.invalidated = 0
        self.promoted = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple, graph_version: int):
        """The cached entry for ``key`` at ``graph_version``, else None."""
        e = self._entries.get(key)
        if e is None or e.graph_version != graph_version:
            self.misses += 1
            return None
        self.hits += 1
        e.hits += 1
        return e

    def put(
        self, key: tuple, x: np.ndarray, rounds: int,
        support_blocks, graph_version: int, x0_fill: float,
    ) -> None:
        self._entries[key] = CacheEntry(
            x=np.asarray(x).copy(), rounds=int(rounds),
            support_blocks=frozenset(int(b) for b in support_blocks),
            graph_version=graph_version, x0_fill=float(x0_fill),
        )

    def apply_delta(
        self, touched_blocks, new_version: int, n_new: int | None = None,
    ) -> None:
        """Promote entries untouched by the delta; drop the rest.

        ``touched_blocks`` — block ids containing any endpoint of a
        mutated (added/deleted/reweighted) edge. ``n_new`` extends promoted
        states with their inert fill when the delta appended vertices.
        """
        touched = frozenset(int(b) for b in touched_blocks)
        keep: dict[tuple, CacheEntry] = {}
        for key, e in self._entries.items():
            if e.graph_version != new_version - 1 or (e.support_blocks & touched):
                self.invalidated += 1
                continue
            e.graph_version = new_version
            if n_new is not None and n_new > len(e.x):
                e.x = np.concatenate([
                    e.x,
                    np.full(n_new - len(e.x), e.x0_fill, e.x.dtype),
                ])
            keep[key] = e
            self.promoted += 1
        self._entries = keep

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "invalidated": self.invalidated,
            "promoted": self.promoted,
        }
