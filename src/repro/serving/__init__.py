from repro.graphs.delta import GraphDelta
from repro.serving.cache import CacheEntry, ResultCache
from repro.serving.scheduler import POLICIES, Scheduler, family_key
from repro.serving.server import GraphServer, Ticket
from repro.serving.stats import ServerStats, percentile

__all__ = [
    "GraphServer",
    "Ticket",
    "GraphDelta",
    "ResultCache",
    "CacheEntry",
    "Scheduler",
    "POLICIES",
    "family_key",
    "ServerStats",
    "percentile",
]
