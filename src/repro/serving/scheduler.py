"""Admission scheduling for the serving loop: families, queues, policies.

Queries can only share a resident state matrix when they share *structure* —
edge arrays, semiring, combine, residual, eps — i.e. when they differ only
in the per-column vertex arrays (``x0``/``c``/``fixed``). :func:`family_key`
classifies a submission without building the instance: each algorithm names
the constructor parameters that only shape columns (`COLUMN_PARAMS` — e.g.
SSSP's ``source``, PPR's ``seeds``); everything else is structural and keys
the family. The server double-checks the classification against the built
instances at swap-in time, so a wrong table entry fails loudly instead of
silently mixing incompatible queries.

Three admission policies order each family's queue (PriorityGraph-style
ordered scheduling at query granularity):

* ``fifo``      — arrival order.
* ``priority``  — higher ``priority`` first; FIFO among equals.
* ``deadline``  — earliest absolute deadline first (EDF; ``deadline`` is
  seconds after submit, ``None`` sorts last); priority, then FIFO, break
  ties.
"""
from __future__ import annotations

import heapq
import math
from typing import TYPE_CHECKING, Any, Optional

import numpy as np

if TYPE_CHECKING:  # server imports this module; type-only edge back
    from repro.serving.server import Ticket

POLICIES = ("fifo", "priority", "deadline")

# constructor kwargs that only shape per-column vertex arrays (x0/c/fixed);
# everything else (weights transforms, eps, damping, ...) is structural.
COLUMN_PARAMS = {
    "pagerank": (),
    "katz": (),
    "cc": (),
    "php": ("target",),
    "adsorption": ("seeds", "p_inj"),
    "sssp": ("source",),
    "bfs": ("source",),
    "sswp": ("source",),
    "reachability": ("source",),
    "ppr": ("seeds",),
    "ms_sssp": ("sources",),
}


def canon(value: Any) -> Any:
    """Canonicalize a parameter value into a hashable key component."""
    if isinstance(value, dict):
        return tuple(sorted((k, canon(v)) for k, v in value.items()))
    if isinstance(value, np.ndarray):
        return tuple(canon(v) for v in value.tolist())
    if isinstance(value, (list, tuple, range)):
        return tuple(canon(v) for v in value)
    if isinstance(value, (np.integer,)):
        return int(value)
    if isinstance(value, (np.floating,)):
        return float(value)
    return value


def family_key(algo: str, params: dict) -> tuple:
    """(algo, sorted structural params) — the unit that shares one resident
    state matrix. Unknown algorithms treat *all* params as structural (no
    sharing across differing params — always sound, just less packed)."""
    column = COLUMN_PARAMS.get(algo, None)
    items = [
        (k, canon(v)) for k, v in sorted(params.items())
        if column is None or k not in column
    ]
    return (algo, tuple(items))


class Scheduler:
    """Per-family admission queues under one policy.

    Tickets enter with :meth:`push` and leave with :meth:`pop` when the
    server has a free column in that family's resident matrix. Order within
    a family follows the policy; across families the server round-robins,
    so one hot family cannot starve another's resident slots.
    """

    def __init__(self, policy: str = "fifo") -> None:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.policy = policy
        self._queues: dict[tuple, list] = {}

    def _key(self, ticket: "Ticket") -> tuple:
        # every key ends in the unique ticket id: deterministic FIFO
        # tie-breaking, and heap entries never fall through to comparing
        # Ticket objects
        if self.policy == "fifo":
            return (ticket.id,)
        if self.policy == "priority":
            return (-ticket.priority, ticket.id)
        edf = (
            ticket.submitted_at + ticket.deadline
            if ticket.deadline is not None else math.inf
        )
        return (edf, -ticket.priority, ticket.id)

    def push(self, ticket: "Ticket") -> None:
        q = self._queues.setdefault(ticket.family, [])
        heapq.heappush(q, (self._key(ticket), ticket))

    def pop(self, family: tuple) -> Optional["Ticket"]:
        """Next ticket for ``family`` per policy, or None."""
        q = self._queues.get(family)
        if not q:
            return None
        return heapq.heappop(q)[1]

    def peek(self, family: tuple) -> Optional["Ticket"]:
        """The ticket :meth:`pop` would return, without removing it."""
        q = self._queues.get(family)
        return q[0][1] if q else None

    def pending(self, family: tuple) -> int:
        return len(self._queues.get(family, ()))

    def total_pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def families(self) -> list[tuple]:
        """Family keys with at least one queued ticket (insertion order)."""
        return [k for k, q in self._queues.items() if q]
