"""Continuous-batching GraphServer — the serving front end over the engines.

The paper cuts *rounds per query*; PRs 1–4 cut *cost per round*. What was
still missing for the ROADMAP's "serve heavy traffic" north star is the
layer between a query stream and the engines: a static f32[n, d] batch
wastes its converged columns, because per-query round counts are heavily
skewed (paper Fig. 7) and every finished query's slot idles until the
slowest one drains. :class:`GraphServer` is the graph analogue of an LLM
server's continuous batching:

* :meth:`submit` files a :class:`Ticket`. Queries of the same *family*
  (same tenant + algorithm structure — edges, semiring, combine, eps; see
  `scheduler.family_key`) share one resident state matrix whose columns are
  slots.
* The event loop (:meth:`step`) packs queued tickets into free columns,
  runs a bounded batch of engine rounds (`engine.async_block.
  AsyncBlockSession` — the shared harness with per-column freezing), and on
  per-column convergence **swaps the finished column out and a queued query
  in**: the newcomer's ``x0``/``c``/``fixed`` overwrite the column
  (`harness.swap_in_column_device` — a jitted functional update, the
  matrices never leave the device), its convergence bookkeeping resets
  (`convergence.reinit_columns` on the device-side accounting), and under
  the pallas megakernel its support blocks are OR-ed into the dirty
  frontier (`kernels.gs_sweep.or_dirty_blocks`) so only what the newcomer
  needs is re-touched.
* The server is **multi-tenant**: it hosts several independent graphs side
  by side (:meth:`add_tenant`), each with its own graph version, families,
  and deltas. :meth:`step` interleaves family batches round-robin *across
  tenants* with a rotating start, so one hot tenant cannot starve another's
  resident slots; `ServerStats.tenant_batches` exposes the share each
  tenant actually received.
* Results land in a byte-budgeted LRU graph-version cache (`serving.cache`)
  keyed by ``(tenant, algo, params)``; a later identical submit is served
  without running anything.
* :meth:`apply_delta` ingests a live :class:`~repro.graphs.delta.
  GraphDelta` between batches for one tenant: its graph version bumps, its
  cache entries whose support intersects the delta-touched blocks are
  invalidated (the rest promoted; other tenants' entries are never
  touched), and its in-flight queries either continue warm
  (``delta_mode="warm"``, reusing `engine.incremental`'s warm-state /
  affected-region machinery with the carry staying on device) or restart
  on the new graph (``delta_mode="restart"``, keeping per-query round
  counts solo-exact).

The sessions are device-resident end to end: state, operands, frontier
bitmaps, and per-column accounting live as jax arrays across batches,
swaps, and delta rebuilds. The only (n,)-sized host transfer happens in
:meth:`_resolve`, when a finished column becomes a ticket's result.

Correctness contract (mirrors PR 4, enforced by ``tests/test_serving.py``):
a query's resolved state and round count equal a solo ``run_async_block``
of the same query on the graph version it ran against — bitwise for
min/max semirings, within eps for sum semirings — for *any* arrival
schedule, batch granularity, and admission policy, because state-matrix
columns are independent under every sweep and batch boundaries are
invisible to a column's trajectory.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gograph import RankMaintainer, regional_rerank
from repro.core.metric import MetricTracker
from repro.engine import harness
from repro.engine.algorithms import ALGORITHMS, AlgoInstance, get_algorithm, remake
from repro.engine.async_block import AsyncBlockSession
from repro.engine.incremental import (
    affected_region,
    instance_edge_diff,
)
from repro.graphs.delta import GraphDelta
from repro.graphs.graph import Graph, check_permutation, rank_to_order
from repro.obs.trace import Tracer, tspan
from repro.serving.cache import ResultCache
from repro.serving.scheduler import Scheduler, canon, family_key
from repro.serving.stats import ServerStats

DEFAULT_TENANT = "default"


@dataclasses.dataclass
class Ticket:
    """One submitted query, tracked from admission to resolution."""

    id: int
    algo: str
    params: dict
    priority: int
    deadline: Optional[float]     # seconds after submit (EDF policy input)
    family: tuple                 # (tenant,) + scheduler.family_key(...)
    submitted_at: float
    graph_version: int            # version submitted at; updated on resolve
    tenant: str = DEFAULT_TENANT
    status: str = "queued"        # queued | running | done | cached | failed
    started_at: Optional[float] = None
    resolved_at: Optional[float] = None
    rounds: int = 0               # engine rounds this query consumed
    converged: bool = False
    from_cache: bool = False
    result: Optional[np.ndarray] = None   # (n,) state at resolution
    error: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.status in ("done", "cached", "failed")


@dataclasses.dataclass
class _ReorderTuner:
    """Per-tenant rounds-saved measurement behind the online reordering knob.

    The locality-reordering literature (arxiv 2111.12281) shows the payoff of
    a better order depends on graph structure — some tenants simply cannot
    win. The tuner compares the mean resolved rounds-per-query over a window
    before each order swap against the window after it; ``patience``
    consecutive swaps with no measured gain flip ``enabled`` off, and the
    server stops re-ranking that tenant (the metric tracker keeps counting,
    so telemetry still shows the decay it chose to ignore).
    """

    patience: int
    window: int = 8
    min_gain: float = 0.0
    strikes: int = 0
    swaps: int = 0
    enabled: bool = True
    _recent: list = dataclasses.field(default_factory=list)
    _before: Optional[float] = None
    _after: Optional[list] = None

    def record_resolve(self, rounds: int) -> None:
        self._recent.append(rounds)
        if len(self._recent) > 4 * self.window:
            del self._recent[: len(self._recent) // 2]
        if self._after is not None:
            self._after.append(rounds)
            if len(self._after) >= self.window:
                self._judge()

    def note_swap(self) -> None:
        self.swaps += 1
        if self._recent:
            tail = self._recent[-self.window:]
            self._before = sum(tail) / len(tail)
            self._after = []
        # no resolved history yet: nothing to compare against, skip measuring

    def _judge(self) -> None:
        assert self._after is not None
        after = sum(self._after) / len(self._after)
        if self._before is not None and self._before - after <= self.min_gain:
            self.strikes += 1
            if self.strikes >= self.patience:
                self.enabled = False
        else:
            self.strikes = 0
        self._before, self._after = None, None


@dataclasses.dataclass
class _Tenant:
    """One independently served (and independently evolving) graph."""

    name: str
    g: Graph
    graph_version: int = 0
    # online reordering state (None everywhere = id-order serving, the
    # pre-PR 9 fast path): rank is the tenant's processing order, order its
    # inverse (order[p] = vertex at position p), tracker the incremental M
    # counter, maintainer the persistent extend_rank, tuner the rounds-win
    # measurement that can disable re-ranking for this tenant
    rank: Optional[np.ndarray] = None
    order: Optional[np.ndarray] = None
    tracker: Optional[MetricTracker] = None
    maintainer: Optional[RankMaintainer] = None
    tuner: Optional[_ReorderTuner] = None


@dataclasses.dataclass
class _Family:
    """One resident state matrix + its slot bookkeeping."""

    key: tuple
    tenant: str
    probe: AlgoInstance                 # d = 1 structural reference
    session: AsyncBlockSession
    tickets: list                       # Optional[Ticket] per slot
    queries: list                       # Optional[AlgoInstance] per slot
    # (ticket_id, instance) built by _ensure_family's probe pass, consumed
    # by _fill_slots so the family-opening query isn't constructed twice
    probe_cache: Optional[tuple] = None

    def free_slots(self) -> list[int]:
        return [j for j, t in enumerate(self.tickets) if t is None]

    def occupied(self) -> list[tuple[int, Ticket]]:
        return [(j, t) for j, t in enumerate(self.tickets) if t is not None]


class GraphServer:
    """Continuous-batching query server over one or more (evolving) graphs.

    Parameters
    ----------
    graph : the default tenant's graph (mutated only through
        :meth:`apply_delta`). Add further tenants with :meth:`add_tenant`
        or pass ``graphs`` directly.
    graphs : optional ``{tenant_name: Graph}`` mapping served alongside
        (or instead of) ``graph``.
    slots : columns per family's resident state matrix (the ``d`` of the
        f32[n, d] batches).
    rounds_per_batch : engine rounds between swap opportunities. Smaller =
        tighter refill latency, more host round-trips; must be a multiple
        of ``sweeps_per_call``.
    backend / inner / sweeps_per_call / bs : forwarded to
        `engine.async_block.AsyncBlockSession` (``backend="distributed"``
        backs each family with the shard_map superstep so a large tenant's
        resident state spans devices).
    policy : admission order — "fifo" | "priority" | "deadline".
    cache : enable the graph-version result cache.
    cache_max_bytes : byte budget for the cache (LRU eviction); None =
        unbounded.
    refill : "continuous" (swap per converged column — the point of this
        module) or "static" (refill only when every slot resolved; the
        benchmark baseline).
    delta_mode : in-flight queries across :meth:`apply_delta` — "warm"
        (keep progress; min/max still resolve bitwise-exact states, sum
        within eps; round counts reflect the warm continuation) or
        "restart" (recompute from x0 on the new graph; round counts stay
        solo-exact).
    transfer_guard : device->host transfer sanitizer wrapped around every
        :meth:`step` tick (None = jax default, or ``"allow"`` / ``"log"`` /
        ``"disallow"``); with ``"disallow"`` any unaudited readback inside
        the serving loop faults instead of silently syncing.
    push_threshold : frontier-fraction cutoff for vertex-granular delta
        absorption (0 = off). When :meth:`apply_delta` lands a warm-mode
        delta whose depth-1 out-closure (`GraphDelta.touched_vertices`
        with ``closure=1``) covers less than this fraction of the tenant's
        vertices, each in-flight column is resolved to its new fixpoint by
        the residual push engine (``solve(engine="push")``) during the
        rebuild — work proportional to the touched neighborhood — instead
        of re-sweeping ``bs``-blocks next tick.
    rank : processing order for the default tenant (``rank[v]`` = position,
        e.g. a `core.gograph.gograph_order` result). The tenant's sessions
        pack and sweep relabeled; queries and results stay in id space.
        ``add_tenant`` takes a per-tenant rank. None = id order, unless
        ``reorder_threshold > 0`` (which starts from the identity order).
    reorder_threshold : online reordering trigger (0 = off). Each tenant
        gets a `core.metric.MetricTracker`; after a delta lands, any rank
        region whose positive-edge fraction fell below this value (and
        below its level at the last re-rank) is repaired with
        `core.gograph.regional_rerank` and the new order is swapped into
        the tenant's families at the batch boundary (:meth:`swap_order`
        semantics: in-flight state carried by pure device-side permutation,
        bitwise for min/max).
    reorder_regions : rank regions the metric tracker watches per tenant.
    reorder_patience : consecutive order swaps with no measured
        rounds-per-query win before the per-tenant auto-tuner disables
        reordering for that tenant (`ServerStats.reorders_disabled`).
    trace : optional `repro.obs.Tracer` shared by the serving loop and the
        per-family engine sessions. The server emits ``delta_apply`` spans,
        ``reorder_swap`` / ``resolve`` events, and forwards the tracer to
        each `AsyncBlockSession` (``pack`` / ``batch`` / ``sweep_call``
        spans tagged with tenant, family, and graph version). Tracing is
        batch-granular: under ``transfer_guard="disallow"`` it adds no
        device->host transfers beyond the audited per-batch readout.
    """

    def __init__(
        self, graph: Optional[Graph] = None, *,
        graphs: Optional[dict] = None, slots: int = 8, bs: int = 64,
        rounds_per_batch: int = 8, inner: int = 1, backend: str = "jax",
        sweeps_per_call: int = 1, policy: str = "fifo", cache: bool = True,
        cache_max_bytes: Optional[int] = None,
        refill: str = "continuous", delta_mode: str = "warm",
        max_rounds_per_query: int = 2000,
        transfer_guard: Optional[str] = None,
        push_threshold: float = 0.0,
        rank: Optional[np.ndarray] = None,
        reorder_threshold: float = 0.0,
        reorder_regions: int = 8,
        reorder_patience: int = 2,
        trace: Optional[Tracer] = None,
    ) -> None:
        if refill not in ("continuous", "static"):
            raise ValueError(f"unknown refill mode {refill!r}")
        if not 0.0 <= reorder_threshold <= 1.0:
            raise ValueError(
                f"reorder_threshold is an M fraction in [0, 1], "
                f"got {reorder_threshold}"
            )
        if reorder_regions < 1:
            raise ValueError(
                f"reorder_regions must be >= 1, got {reorder_regions}"
            )
        if reorder_patience < 1:
            raise ValueError(
                f"reorder_patience must be >= 1, got {reorder_patience}"
            )
        if rank is not None and graph is None:
            raise ValueError(
                "rank orders the default tenant; pass graph=, or use "
                "add_tenant(name, graph, rank=...) for named tenants"
            )
        if transfer_guard not in (None, "allow", "log", "disallow"):
            raise ValueError(
                f"transfer_guard must be None, 'allow', 'log' or 'disallow', "
                f"got {transfer_guard!r}"
            )
        if not 0.0 <= push_threshold <= 1.0:
            raise ValueError(
                f"push_threshold is a frontier fraction in [0, 1], "
                f"got {push_threshold}"
            )
        if delta_mode not in ("warm", "restart"):
            raise ValueError(f"unknown delta_mode {delta_mode!r}")
        if trace is not None and not isinstance(trace, Tracer):
            raise TypeError(
                f"trace must be a repro.obs.Tracer or None, "
                f"got {type(trace).__name__}"
            )
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if rounds_per_batch < 1:
            # 0 would run zero-round batches forever without ever resolving
            raise ValueError(
                f"rounds_per_batch must be >= 1, got {rounds_per_batch}"
            )
        if sweeps_per_call < 1:
            raise ValueError(f"sweeps_per_call must be >= 1, got {sweeps_per_call}")
        if rounds_per_batch % sweeps_per_call:
            raise ValueError(
                "rounds_per_batch must be a multiple of sweeps_per_call "
                "(the megakernel advances whole batches of sweeps)"
            )
        self.reorder_threshold = reorder_threshold
        self.reorder_regions = reorder_regions
        self.reorder_patience = reorder_patience
        self.tenants: dict[str, _Tenant] = {}
        if graph is not None:
            ten = _Tenant(DEFAULT_TENANT, graph)
            self.tenants[DEFAULT_TENANT] = ten
            self._init_tenant_order(ten, rank)
        for name, g in (graphs or {}).items():
            if name in self.tenants:
                raise ValueError(f"duplicate tenant {name!r}")
            ten = _Tenant(name, g)
            self.tenants[name] = ten
            self._init_tenant_order(ten, None)
        if not self.tenants:
            raise ValueError("GraphServer needs at least one graph to serve")
        self.slots = slots
        self.bs = bs
        self.rounds_per_batch = rounds_per_batch
        self.inner = inner
        self.backend = backend
        self.sweeps_per_call = sweeps_per_call
        self.refill = refill
        self.delta_mode = delta_mode
        self.max_rounds_per_query = max_rounds_per_query
        self.transfer_guard = transfer_guard
        self.push_threshold = push_threshold
        self.scheduler = Scheduler(policy)
        self.cache = ResultCache(max_bytes=cache_max_bytes) if cache else None
        self.trace = trace
        self.stats = ServerStats(slots=slots)
        # LIVE (queued/running) tickets only: terminal transitions drop the
        # entry so a long-running server doesn't retain every (n,) result
        # ever served — the caller's own Ticket reference from submit()
        # keeps the result alive exactly as long as the caller wants it
        self.tickets: dict[int, Ticket] = {}
        self._families: dict[tuple, _Family] = {}
        self._next_id = 0
        self._rr = 0   # rotating tenant offset for cross-tenant fairness

    # ---------------------------------------------------------- back-compat
    # single-tenant spelling: srv.g / srv.graph_version read the default
    # tenant, exactly the pre-multi-tenant surface

    @property
    def g(self) -> Graph:
        return self.tenants[DEFAULT_TENANT].g

    @property
    def graph_version(self) -> int:
        return self.tenants[DEFAULT_TENANT].graph_version

    # ------------------------------------------------------------------ API

    def add_tenant(self, name: str, graph: Graph,
                   rank: Optional[np.ndarray] = None) -> None:
        """Serve another independent graph under ``name``, optionally under
        a processing order ``rank`` (see the constructor's ``rank``)."""
        if name in self.tenants:
            raise ValueError(f"duplicate tenant {name!r}")
        ten = _Tenant(name, graph)
        self.tenants[name] = ten
        self._init_tenant_order(ten, rank)

    def swap_order(self, rank: np.ndarray,
                   tenant: str = DEFAULT_TENANT) -> None:
        """Swap a new processing order into ``tenant`` at a batch boundary.

        Every in-flight column's state (and its convergence bookkeeping) is
        carried into the new order by a pure device-side permutation
        (`harness.gather_rows` — a bit-copy, so min/max states move
        bitwise), queued tickets are untouched (they pack under the new
        order at swap-in), and round counts continue exactly: a swap is
        invisible to a query's value trajectory, only future sweeps visit
        vertices in the new order. The online-reordering path
        (``reorder_threshold``) calls the same machinery after a regional
        re-rank.
        """
        ten = self._tenant(tenant)
        rank = np.asarray(rank)
        check_permutation(rank, ten.g.n)
        rank_old = ten.rank
        if ten.tuner is None:
            ten.tuner = _ReorderTuner(patience=self.reorder_patience)
        self._set_rank(ten, rank)
        for fam in self._families.values():
            if fam.tenant == tenant:
                self._rebuild_family(fam, rank_old=rank_old)

    def submit(
        self, algo: str, params: Optional[dict] = None, *,
        tenant: str = DEFAULT_TENANT,
        priority: int = 0, deadline: Optional[float] = None,
    ) -> Ticket:
        """File a query against ``tenant``'s graph; returns its
        :class:`Ticket` (possibly already resolved from the cache). One
        query per ticket — batched constructors (``ppr`` with one seed,
        ``sssp`` with one source) are submitted per column."""
        if algo not in ALGORITHMS:
            raise KeyError(
                f"unknown algorithm {algo!r}; one of {sorted(ALGORITHMS)}"
            )
        ten = self._tenant(tenant)
        params = dict(params or {})
        t = Ticket(
            id=self._next_id, algo=algo, params=params, priority=priority,
            deadline=deadline, family=(tenant,) + family_key(algo, params),
            submitted_at=self.stats.now(), graph_version=ten.graph_version,
            tenant=tenant,
        )
        self._next_id += 1
        self.tickets[t.id] = t
        self.stats.record_submit(tenant=tenant)
        if self.cache is not None:
            entry = self.cache.get(
                (tenant, algo, canon(params)), ten.graph_version
            )
            if entry is not None:
                t.status = "cached"
                t.from_cache = True
                t.converged = True
                t.result = entry.x.copy()
                t.resolved_at = self.stats.now()
                self.tickets.pop(t.id, None)
                self.stats.record_cache_hit(tenant=tenant, family=algo)
                if self.trace is not None:
                    self.trace.event(
                        "resolve", tenant=tenant, algo=algo, rounds=0,
                        converged=True, from_cache=True,
                    )
                return t
        self.scheduler.push(t)
        return t

    def step(self) -> int:
        """One server tick: for every family with work, fill free columns
        from the queue and run one bounded batch of rounds. Families are
        interleaved round-robin across tenants with a rotating start, so
        every tick gives every tenant with work a batch before any tenant
        gets a second one. Returns the number of family batches executed
        (0 = fully idle)."""
        if self.transfer_guard is not None:
            # every device->host edge inside a tick is audited (device_get
            # + pragma); the guard makes any future unaudited one a fault
            with jax.transfer_guard_device_to_host(self.transfer_guard):
                return self._step_inner()
        return self._step_inner()

    def _step_inner(self) -> int:
        keys = list(self._families)
        keys += [k for k in self.scheduler.families() if k not in self._families]
        by_tenant: dict[str, list[tuple]] = {}
        for k in keys:
            by_tenant.setdefault(k[0], []).append(k)
        names = list(by_tenant)
        if names:
            off = self._rr % len(names)
            names = names[off:] + names[:off]
            self._rr += 1
        worked = 0
        # one family per tenant per round of the interleave
        rotations = max((len(v) for v in by_tenant.values()), default=0)
        for i in range(rotations):
            for name in names:
                fams = by_tenant[name]
                if i >= len(fams):
                    continue
                worked += self._run_family_batch(fams[i])
        return worked

    def _run_family_batch(self, key: tuple) -> int:
        fam = self._ensure_family(key)
        if fam is None:
            return 0
        self._fill_slots(fam)
        occupied = fam.occupied()
        if not occupied:
            return 0
        rep = fam.session.run_batch(self.rounds_per_batch)
        self.stats.record_batch(len(occupied), rep.rounds, tenant=fam.tenant)
        # one host readout of the (d,)-sized accounting per family batch
        col_done, col_rounds = jax.device_get(
            (fam.session.col_done, fam.session.col_rounds)
        )  # repro: allow-host-sync(per-batch (d,)-sized slot accounting)
        for j, t in occupied:
            # the session's cumulative accounting (reset per swap-in,
            # carried across delta rebuilds) is the single source of
            # per-query round truth
            t.rounds = int(col_rounds[j])
            if bool(col_done[j]):
                self._resolve(fam, j, t, converged=True)
            elif t.rounds >= self.max_rounds_per_query:
                self._resolve(fam, j, t, converged=False)
        return 1

    def run(self, max_steps: Optional[int] = None) -> dict:
        """Drive :meth:`step` until every submitted ticket resolved (or
        ``max_steps``); returns ``stats.summary()``."""
        steps = 0
        while self.scheduler.total_pending() or self._busy():
            if max_steps is not None and steps >= max_steps:
                break
            if self.step() == 0:
                break
            steps += 1
        return self.stats.summary()

    def metrics_text(self) -> str:
        """Prometheus text exposition of the server's metrics registry —
        serve it verbatim from a ``/metrics`` endpoint."""
        return self.stats.metrics_text()

    def apply_delta(self, delta: GraphDelta,
                    tenant: str = DEFAULT_TENANT) -> None:
        """Ingest a live graph mutation for one tenant between batches.

        Bumps the tenant's graph version, region-invalidates its cache
        entries (entries whose support misses every delta-touched block are
        *promoted* to the new version instead; other tenants' entries are
        never inspected), rebuilds each of the tenant's families on the
        mutated graph, and carries in-flight queries per ``delta_mode``.
        Queued tickets need nothing: queries are instantiated against the
        tenant's current graph at swap-in time, so a query that arrives the
        same batch a delta lands simply runs on the new graph.
        """
        ten = self._tenant(tenant)
        with tspan(self.trace, "delta_apply", tenant=tenant,
                   graph_version=ten.graph_version + 1):
            self._apply_delta_inner(delta, ten)

    def _apply_delta_inner(self, delta: GraphDelta, ten: _Tenant) -> None:
        tenant = ten.name
        g_new = delta.apply(ten.g)
        ten.graph_version += 1
        if self.cache is not None:
            touched = np.unique(delta.touched_vertices() // self.bs)
            self.cache.apply_delta(
                touched, ten.graph_version, n_new=g_new.n,
                select=lambda key: key[0] == tenant,
            )
        ten.g = g_new
        self.stats.record_delta(tenant)
        rank_old = ten.rank
        if ten.rank is not None:
            # incremental order maintenance: place appended vertices (rank-
            # relative order of existing vertices is preserved, so the O(|d|)
            # tracker update stays exact), then check for regional decay
            rank_ext = ten.maintainer.extend(g_new)
            if ten.tracker is not None:
                ten.tracker.apply_delta(
                    delta, rank_new=rank_ext if delta.n_add else None
                )
            ten.rank = rank_ext
            ten.order = rank_to_order(rank_ext)
            if (ten.tracker is not None and ten.tuner.enabled
                    and self.reorder_threshold > 0.0):
                decayed = ten.tracker.decayed_regions(self.reorder_threshold)
                if len(decayed):
                    members = ten.tracker.region_members(decayed)
                    rank2 = regional_rerank(g_new, rank_ext, members)
                    self._set_rank(ten, rank2)
        for fam in self._families.values():
            if fam.tenant == tenant:
                self._rebuild_family(fam, delta=delta, rank_old=rank_old)

    # ------------------------------------------------------------ internals

    def _tenant(self, name: str) -> _Tenant:
        try:
            return self.tenants[name]
        except KeyError:
            raise KeyError(
                f"unknown tenant {name!r}; one of {sorted(self.tenants)}"
            ) from None

    def _busy(self) -> bool:
        return any(f.occupied() for f in self._families.values())

    def _init_tenant_order(self, ten: _Tenant,
                           rank: Optional[np.ndarray]) -> None:
        """Arm a tenant's ordering state: an explicit rank, or the identity
        order when online reordering is on (the tracker needs *some* base
        order to watch decay against); no rank + reordering off keeps the
        id-order fast path (every ordering field stays None)."""
        if rank is None:
            if self.reorder_threshold == 0.0:
                return
            rank = np.arange(ten.g.n, dtype=np.int64)
        else:
            rank = np.asarray(rank)
            check_permutation(rank, ten.g.n)
        ten.rank = rank
        ten.order = rank_to_order(rank)
        ten.maintainer = RankMaintainer(rank)
        ten.tuner = _ReorderTuner(patience=self.reorder_patience)
        if self.reorder_threshold > 0.0:
            ten.tracker = MetricTracker(
                ten.g, rank, regions=self.reorder_regions
            )

    def _set_rank(self, ten: _Tenant, rank_new: np.ndarray) -> None:
        """Adopt an arbitrary new order for a tenant (rank already
        validated): rebase the metric tracker (relative order is not
        preserved, so the O(|delta|) update rule does not apply), restart
        incremental order maintenance from the new rank, and let the
        auto-tuner open a rounds-per-query measurement window."""
        ten.rank = np.asarray(rank_new)
        ten.order = rank_to_order(ten.rank)
        if ten.tracker is not None:
            ten.tracker.rebase(ten.g, ten.rank)
        ten.maintainer = RankMaintainer(ten.rank)
        if ten.tuner is not None:
            ten.tuner.note_swap()
        self.stats.record_reorder(ten.name)
        if self.trace is not None:
            # covers both entry points uniformly: explicit swap_order and
            # the post-delta regional re-rank
            self.trace.event(
                "reorder_swap", tenant=ten.name,
                graph_version=ten.graph_version,
                swaps=0 if ten.tuner is None else ten.tuner.swaps,
            )

    # constructor params that name vertices; validated against the CURRENT
    # graph at swap-in time — numpy would otherwise accept a negative id
    # silently (aliasing vertex n+v) and an oversized one as an IndexError
    # that would escape the per-ticket failure handling
    _VERTEX_PARAMS = ("source", "target", "seeds", "sources")

    def _build_query(self, t: Ticket) -> AlgoInstance:
        g = self._tenant(t.tenant).g
        for name in self._VERTEX_PARAMS:
            if name in t.params:
                v = np.asarray(t.params[name]).reshape(-1)
                if len(v) and (v.min() < 0 or v.max() >= g.n):
                    raise ValueError(
                        f"{name}={t.params[name]} out of range for a graph "
                        f"with n={g.n} vertices"
                    )
        q = get_algorithm(t.algo, g, **t.params)
        if q.d != 1:
            raise ValueError(
                f"one query per ticket: {t.algo} with {t.params} builds "
                f"d={q.d} columns; submit them as separate tickets"
            )
        return q

    def _fail(self, t: Ticket, err: Exception) -> None:
        t.status = "failed"
        t.error = f"{type(err).__name__}: {err}"
        t.resolved_at = self.stats.now()
        self.tickets.pop(t.id, None)
        self.stats.record_fail(tenant=t.tenant)

    def _make_family(self, key: tuple, tenant: str,
                     probe: AlgoInstance) -> _Family:
        n, d = probe.n, self.slots
        # a ranked tenant's session lives in rank space: the resident state
        # matrix row p is the vertex at order position p, so the engine's
        # block sweep IS the GoGraph processing order. fam.probe (and every
        # fam.queries entry) stays in id space — compat checks, delta diffs
        # and cache support are order-independent concerns
        ten = self._tenant(tenant)
        structural = probe.relabel(ten.rank) if ten.rank is not None else probe
        # idle columns are pinned everywhere: they converge on their first
        # verification round and can never influence a real query's column
        idle = dataclasses.replace(
            structural,
            x0=np.zeros((n, d), np.float32),
            c=np.full((n, d), probe.c_pad_fill, np.float32),
            fixed=np.ones((n, d), bool),
            exact_fn=None, params=None,
        )
        session = AsyncBlockSession(
            idle, bs=self.bs, inner=self.inner, backend=self.backend,
            sweeps_per_call=self.sweeps_per_call,
            trace=self.trace,
            trace_attrs={
                "tenant": tenant, "family": probe.name,
                "graph_version": ten.graph_version,
            },
        )
        return _Family(
            key=key, tenant=tenant, probe=probe, session=session,
            tickets=[None] * d, queries=[None] * d,
        )

    def _ensure_family(self, key: tuple) -> Optional[_Family]:
        fam = self._families.get(key)
        if fam is not None:
            return fam
        while True:
            t = self.scheduler.peek(key)
            if t is None:
                return None
            try:
                q = self._build_query(t)
            except (ValueError, KeyError, TypeError, IndexError) as e:
                self.scheduler.pop(key)
                self._fail(t, e)
                continue
            # the probe only donates structure; the ticket stays queued and
            # is admitted through the ordinary _fill_slots path (which
            # reuses this already-built instance)
            fam = self._make_family(key, t.tenant, q)
            fam.probe_cache = (t.id, q)
            self._families[key] = fam
            return fam

    def _check_compat(self, fam: _Family, q: AlgoInstance, t: Ticket) -> None:
        p = fam.probe
        ok = (
            p.n == q.n and p.m == q.m and p.semiring == q.semiring
            and p.combine == q.combine and p.residual == q.residual
            and p.eps == q.eps
            and np.array_equal(p.src, q.src) and np.array_equal(p.dst, q.dst)
            and np.array_equal(p.w, q.w)
        )
        if not ok:
            raise ValueError(
                f"{t.algo} with {t.params} is structurally incompatible with "
                f"family {fam.key}; scheduler.COLUMN_PARAMS misclassifies one "
                f"of its parameters as per-column"
            )

    def _install(self, fam: _Family, j: int, t: Ticket, q: AlgoInstance) -> None:
        x0, c, fixed = q.x0[:, 0], q.c[:, 0], q.fixed[:, 0]
        order = self._tenant(fam.tenant).order
        if order is not None:
            # pack the id-space query into the session's rank space (host
            # gathers: these (n,) operands are crossing to the device anyway)
            x0, c, fixed = x0[order], c[order], fixed[order]
        fam.session.swap_in(j, x0, c, fixed)
        fam.tickets[j] = t
        fam.queries[j] = q
        t.status = "running"
        if t.started_at is None:   # delta rebuilds re-install running tickets
            t.started_at = self.stats.now()

    def _fill_slots(self, fam: _Family) -> None:
        free = fam.free_slots()
        if self.refill == "static" and len(free) < self.slots:
            return  # static batching: refill only at the full-batch barrier
        for j in free:
            while True:
                t = self.scheduler.pop(fam.key)
                if t is None:
                    return
                if fam.probe_cache is not None and fam.probe_cache[0] == t.id:
                    q = fam.probe_cache[1]   # the family's own probe: built
                    fam.probe_cache = None   # and compat-checked by identity
                else:
                    try:
                        q = self._build_query(t)
                        self._check_compat(fam, q, t)
                    except (ValueError, KeyError, TypeError, IndexError) as e:
                        self._fail(t, e)
                        continue
                self._install(fam, j, t, q)
                break

    def _resolve(self, fam: _Family, j: int, t: Ticket, converged: bool) -> None:
        q = fam.queries[j]
        ten = self._tenant(fam.tenant)
        # the ONE (n,)-sized device->host transfer of a query's lifecycle
        x = jax.device_get(
            fam.session.state[:, j]
        )  # repro: allow-host-sync(resolved column becomes the ticket result)
        if ten.rank is not None:
            x = x[ten.rank]   # rank space -> id space (x_id[v] = x_r[rank[v]])
        t.result = x
        if ten.tuner is not None and converged:
            was_enabled = ten.tuner.enabled
            ten.tuner.record_resolve(t.rounds)
            if was_enabled and not ten.tuner.enabled:
                self.stats.record_reorder_disabled(ten.name)
        t.converged = converged
        t.status = "done"
        t.resolved_at = self.stats.now()
        t.graph_version = self._tenant(t.tenant).graph_version
        self.tickets.pop(t.id, None)
        self.stats.record_resolve(t)
        if self.trace is not None:
            self.trace.event(
                "resolve", tenant=t.tenant, algo=t.algo, rounds=t.rounds,
                converged=converged, graph_version=t.graph_version,
            )
        if self.cache is not None and converged:
            support = harness.column_support(
                q.x0[:, 0], q.c[:, 0], q.fixed[:, 0],
                reduce=q.semiring.reduce, c_fill=q.c_pad_fill, x=x,
            )
            blocks = np.unique(np.nonzero(support)[0] // self.bs)
            self.cache.put(
                (t.tenant, t.algo, canon(t.params)), x, t.rounds, blocks,
                t.graph_version,
                x0_fill=harness.X0_FILL[q.semiring.reduce],
            )
        if not converged:
            # neutralize the slot: a stale non-converged column would keep
            # every future batch from early-exiting (converged columns are
            # frozen/fixpoints and cost nothing, so they can stay)
            n = q.n
            fam.session.swap_in(
                j, np.zeros(n, np.float32),
                np.full(n, q.c_pad_fill, np.float32), np.ones(n, bool),
            )
        fam.tickets[j] = None
        fam.queries[j] = None

    def _rebuild_family(
        self, fam: _Family, delta: Optional[GraphDelta] = None,
        rank_old: Optional[np.ndarray] = None,
    ) -> None:
        ten = self._tenant(fam.tenant)
        probe_old = fam.probe
        probe_new = remake(probe_old, ten.g)
        occupied = [(j, t, fam.queries[j]) for j, t in fam.occupied()]
        old_state = fam.session.state   # device (n_old, d); read per column
        new = self._make_family(fam.key, fam.tenant, probe_new)
        # a pure order swap (delta is None) always carries state: the carry
        # is a bit-exact permutation, so even delta_mode="restart" (which
        # exists to keep round counts solo-exact) loses nothing by keeping it
        carry = self.delta_mode == "warm" or delta is None
        region = None
        if carry and delta is not None and probe_new.semiring.reduce != "sum":
            # a loosening delta (deletions / weights moved against the
            # reduce direction) can invalidate warm values; mask everything
            # downstream of the loosened edges back to x0 and recompute —
            # the same regional argument as engine.incremental, which never
            # needed the prior state to be *converged*, only path-witnessed
            diff = instance_edge_diff(probe_old, probe_new)
            if diff.loosening:
                seeds = np.concatenate([diff.removed_dst, diff.loosened_dst])
                region = affected_region(probe_new, seeds)
        # vertex-granular absorption: a sparse delta's depth-1 out-closure
        # bounds the first warm round's frontier, so when it is a sliver of
        # the graph the push engine resolves each in-flight column at
        # touched-neighborhood cost right now, and the next family batch's
        # sweep is just the verification round
        absorb = False
        if (self.push_threshold > 0.0 and delta is not None
                and self.delta_mode == "warm" and occupied):
            g_new = self._tenant(fam.tenant).g
            closure = delta.touched_vertices(g_new, closure=1)
            absorb = len(closure) / max(g_new.n, 1) < self.push_threshold
        for j, t, q_old in occupied:
            q_new = remake(q_old, ten.g)
            self._install(new, j, t, q_new)
            if carry:
                # device-side warm carry (the jnp mirror of `engine.
                # incremental.warm_state` for one column): surviving
                # vertices keep their device values, appended vertices
                # start at x0, pins and the loosened region serve x0.
                # The carry itself is assembled in id space — the old
                # session's rank (if any) is undone first and the new
                # tenant order applied last, two jitted device gathers
                # (`harness.gather_rows`, bit-copies: min/max states and
                # the loosening/pin masks move bitwise)
                old_col = old_state[: q_old.n, j]
                if rank_old is not None:
                    old_col = harness.gather_rows(old_col, rank_old)
                base = jnp.asarray(q_new.x0[:, 0])
                col = jnp.concatenate([old_col, base[q_old.n:]])
                col = jnp.where(jnp.asarray(q_new.fixed[:, 0]), base, col)
                if region is not None:
                    col = jnp.where(jnp.asarray(region), base, col)
                rounds = t.rounds
                if absorb:
                    from repro.engine.api import solve

                    col_host = jax.device_get(
                        col
                    )  # repro: allow-host-sync(push absorption reads one warm column per delta)
                    try:
                        res = solve(
                            q_new, engine="push", x_init=col_host,
                            backend="jax",
                            max_iters=self.max_rounds_per_query,
                        )
                    except NotImplementedError:
                        pass   # semiring with no push form: plain warm carry
                    else:
                        col = jnp.asarray(
                            np.asarray(res.x, np.float32).reshape(-1)
                        )
                        rounds += res.rounds
                if ten.order is not None:
                    col = harness.gather_rows(col, ten.order)
                new.session.load_state_column(j, col)
                # the new session's accounting starts at 0; carry the
                # rounds the warm continuation (and any push absorption)
                # already consumed
                new.session.set_col_rounds(j, rounds)
            else:
                t.rounds = 0   # restart: solo-exact counts on the new graph
        fam.probe = probe_new
        fam.session = new.session
        fam.tickets = new.tickets
        fam.queries = new.queries
