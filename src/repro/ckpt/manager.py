"""Sharded, atomic, elastic-remesh checkpointing.

Layout per step:
    <dir>/step_000123.tmp/        (written first)
        manifest.json             (tree structure, shapes, dtypes, mesh shape)
        arr_00000.npy ...         (one .npy per leaf, *full* array)
    <dir>/step_000123/            (atomic rename on completion)

Design notes for the 1000+-node posture:
  * atomicity: a checkpoint is visible iff its directory lost the ``.tmp``
    suffix; a crash mid-write leaves only a .tmp that restore() ignores and
    the next save() garbage-collects.
  * elastic re-mesh: leaves are stored unsharded with their full logical
    shape, so restore(target_shardings=...) can re-shard onto ANY mesh
    (checkpoints taken on (16,16) restore onto (2,16,16) or a degraded
    (15,16) rescue mesh). ``np.load(mmap_mode="r")`` + per-shard slicing
    keeps host memory at one shard, not one array, for the big tables.
  * retention: keep_last newest checkpoints are retained, older deleted.
  * multi-host: in a real deployment each host writes only the shards it
    owns (jax.experimental array serialization); this single-process
    implementation writes full arrays but restores shard-by-shard, which is
    the path that matters for elasticity.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state=None, extra: dict | None = None):
        tree = {"params": params}
        if opt_state is not None:
            tree["opt"] = opt_state
        flat, treedef = jax.tree.flatten(tree)
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "n_leaves": len(flat),
            "leaves": [],
            "extra": extra or {},
        }
        # the tree structure is recorded as key paths (robust across versions);
        # jax.tree.flatten_with_path only exists on jax >= 0.5 — go through
        # tree_util, which carries it on the 0.4.x line too
        paths = [
            jax.tree_util.keystr(kp)
            for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
        ]
        manifest["paths"] = paths
        for i, leaf in enumerate(flat):
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
            manifest["leaves"].append({
                "shape": list(arr.shape), "dtype": str(arr.dtype), "path": paths[i],
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({k: v for k, v in manifest.items() if k != "treedef"}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)
        # clean dead tmps
        for d in os.listdir(self.dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, template=None, shardings=None):
        """Restore a pytree.

        template: pytree with the same structure (e.g. abstract params);
        shardings: matching pytree of NamedSharding — when given, each leaf is
        materialized shard-by-shard from a memory-mapped .npy, enabling
        restore onto a different mesh than the one that saved (elastic).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints in " + self.dir)
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        n = manifest["n_leaves"]
        arrays = []
        shard_list = None
        if shardings is not None and template is not None:
            shard_list = jax.tree.flatten(shardings)[0]
        for i in range(n):
            path = os.path.join(d, f"arr_{i:05d}.npy")
            if shard_list is not None:
                mm = np.load(path, mmap_mode="r")
                sh = shard_list[i]
                arr = jax.make_array_from_callback(
                    mm.shape, sh, lambda idx, _mm=mm: np.asarray(_mm[idx])
                )
            else:
                arr = np.load(path)
            arrays.append(arr)
        if template is not None:
            treedef = jax.tree.structure(template)
            tree = jax.tree.unflatten(treedef, arrays)
        else:
            # reconstruct {params, opt} structure losslessly only with template;
            # fall back to a flat dict keyed by path
            tree = {manifest["leaves"][i]["path"]: arrays[i] for i in range(n)}
        return tree, manifest

    def restore_train_state(self, model, mesh, shardings, step=None):
        """Convenience for the train loop: returns (params, opt, step)."""
        shapes, _ = model.param_specs()
        from repro.train.optim import init_opt_state
        opt_shapes = jax.eval_shape(init_opt_state, shapes)
        template = {"params": shapes, "opt": opt_shapes}
        shard_tree = {"params": shardings["params"], "opt": shardings["opt"]}
        tree, manifest = self.restore(step, template=template, shardings=shard_tree)
        return tree["params"], tree["opt"], manifest["step"]
