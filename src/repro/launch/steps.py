"""Per-cell step builders: (arch x shape x mesh) -> (fn, abstract args,
in_shardings, donate) ready for jax.jit(...).lower(...).

The SAME builders drive real execution (train loop / serve loop) and the
dry-run — there is no separate "dry-run model", so a green compile here is
evidence the production configuration is coherent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.shapes import SHAPES, WHISPER_ENC_FRAMES, ShapeCell
from repro.models.model import ModelConfig, build_model
from repro.sharding.rules import (
    ShardingRules, batch_axes_for_mesh, build_param_specs,
)
from repro.train import optim
from repro.train.loop import TrainConfig, make_train_step


# ---------------------------------------------------------------- shardings

def _batch_axes(mesh, global_batch: int):
    ba = batch_axes_for_mesh(mesh)
    size = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    while ba and global_batch % size != 0:
        ba = ba[1:] if len(ba) > 1 else ()
        size = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    return ba


def cache_shardings(cfg: ModelConfig, mesh, cache_shapes, batch_axes):
    """NamedShardings for a decode-cache pytree, dispatched on leaf key."""
    ba = batch_axes if batch_axes else None
    model_ax = "model"
    kv_heads_ok = cfg.n_kv % mesh.shape[model_ax] == 0

    def leaf_spec(path, leaf):
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        nd = len(leaf.shape)
        if key in ("k", "v"):
            # (layers, B, S_c, Hkv, hd)
            lead = [None] * (nd - 4)
            if cfg.decode_seq_shard:
                spec = lead + [ba, model_ax, None, None]
            elif kv_heads_ok:
                spec = lead + [ba, None, model_ax, None]
            else:
                spec = lead + [ba, None, None, None]
        elif key in ("k_scale", "v_scale"):
            # (layers, B, S_c, Hkv) — mirror the k/v sharding minus head_dim
            lead = [None] * (nd - 3)
            if cfg.decode_seq_shard:
                spec = lead + [ba, model_ax, None]
            elif kv_heads_ok:
                spec = lead + [ba, None, model_ax]
            else:
                spec = lead + [ba, None, None]
        elif key == "slot_pos":
            lead = [None] * (nd - 2)
            spec = lead + [ba, model_ax if cfg.decode_seq_shard else None]
        elif key == "C":  # mlstm matrix memory (layers, B, H, hd, hd)
            lead = [None] * (nd - 4)
            spec = lead + [ba, None, None, None]
        else:  # small recurrent states: shard batch only
            spec = [None] * nd
            if nd >= 2:
                spec[1] = ba
            elif nd == 1:
                spec[0] = None
        # divisibility guard
        out = []
        for i, e in enumerate(spec):
            if e is None:
                out.append(None)
                continue
            axes = (e,) if isinstance(e, str) else tuple(e)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            out.append(e if leaf.shape[i] % size == 0 else None)
        return NamedSharding(mesh, P(*out))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


# ------------------------------------------------------------------- inputs

def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.arch_type == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s - cfg.prefix_len), i32),
            "labels": jax.ShapeDtypeStruct((b, s - cfg.prefix_len), i32),
        }
        if cfg.prefix_len:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), jnp.float32
            )
        return out
    if shape.kind == "prefill":
        if cfg.arch_type == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct(
                    (b, min(s, WHISPER_ENC_FRAMES), cfg.d_model), jnp.float32
                ),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
        out = {"tokens": jax.ShapeDtypeStruct((b, s - cfg.prefix_len), i32)}
        if cfg.prefix_len:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), jnp.float32
            )
        return out
    if shape.kind == "decode":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }
        if cfg.arch_type == "encdec":
            out["enc_out"] = jax.ShapeDtypeStruct(
                (b, WHISPER_ENC_FRAMES, cfg.d_model), cfg.jnp_dtype
            )
        return out
    raise ValueError(shape.kind)


# -------------------------------------------------------------------- cells

@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeCell
    fn: object                  # callable to jit+lower
    args: tuple                 # abstract args (ShapeDtypeStructs)
    in_shardings: tuple
    donate: tuple = ()
    notes: list = dataclasses.field(default_factory=list)


def build_cell(
    cfg: ModelConfig, shape_name: str, mesh, rules: ShardingRules,
    tcfg: Optional[TrainConfig] = None,
) -> Cell:
    shape = SHAPES[shape_name]
    ba = _batch_axes(mesh, shape.global_batch)
    cfg = dataclasses.replace(
        cfg, decode_batch_axes=(ba if ba else None) if len(ba) != 1 else ba[0]
    )
    model = build_model(cfg)
    shapes_p, logical = model.param_specs()
    param_sh = build_param_specs(mesh, rules, shapes_p, logical)
    bspec = P(ba if len(ba) > 1 else (ba[0] if ba else None))
    data_sh = NamedSharding(mesh, bspec)
    notes = list(rules.fallbacks)
    ins = input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        step_fn, sh = make_train_step(model, mesh, rules, tcfg)
        opt_shapes = jax.eval_shape(optim.init_opt_state, shapes_p)
        args = (shapes_p, opt_shapes, ins)
        return Cell(cfg.name, shape, step_fn, args, (), donate=(), notes=notes)

    if shape.kind == "prefill":
        if cfg.arch_type == "encdec":
            def fn(params, batch):
                return model.prefill(params, batch["frames"], batch["tokens"], shape.seq)
        else:
            def fn(params, batch):
                return model.prefill(
                    params, batch["tokens"], shape.seq,
                    prefix_embeds=batch.get("prefix_embeds"), mesh=mesh,
                )
        in_sh = (param_sh, {k: data_sh for k in ins})
        jfn = jax.jit(fn, in_shardings=in_sh)
        return Cell(cfg.name, shape, jfn, (shapes_p, ins), in_sh, notes=notes)

    # decode
    cache_shapes = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, shape.seq)
    )
    cache_sh = cache_shardings(cfg, mesh, cache_shapes, ba if ba else None)
    tok_sh = data_sh
    pos_sh = data_sh
    if cfg.arch_type == "encdec":
        def fn(params, caches, batch):
            return model.decode_step(
                params, caches, batch["enc_out"], batch["tokens"], batch["pos"]
            )
    else:
        def fn(params, caches, batch):
            return model.decode_step(
                params, caches, batch["tokens"], batch["pos"], mesh=mesh
            )
    batch_sh = {k: data_sh for k in ins}
    in_sh = (param_sh, cache_sh, batch_sh)
    jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=(1,))
    return Cell(cfg.name, shape, jfn, (shapes_p, cache_shapes, ins), in_sh,
                donate=(1,), notes=notes)
