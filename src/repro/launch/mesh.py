"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax import
and only then calls make_production_mesh().

Mesh geometry (TPU v5e pods of 256 chips):
  single-pod: (16, 16)            axes (data, model)
  multi-pod:  (2, 16, 16)         axes (pod, data, model)

The "model" axis carries TP/EP/sequence sharding (high-bandwidth inner ICI
ring); "data"/"pod" carry data parallelism (gradient all-reduce tolerates the
lower-bandwidth cross-pod links — DCN between pods in a real deployment).
"""
from __future__ import annotations

import jax

from repro.runtime.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return make_mesh((n_data, n_model), ("data", "model"))
