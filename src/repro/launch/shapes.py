"""Assigned input-shape cells and applicability logic.

LM transformer shapes are seq_len x global_batch. decode_*/long_* lower
serve_step (one new token against a KV cache of seq_len), NOT train_step.
long_500k requires sub-quadratic attention and runs only for archs with
cfg.subquadratic=True (gemma3-4b 5:1 local:global, xlstm-350m, and
recurrentgemma-2b) — skips are recorded, not silently dropped.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

WHISPER_ENC_FRAMES = 1500  # whisper's native encoder length (30 s of audio)


def cell_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, (
            "long_500k skipped: pure full-attention arch (O(S) KV per layer "
            "with no sub-quadratic path); see DESIGN.md §4"
        )
    return True, ""


def all_cells(arch_names, cfgs) -> list[tuple[str, str, bool, str]]:
    """[(arch, shape, applicable, reason)] — the full 40-cell table."""
    out = []
    for a in arch_names:
        for s in SHAPES:
            ok, why = cell_applicable(cfgs[a], s)
            out.append((a, s, ok, why))
    return out
