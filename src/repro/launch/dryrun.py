import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ the two lines above MUST run before ANY other import (including repro.*):
#   jax locks the device count on first init.
#
# Multi-pod dry-run driver.
#
# For every (architecture x input shape) cell, lower + compile the REAL
# train/serve step (the same builders the run loops use) against the
# production mesh, print memory_analysis()/cost_analysis(), and record the
# roofline inputs as JSON under experiments/dryrun/.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

import argparse
import json
import time
import traceback


from repro.configs import ALL_ARCHS, get_config, get_train_overrides
from repro.launch.mesh import make_production_mesh
from repro.runtime.jax_compat import set_mesh as compat_set_mesh
from repro.launch.shapes import SHAPES, cell_applicable
from repro.launch.steps import build_cell
from repro.roofline.analysis import (
    model_flops, roofline_terms, mfu_fraction, HW_V5E,
)
from repro.roofline.hlo_parse import analyze as hlo_analyze
from repro.sharding.rules import default_rules
from repro.train.loop import TrainConfig


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             tcfg: TrainConfig | None = None, rules_opts: dict | None = None,
             tag: str = "", verbose: bool = True,
             cfg_overrides: dict | None = None) -> dict:
    import dataclasses as _dc

    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    ok, why = cell_applicable(cfg, shape_name)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "applicable": ok, "skip_reason": why, "tag": tag,
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
    )
    if not ok:
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[skip] {arch} x {shape_name} ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh, **(rules_opts or {}))
    if tcfg is None:
        tcfg = TrainConfig(**get_train_overrides(arch))
    rec["train_config"] = {
        "microbatches": tcfg.microbatches, "zero1": tcfg.zero1,
        "zero2_grads": tcfg.zero2_grads,
    }
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape_name, mesh, rules, tcfg=tcfg)
        with compat_set_mesh(mesh):
            lowered = cell.fn.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis()
            if isinstance(ca, list):  # jax 0.4.x: one dict per program
                ca = ca[0] if ca else {}
            hlo = compiled.as_text()
        # structural HLO analysis: scan/while bodies scaled by trip counts
        # (XLA's cost_analysis counts each computation once — see hlo_parse)
        scaled = hlo_analyze(hlo)
        n_chips = mesh.devices.size
        flops_dev = float(scaled["flops_scaled"])
        # memory term uses the TPU-fusion traffic model (matmul-boundary +
        # state-update + collective bytes); the all-op upper bound is kept in
        # the record for bracketing
        bytes_dev = float(scaled["traffic_dot_bytes_scaled"])
        coll_dev = float(scaled["collective_bytes"]["total"])
        terms = roofline_terms(flops_dev, bytes_dev, coll_dev)
        mf = model_flops(cfg, SHAPES[shape_name])
        mf_dev = mf / n_chips
        rec.update({
            "n_chips": n_chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
                # XLA:CPU FloatNormalization materializes f32 twins of big
                # bf16 buffers (no native host bf16); a TPU executable does
                # not allocate these — subtract them for the capacity check
                "cpu_bf16_upcast_bytes": scaled["cpu_bf16_upcast_bytes"],
                # floor: the corrected estimate can never drop below the real
                # argument (weights/optimizer/cache) footprint
                "tpu_est_bytes": max(
                    ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    - scaled["cpu_bf16_upcast_bytes"],
                    ma.argument_size_in_bytes,
                ),
                "fits_16g": max(
                    ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    - scaled["cpu_bf16_upcast_bytes"],
                    ma.argument_size_in_bytes,
                ) < HW_V5E["hbm_bytes"],
            },
            "cost": {
                "flops_scaled": flops_dev,
                "traffic_dot_bytes_scaled": bytes_dev,
                "traffic_allop_bytes_scaled": float(scaled["traffic_bytes_scaled"]),
                "xla_cost_flops_unscaled": float(ca.get("flops", 0.0)),
                "xla_cost_bytes_unscaled": float(ca.get("bytes accessed", 0.0)),
            },
            "collectives": {
                "bytes": scaled["collective_bytes"],
                "counts": scaled["collective_counts"],
            },
            "while_trip_counts": scaled["while_trip_counts"],
            "roofline": terms.as_dict(),
            "model_flops_total": mf,
            "model_flops_per_device": mf_dev,
            "useful_flops_ratio": (mf_dev / flops_dev) if flops_dev else None,
            "roofline_fraction": mfu_fraction(terms, mf_dev),
            "sharding_fallbacks": cell.notes,
            "hlo_bytes": len(hlo),
        })
        if verbose:
            mem_gb = rec["memory"]["tpu_est_bytes"] / 1e9
            print(
                f"[ok]  {arch:22s} {shape_name:12s} {mesh_name:16s} "
                f"compile={t_compile:6.1f}s mem={mem_gb:6.2f}G "
                f"dom={terms.dominant:10s} frac={rec['roofline_fraction']:.3f}"
            )
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: {rec['error'][:200]}")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--tag", default="")
    p.add_argument("--set", action="append", default=[],
                   help="ModelConfig override, e.g. --set q_chunk=2048")
    p.add_argument("--train-set", action="append", default=[],
                   help="TrainConfig override, e.g. --train-set microbatches=8")
    p.add_argument("--seq-shard", action="store_true",
                   help="sequence-parallel activation sharding rules")
    args = p.parse_args()

    def _parse_sets(items):
        out = {}
        for it in items:
            k, v = it.split("=", 1)
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = {"true": True, "false": False}.get(v.lower(), v)
        return out

    cfg_overrides = _parse_sets(args.set)
    tset = _parse_sets(args.train_set)
    rules_opts = {"seq_shard": True} if args.seq_shard else None

    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                tcfg = None
                if tset:
                    base = get_train_overrides(a)
                    tcfg = TrainConfig(**{**base, **tset})
                results.append(run_cell(
                    a, s, mp, args.out, tcfg=tcfg, tag=args.tag,
                    cfg_overrides=cfg_overrides or None,
                    rules_opts=rules_opts,
                ))
    n_ok = sum(1 for r in results if "error" not in r and r.get("applicable"))
    n_skip = sum(1 for r in results if not r.get("applicable"))
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n=== dry-run: {n_ok} ok, {n_skip} skipped (inapplicable), {n_fail} FAILED ===")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
