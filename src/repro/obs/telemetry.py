"""Uniform convergence telemetry: the per-round residual/work trace.

Every engine already surfaces, at its existing host sync point, enough to
reconstruct *what each round cost and bought*:

* the loop engines (sync / async_block / distributed) return a per-round
  residual buffer plus ``col_rounds[j]`` — the round at which column j
  froze — from which the number of still-active columns at round k is just
  ``sum(col_rounds > k)``;
* the sweep-batched megakernel additionally reports
  ``active_block_fraction`` — the fraction of row-blocks its frontier
  actually swept each round;
* the push engine counts settled vertices and scattered edges per round in
  its host driver.

:class:`ConvergenceTrace` normalizes all of these into one shape —
``residual[k]``, ``active_fraction[k]``, ``work[k]`` — so residual-decay
plots and work accounting read identically across engines. The builders
here consume **already-transferred host arrays only** (the batch-granular
readout contract): constructing a trace never touches the device, so
enabling telemetry cannot add a transfer and ``transfer_guard="disallow"``
stays green.

``work`` units differ by engine (named in ``unit``):

``swept_vertex_cols``   loop engines: active columns × n vertices — every
                        active column pays a full vertex sweep per round.
``swept_block_cells``   megakernel: active blocks × bs rows × d columns —
                        the frontier-skipping engine's finer-grained bill.
``pushed_vertices``     push engine: vertices settled this round (its
                        verification rounds push nothing, so those rounds
                        show work 0 — and residual 0, which is what proved
                        convergence).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class ConvergenceTrace:
    """Per-round telemetry for one solve, uniform across engines.

    All arrays have length ``rounds`` (the number of rounds the run
    executed). ``residual[k]`` is the max-over-active-columns residual
    after round k — the engine's own convergence criterion, so
    ``final_residual <= eps`` iff the run converged within budget.
    """

    residual: np.ndarray         # f32[rounds]
    active_fraction: np.ndarray  # f32[rounds], in [0, 1]
    work: np.ndarray             # f32[rounds], unit below
    unit: str

    @property
    def rounds(self) -> int:
        return int(self.residual.shape[0])

    @property
    def final_residual(self) -> float:
        """Residual after the last executed round (inf for a 0-round run)."""
        return float(self.residual[-1]) if self.rounds else float("inf")

    @property
    def total_work(self) -> float:
        return float(self.work.sum())

    def to_json(self) -> dict:
        return {
            "unit": self.unit,
            "rounds": self.rounds,
            "residual": [float(v) for v in self.residual],
            "active_fraction": [float(v) for v in self.active_fraction],
            "work": [float(v) for v in self.work],
        }


def active_columns_per_round(col_rounds: np.ndarray, rounds: int) -> np.ndarray:
    """``out[k] = number of columns still active during round k``.

    ``col_rounds[j]`` counts the rounds column j paid for before freezing,
    so column j was active in rounds ``0..col_rounds[j]-1`` — the count at
    round k is simply ``sum(col_rounds > k)``. Pure host arithmetic on the
    already-transferred bookkeeping; no device access.
    """
    col_rounds = np.asarray(col_rounds).reshape(-1)
    if rounds <= 0:
        return np.zeros((0,), dtype=np.float32)
    ks = np.arange(rounds, dtype=col_rounds.dtype)
    return (ks[:, None] < col_rounds[None, :]).sum(axis=1).astype(np.float32)


def trace_from_col_rounds(
    residuals: np.ndarray,
    col_rounds: Optional[np.ndarray],
    *,
    rounds: int,
    n: int,
    d: int,
) -> ConvergenceTrace:
    """Trace for the loop engines (sync / async_block / distributed).

    Each active column pays one full vertex sweep per round, so
    ``work[k] = active_cols[k] * n``. When per-column bookkeeping is
    absent (priority-block scheduling has no per-query rounds) every
    executed round is billed at full width.
    """
    res = np.asarray(residuals, dtype=np.float32).reshape(-1)[:rounds]
    if col_rounds is not None:
        active = active_columns_per_round(col_rounds, rounds)
    else:
        active = np.full((rounds,), float(d), dtype=np.float32)
    return ConvergenceTrace(
        residual=res,
        active_fraction=active / max(d, 1),
        work=active * float(n),
        unit="swept_vertex_cols",
    )


def trace_from_block_activity(
    residuals: np.ndarray,
    block_fraction: np.ndarray,
    *,
    rounds: int,
    nb: int,
    bs: int,
    d: int,
) -> ConvergenceTrace:
    """Trace for the sweep-batched megakernel.

    ``block_fraction[k]`` is the fraction of the nb row-blocks the frontier
    actually swept in round k, so the bill is
    ``work[k] = block_fraction[k] * nb * bs * d`` state cells touched —
    strictly finer than the loop engines' column-granular accounting.
    """
    res = np.asarray(residuals, dtype=np.float32).reshape(-1)[:rounds]
    frac = np.asarray(block_fraction, dtype=np.float32).reshape(-1)[:rounds]
    return ConvergenceTrace(
        residual=res,
        active_fraction=frac,
        work=frac * float(nb) * float(bs) * float(d),
        unit="swept_block_cells",
    )


def trace_from_push_counts(
    residuals: Sequence[float],
    pushed: Sequence[float],
    *,
    n: int,
) -> ConvergenceTrace:
    """Trace for the push engine's host driver.

    One entry per round, *including* the empty-frontier verification
    rounds (residual 0, work 0) so the trace length equals the round count
    and the final entry is the residual that decided convergence.
    """
    res = np.asarray(list(residuals), dtype=np.float32)
    work = np.asarray(list(pushed), dtype=np.float32)
    return ConvergenceTrace(
        residual=res,
        active_fraction=work / max(n, 1),
        work=work,
        unit="pushed_vertices",
    )
