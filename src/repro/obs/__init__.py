"""Observability substrate: span tracing, metrics, convergence telemetry.

Three pieces, deliberately dependency-free (stdlib + numpy only) so every
layer above — engines, kernels, serving — can import them without cycles:

* `repro.obs.trace` — zero-cost-when-disabled context-manager spans with a
  ring buffer and an optional JSONL sink.
* `repro.obs.metrics` — a counters/gauges/histograms registry with
  ``summary()`` (dict) and ``prometheus_text()`` exporters.
* `repro.obs.telemetry` — the uniform per-round ``ConvergenceTrace``
  (residual / active fraction / work) every engine attaches to its
  :class:`~repro.engine.convergence.RunResult`.
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    bounded_append,
    percentile,
)
from repro.obs.telemetry import (
    ConvergenceTrace,
    active_columns_per_round,
    trace_from_block_activity,
    trace_from_col_rounds,
    trace_from_push_counts,
)
from repro.obs.trace import NULL_SPAN, SPAN_NAMES, Span, Tracer, tspan

__all__ = [
    "NULL_SPAN",
    "SPAN_NAMES",
    "ConvergenceTrace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "active_columns_per_round",
    "bounded_append",
    "percentile",
    "trace_from_block_activity",
    "trace_from_col_rounds",
    "trace_from_push_counts",
    "tspan",
]
