"""Structured span tracing for the engines and the serving loop.

The paper's quantity of interest is *rounds*, and rounds are only visible
from the host side of the batch boundary — so the tracer records exactly
the host-side control-flow edges where round/convergence information
already surfaces, never anything per-round on device:

``solve``         one engine entry (`repro.engine.api.solve`)
``pack``          operand packing (block padding / flat-BSR layout)
``batch``         one bounded-round session batch (`AsyncBlockSession`)
``sweep_call``    one megakernel dispatch inside a batch (dispatch-side
                  duration: the launch is asynchronous, the following
                  batch-granular readout is the real sync point)
``delta_apply``   one `GraphServer.apply_delta` ingestion
``reorder_swap``  one online order swap (`GraphServer._set_rank`)
``resolve``       instantaneous event: a ticket resolved (tenant / algo /
                  rounds / converged — the per-query round histogram source)

Spans carry flat attribute dicts (``tenant`` / ``algo`` / ``engine`` /
``graph_version`` / ...). Attribute values MUST be host scalars, strings,
or small lists of host scalars — never jax arrays: an implicit coercion
(``float(jnp_scalar)``) at a recording call site is a hidden device->host
sync, exactly the bug class the host-sync checker (HS001) flags; this
module and every module with recording hooks sit in repro-lint's hot-path
globs.

Cost model (the "zero-cost-when-disabled" contract): a disabled tracer's
:meth:`Tracer.span` returns the shared :data:`NULL_SPAN` singleton — no
span object, no timestamp, no buffer traffic; the only cost at a disabled
call site is building the keyword dict. Enabled spans pay two
``perf_counter`` reads, one small object, and (when a JSONL sink is
configured) one serialized line. All spans are batch-granular or coarser,
so even enabled tracing is O(batches), never O(rounds).

Finished spans land in an in-memory ring buffer (``deque(maxlen=ring)`` —
a long-lived server keeps the most recent window) and, optionally, in a
JSONL sink: one JSON object per finished span, written and flushed at span
exit so a live reader (``examples/observe_serving.py``) can tail the file
mid-run.
"""
from __future__ import annotations

import collections
import json
import time
from types import TracebackType
from typing import IO, Any, Optional, Union

SPAN_NAMES = (
    "solve", "pack", "batch", "sweep_call", "delta_apply", "reorder_swap",
    "resolve",
)


class _NullSpan:
    """The shared do-nothing span a disabled tracer hands out.

    One module-level instance serves every disabled call site: entering,
    exiting, and :meth:`set` are all no-ops, so ``with tracer.span(...)``
    costs nothing measurable when tracing is off.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(
        self,
        exc_type: Optional[type[BaseException]],
        exc: Optional[BaseException],
        tb: Optional[TracebackType],
    ) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One finished-or-open span: a named, timed, attributed interval."""

    __slots__ = ("name", "attrs", "t_start", "t_end", "_tracer")

    def __init__(self, tracer: Optional["Tracer"], name: str,
                 attrs: dict[str, Any]) -> None:
        self.name = name
        self.attrs = attrs
        self.t_start: float = 0.0
        self.t_end: Optional[float] = None
        self._tracer = tracer

    @property
    def duration_s(self) -> float:
        """Wall duration; 0.0 while the span is still open."""
        return 0.0 if self.t_end is None else self.t_end - self.t_start

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes discovered mid-span (e.g. the batch's round
        count, known only after the batch-granular readout)."""
        self.attrs.update(attrs)
        return self

    def to_json(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "t_start": self.t_start,
            "duration_s": self.duration_s,
            **self.attrs,
        }

    def __enter__(self) -> "Span":
        self.t_start = time.perf_counter()
        return self

    def __exit__(
        self,
        exc_type: Optional[type[BaseException]],
        exc: Optional[BaseException],
        tb: Optional[TracebackType],
    ) -> bool:
        self.t_end = time.perf_counter()
        if self._tracer is not None:
            self._tracer._record(self)
        return False

    def __repr__(self) -> str:  # debugging/REPL aid only
        return f"Span({self.name!r}, {self.duration_s * 1e3:.3f}ms, {self.attrs})"


class Tracer:
    """Span recorder: ring buffer + optional JSONL sink.

    Parameters
    ----------
    enabled : master switch. Disabled tracers hand out :data:`NULL_SPAN`
        and record nothing — the zero-cost path.
    ring : finished spans kept in memory (oldest evicted first).
    jsonl : optional sink — a filesystem path (opened lazily, append mode)
        or any object with ``write``; each finished span becomes one JSON
        line, flushed immediately.
    """

    def __init__(self, enabled: bool = True, ring: int = 4096,
                 jsonl: Union[str, IO[str], None] = None) -> None:
        self.enabled = enabled
        self.spans: collections.deque[Span] = collections.deque(maxlen=ring)
        self._jsonl = jsonl
        self._sink: Optional[IO[str]] = None
        self._owns_sink = False

    def span(self, name: str, **attrs: Any) -> Union[Span, _NullSpan]:
        """Open a span; use as ``with tracer.span("batch", tenant=t) as sp``."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def event(self, name: str, **attrs: Any) -> None:
        """Record an instantaneous (zero-duration) span."""
        if not self.enabled:
            return
        sp = Span(self, name, attrs)
        sp.t_start = time.perf_counter()
        sp.t_end = sp.t_start
        self._record(sp)

    def find(self, name: str) -> list[Span]:
        """Recorded spans with the given name, oldest first."""
        return [s for s in self.spans if s.name == name]

    def close(self) -> None:
        """Close a path-opened sink (file-like sinks stay the caller's)."""
        if self._sink is not None and self._owns_sink:
            self._sink.close()
        self._sink = None

    # ------------------------------------------------------------ internal

    def _record(self, span: Span) -> None:
        self.spans.append(span)
        sink = self._ensure_sink()
        if sink is not None:
            sink.write(json.dumps(span.to_json()) + "\n")
            sink.flush()

    def _ensure_sink(self) -> Optional[IO[str]]:
        if self._sink is None and self._jsonl is not None:
            if isinstance(self._jsonl, str):
                self._sink = open(self._jsonl, "a", encoding="utf-8")
                self._owns_sink = True
            else:
                self._sink = self._jsonl
        return self._sink


def tspan(tracer: Optional[Tracer], name: str,
          **attrs: Any) -> Union[Span, _NullSpan]:
    """``tracer.span(...)`` that also accepts ``tracer=None`` (tracing off).

    The one helper every instrumented call site uses, so ``None`` /
    disabled / enabled all read identically:
    ``with tspan(o.trace, "pack", algo=algo.name): ...``
    """
    if tracer is None or not tracer.enabled:
        return NULL_SPAN
    return Span(tracer, name, attrs)
