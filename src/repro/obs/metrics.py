"""Metrics registry: counters / gauges / histograms + two exporters.

One process-local registry holds every operational number the serving
layer (and anything else) wants to expose. Metrics are *families*: a name,
a help string, and a fixed tuple of label names; each distinct label-value
combination is a child time series. Two exporters:

* :meth:`MetricsRegistry.summary` — a plain nested dict, the programmatic
  form `ServerStats.summary()` builds on (and the benchmark JSONs embed).
* :meth:`MetricsRegistry.prometheus_text` — the Prometheus text exposition
  format (``# HELP`` / ``# TYPE`` / samples with escaped label values),
  served verbatim by ``GraphServer.metrics_text()`` so a scrape endpoint
  is one ``web.Response(text=srv.metrics_text())`` away.

Histograms keep three things per child: cumulative bucket counts (the
Prometheus ``_bucket{le=...}`` series), exact ``sum``/``count``, and a
*bounded* reservoir of recent samples for nearest-rank percentiles — the
same window-halving rule `ServerStats` has always used (when the list
exceeds ``max_samples`` the oldest half is dropped), so a long-running
server's percentiles track the recent window in O(max_samples) memory.

Everything here is host-side Python on host scalars. Recording a device
value means the *call site* synced it — that is a hot-path decision, and
the host-sync checker audits those call sites (see `repro.obs.trace`).
"""
from __future__ import annotations

from typing import Any, Iterable, Optional, Sequence, Union

Number = Union[int, float]

# Default histogram buckets: tuned for the serving layer's two populations,
# sub-second latencies and round counts in the tens-to-hundreds.
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0,
)


def percentile(values: Iterable[Number], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input.

    Nearest-rank keeps the answer an *observed* sample — a p99 users
    actually experienced — instead of an interpolated value between two
    observations. Edge behavior (pinned by tests/test_obs.py): ``q=0``
    returns the minimum (rank clamps to 1), ``q=100`` the maximum, a
    single-sample list returns that sample for every q.
    """
    vals = sorted(float(v) for v in values)
    if not vals:
        return 0.0
    rank = max(1, int(-(-q * len(vals) // 100)))  # ceil without math import
    return vals[min(rank, len(vals)) - 1]


def bounded_append(samples: list, value: Any, max_samples: int) -> None:
    """Append under the window-halving bound: past ``max_samples`` the
    oldest half is dropped, so the list is O(max_samples) forever and its
    percentiles reflect the most recent window."""
    samples.append(value)
    if len(samples) > max_samples:
        del samples[: len(samples) // 2]


def _label_values(labelnames: tuple[str, ...],
                  labels: dict[str, Any]) -> tuple[str, ...]:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"metric labels {sorted(labels)} != declared {sorted(labelnames)}"
        )
    return tuple(str(labels[n]) for n in labelnames)


def _escape(value: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (value.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt(value: float) -> str:
    """Render a sample value: integers without a trailing .0 noise-free."""
    return str(int(value)) if float(value).is_integer() else repr(float(value))


class _Metric:
    """Shared family plumbing: name, help, labelnames, child lookup."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...]) -> None:
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._children: dict[tuple[str, ...], Any] = {}

    def _child(self, labels: dict[str, Any], default: Any) -> tuple[str, ...]:
        key = _label_values(self.labelnames, labels)
        if key not in self._children:
            self._children[key] = default
        return key

    def _series(self, key: tuple[str, ...], suffix: str = "",
                extra: Optional[tuple[str, str]] = None) -> str:
        pairs = [f'{n}="{_escape(v)}"' for n, v in zip(self.labelnames, key,
                                                       strict=True)]
        if extra is not None:
            pairs.append(f'{extra[0]}="{_escape(extra[1])}"')
        body = "{" + ",".join(pairs) + "}" if pairs else ""
        return f"{self.name}{suffix}{body}"

    def header(self) -> list[str]:
        return [f"# HELP {self.name} {self.help}",
                f"# TYPE {self.name} {self.kind}"]


class Counter(_Metric):
    """Monotone counter family (Prometheus ``counter``)."""

    kind = "counter"

    def inc(self, value: Number = 1, **labels: Any) -> None:
        if value < 0:
            raise ValueError(f"counters only go up; inc({value})")
        key = self._child(labels, 0.0)
        self._children[key] += value

    def value(self, **labels: Any) -> float:
        return float(self._children.get(
            _label_values(self.labelnames, labels), 0.0))

    def total(self) -> float:
        """Sum over every child — the label-blind roll-up."""
        return float(sum(self._children.values()))

    def per_label(self, labelname: str) -> dict[str, float]:
        """Roll up children by one label: ``{label_value: sum}``."""
        i = self.labelnames.index(labelname)
        out: dict[str, float] = {}
        for key, v in self._children.items():
            out[key[i]] = out.get(key[i], 0.0) + v
        return out

    def expose(self) -> list[str]:
        return [f"{self._series(k)} {_fmt(v)}"
                for k, v in sorted(self._children.items())]

    def summary_value(self) -> Any:
        if not self.labelnames:
            return float(self._children.get((), 0.0))
        return {"|".join(k): float(v)
                for k, v in sorted(self._children.items())}


class Gauge(_Metric):
    """Set-to-current-value family (Prometheus ``gauge``)."""

    kind = "gauge"

    def set(self, value: Number, **labels: Any) -> None:
        key = self._child(labels, 0.0)
        self._children[key] = float(value)

    def inc(self, value: Number = 1, **labels: Any) -> None:
        key = self._child(labels, 0.0)
        self._children[key] += value

    def value(self, **labels: Any) -> float:
        return float(self._children.get(
            _label_values(self.labelnames, labels), 0.0))

    def per_label(self, labelname: str) -> dict[str, float]:
        i = self.labelnames.index(labelname)
        return {k[i]: float(v) for k, v in sorted(self._children.items())}

    def expose(self) -> list[str]:
        return [f"{self._series(k)} {_fmt(v)}"
                for k, v in sorted(self._children.items())]

    def summary_value(self) -> Any:
        if not self.labelnames:
            return float(self._children.get((), 0.0))
        return {"|".join(k): float(v)
                for k, v in sorted(self._children.items())}


class _HistChild:
    """One histogram time series: buckets + sum/count + bounded samples."""

    __slots__ = ("bucket_counts", "sum", "count", "samples")

    def __init__(self, n_buckets: int) -> None:
        self.bucket_counts = [0] * n_buckets   # non-cumulative per bucket
        self.sum = 0.0
        self.count = 0
        self.samples: list[float] = []


class Histogram(_Metric):
    """Histogram family (Prometheus ``histogram`` + native percentiles).

    ``observe`` is O(len(buckets)); percentiles come from the bounded
    recent-sample reservoir (`bounded_append` window-halving), matching the
    nearest-rank semantics `ServerStats` has always reported.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...],
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 max_samples: int = 100_000) -> None:
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one finite bucket")
        self.max_samples = max_samples

    def observe(self, value: Number, **labels: Any) -> None:
        key = self._child(labels, None)
        child = self._children[key]
        if child is None:
            child = self._children[key] = _HistChild(len(self.buckets) + 1)
        v = float(value)
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        child.bucket_counts[i] += 1
        child.sum += v
        child.count += 1
        bounded_append(child.samples, v, self.max_samples)

    def percentile(self, q: float, **labels: Any) -> float:
        """Nearest-rank percentile of the recent-sample window. With labels,
        one child's window; without (on a labeled family), every child's
        windows merged — the label-blind roll-up `summary()` reports."""
        if labels or not self.labelnames:
            key = _label_values(self.labelnames, labels)
            child = self._children.get(key)
            return percentile(child.samples, q) if child is not None else 0.0
        merged: list[float] = []
        for child in self._children.values():
            merged.extend(child.samples)
        return percentile(merged, q)

    def count(self, **labels: Any) -> int:
        child = self._children.get(_label_values(self.labelnames, labels))
        return 0 if child is None else child.count

    def total_count(self) -> int:
        return sum(c.count for c in self._children.values())

    def per_label(self, labelname: str) -> dict[str, list[float]]:
        """Merge recent-sample windows by one label value."""
        i = self.labelnames.index(labelname)
        out: dict[str, list[float]] = {}
        for key, child in sorted(self._children.items()):
            out.setdefault(key[i], []).extend(child.samples)
        return out

    def expose(self) -> list[str]:
        lines: list[str] = []
        for key, child in sorted(self._children.items()):
            cum = 0
            for b, n in zip(self.buckets, child.bucket_counts,
                            strict=False):
                cum += n
                lines.append(
                    f"{self._series(key, '_bucket', ('le', _fmt(b)))} {cum}"
                )
            lines.append(
                f"{self._series(key, '_bucket', ('le', '+Inf'))} {child.count}"
            )
            lines.append(f"{self._series(key, '_sum')} {_fmt(child.sum)}")
            lines.append(f"{self._series(key, '_count')} {child.count}")
        return lines

    def summary_value(self) -> Any:
        out = {}
        for key, child in sorted(self._children.items()):
            out["|".join(key) if key else "all"] = {
                "count": child.count,
                "sum": child.sum,
                "p50": percentile(child.samples, 50),
                "p99": percentile(child.samples, 99),
            }
        return out


class MetricsRegistry:
    """Name-keyed collection of metric families.

    ``counter`` / ``gauge`` / ``histogram`` get-or-create: asking for an
    existing name returns the existing family (and rejects a mismatched
    re-declaration loudly, so two layers can't silently fork a metric).
    """

    def __init__(self, max_samples: int = 100_000) -> None:
        self.max_samples = max_samples
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls: type, name: str, help: str,
                       labelnames: Sequence[str], **kw: Any) -> Any:
        existing = self._metrics.get(name)
        if existing is not None:
            if type(existing) is not cls or existing.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-declared as {cls.__name__}"
                    f"{tuple(labelnames)}; existing is "
                    f"{type(existing).__name__}{existing.labelnames}"
                )
            return existing
        m = cls(name, help, tuple(labelnames), **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets,
                                   max_samples=self.max_samples)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def summary(self) -> dict[str, Any]:
        """``{metric_name: value}`` — scalars for unlabeled counters/gauges,
        ``{joined_labels: value}`` dicts for labeled families, and
        count/sum/p50/p99 digests for histogram children."""
        return {name: m.summary_value()
                for name, m in sorted(self._metrics.items())}

    def prometheus_text(self) -> str:
        """The Prometheus text exposition format, newline-terminated."""
        lines: list[str] = []
        for _, m in sorted(self._metrics.items()):
            lines.extend(m.header())
            lines.extend(m.expose())
        return "\n".join(lines) + "\n" if lines else ""
