"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Models annotate parameters with *logical* axes ("vocab", "heads", "ffn",
"expert", ...). This module maps them onto the physical mesh with a
divisibility guard: if a dimension cannot be evenly split over its assigned
mesh axis (e.g. gemma3's 4 KV heads over a 16-way model axis) it falls back
to replication and the fallback is recorded — the dry-run report surfaces
every such decision, because each one is a sharding opportunity lost and a
candidate for the perf loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import jax


@dataclasses.dataclass
class ShardingRules:
    """Mapping from logical axis name -> mesh axis (str, tuple, or None)."""

    rules: dict
    fallbacks: list = dataclasses.field(default_factory=list)

    def mesh_axes(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.rules.get(logical)


def batch_axes_for_mesh(mesh) -> tuple:
    """DP axes: ("pod", "data") on the multi-pod mesh, ("data",) otherwise."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def default_rules(mesh, *, seq_shard: bool = False) -> ShardingRules:
    ba = batch_axes_for_mesh(mesh)
    return ShardingRules(rules={
        "batch": ba,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "heads_flat": "model",
        "ffn": "model",
        "expert": "model",
        "embed": None,
        "embed_out": None,
        "head_dim": None,
        "seq": "model" if seq_shard else None,
        "kv_seq": "model",      # sequence-sharded KV caches (split-KV decode)
        "layers": None,
    })


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for_axes(mesh, rules: ShardingRules, logical_axes, shape=None,
                  name: str = "?") -> P:
    """Build a PartitionSpec for one array from its logical axes.

    `logical_axes` is a tuple with one entry per dim (string or None). When
    `shape` is given, divisibility is checked per-dim; failures replicate
    that dim and are appended to rules.fallbacks.
    """
    entries = []
    for i, lax_ in enumerate(logical_axes):
        mesh_axes = rules.mesh_axes(lax_)
        if mesh_axes is None:
            entries.append(None)
            continue
        size = _axis_size(mesh, mesh_axes)
        if shape is not None and shape[i] % size != 0:
            rules.fallbacks.append(
                f"{name}: dim {i} ({lax_}={shape[i]}) not divisible by "
                f"{mesh_axes}({size}) -> replicated"
            )
            entries.append(None)
            continue
        entries.append(mesh_axes)
    # PartitionSpec forbids using a mesh axis twice; replicate later dups
    seen: set = set()
    cleaned = []
    for e in entries:
        flat = (e,) if isinstance(e, str) else (e or ())
        if any(a in seen for a in flat):
            cleaned.append(None)
            continue
        seen.update(flat)
        cleaned.append(e)
    return P(*cleaned)


def build_param_specs(mesh, rules: ShardingRules, shapes, logical_specs):
    """Pytrees (ShapeDtypeStruct, logical axes) -> pytree of NamedSharding."""

    def one(shape_struct, axes):
        spec = spec_for_axes(mesh, rules, tuple(axes), shape_struct.shape)
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        one, shapes, logical_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
