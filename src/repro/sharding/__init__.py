from repro.sharding.rules import (
    ShardingRules,
    default_rules,
    spec_for_axes,
    build_param_specs,
    batch_axes_for_mesh,
)

__all__ = [
    "ShardingRules",
    "default_rules",
    "spec_for_axes",
    "build_param_specs",
    "batch_axes_for_mesh",
]
