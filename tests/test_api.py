"""The unified `repro.solve` entry point and `EngineOptions` (PR tentpole).

Three contracts:

1. **Parity** — `solve(algo, engine=...)` returns exactly what the legacy
   `run_sync` / `run_async_block` / `run_distributed` spellings return:
   bitwise-identical states for min/max semirings, eps-equal for sum, with
   identical round counts — because the shims ARE `solve` now, and `solve`
   dispatches to the same engine bodies.
2. **Validation in one place** — every knob is validated by
   `engine.api.validate_options` regardless of the spelling used, raising
   one exception family (`EngineOptionsError` is a `ValueError`;
   `EngineUnsupportedError` is additionally a `NotImplementedError`), so
   pre-redesign `except ValueError` / `except NotImplementedError` callers
   keep working.
3. **Device residency** — `AsyncBlockSession` keeps state, operands, and
   per-column accounting as jax arrays across batches and column swaps;
   nothing round-trips through host numpy between batches.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro
from repro import (
    EngineOptions,
    EngineOptionsError,
    EngineUnsupportedError,
    get_algorithm,
    personalized_pagerank,
    run_async_block,
    run_distributed,
    run_sync,
    solve,
)
from repro.engine.api import validate_options
from repro.engine.async_block import AsyncBlockSession
from repro.graphs import generators as gen

N = 300
BS = 64


@pytest.fixture(scope="module")
def gw():
    g = gen.scrambled(gen.powerlaw_cluster(N, 4, p=0.4, seed=1), seed=9)
    return gen.with_random_weights(g, lo=0.1, hi=1.0, seed=2)


# one algorithm per reduce direction: sum (eps-equal), min and max
# (bitwise — selective semirings copy values, never blend them)
CASES = [("pagerank", {}, "sum"), ("sssp", {"source": 3}, "min"),
         ("sswp", {"source": 3}, "max")]


def _assert_same(r_a, r_b, reduce):
    assert r_a.rounds == r_b.rounds
    assert r_a.converged and r_b.converged
    if reduce == "sum":
        np.testing.assert_allclose(r_a.x, r_b.x, rtol=0, atol=1e-6)
    else:
        np.testing.assert_array_equal(r_a.x, r_b.x)


# ---------------------------------------------------------------- parity


@pytest.mark.parametrize("algo_name,params,reduce", CASES)
def test_solve_matches_run_sync(gw, algo_name, params, reduce):
    algo = get_algorithm(algo_name, gw, **params)
    _assert_same(solve(algo, engine="sync"), run_sync(algo), reduce)


@pytest.mark.parametrize("algo_name,params,reduce", CASES)
def test_solve_matches_run_async_block(gw, algo_name, params, reduce):
    algo = get_algorithm(algo_name, gw, **params)
    _assert_same(
        solve(algo, engine="async_block", bs=BS, inner=2),
        run_async_block(algo, bs=BS, inner=2), reduce,
    )


@pytest.mark.parametrize("algo_name,params,reduce", CASES)
def test_solve_matches_run_distributed(gw, algo_name, params, reduce):
    algo = get_algorithm(algo_name, gw, **params)
    _assert_same(
        solve(algo, engine="distributed", bs=BS),
        run_distributed(algo, bs=BS), reduce,
    )


def test_solve_options_object_equals_overrides(gw):
    algo = get_algorithm("pagerank", gw)
    r_opt = solve(algo, options=EngineOptions(bs=BS, inner=2))
    r_kw = solve(algo, bs=BS, inner=2)
    _assert_same(r_opt, r_kw, "sum")


def test_solve_distributed_batched_columns(gw):
    """d>1 through the shard_map path (new in this PR) matches async_block."""
    algo = personalized_pagerank(gw, [0, 5, 17, 99])
    r_d = solve(algo, engine="distributed", bs=BS)
    r_a = solve(algo, engine="async_block", bs=BS)
    assert r_d.rounds == r_a.rounds
    np.testing.assert_allclose(r_d.x, r_a.x, rtol=0, atol=1e-5)
    np.testing.assert_array_equal(r_d.col_rounds, r_a.col_rounds)


def test_solve_pallas_backend_bitwise(gw):
    algo = get_algorithm("sssp", gw, source=3)
    r_p = solve(algo, backend="pallas", bs=BS)
    r_j = solve(algo, backend="jax", bs=BS)
    assert r_p.rounds == r_j.rounds
    np.testing.assert_array_equal(r_p.x, r_j.x)


def test_shims_route_through_solve(gw, monkeypatch):
    """run_* are thin shims: stubbing solve() is enough to divert them."""
    calls = []

    def fake_solve(algo, engine="async_block", options=None, **kw):
        calls.append((engine, options))
        return "sentinel"

    import repro.engine.api as api
    monkeypatch.setattr(api, "solve", fake_solve)
    algo = get_algorithm("pagerank", gw)
    assert run_sync(algo) == "sentinel"
    assert run_async_block(algo, bs=BS) == "sentinel"
    assert run_distributed(algo, bs=BS) == "sentinel"
    assert [c[0] for c in calls] == ["sync", "async_block", "distributed"]
    assert all(isinstance(c[1], EngineOptions) for c in calls)


# ------------------------------------------------------------- validation


def test_unknown_engine_rejected(gw):
    algo = get_algorithm("pagerank", gw)
    with pytest.raises(EngineOptionsError, match="unknown engine"):
        solve(algo, engine="warp")


def test_unknown_backend_rejected(gw):
    algo = get_algorithm("pagerank", gw)
    with pytest.raises(EngineOptionsError, match="unknown backend"):
        solve(algo, backend="cuda")


def test_unknown_option_field_rejected(gw):
    algo = get_algorithm("pagerank", gw)
    with pytest.raises(EngineOptionsError, match="block_size"):
        solve(algo, block_size=64)  # the field is called bs


@pytest.mark.parametrize("kw,msg", [
    ({"bs": 0}, "bs must be >= 1"),
    ({"inner": 0}, "inner must be >= 1"),
    ({"max_iters": 0}, "max_iters must be >= 1"),
    ({"sweeps_per_call": 0}, "sweeps_per_call must be >= 1"),
])
def test_bad_knob_values_rejected(gw, kw, msg):
    algo = get_algorithm("pagerank", gw)
    with pytest.raises(EngineOptionsError, match=msg):
        solve(algo, **kw)


def test_pallas_knobs_rejected_on_jax_backend(gw):
    algo = get_algorithm("sssp", gw, source=3)
    with pytest.raises(EngineOptionsError, match="pallas-backend knobs"):
        solve(algo, backend="jax", sweeps_per_call=4)


def test_extrapolation_contracts(gw):
    """Extrapolation: sum-semiring only, every >= 2, not under the
    megakernel — and EngineUnsupportedError still reads as the
    NotImplementedError the old engines raised."""
    sum_algo = get_algorithm("pagerank", gw)
    min_algo = get_algorithm("sssp", gw, source=3)
    with pytest.raises(NotImplementedError, match="sum-semiring"):
        solve(min_algo, extrapolate_every=4)
    with pytest.raises(ValueError, match=">= 2"):
        solve(sum_algo, extrapolate_every=1)
    with pytest.raises(EngineUnsupportedError):
        solve(sum_algo, backend="pallas", bs=BS,
              sweeps_per_call=4, extrapolate_every=4)
    assert solve(sum_algo, extrapolate_every=4, bs=BS).converged


def test_exception_family_is_compatible():
    assert issubclass(EngineOptionsError, ValueError)
    assert issubclass(EngineUnsupportedError, EngineOptionsError)
    assert issubclass(EngineUnsupportedError, NotImplementedError)


def test_options_frozen_and_validate_direct():
    o = EngineOptions(bs=BS)
    with pytest.raises(dataclasses.FrozenInstanceError):
        o.bs = 128
    with pytest.raises(EngineOptionsError, match="unknown engine"):
        validate_options("nope", o)


def test_session_constructor_validates(gw):
    algo = get_algorithm("pagerank", gw)
    with pytest.raises(EngineOptionsError, match="bs must be >= 1"):
        AsyncBlockSession(algo, bs=0)
    with pytest.raises(EngineOptionsError, match="unknown backend"):
        AsyncBlockSession(algo, backend="cuda")


# -------------------------------------------------------- public surface


def test_top_level_public_surface():
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    assert repro.solve is solve
    assert repro.GraphServer.__name__ == "GraphServer"
    with pytest.raises(AttributeError):
        repro.definitely_not_an_attr


# ------------------------------------------------------- device residency


def _is_device(a):
    return isinstance(a, jax.Array)


def test_session_state_stays_on_device(gw, transfer_guard_disallow):
    """The tentpole's residency contract: packed state, operands, and
    per-column accounting are jax arrays after construction, after every
    run_batch, and after a column swap — host numpy appears only when the
    caller reads a result out. Runs under the device->host transfer guard,
    so any implicit readback in the session/engine path faults."""
    algo = personalized_pagerank(gw, [2, 7, 11, 42])
    ses = AsyncBlockSession(algo, bs=BS)

    def check(where):
        for name in ("x", "x0", "c", "fixed", "col_done", "col_rounds"):
            assert _is_device(getattr(ses, name)), (where, name)
        assert _is_device(ses.state), where

    check("init")
    ses.run_batch(4)
    check("after batch 1")
    ses.run_batch(4)
    check("after batch 2")
    q = personalized_pagerank(gw, [123])
    ses.swap_in(1, q.x0[:, 0], q.c[:, 0], q.fixed[:, 0])
    check("after swap_in")
    ses.run_batch(2000)
    check("after drain")
    # and the resident computation is still correct end to end
    solo = run_async_block(q, bs=BS)
    np.testing.assert_allclose(
        jax.device_get(ses.state[:, 1]), solo.x, rtol=0, atol=1e-6
    )
    assert int(jax.device_get(ses.col_rounds)[1]) == solo.rounds


def test_session_pallas_state_stays_on_device(gw, transfer_guard_disallow):
    from repro.engine import multi_source_sssp

    # min semiring: selective updates make the resident megakernel state
    # bitwise-comparable to the solo run regardless of sweep granularity
    algo = multi_source_sssp(gw, [3, 5])
    ses = AsyncBlockSession(algo, bs=BS, backend="pallas", sweeps_per_call=2)
    ses.run_batch(4)
    assert _is_device(ses.x) and _is_device(ses.dirty)
    ses.run_batch(2000)
    assert _is_device(ses.state)
    solo = run_async_block(algo, bs=BS)
    np.testing.assert_array_equal(
        jax.device_get(ses.state), np.asarray(solo.x, np.float32)
    )


def test_server_resolution_is_the_only_host_copy(gw, transfer_guard_disallow):
    """End to end through the server: the family session's arrays remain
    device arrays across ticks/swaps; the Ticket.result is host numpy.
    The server's own sanitizer knob is also on, nested inside the fixture's
    guard — both paths must hold."""
    from repro.serving import GraphServer

    srv = GraphServer(gw, slots=2, bs=BS, rounds_per_batch=4,
                      transfer_guard="disallow")
    tickets = [srv.submit("ppr", {"seeds": [s]}) for s in (1, 2, 3, 4)]
    srv.run()
    fam = next(iter(srv._families.values()))
    assert _is_device(fam.session.x)
    assert _is_device(fam.session.col_rounds)
    for t in tickets:
        assert isinstance(t.result, np.ndarray)
        solo = run_async_block(
            personalized_pagerank(gw, t.params["seeds"]), bs=BS
        )
        assert t.rounds == solo.rounds
        np.testing.assert_allclose(t.result, solo.x, rtol=0, atol=1e-6)


# -------------------------------------------------- transfer-guard knob


def test_transfer_guard_value_validated():
    with pytest.raises(EngineOptionsError, match="transfer_guard"):
        validate_options(
            "async_block", EngineOptions(transfer_guard="everything")
        )
    for ok in (None, "allow", "log", "disallow"):
        validate_options("async_block", EngineOptions(transfer_guard=ok))


def test_mesh_rejected_outside_distributed():
    with pytest.raises(EngineOptionsError, match="mesh"):
        validate_options("async_block", EngineOptions(mesh=object()))
    with pytest.raises(EngineOptionsError, match="mesh"):
        validate_options("sync", EngineOptions(mesh=object()))


def test_x_init_rank_validated():
    with pytest.raises(EngineOptionsError, match="x_init"):
        validate_options(
            "async_block", EngineOptions(x_init=np.zeros((2, 2, 2)))
        )
    validate_options("async_block", EngineOptions(x_init=np.zeros(4)))
    validate_options("async_block", EngineOptions(x_init=np.zeros((4, 2))))


def test_axis_validated():
    with pytest.raises(EngineOptionsError, match="axis"):
        validate_options("distributed", EngineOptions(axis=""))


@pytest.mark.parametrize("algo_name,params,reduce", CASES)
def test_solve_under_transfer_guard_matches_plain(gw, algo_name, params,
                                                  reduce):
    """The engines run start-to-finish under the device->host guard: every
    transfer in the hot path is an audited jax.device_get."""
    algo = get_algorithm(algo_name, gw, **params)
    plain = solve(algo, engine="async_block", bs=BS)
    guarded = solve(algo, engine="async_block", bs=BS,
                    transfer_guard="disallow")
    _assert_same(plain, guarded, reduce)


def test_solve_pallas_under_transfer_guard(gw):
    algo = get_algorithm("sssp", gw, source=3)
    plain = solve(algo, engine="async_block", bs=BS, backend="pallas",
                  sweeps_per_call=4)
    guarded = solve(algo, engine="async_block", bs=BS, backend="pallas",
                    sweeps_per_call=4, transfer_guard="disallow")
    _assert_same(plain, guarded, "min")


def test_server_transfer_guard_rejects_bad_value(gw):
    from repro.serving import GraphServer

    with pytest.raises(ValueError, match="transfer_guard"):
        GraphServer(gw, transfer_guard="everything")
