"""Continuous-batching serving layer (`repro.serving`).

The load-bearing contract: for ANY arrival schedule, batch granularity, and
admission policy, a query resolved by the server carries exactly the state
and round count a solo `run_async_block` of that query would produce on the
graph version it ran against — bitwise for min/max semirings, within eps
for sum semirings — including queries that arrive the same batch a
GraphDelta lands. Plus: region-invalidation soundness of the result cache,
admission policies, the static-batching baseline, and regression tests for
the deduplicated per-column convergence accounting (PR satellite).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.engine import get_algorithm, personalized_pagerank, run_async_block
from repro.engine.convergence import converge_step, reinit_columns
from repro.engine import harness
from repro.graphs import generators as gen
from repro.graphs.delta import GraphDelta, random_delta
from repro.graphs.graph import Graph
from repro.serving import GraphServer, Scheduler, family_key
from repro.serving.stats import percentile

N = 350
BS = 64


def _base_graph():
    g = gen.scrambled(gen.powerlaw_cluster(N, 4, p=0.4, seed=1), seed=9)
    # weights <= 1 keep the pagerank-family spectral radius < damping, so
    # PPR and SSSP traffic can share one weighted graph
    return gen.with_random_weights(g, lo=0.1, hi=1.0, seed=2)


GW = _base_graph()
_SOLO_CACHE: dict = {}


def _solo(algo, src, graph=None, key=None):
    """Memoized solo reference run (same engine config the server uses)."""
    if graph is None:
        graph = GW
        key = (algo, src)
    if key not in _SOLO_CACHE:
        p = {"seeds": [src]} if algo == "ppr" else {"source": src}
        _SOLO_CACHE[key] = run_async_block(
            get_algorithm(algo, graph, **p), bs=BS
        )
    return _SOLO_CACHE[key]


def _check_ticket(t, solo, *, rounds=True):
    assert t.done and t.converged, (t.algo, t.params, t.status)
    is_sum = t.algo in ("ppr", "pagerank", "katz", "php", "adsorption")
    if rounds:
        assert t.rounds == solo.rounds, (t.algo, t.params, t.rounds, solo.rounds)
    if is_sum:
        np.testing.assert_allclose(t.result, solo.x, atol=1e-5, rtol=0)
    else:
        np.testing.assert_array_equal(t.result, solo.x, err_msg=str(t.params))


# ---------------------------------------------------------------------------
# swap-in equivalence: any arrival schedule == solo runs
# ---------------------------------------------------------------------------

@st.composite
def schedules(draw):
    n_q = draw(st.integers(3, 9))
    queries = sorted(
        (
            draw(st.integers(0, 5)),                       # arrival tick
            draw(st.sampled_from(["sssp", "bfs", "ppr"])),
            draw(st.integers(0, N - 1)),                   # source/seed
            draw(st.integers(0, 3)),                       # priority
        )
        for _ in range(n_q)
    )
    rpb = draw(st.sampled_from([1, 2, 3, 5]))
    slots = draw(st.sampled_from([2, 3, 4]))
    policy = draw(st.sampled_from(["fifo", "priority", "deadline"]))
    return queries, rpb, slots, policy


@given(schedules())
@settings(max_examples=6, deadline=None)
def test_any_arrival_schedule_matches_solo_runs(schedule):
    queries, rpb, slots, policy = schedule
    srv = GraphServer(
        GW, slots=slots, bs=BS, rounds_per_batch=rpb, policy=policy,
        cache=False,
    )
    pending = list(queries)
    tickets = []
    tick = 0
    while pending or srv.scheduler.total_pending() or srv._busy():
        while pending and pending[0][0] <= tick:
            _, algo, src, prio = pending.pop(0)
            p = {"seeds": [src]} if algo == "ppr" else {"source": src}
            tickets.append((algo, src, srv.submit(algo, p, priority=prio)))
        srv.step()
        tick += 1
    for algo, src, t in tickets:
        _check_ticket(t, _solo(algo, src))


def test_cached_resubmit_serves_identical_result():
    srv = GraphServer(GW, slots=2, bs=BS, rounds_per_batch=4)
    t1 = srv.submit("sssp", {"source": 3})
    srv.run()
    t2 = srv.submit("sssp", {"source": 3})
    assert t2.status == "cached" and t2.rounds == 0
    np.testing.assert_array_equal(t2.result, t1.result)
    assert srv.cache.stats()["hits"] == 1


# ---------------------------------------------------------------------------
# live delta ingestion
# ---------------------------------------------------------------------------

def _delta_setup(delta_mode, seed=5, loosening=True):
    srv = GraphServer(
        GW, slots=3, bs=BS, rounds_per_batch=2, delta_mode=delta_mode,
    )
    t_flight = srv.submit("sssp", {"source": 0})
    t_ppr = srv.submit("ppr", {"seeds": [7]})
    srv.step()
    assert t_flight.status == "running"   # mid-convergence when delta lands
    delta = random_delta(
        GW, frac_add=0.01,
        frac_del=0.003 if loosening else 0.0,
        frac_rew=0.003 if loosening else 0.0,
        n_add_vertices=3, seed=seed,
    )
    srv.apply_delta(delta)
    # arrives the same batch the delta lands: must run on the NEW graph
    t_same = srv.submit("sssp", {"source": 11})
    srv.run()
    return srv, t_flight, t_ppr, t_same


@pytest.mark.parametrize("delta_mode", ["warm", "restart"])
@pytest.mark.parametrize("loosening", [False, True])
def test_delta_in_flight_and_same_batch_arrival(delta_mode, loosening):
    srv, t_flight, t_ppr, t_same = _delta_setup(delta_mode, loosening=loosening)
    g2 = srv.g
    # the same-batch arrival is solo-exact on the mutated graph, rounds incl.
    solo_same = run_async_block(get_algorithm("sssp", g2, source=11), bs=BS)
    assert t_same.rounds == solo_same.rounds
    np.testing.assert_array_equal(t_same.result, solo_same.x)
    # the in-flight min-semiring query resolves the exact new fixpoint
    # (bitwise) in both modes; restart additionally keeps solo round counts
    solo_flight = run_async_block(get_algorithm("sssp", g2, source=0), bs=BS)
    np.testing.assert_array_equal(t_flight.result, solo_flight.x)
    if delta_mode == "restart":
        assert t_flight.rounds == solo_flight.rounds
    # the in-flight sum-semiring query lands within stopping tolerance
    solo_ppr = run_async_block(personalized_pagerank(g2, [7]), bs=BS)
    np.testing.assert_allclose(t_ppr.result, solo_ppr.x, atol=1e-5, rtol=0)


def test_delta_bumps_version_and_reruns_invalidated():
    srv = GraphServer(GW, slots=2, bs=BS, rounds_per_batch=4)
    t1 = srv.submit("sssp", {"source": 0})
    srv.run()
    delta = random_delta(GW, frac_add=0.01, seed=6)
    srv.apply_delta(delta)
    assert srv.graph_version == 1
    t2 = srv.submit("sssp", {"source": 0})
    assert t2.status != "cached"    # support intersects this dense delta
    srv.run()
    solo = run_async_block(get_algorithm("sssp", srv.g, source=0), bs=BS)
    np.testing.assert_array_equal(t2.result, solo.x)
    assert t2.rounds == solo.rounds


# ---------------------------------------------------------------------------
# result cache: region invalidation
# ---------------------------------------------------------------------------

def _two_component_graph():
    """Components in disjoint block ranges: A = blocks 0..2, B = 3..5."""
    ga = gen.powerlaw_cluster(3 * BS, 4, p=0.3, seed=3)
    gb = gen.powerlaw_cluster(3 * BS, 4, p=0.3, seed=4)
    src = np.concatenate([ga.src, gb.src + ga.n])
    dst = np.concatenate([ga.dst, gb.dst + ga.n])
    g = Graph(ga.n + gb.n, src, dst)
    return gen.with_random_weights(g, lo=0.1, hi=1.0, seed=7), ga.n


def test_cache_survives_far_delta_and_dies_on_near_delta():
    g2c, n_a = _two_component_graph()
    srv = GraphServer(g2c, slots=2, bs=BS, rounds_per_batch=4)
    ta = srv.submit("sssp", {"source": 5})           # component A
    tb = srv.submit("sssp", {"source": n_a + 5})     # component B
    srv.run()
    # delta confined to component B's blocks
    delta = GraphDelta(
        add_src=[n_a + 10, n_a + 40], add_dst=[n_a + 90, n_a + 120],
        add_w=[0.5, 0.5],
    )
    srv.apply_delta(delta)
    hit = srv.submit("sssp", {"source": 5})
    miss = srv.submit("sssp", {"source": n_a + 5})
    assert hit.status == "cached", "A-entry must survive a B-only delta"
    assert miss.status != "cached", "B-entry must be invalidated"
    srv.run()
    # the promoted answer is still the exact answer on the mutated graph
    solo_a = run_async_block(get_algorithm("sssp", srv.g, source=5), bs=BS)
    np.testing.assert_array_equal(hit.result, solo_a.x)
    solo_b = run_async_block(get_algorithm("sssp", srv.g, source=n_a + 5), bs=BS)
    np.testing.assert_array_equal(miss.result, solo_b.x)
    assert srv.cache.stats()["promoted"] >= 1
    assert ta.result is not None and tb.result is not None


def test_cache_extends_promoted_entries_over_appended_vertices():
    g2c, n_a = _two_component_graph()
    srv = GraphServer(g2c, slots=2, bs=BS, rounds_per_batch=4)
    srv.submit("sssp", {"source": 5})
    srv.run()
    # append a vertex wired into component B only
    delta = GraphDelta(n_add=1, add_src=[n_a + 3], add_dst=[g2c.n],
                       add_w=[0.5])
    srv.apply_delta(delta)
    hit = srv.submit("sssp", {"source": 5})
    assert hit.status == "cached"
    assert hit.result.shape == (g2c.n + 1,)
    solo = run_async_block(get_algorithm("sssp", srv.g, source=5), bs=BS)
    np.testing.assert_array_equal(hit.result, solo.x)


# ---------------------------------------------------------------------------
# scheduler policies
# ---------------------------------------------------------------------------

def _resolution_order(policy, submits):
    srv = GraphServer(GW, slots=1, bs=BS, rounds_per_batch=4, policy=policy,
                      cache=False)
    tickets = {}
    for label, src, kw in submits:
        tickets[label] = srv.submit("sssp", {"source": src}, **kw)
    srv.run()
    return sorted(tickets, key=lambda k: tickets[k].resolved_at)


def test_priority_policy_orders_admission():
    order = _resolution_order("priority", [
        ("lo", 3, {"priority": 0}),
        ("hi", 17, {"priority": 5}),
        ("mid", 29, {"priority": 2}),
    ])
    assert order == ["hi", "mid", "lo"]


def test_deadline_policy_is_edf():
    order = _resolution_order("deadline", [
        ("late", 3, {"deadline": 100.0}),
        ("soon", 17, {"deadline": 1.0}),
        ("none", 29, {}),
    ])
    assert order == ["soon", "late", "none"]


def test_fifo_policy_is_arrival_order():
    order = _resolution_order("fifo", [
        ("a", 3, {"priority": 0}),
        ("b", 17, {"priority": 9}),   # priority ignored under fifo
        ("c", 29, {}),
    ])
    assert order == ["a", "b", "c"]


def test_family_key_groups_structurally():
    assert family_key("sssp", {"source": 1}) == family_key("sssp", {"source": 9})
    assert family_key("sssp", {"source": 1, "eps": 0.5}) != \
        family_key("sssp", {"source": 1, "eps": 2.5})
    assert family_key("ppr", {"seeds": [3]}) == family_key("ppr", {"seeds": [8]})
    assert family_key("pagerank", {"damping": 0.85}) != \
        family_key("pagerank", {"damping": 0.5})


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        Scheduler("lifo")


def test_submit_validation():
    srv = GraphServer(GW, slots=2, bs=BS)
    with pytest.raises(KeyError, match="unknown algorithm"):
        srv.submit("dijkstra", {})
    t = srv.submit("ppr", {"seeds": [1, 2, 3]})   # d=3: one query per ticket
    srv.run()
    assert t.status == "failed" and "one query per ticket" in t.error


# ---------------------------------------------------------------------------
# continuous vs static refill (the point of the subsystem)
# ---------------------------------------------------------------------------

def _skewed_graph():
    """Hub cluster + a path tail feeding INTO the hub: hub SSSP queries
    converge in a few sweeps (they never reach the tail), tail-depth
    queries need many (the paper-Fig.-7 skew, condensed). Returns the
    served graph and the scramble rank (pre-scramble id -> served id)."""
    hub = gen.powerlaw_cluster(160, 4, p=0.3, seed=2)
    path_n = 96
    n = hub.n + path_n
    ps = np.arange(hub.n + 1, n, dtype=np.int32)   # p_k -> p_{k-1}
    pd = np.arange(hub.n, n - 1, dtype=np.int32)
    g = Graph(n, np.concatenate([hub.src, ps, [hub.n]]),
              np.concatenate([hub.dst, pd, [0]]))
    rank = np.random.default_rng(13).permutation(n).astype(np.int64)
    return gen.with_random_weights(g.relabel(rank), lo=0.1, hi=1.0, seed=3), rank


def test_continuous_batching_beats_static_on_skewed_rounds():
    gw, rank = _skewed_graph()
    rng = np.random.default_rng(0)
    # 8 fast hub sources + 4 slow tail sources, interleaved
    pre = np.concatenate([rng.integers(0, 160, size=8),
                          160 + rng.integers(48, 96, size=4)])
    rng.shuffle(pre)
    sources = [int(s) for s in rank[pre]]
    results = {}
    for refill in ("continuous", "static"):
        srv = GraphServer(gw, slots=4, bs=BS, rounds_per_batch=2,
                          refill=refill, cache=False)
        ts = [srv.submit("sssp", {"source": s}) for s in sources]
        srv.run()
        for t, s in zip(ts, sources, strict=True):
            solo = _solo("sssp", s, graph=gw, key=("skew", s))
            assert t.rounds == solo.rounds
            np.testing.assert_array_equal(t.result, solo.x)
        results[refill] = srv.stats.rounds_total
    assert results["continuous"] < results["static"], results


# ---------------------------------------------------------------------------
# pallas backends through the server
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sweeps", [1, 2])
def test_server_pallas_backend_bitwise(sweeps):
    srv = GraphServer(GW, slots=2, bs=BS, rounds_per_batch=2,
                      backend="pallas", sweeps_per_call=sweeps, cache=False)
    ts = [srv.submit("sssp", {"source": s}) for s in (0, 7, 100)]
    srv.run()
    for t in ts:
        solo = _solo("sssp", t.params["source"])
        assert t.rounds == solo.rounds, (sweeps, t.params)
        np.testing.assert_array_equal(t.result, solo.x)


# ---------------------------------------------------------------------------
# deduplicated convergence accounting (satellite regression)
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_converge_step_matches_inline_reference(seed):
    """The shared implementation reproduces the exact logic both round
    drivers previously inlined — on numpy AND jax arrays, bit-for-bit."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 9))
    res = rng.uniform(0, 2, d).astype(np.float32)
    eps = float(rng.uniform(0, 2))
    done = rng.random(d) < 0.3
    rounds = rng.integers(0, 50, d).astype(np.int32)
    # the pre-refactor inline logic, verbatim
    ref_active = ~done
    ref_newly = ref_active & (res <= eps)
    ref_done = done | ref_newly
    ref_rounds = rounds + ref_active.astype(np.int32)
    for xp in (np, jnp):
        newly, active, done2, rounds2 = converge_step(
            xp.asarray(res), eps, xp.asarray(done), xp.asarray(rounds)
        )
        np.testing.assert_array_equal(np.asarray(newly), ref_newly)
        np.testing.assert_array_equal(np.asarray(active), ref_active)
        np.testing.assert_array_equal(np.asarray(done2), ref_done)
        np.testing.assert_array_equal(np.asarray(rounds2), ref_rounds)


def test_reinit_columns_is_freeze_inverse():
    done = np.array([True, True, False, True])
    rounds = np.array([5, 9, 3, 7], np.int32)
    done2, rounds2 = reinit_columns(done, rounds, [1, 3])
    np.testing.assert_array_equal(done2, [True, False, False, False])
    np.testing.assert_array_equal(rounds2, [5, 0, 3, 0])
    # inputs untouched
    assert done[1] and rounds[1] == 9


def test_column_support_marks_inputs_and_reach():
    q = get_algorithm("sssp", GW, source=4)
    sup = harness.column_support(
        q.x0[:, 0], q.c[:, 0], q.fixed[:, 0],
        reduce="min", c_fill=q.c_pad_fill,
    )
    assert sup[4] and sup.sum() == 1          # only the source injects
    solo = _solo("sssp", 4)
    sup_x = harness.column_support(
        q.x0[:, 0], q.c[:, 0], q.fixed[:, 0],
        reduce="min", c_fill=q.c_pad_fill, x=solo.x,
    )
    reached = solo.x < 3.0e38
    np.testing.assert_array_equal(sup_x, reached | sup)


def test_percentile_nearest_rank():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 99) == 5.0
    vals = list(range(1, 101))
    assert percentile(vals, 50) == 50
    assert percentile(vals, 99) == 99
    assert percentile(vals, 100) == 100


def test_swap_in_column_keeps_padding():
    q = get_algorithm("sssp", GW, source=0)
    d = 3
    fam = dataclasses.replace(
        q, x0=np.zeros((q.n, d), np.float32),
        c=np.full((q.n, d), q.c_pad_fill, np.float32),
        fixed=np.ones((q.n, d), bool), exact_fn=None, params=None,
    )
    _, x0, c, fixed, npad = harness.pack(fam, BS)
    x = x0.copy()
    harness.swap_in_column(x, x0, c, fixed, 1, q.n,
                           q.x0[:, 0], q.c[:, 0], q.fixed[:, 0])
    np.testing.assert_array_equal(x0[: q.n, 1], q.x0[:, 0])
    np.testing.assert_array_equal(x[:, 1], x0[:, 1])
    # padding rows keep the reduce-identity fill in every column
    assert (x0[q.n :, :] == fam.semiring.identity).all()
    assert fixed[q.n :, :].all()


# ---------------------------------------------------------------------------
# multi-tenancy: several graphs per server, fair slots, scoped deltas
# ---------------------------------------------------------------------------

def _second_graph():
    g = gen.scrambled(gen.powerlaw_cluster(N + 40, 4, p=0.4, seed=11), seed=3)
    return gen.with_random_weights(g, lo=0.1, hi=1.0, seed=4)


GW2 = _second_graph()


def test_multi_tenant_results_match_each_tenants_graph():
    srv = GraphServer(GW, slots=2, bs=BS, rounds_per_batch=4)
    srv.add_tenant("b", GW2)
    t_a = srv.submit("sssp", {"source": 0})
    t_b = srv.submit("sssp", {"source": 0}, tenant="b")
    srv.run()
    solo_a = _solo("sssp", 0)
    solo_b = run_async_block(get_algorithm("sssp", GW2, source=0), bs=BS)
    _check_ticket(t_a, solo_a)
    assert t_b.rounds == solo_b.rounds
    np.testing.assert_array_equal(t_b.result, solo_b.x)
    assert t_a.result.shape != t_b.result.shape  # really two graphs


def test_multi_tenant_fair_round_robin():
    """Symmetric load on two tenants -> batch counts within one of each
    other: the rotating interleave gives every tenant with work a batch
    before any tenant gets a second one."""
    srv = GraphServer(graphs={"a": GW, "b": GW}, slots=2, bs=BS,
                      rounds_per_batch=2)
    for s in (0, 3, 9, 14):
        srv.submit("ppr", {"seeds": [s]}, tenant="a")
        srv.submit("ppr", {"seeds": [s]}, tenant="b")
    srv.run()
    tb = srv.stats.tenant_batches
    assert set(tb) == {"a", "b"}
    assert abs(tb["a"] - tb["b"]) <= 1, tb
    tr = srv.stats.tenant_rounds
    assert tr["a"] > 0 and tr["b"] > 0
    s = srv.stats.summary()
    assert s["tenant_batches"] == tb and s["tenant_rounds"] == tr


def test_multi_tenant_delta_scoped_to_one_tenant():
    """Tenant a's delta bumps only a's version and can only invalidate a's
    cache entries; tenant b's cached result keeps serving hits."""
    srv = GraphServer(graphs={"a": GW, "b": GW2}, slots=2, bs=BS,
                      rounds_per_batch=4)
    srv.submit("pagerank", {}, tenant="a")
    srv.submit("pagerank", {}, tenant="b")
    srv.run()
    assert len(srv.cache) == 2
    delta = random_delta(GW, frac_add=0.01, seed=5)
    srv.apply_delta(delta, tenant="a")
    assert srv.tenants["a"].graph_version == 1
    assert srv.tenants["b"].graph_version == 0
    # pagerank has global support: a's entry must die, b's must survive
    t_b = srv.submit("pagerank", {}, tenant="b")
    assert t_b.from_cache
    t_a = srv.submit("pagerank", {}, tenant="a")
    assert not t_a.from_cache
    srv.run()
    solo_a = run_async_block(get_algorithm("pagerank", srv.tenants["a"].g),
                             bs=BS)
    assert t_a.rounds == solo_a.rounds
    np.testing.assert_allclose(t_a.result, solo_a.x, atol=1e-5, rtol=0)


def test_tenant_validation():
    srv = GraphServer(GW, slots=2, bs=BS)
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.submit("sssp", {"source": 0}, tenant="nope")
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.apply_delta(random_delta(GW, frac_add=0.01, seed=1), tenant="no")
    srv.add_tenant("b", GW2)
    with pytest.raises(ValueError, match="duplicate tenant"):
        srv.add_tenant("b", GW2)
    with pytest.raises(ValueError, match="duplicate tenant"):
        GraphServer(GW, graphs={"default": GW2})
    with pytest.raises(ValueError, match="at least one graph"):
        GraphServer()


# ---------------------------------------------------------------------------
# byte-budgeted LRU result cache
# ---------------------------------------------------------------------------

def test_cache_lru_evicts_oldest_within_budget():
    from repro.serving import ResultCache

    x = np.zeros(100, np.float32)          # 400 bytes + overhead per entry
    per = x.nbytes + 256
    c = ResultCache(max_bytes=3 * per)
    for i in range(4):
        c.put(("t", "a", i), x, 1, [0], 0, x0_fill=0.0)
    assert len(c) == 3 and c.bytes <= 3 * per
    assert c.get(("t", "a", 0), 0) is None          # LRU entry evicted
    assert c.get(("t", "a", 1), 0) is not None      # ...and now refreshed
    c.put(("t", "a", 9), x, 1, [0], 0, x0_fill=0.0)
    assert c.get(("t", "a", 2), 0) is None          # 2 was the new LRU
    assert c.get(("t", "a", 1), 0) is not None
    assert c.stats()["evicted"] == 2
    # an entry bigger than the whole budget is not retained
    c.put(("t", "big", 0), np.zeros(10_000, np.float32), 1, [0], 0,
          x0_fill=0.0)
    assert c.get(("t", "big", 0), 0) is None
    with pytest.raises(ValueError, match="max_bytes"):
        ResultCache(max_bytes=-1)


def test_server_cache_budget_end_to_end():
    per_entry = GW.n * 4 + 256
    srv = GraphServer(GW, slots=2, bs=BS, rounds_per_batch=4,
                      cache_max_bytes=2 * per_entry)
    for s in (0, 3, 9, 14):
        srv.submit("ppr", {"seeds": [s]})
    srv.run()
    st = srv.cache.stats()
    assert st["entries"] <= 2 and st["bytes"] <= 2 * per_entry
    assert st["evicted"] >= 2
    # the retained (most recent) entries still serve hits
    t = srv.submit("ppr", {"seeds": [14]})
    assert t.from_cache
