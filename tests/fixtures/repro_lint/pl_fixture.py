"""Seeded Pallas resource violations for the repro-lint self-tests.

Never imported (the dimension names are deliberately unbound) — the checker
parses this as source and evaluates shapes at the budget points the test
injects. Line numbers are asserted exactly in tests/test_repro_lint.py.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def bad_kernel(x):
    return pl.pallas_call(
        lambda x_ref, o_ref: None,
        grid=(nb,),
        in_specs=[pl.BlockSpec((bs, d), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((bs, d), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        input_output_aliases={5: 0},
    )(x)


def unbudgeted_kernel(x):
    return pl.pallas_call(
        lambda x_ref, o_ref: None,
        grid=(4,),
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )(x)


def unresolvable_kernel(x):
    return pl.pallas_call(
        lambda x_ref, o_ref: None,
        grid=(4,),
        scratch_shapes=[pltpu.VMEM((mystery_dim, 8), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )(x)
