"""Seeded options-drift violations for the repro-lint self-tests.

A knob dataclass with one validated, documented field (``bs``) and one
field nothing validates or documents (``unchecked``).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    bs: int = 8
    unchecked: int = 0


def validate_options(engine, o, algo=None):
    if o.bs < 1:
        raise ValueError("bs must be >= 1")
