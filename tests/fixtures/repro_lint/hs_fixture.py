"""Seeded host-sync violations for the repro-lint self-tests.

Never imported — tests feed this file to the checker as source. Line
numbers are asserted exactly in tests/test_repro_lint.py; edit with care.
"""
import jax
import jax.numpy as jnp
import numpy as np


def leaks():
    x = jnp.zeros((4, 4))
    a = float(jnp.sum(x))
    b = x.item()
    c = np.asarray(x)
    if x:
        pass
    d = jax.device_get(x)
    e = jax.device_get(x)  # repro: allow-host-sync(audited test readout)
    g = jax.device_get(x)  # repro: allow-host-sync()
    return a, b, c, d, e, g


def multiline_pragma_covers():
    x = jnp.ones((2, 2))
    y = jax.device_get(
        x
    )  # repro: allow-host-sync(pragma sits on the closing-paren line)
    return y


def host_only_stays_quiet(values):
    arr = np.asarray(values)
    total = float(np.sum(arr))
    if arr.size:
        total += int(arr[0])
    return total
