"""Seeded observability-hot-path violations for the repro-lint self-tests.

Never imported — tests feed this file to the checker as source. It models
the bug class the tracing layer's contract forbids (`repro.obs.trace`):
span/metric attribute values must already be host scalars, so an implicit
coercion of a jax array *at the recording call site* is a hidden
device->host sync. Line numbers are asserted exactly in
tests/test_repro_lint.py; edit with care.
"""
import jax
import jax.numpy as jnp
import numpy as np


def record_batch_span(tracer, deltas):
    deltas = jnp.asarray(deltas)
    with tracer.span("sweep_call", sweeps=4) as sp:
        sp.set(max_delta=float(jnp.max(deltas)))
    return sp


def record_metric_observation(hist, state):
    state = jnp.asarray(state)
    hist.observe(state.sum().item(), tenant="default")


def audited_readout_stays_quiet(tracer, deltas):
    deltas_np = np.asarray(
        jax.device_get(deltas)  # repro: allow-host-sync(batch trace readout)
    )
    with tracer.span("sweep_call") as sp:
        sp.set(max_delta=float(np.max(deltas_np)))
    return sp
