"""Per-arch REDUCED smoke tests: one forward/train step on CPU, asserting
output shapes and no NaNs — plus decode<->forward consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_reduced
from repro.models.model import build_model


def _batch(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
    if cfg.arch_type == "encdec":
        frames = jax.random.normal(key, (b, s, cfg.d_model))
        return {"frames": frames, "tokens": toks[:, :-1], "labels": toks[:, 1:]}
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.prefix_len:
        batch["prefix_embeds"] = jax.random.normal(
            key, (b, cfg.prefix_len, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_smoke_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, extras = model.loss_fn(params, batch)
    assert jnp.isfinite(loss), arch
    assert 1.0 < float(loss) < 20.0, (arch, float(loss))
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert jnp.isfinite(leaf).all(), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_smoke_forward_shapes(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    if cfg.arch_type == "encdec":
        from repro.models import encdec as E

        frames = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model))
        enc = E.encode(cfg, params, frames)
        assert enc.shape == (b, s, cfg.d_model)
        logits, _ = E.decoder_forward(cfg, params, toks, enc)
        assert logits.shape == (b, s, cfg.vocab)
    else:
        kw = {}
        if cfg.prefix_len:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (b, cfg.prefix_len, cfg.d_model)
            )
        logits, _, _ = model.forward(params, toks, **kw)
        assert logits.shape == (b, s + cfg.prefix_len, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if
                                  get_reduced(a).arch_type == "decoder"
                                  and not get_reduced(a).prefix_len])
def test_reduced_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    if cfg.moe_experts:
        # capacity dropping is position-dependent (a token near the end of a
        # full sequence can be dropped where a decode step never is); with a
        # no-drop capacity factor decode must match forward exactly
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_capacity=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    _, caches = model.prefill(params, toks[:, :-1], max_seq=s)
    step_logits, _ = model.decode_step(
        params, caches, toks[:, -1:], jnp.full((b,), s - 1)
    )
    full_logits, _, _ = model.forward(params, toks)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, -1]),
        atol=2e-4, rtol=2e-3,
    )


def test_chunked_attention_matches_full():
    from repro.models.attention import AttnConfig, attention_chunked, attention_full

    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, hd = 2, 64, 8, 4, 16
    cfg = AttnConfig(d_model=0, n_heads=hq, n_kv=hkv, head_dim=hd, kv_chunk=16)
    q = jax.random.normal(key, (b, s, hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
    pos = jnp.arange(s)
    o1 = attention_full(cfg, q, k, v, pos, pos)
    o2 = attention_chunked(cfg, q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5, rtol=1e-4)
    # sliding window variant
    import dataclasses
    cfgw = dataclasses.replace(cfg, window=7)
    o1w = attention_full(cfgw, q, k, v, pos, pos)
    o2w = attention_chunked(cfgw, q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(o1w), np.asarray(o2w), atol=1e-5, rtol=1e-4)


def test_moe_capacity_and_aux():
    from repro.models.moe import MoEConfig, init_moe, apply_moe

    cfg = MoEConfig(d_model=16, n_experts=6, top_k=2, d_expert=8, n_shared=1,
                    pad_experts_to=8)
    params, _ = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    y, aux = apply_moe(cfg, params, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    # padded experts must never receive tokens: zero their weights and check
    # output is unchanged
    p2 = jax.tree.map(lambda a: a, params)
    p2["wi"] = p2["wi"].at[6:].set(1e6)
    y2, _ = apply_moe(cfg, p2, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)


def test_rglru_step_matches_scan():
    from repro.models.recurrent import RGLRUConfig, init_rglru, apply_rglru, rglru_state

    cfg = RGLRUConfig(d_model=16, d_rnn=16)
    params, _ = init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y_full, _ = apply_rglru(cfg, params, x)
    state = rglru_state(cfg, 2, jnp.float32)
    ys = []
    for t in range(8):
        y_t, state = apply_rglru(cfg, params, x[:, t:t + 1], state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-4, rtol=1e-3)


def test_mlstm_chunk_invariance():
    """Chunked mLSTM must give the same output for any chunk size."""
    from repro.models.recurrent import MLSTMConfig, init_mlstm, apply_mlstm
    import dataclasses

    cfg = MLSTMConfig(d_model=16, n_heads=2, chunk=16)
    params, _ = init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16)) * 0.5
    y16, _ = apply_mlstm(cfg, params, x)
    y4, _ = apply_mlstm(dataclasses.replace(cfg, chunk=4), params, x)
    y1, _ = apply_mlstm(dataclasses.replace(cfg, chunk=1), params, x)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y4), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y1), atol=1e-4, rtol=1e-3)


def test_config_dims_match_assignment():
    """The exact published dims from the assignment table."""
    expect = {
        "olmo-1b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv=16,
                        d_ff=8192, vocab=50304, norm_kind="nonparam"),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32, n_kv=32,
                            d_ff=11008, vocab=102400),
        "gemma3-4b": dict(n_layers=34, d_model=2560, n_heads=8, n_kv=4,
                          d_ff=10240, vocab=262144, head_dim=256),
        "gemma-7b": dict(n_layers=28, d_model=3072, n_heads=16, n_kv=16,
                         d_ff=24576, vocab=256000, head_dim=256,
                         mlp_kind="geglu"),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16, n_kv=16,
                                vocab=151936, moe_experts=60, moe_top_k=4,
                                moe_d_expert=1408, moe_shared=4),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv=8, vocab=49155, moe_experts=32,
                                     moe_top_k=8, moe_d_expert=512),
        "internvl2-76b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv=8,
                              d_ff=28672, vocab=128256),
        "xlstm-350m": dict(n_layers=24, d_model=1024, n_heads=4, d_ff=0,
                           vocab=50304),
        "whisper-tiny": dict(d_model=384, n_heads=6, d_ff=1536, vocab=51865,
                             enc_layers=4, dec_layers=4, arch_type="encdec"),
        "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                                  n_kv=1, d_ff=7680, vocab=256000),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_gemma3_pattern_is_5_local_1_global():
    cfg = get_config("gemma3-4b")
    assert cfg.pattern == ("local+mlp",) * 5 + ("attn+mlp",)
    assert cfg.subquadratic


def test_recurrentgemma_pattern():
    cfg = get_config("recurrentgemma-2b")
    assert cfg.pattern == ("rglru+mlp", "rglru+mlp", "local+mlp")
    kinds = [cfg.pattern[i % 3] for i in range(cfg.n_layers)]
    assert kinds.count("local+mlp") == 8  # 26 layers -> 8 attention blocks


def test_attention_chunked_q_matches_full():
    """Doubly-chunked (q+kv) attention must be exact."""
    import dataclasses
    from repro.models.attention import (
        AttnConfig, attention_chunked_q, attention_full,
    )

    key = jax.random.PRNGKey(3)
    b, s, hq, hkv, hd = 2, 64, 4, 2, 16
    cfg = AttnConfig(d_model=0, n_heads=hq, n_kv=hkv, head_dim=hd, kv_chunk=8)
    q = jax.random.normal(key, (b, s, hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
    pos = jnp.arange(s)
    o_full = attention_full(cfg, q, k, v, pos, pos)
    for qc in (8, 16, 24):
        o_q = attention_chunked_q(cfg, q, k, v, pos, pos, qc)
        np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_q),
                                   atol=1e-5, rtol=1e-4)
    # sliding window variant
    cfgw = dataclasses.replace(cfg, window=11)
    o_fw = attention_full(cfgw, q, k, v, pos, pos)
    o_qw = attention_chunked_q(cfgw, q, k, v, pos, pos, 16)
    np.testing.assert_allclose(np.asarray(o_fw), np.asarray(o_qw),
                               atol=1e-5, rtol=1e-4)


def test_slstm_time_chunk_invariance():
    """sLSTM output/state must be identical for any time_chunk."""
    import dataclasses
    from repro.models.recurrent import SLSTMConfig, init_slstm, apply_slstm

    cfg1 = SLSTMConfig(d_model=16, n_heads=2, time_chunk=1)
    params, _ = init_slstm(jax.random.PRNGKey(0), cfg1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16)) * 0.5
    y1, s1 = apply_slstm(cfg1, params, x)
    for tc in (4, 8, 24):
        cfg = dataclasses.replace(cfg1, time_chunk=tc)
        y, s = apply_slstm(cfg, params, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y),
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1["c"]), np.asarray(s["c"]),
                                   atol=1e-5, rtol=1e-4)


def test_int8_kv_cache_decode_close_to_bf16():
    """int8 KV quantization: decode logits stay close to the full-precision
    path; at-rest cache is half the bytes."""
    import dataclasses
    cfg = get_reduced("deepseek-7b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m = build_model(cfg)
    m8 = build_model(cfg8)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    _, caches = m.prefill(params, toks[:, :-1], max_seq=16)
    _, caches8 = m8.prefill(params, toks[:, :-1], max_seq=16)
    # capacity: int8 k/v leaves are 1 byte/elt vs 4 (f32 reduced config)
    k = jax.tree.leaves(caches)[0]
    k8 = [l for l in jax.tree.leaves(caches8) if l.dtype == jnp.int8][0]
    assert k8.dtype == jnp.int8
    pos = jnp.full((2,), 15)
    lo, _ = m.decode_step(params, caches, toks[:, -1:], pos)
    lo8, _ = m8.decode_step(params, caches8, toks[:, -1:], pos)
    # logits agree to quantization tolerance and rank the same argmax
    assert jnp.mean(jnp.abs(lo - lo8)) < 0.05 * jnp.std(lo)
    assert jnp.array_equal(jnp.argmax(lo, -1), jnp.argmax(lo8, -1))
