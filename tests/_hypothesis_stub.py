"""Deterministic mini-implementation of the `hypothesis` API surface the
test-suite uses (given / settings / strategies.{integers,floats,sampled_from,
composite}).

conftest.py installs this as ``sys.modules["hypothesis"]`` ONLY when the real
package is missing (the hermetic tier-1 environment cannot pip-install). CI
installs real hypothesis via ``pip install -e .[test]`` and never sees this
file. Examples are drawn from a per-test seeded PRNG, so runs are
reproducible; there is no shrinking and no database — this is a fallback, not
a replacement.
"""
from __future__ import annotations

import functools
import random
import types


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example_from(self, rng: random.Random):
        return self._sample(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: options[rng.randrange(len(options))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def _just(value):
    return _Strategy(lambda rng: value)


def _composite(fn):
    @functools.wraps(fn)
    def build(*args, **kw):
        def sample(rng):
            return fn(lambda strat: strat.example_from(rng), *args, **kw)

        return _Strategy(sample)

    return build


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies, **kw_strategies):
    def deco(test_fn):
        @functools.wraps(test_fn)
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", 10)
            rng = random.Random(f"{test_fn.__module__}.{test_fn.__qualname__}")
            for _ in range(n):
                args = [s.example_from(rng) for s in strategies]
                kw = {k: s.example_from(rng) for k, s in kw_strategies.items()}
                test_fn(*args, **kw)

        # pytest resolves fixtures from inspect.signature, which follows
        # __wrapped__ — drop it so the drawn parameters aren't mistaken for
        # fixture requests (real hypothesis does the same signature rewrite)
        del wrapper.__wrapped__
        return wrapper

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans
strategies.just = _just
strategies.composite = _composite

HealthCheck = types.SimpleNamespace(
    too_slow="too_slow", data_too_large="data_too_large",
    filter_too_much="filter_too_much",
)


def assume(condition) -> bool:
    """Stub assume: silently accept (no example rejection machinery)."""
    return bool(condition)
