"""Evolving-graph serving: GraphDelta, warm starts, the incremental engine,
incremental order maintenance — plus regression tests for the PR's engine
bugfixes (sssp/bfs eps plumbing, metric_m_jax dtype, gs_sweep guard)."""
import numpy as np
import pytest

from repro.core.gograph import extend_rank, gograph_order
from repro.engine import (
    ALGORITHMS,
    get_algorithm,
    remake,
    run_async_block,
    run_incremental,
    run_sync,
)
from repro.engine.algorithms import make_bfs, make_sssp
from repro.engine.incremental import instance_edge_diff, warm_state
from repro.graphs import generators as gen
from repro.graphs.delta import GraphDelta, random_delta
from repro.graphs.graph import Graph


@pytest.fixture(scope="module")
def graphs():
    g = gen.scrambled(gen.powerlaw_cluster(700, 4, p=0.4, seed=1), seed=9)
    gw = gen.with_random_weights(g, seed=2)
    return g, gw


def _algo(name, g, gw):
    return get_algorithm(name, gw if name in ("sssp", "sswp", "ms_sssp") else g)


ENGINES = {
    "sync": lambda a, **kw: run_sync(a, **kw),
    "async_block": lambda a, **kw: run_async_block(a, bs=64, **kw),
}


# ---------------------------------------------------------------------------
# GraphDelta
# ---------------------------------------------------------------------------

def test_graph_delta_apply_semantics():
    g = Graph(4, [0, 1, 2], [1, 2, 3], np.array([1.0, 2.0, 3.0], np.float32))
    d = GraphDelta(
        n_add=1,
        add_src=[3, 4], add_dst=[4, 0], add_w=[5.0, 6.0],
        del_src=[0], del_dst=[1],
        rew_src=[1], rew_dst=[2], rew_w=[9.0],
    )
    g2 = d.apply(g)
    assert g2.n == 5
    pairs = {(int(s), int(t)): float(w)
             for s, t, w in zip(g2.src, g2.dst, g2.weights, strict=True)}
    assert pairs == {(1, 2): 9.0, (2, 3): 3.0, (3, 4): 5.0, (4, 0): 6.0}
    # original untouched
    assert g.m == 3 and g.n == 4


def test_graph_delta_unweighted_stays_unweighted():
    g = Graph(3, [0, 1], [1, 2])
    g2 = GraphDelta(add_src=[2], add_dst=[0]).apply(g)
    assert g2.w is None and g2.m == 3
    # reweighting an unweighted graph materializes weights
    g3 = GraphDelta(rew_src=[0], rew_dst=[1], rew_w=[4.0]).apply(g)
    assert g3.w is not None
    assert float(g3.weights[0]) == 4.0 and float(g3.weights[1]) == 1.0


def test_graph_delta_rejects_out_of_range_del_rew():
    """Out-of-range del/rew endpoints would alias a different edge through
    the src*n+dst key packing (e.g. key 0*10+13 == 1*10+3)."""
    g = Graph(10, [1], [3], np.array([1.0], np.float32))
    with pytest.raises(ValueError, match="out of range"):
        GraphDelta(rew_src=[0], rew_dst=[13], rew_w=[99.0]).apply(g)
    with pytest.raises(ValueError, match="out of range"):
        GraphDelta(del_src=[0], del_dst=[13]).apply(g)
    assert float(g.weights[0]) == 1.0


def test_random_delta_no_parallel_edges(graphs):
    """Seed edges for appended vertices must join the dedupe set, or a later
    uniform insertion can duplicate them (parallel edges double a sum
    semiring's contribution)."""
    g, _ = graphs
    for seed in range(8):
        d = random_delta(g, frac_add=0.05, n_add_vertices=10, seed=seed)
        g2 = d.apply(g)
        keys = g2.src.astype(np.int64) * g2.n + g2.dst
        assert len(np.unique(keys)) == len(keys), f"seed {seed}"


def test_random_delta_shapes_and_ranges(graphs):
    g, gw = graphs
    d = random_delta(gw, frac_add=0.02, frac_del=0.01, frac_rew=0.01,
                     n_add_vertices=5, seed=0)
    g2 = d.apply(gw)
    assert g2.n == gw.n + 5
    assert d.add_w is not None  # weighted graph gets weighted insertions
    # every appended vertex has at least one incident edge
    deg = g2.degrees()
    assert (deg[gw.n:] > 0).all()
    # deleted pairs are gone
    keys2 = set((g2.src.astype(np.int64) * g2.n + g2.dst).tolist())
    for s, t in zip(d.del_src, d.del_dst, strict=True):
        assert int(s) * g2.n + int(t) not in keys2


# ---------------------------------------------------------------------------
# warm starts: every engine x every algorithm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", sorted(ENGINES))
@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_warm_restart_is_bitwise_noop(graphs, name, engine):
    """x_init = converged state => one verification sweep, state unchanged
    bitwise (the loop keeps the pre-sweep state of a converging column)."""
    g, gw = graphs
    algo = _algo(name, g, gw)
    run = ENGINES[engine]
    r1 = run(algo)
    assert r1.converged
    r2 = run(algo, x_init=r1.x)
    assert r2.rounds <= 1, f"{name}/{engine}: {r2.rounds} rounds"
    np.testing.assert_array_equal(r2.x, r1.x, err_msg=f"{name}/{engine}")


def test_warm_restart_pallas_backend(graphs):
    g, gw = graphs
    for name in ("pagerank", "sssp"):  # the kernel's two semiring pairs
        algo = _algo(name, g, gw)
        r1 = run_async_block(algo, bs=64, backend="pallas", max_iters=300)
        r2 = run_async_block(algo, bs=64, backend="pallas", max_iters=300,
                             x_init=r1.x)
        assert r2.rounds <= 1 and np.array_equal(r2.x, r1.x), name


def test_warm_restart_distributed_all_algorithms():
    from tests.util import run_with_devices

    run_with_devices("""
import numpy as np
from repro.graphs import generators as gen
from repro.engine import ALGORITHMS, get_algorithm
from repro.engine.distributed import run_distributed
g = gen.scrambled(gen.powerlaw_cluster(300, 3, p=0.4, seed=1), seed=5)
gw = gen.with_random_weights(g, seed=2)
for name in sorted(ALGORITHMS):
    algo = get_algorithm(name, gw if name in ('sssp', 'sswp', 'ms_sssp') else g)
    r1 = run_distributed(algo, bs=32)
    assert r1.converged, name
    r2 = run_distributed(algo, bs=32, x_init=r1.x)
    assert r2.rounds <= 1, (name, r2.rounds)
    np.testing.assert_array_equal(r2.x, r1.x, err_msg=name)
print('ok')
""", n_devices=4)


# ---------------------------------------------------------------------------
# incremental engine vs cold recompute
# ---------------------------------------------------------------------------

def _check_incremental(name, graph, delta, engine="async_block"):
    algo_old = get_algorithm(name, graph)
    g2 = delta.apply(graph)
    algo_new = remake(algo_old, g2)
    run = ENGINES[engine]
    prior = run(algo_old)
    cold = run(algo_new)
    kw = {"bs": 64} if engine == "async_block" else {}
    warm = run_incremental(algo_new, algo_old, prior, engine=engine, **kw)
    assert warm.converged
    if algo_new.semiring.reduce == "sum":
        # both endpoints stop on successive-change <= eps, i.e. each sits
        # within ~eps*rho/(1-rho) of the fixpoint; 10*eps bounds the gap
        np.testing.assert_allclose(
            warm.x, cold.x, atol=10 * algo_new.eps, rtol=0,
            err_msg=f"{name} warm vs cold",
        )
    else:
        np.testing.assert_array_equal(warm.x, cold.x, err_msg=name)
    return warm, cold


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_incremental_matches_cold_insertions(graphs, name):
    g, gw = graphs
    graph = gw if name in ("sssp", "sswp", "ms_sssp") else g
    delta = random_delta(graph, frac_add=0.01, seed=3)
    _check_incremental(name, graph, delta)


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_incremental_matches_cold_churn(graphs, name):
    """Deletions + reweights: the signed-residual path for sum semirings,
    the masked regional recompute for min/max."""
    g, gw = graphs
    graph = gw if name in ("sssp", "sswp", "ms_sssp") else g
    delta = random_delta(graph, frac_add=0.005, frac_del=0.005,
                         frac_rew=0.005, n_add_vertices=4, seed=4)
    _check_incremental(name, graph, delta)


def test_incremental_sync_engine(graphs):
    g, gw = graphs
    delta = random_delta(g, frac_add=0.01, seed=5)
    _check_incremental("pagerank", g, delta, engine="sync")


def test_incremental_batched_queries(graphs):
    """A batched (d > 1) PPR instance absorbs a delta column-for-column."""
    from repro.engine import personalized_pagerank

    g, _ = graphs
    seeds = [0, 13, 202, 77]
    algo_old = personalized_pagerank(g, seeds)
    delta = random_delta(g, frac_add=0.01, seed=6)
    g2 = delta.apply(g)
    algo_new = personalized_pagerank(g2, seeds)
    prior = run_async_block(algo_old, bs=64)
    cold = run_async_block(algo_new, bs=64)
    warm = run_incremental(algo_new, algo_old, prior, bs=64)
    assert warm.x.shape == (g2.n, len(seeds))
    np.testing.assert_allclose(warm.x, cold.x, atol=10 * algo_new.eps, rtol=0)


def test_incremental_saves_rounds_on_insertions(graphs):
    """The serving claim: a 1% insertion delta converges warm in well under
    the cold round count (the benchmark's acceptance bound is 50%)."""
    g, gw = graphs
    total_warm = total_cold = 0
    for name in ("pagerank", "php", "sssp", "bfs"):
        graph = gw if name == "sssp" else g
        delta = random_delta(graph, frac_add=0.01, seed=7)
        warm, cold = _check_incremental(name, graph, delta)
        total_warm += warm.rounds
        total_cold += cold.rounds
    assert total_warm <= 0.5 * total_cold, (total_warm, total_cold)


def test_incremental_rejects_mismatched_instances(graphs):
    g, gw = graphs
    a1 = get_algorithm("pagerank", g)
    a2 = get_algorithm("katz", g)
    with pytest.raises(ValueError, match="instance mismatch"):
        run_incremental(a2, a1, np.zeros(g.n, np.float32))


def test_warm_state_pins_fixed_and_extends(graphs):
    g, _ = graphs
    algo_old = get_algorithm("php", g, target=3)
    delta = random_delta(g, frac_add=0.005, n_add_vertices=6, seed=8)
    algo_new = remake(algo_old, delta.apply(g))
    prior = np.full(g.n, 0.25, np.float32)
    x = warm_state(algo_new, algo_old, prior)
    assert x.shape == (g.n + 6, 1)
    assert x[3, 0] == 1.0           # pinned target serves its pin, not prior
    assert (x[g.n:, 0] == 0.0).all()  # appended vertices start at x0
    assert x[4, 0] == np.float32(0.25)


def test_instance_edge_diff_classifies(graphs):
    _, gw = graphs
    algo_old = get_algorithm("sssp", gw)
    # raise one weight (loosening for min), lower another (tightening),
    # delete one edge, add one
    d = GraphDelta(
        add_src=[int(gw.dst[0])], add_dst=[int(gw.src[0])],
        del_src=[int(gw.src[1])], del_dst=[int(gw.dst[1])],
        rew_src=[int(gw.src[2]), int(gw.src[3])],
        rew_dst=[int(gw.dst[2]), int(gw.dst[3])],
        rew_w=[float(gw.weights[2]) + 5.0, max(0.01, float(gw.weights[3]) - 0.5)],
    )
    algo_new = remake(algo_old, d.apply(gw))
    diff = instance_edge_diff(algo_old, algo_new)
    assert diff.loosening
    assert int(gw.dst[1]) in set(diff.removed_dst.tolist())
    assert int(gw.dst[2]) in set(diff.loosened_dst.tolist())
    assert int(gw.dst[3]) in set(diff.tightened_dst.tolist())
    # insert-only delta is not loosening
    d2 = GraphDelta(add_src=[0], add_dst=[int(gw.src[0])])
    diff2 = instance_edge_diff(algo_old, remake(algo_old, d2.apply(gw)))
    assert not diff2.loosening
    # tighter/looser is meaningless for sum semirings (they diff by residual)
    pr = get_algorithm("pagerank", gw)
    with pytest.raises(ValueError, match="min/max"):
        instance_edge_diff(pr, pr)


# ---------------------------------------------------------------------------
# incremental order maintenance
# ---------------------------------------------------------------------------

def test_extend_rank_places_new_vertices(graphs):
    g, _ = graphs
    rank = gograph_order(g)
    delta = random_delta(g, frac_add=0.02, n_add_vertices=12, seed=11)
    g2 = delta.apply(g)
    rank2 = extend_rank(g2, rank)
    assert rank2.shape == (g2.n,)
    assert np.array_equal(np.sort(rank2), np.arange(g2.n))  # permutation
    # old vertices keep their relative order exactly
    old_slots = rank2[: g.n]
    assert np.array_equal(np.argsort(np.argsort(old_slots)),
                          np.argsort(np.argsort(rank)))


def test_incremental_with_rank_matches_without(graphs):
    g, _ = graphs
    algo_old = get_algorithm("pagerank", g)
    rank = gograph_order(g)
    delta = random_delta(g, frac_add=0.01, n_add_vertices=5, seed=12)
    g2 = delta.apply(g)
    algo_new = remake(algo_old, g2)
    rank2 = extend_rank(g2, rank)
    prior = run_async_block(algo_old, bs=64)
    plain = run_incremental(algo_new, algo_old, prior, bs=64)
    ranked = run_incremental(algo_new, algo_old, prior, bs=64, rank=rank2)
    # both converge to the same fixpoint, reported in id space
    np.testing.assert_allclose(ranked.x, plain.x, atol=10 * algo_new.eps, rtol=0)


# ---------------------------------------------------------------------------
# regression tests for this PR's bugfixes
# ---------------------------------------------------------------------------

def test_sssp_eps_is_plumbed(graphs):
    """make_sssp silently hardcoded eps=0.5; the argument must stick."""
    _, gw = graphs
    assert make_sssp(gw, 0, eps=2.5).eps == 2.5
    assert make_sssp(gw, 0).eps == 0.5      # default preserved
    assert make_bfs(gw, 0, eps=1.5).eps == 1.5
    assert make_bfs(gw, 0).eps == 0.5
    # a loose eps ("stop with <= 2 states still moving") must stop earlier
    tight = run_sync(make_sssp(gw, 0))
    loose = run_sync(make_sssp(gw, 0, eps=2.5))
    assert loose.rounds <= tight.rounds


def test_metric_m_jax_int32_without_x64(graphs):
    """metric_m_jax built int64 sums that silently downcast when x64 is off;
    the dtype must now be explicitly int32 and the count exact."""
    import jax.numpy as jnp

    from repro.core.metric import metric_m, metric_m_jax

    g, _ = graphs
    rank = np.random.default_rng(0).permutation(g.n)
    out = metric_m_jax(jnp.asarray(g.src), jnp.asarray(g.dst),
                       jnp.asarray(rank))
    assert out.dtype == jnp.int32
    assert int(out) == metric_m(g, rank)


def test_extrapolation_rejected_for_nonlinear_semirings(graphs):
    """Aitken extrapolation on a min/max lattice sweep NaNs on the BIG
    sentinels; the engines must refuse it rather than return garbage."""
    _, gw = graphs
    algo = get_algorithm("sssp", gw)
    with pytest.raises(NotImplementedError, match="sum-semiring"):
        run_sync(algo, extrapolate_every=2)
    with pytest.raises(NotImplementedError, match="sum-semiring"):
        run_async_block(algo, bs=64, extrapolate_every=2)


def test_extrapolation_period_must_leave_mixing_rounds(graphs):
    """Period 1 jumps every round off the previous jump's own step — the
    amplifications compound and the iteration NaNs; reject <2 up front."""
    g, _ = graphs
    algo = get_algorithm("pagerank", g)
    for bad in (1, -3):
        with pytest.raises(ValueError, match=">= 2"):
            run_sync(algo, extrapolate_every=bad)
    assert run_sync(algo, extrapolate_every=2).converged


def test_remake_refuses_relabeled_instance(graphs):
    """relabel drops id-valued params, so remake on a relabeled instance
    fails loudly instead of pinning the wrong vertex in rank space."""
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=5)
    rank = np.random.default_rng(0).permutation(gw.n)
    with pytest.raises(ValueError, match="params"):
        remake(algo.relabel(rank), gw)


def test_incremental_rejects_explicit_extrapolation_on_minmax(graphs):
    _, gw = graphs
    algo_old = get_algorithm("sssp", gw)
    delta = random_delta(gw, frac_add=0.01, seed=13)
    algo_new = remake(algo_old, delta.apply(gw))
    prior = run_async_block(algo_old, bs=64)
    with pytest.raises(NotImplementedError, match="sum-semiring"):
        run_incremental(algo_new, algo_old, prior, bs=64, extrapolate_every=4)


def test_gs_sweep_rejects_unsupported_combos():
    """Each supported semiring/combine pair has its own accumulator identity;
    any other pairing (e.g. min_plus with a "replace" combine) must fail
    loudly, not start from the wrong identity and return garbage shaped like
    an answer."""
    import jax.numpy as jnp

    from repro.kernels.gs_sweep import gs_sweep_pallas

    bs = 8
    rowptr = jnp.zeros((2,), jnp.int32)
    tilecols = jnp.zeros((1,), jnp.int32)
    tiles = jnp.zeros((1, bs, bs), jnp.float32)
    v = jnp.zeros((bs, 1), jnp.float32)
    for semiring, combine in [("min_plus", "max_old"), ("min_plus", "replace"),
                              ("plus_times", "max_old"), ("max_min", "min_old"),
                              ("max_times", "replace")]:
        with pytest.raises(NotImplementedError):
            gs_sweep_pallas(rowptr, tilecols, tiles, v, v, v, v,
                            semiring=semiring, combine=combine, bs=bs,
                            interpret=True)
