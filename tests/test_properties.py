"""Cross-cutting property tests (hypothesis) for the system's invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import metric, baselines
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm, run_sync
from repro.graphs import generators as gen


@st.composite
def graphs(draw):
    n = draw(st.integers(10, 200))
    kind = draw(st.sampled_from(["er", "ba", "plc"]))
    seed = draw(st.integers(0, 50))
    if kind == "er":
        return gen.erdos_renyi(n, draw(st.floats(1.0, 5.0)), seed=seed)
    if kind == "ba":
        m = min(draw(st.integers(1, 3)), n - 2)
        return gen.barabasi_albert(max(n, m + 2), m, seed=seed)
    return gen.powerlaw_cluster(n, min(3, n - 2), seed=seed)


@given(graphs(), st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_metric_reversal_identity(g, seed):
    """For any order: M(rank) + M(reversed rank) == |E| exactly (every edge
    is positive in precisely one of the two directions)."""
    rng = np.random.default_rng(seed)
    rank = rng.permutation(g.n).astype(np.int64)
    rev = (g.n - 1) - rank
    assert metric.metric_m(g, rank) + metric.metric_m(g, rev) == g.m


@given(graphs())
@settings(max_examples=15, deadline=None)
def test_all_reorderers_emit_permutations(g):
    for name, fn in baselines.all_reorderers().items():
        rank = fn(g)
        assert sorted(rank.tolist()) == list(range(g.n)), name


@given(graphs(), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_relabel_invariance_of_fixpoint(g, seed):
    """Solving a relabeled instance and mapping back gives the original
    solution — for any permutation, any monotone algorithm."""
    if g.m == 0:
        return
    rng = np.random.default_rng(seed)
    rank = rng.permutation(g.n).astype(np.int64)
    algo = get_algorithm("pagerank", g)
    r = run_sync(algo.relabel(rank))
    np.testing.assert_allclose(r.x[rank], algo.exact(), atol=1e-4, rtol=1e-3)


@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 20))
@settings(max_examples=25, deadline=None)
def test_kv_quantization_error_bound(hd, heads, seed):
    """int8 KV round-trip error is bounded by scale/2 = max|x|/254."""
    import jax.numpy as jnp
    from repro.models.blocks import _quantize_kv, _dequantize_kv

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 3, heads, hd)).astype(np.float32))
    q, s = _quantize_kv(x)
    back = _dequantize_kv(q, s, jnp.float32)
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1) / 127.0 * 0.5 + 1e-6)
    err = np.asarray(jnp.abs(back - x))
    assert (err <= bound[..., None] + 1e-6).all()


@given(st.integers(20, 100), st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_block_fresh_fractions_partition(n, seed):
    g = gen.erdos_renyi(n, 3.0, seed=seed)
    if g.m == 0:
        return
    rank = gograph_order(g)
    f = metric.block_fresh_fraction(g, rank, bs=16)
    assert abs(f["fresh"] + f["intra"] + f["stale"] - 1.0) < 1e-9


def test_dryrun_single_cell_subprocess():
    """The dry-run deliverable end-to-end for one cell (512 host devices)."""
    import json
    import subprocess
    import sys
    import tempfile

    out = tempfile.mkdtemp()
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k", "--mesh", "single", "--out", out],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ,
             "PYTHONPATH": "src:" + __import__("os").environ.get("PYTHONPATH", "")},
        cwd=__import__("os").path.join(__import__("os").path.dirname(__file__), ".."),
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(f"{out}/olmo-1b__decode_32k__pod_16x16.json"))
    assert rec["n_chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["memory"]["fits_16g"]
