"""Helpers for multi-device tests: run a snippet in a subprocess with its own
XLA_FLAGS so the main pytest process keeps a single CPU device."""
from __future__ import annotations

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr[-3000:]}"
    return out.stdout
