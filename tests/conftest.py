# NOTE: deliberately NOT setting --xla_force_host_platform_device_count here:
# smoke tests and benches must see the real single device. Multi-device tests
# run in subprocesses (tests/util.py) with their own XLA_FLAGS.
import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The hermetic tier-1 environment cannot pip-install; fall back to the
# deterministic stub (tests/_hypothesis_stub.py) when hypothesis is missing
# so the suite still collects and runs. CI installs the real package.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"),
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


import pytest  # noqa: E402


@pytest.fixture
def transfer_guard_disallow():
    """Run the test body under the device->host transfer sanitizer.

    Any *implicit* readback (np.asarray on a jax array, float()/int() on a
    traced scalar, ...) raises; explicit jax.device_get stays allowed — the
    runtime complement of the `tools.check` host-sync checker.
    """
    import jax

    with jax.transfer_guard_device_to_host("disallow"):
        yield
