# NOTE: deliberately NOT setting --xla_force_host_platform_device_count here:
# smoke tests and benches must see the real single device. Multi-device tests
# run in subprocesses (tests/util.py) with their own XLA_FLAGS.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
