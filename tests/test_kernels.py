"""Pallas kernels vs pure-numpy oracles: flat-BSR shape/semiring sweeps,
engine parity, and the padding contract across every supported
semiring/combine pair (non-divisible n, batched d > 1, warm-start x_init)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.engine.algorithms import BIG
from repro.engine import get_algorithm, run_async_block
from repro.graphs import generators as gen
from repro.kernels import bsr_spmm, gs_sweep
from repro.kernels.ops import pack_algorithm, run_async_block_pallas
from repro.kernels.ref import ref_bsr_spmm, ref_gs_sweep

RNG = np.random.default_rng(0)

SEMIRINGS = ["plus_times", "min_plus", "max_min", "max_times"]

# every fused pair the kernels implement, with a graph workload that
# exercises it (weighted graphs where the semiring needs real weights)
PAIRS = [
    ("pagerank", False),      # plus_times / replace
    ("sssp", True),           # min_plus  / min_old
    ("sswp", True),           # max_min   / max_old
    ("reachability", False),  # max_times / max_old
]


def _rand_tiles(nnz, bs, semiring):
    """Random tiles: ~20% real entries, the rest the semiring's in-tile fill."""
    from repro.kernels.semirings import TILE_FILL

    real = RNG.random((nnz, bs, bs)) < 0.2
    vals = (RNG.random((nnz, bs, bs)) * 5).astype(np.float32)
    return np.where(real, vals, np.float32(TILE_FILL[semiring])).astype(np.float32)


def _flat_operands(bs, d, nb, kmax, dtype, semiring):
    """Random ragged flat-BSR operands: row i owns i%(kmax+1) tiles (so some
    rows are empty — the layout's whole point) with random column blocks."""
    counts = np.arange(nb) % (kmax + 1)
    rowptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    nnz = int(rowptr[-1])
    tilecols = RNG.integers(0, nb, size=max(1, nnz)).astype(np.int32)
    tilerows = (np.repeat(np.arange(nb), counts).astype(np.int32)
                if nnz else np.zeros(1, np.int32))
    tiles = _rand_tiles(max(1, nnz), bs, semiring)
    x = RNG.random((nb * bs, d)).astype(np.float32)
    return (jnp.asarray(rowptr), jnp.asarray(tilerows), jnp.asarray(tilecols),
            jnp.asarray(tiles).astype(dtype), jnp.asarray(x).astype(dtype))


@pytest.mark.parametrize("bs,d,nb,kmax", [
    (8, 8, 3, 2), (8, 128, 4, 3), (16, 16, 5, 4), (32, 64, 3, 2),
    (128, 128, 2, 2),
])
@pytest.mark.parametrize("semiring", SEMIRINGS)
def test_bsr_spmm_shapes(bs, d, nb, kmax, semiring):
    rowptr, tilerows, tilecols, tiles, x = _flat_operands(
        bs, d, nb, kmax, jnp.float32, semiring)
    y = bsr_spmm(rowptr, tilerows, tilecols, tiles, x, semiring=semiring)
    yref = ref_bsr_spmm(rowptr, tilecols, tiles, x, semiring=semiring)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               atol=1e-4, rtol=1e-4)


def test_bsr_spmm_empty_rows_get_identity():
    """Row-blocks with no tiles never enter the grid; the wrapper must still
    write the reduce identity into their output rows."""
    for semiring, ident in [("plus_times", 0.0), ("min_plus", BIG),
                            ("max_min", -BIG), ("max_times", -BIG)]:
        rowptr, tilerows, tilecols, tiles, x = _flat_operands(
            8, 4, 5, 2, jnp.float32, semiring)
        y = np.asarray(bsr_spmm(rowptr, tilerows, tilecols, tiles, x,
                                semiring=semiring))
        rp = np.asarray(rowptr)
        for i in range(len(rp) - 1):
            if rp[i] == rp[i + 1]:
                np.testing.assert_array_equal(
                    y[i * 8:(i + 1) * 8], np.float32(ident))


def test_bsr_spmm_bf16():
    rowptr, tilerows, tilecols, tiles, x = _flat_operands(
        16, 32, 4, 3, jnp.bfloat16, "plus_times")
    y = bsr_spmm(rowptr, tilerows, tilecols, tiles, x)
    yref = ref_bsr_spmm(rowptr, tilecols,
                        np.asarray(tiles, np.float32),
                        np.asarray(x, np.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32), yref,
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("algo_name,weighted,bs", [
    ("pagerank", False, 32), ("pagerank", False, 64),
    ("sssp", True, 32), ("bfs", False, 64), ("php", False, 32),
    ("cc", False, 32), ("katz", False, 64),
    ("sswp", True, 32), ("reachability", False, 64),
])
def test_gs_sweep_vs_ref(algo_name, weighted, bs):
    g = gen.powerlaw_cluster(400, 3, seed=1)
    if weighted:
        g = gen.with_random_weights(g, seed=2)
    algo = get_algorithm(algo_name, g)
    ops = pack_algorithm(algo, bs=bs)
    args = (ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"],
            ops["x0"], ops["fixed"], ops["x"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"])
    xk = gs_sweep(*args, **kw)
    xr = ref_gs_sweep(*args, **kw)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               atol=1e-4, rtol=1e-4)


def test_pack_algorithm_tiles_are_nnz_proportional():
    """The flat layout's contract: tile memory is nnz_blocks * bs^2 * 4, not
    nb * k_max * bs^2 * 4 (the hub row-block is paid for once)."""
    g = gen.scrambled(gen.powerlaw_cluster(600, 4, seed=3), seed=7)
    ops = pack_algorithm(get_algorithm("pagerank", g), bs=16)
    s = ops["bsr_stats"]
    assert ops["tiles"].shape[0] == s["nnz_blocks"]
    assert s["tile_bytes"] == s["nnz_blocks"] * 16 * 16 * 4
    assert s["nnz_blocks"] < s["nb"] * s["k_max"]  # real skew on powerlaw
    assert s["padding_waste"] > 0.0
    assert s["tile_bytes_saved"] == s["dense_tile_bytes"] - s["tile_bytes"]


@pytest.mark.parametrize("algo_name,weighted", PAIRS)
def test_pallas_engine_matches_jax_engine(algo_name, weighted):
    g = gen.scrambled(gen.powerlaw_cluster(600, 4, seed=3), seed=7)
    graph = gen.with_random_weights(g, seed=1) if weighted else g
    algo = get_algorithm(algo_name, graph)
    r_pal = run_async_block_pallas(algo, bs=64, max_iters=300)
    r_jax = run_async_block(algo, bs=64)
    # float accumulation-order noise near eps can shift convergence by one
    assert abs(r_pal.rounds - r_jax.rounds) <= 1, algo_name
    if algo.semiring.reduce == "sum":
        # block-matmul vs edge-segment-sum accumulation order differs
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
    else:
        # min/max reductions are order-free: the kernels must be bitwise
        # equal to the pure-JAX engine
        np.testing.assert_array_equal(r_pal.x, r_jax.x)
    np.testing.assert_allclose(r_pal.x, algo.exact(), atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# the padding contract, for every supported pair: non-block-divisible n,
# batched d > 1, and warm-start x_init must ride the pallas backend without
# padding rows ever leaking into real states
# ---------------------------------------------------------------------------

def _contract_algo(algo_name, d):
    """An instance on a graph whose n (311) is not divisible by any test bs;
    d > 1 uses the batched constructors where they exist and column broadcast
    otherwise."""
    g = gen.scrambled(gen.powerlaw_cluster(311, 3, seed=9), seed=4)
    gw = gen.with_random_weights(g, seed=6)
    if d == 1:
        return get_algorithm(algo_name, gw if algo_name in ("sssp", "sswp") else g)
    if algo_name == "pagerank":
        return get_algorithm("ppr", g, seeds=list(range(d)))
    if algo_name == "sssp":
        return get_algorithm("ms_sssp", gw, sources=list(range(d)))
    # sswp / reachability have no batched constructor: run d independent
    # single-query columns by stacking the scalar instance's vectors
    import dataclasses

    algo = get_algorithm(algo_name, gw if algo_name == "sswp" else g)
    return dataclasses.replace(
        algo,
        x0=np.repeat(algo.x0, d, axis=1),
        c=np.repeat(algo.c, d, axis=1),
        fixed=np.repeat(algo.fixed, d, axis=1),
        exact_fn=None,
    )


@pytest.mark.parametrize("algo_name,_w", PAIRS)
@pytest.mark.parametrize("d", [1, 3])
def test_padding_contract_all_pairs(algo_name, _w, d):
    """bs=64 does not divide n=311: the last block is padding-heavy, and the
    result must still match the pure-JAX engine for every fused pair."""
    algo = _contract_algo(algo_name, d)
    r_pal = run_async_block_pallas(algo, bs=64, max_iters=300)
    r_jax = run_async_block(algo, bs=64)
    if algo.semiring.reduce == "sum":
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
    else:
        np.testing.assert_array_equal(r_pal.x, r_jax.x)
    np.testing.assert_array_equal(r_pal.col_rounds, r_jax.col_rounds)


@pytest.mark.parametrize("algo_name,_w", PAIRS)
def test_warm_start_contract_all_pairs(algo_name, _w):
    """x_init through the pallas backend: resuming from a mid-run jax-engine
    state must land on the same fixpoint as the jax engine resumed from the
    same state, and resuming from a *converged* state must be a bitwise
    no-op verification sweep (rounds == 1)."""
    algo = _contract_algo(algo_name, 1)
    r_cold = run_async_block(algo, bs=64)
    # mid-run resume: 3 rounds cold, then both backends finish from there
    r_mid = run_async_block(algo, bs=64, max_iters=3)
    r_pal = run_async_block_pallas(algo, bs=64, x_init=r_mid.x, max_iters=300)
    r_jax = run_async_block(algo, bs=64, x_init=r_mid.x)
    if algo.semiring.reduce == "sum":
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
    else:
        np.testing.assert_array_equal(r_pal.x, r_jax.x)
    # converged resume: one verification sweep, state bitwise unchanged
    r_resume = run_async_block_pallas(algo, bs=64, x_init=r_cold.x, max_iters=300)
    assert r_resume.rounds == 1
    np.testing.assert_array_equal(r_resume.x, r_cold.x)


def test_incremental_warm_start_through_pallas_backend():
    """run_incremental(engine='async_block', backend='pallas'): the warm
    state and the delta instance both ride the flat-BSR kernel path."""
    from repro.engine import remake, run_incremental
    from repro.graphs.delta import random_delta

    g0 = gen.scrambled(gen.powerlaw_cluster(300, 3, seed=2), seed=3)
    gw = gen.with_random_weights(g0, seed=1)
    # pagerank needs the unweighted graph (random weights up to 10 make the
    # iteration matrix non-contractive); sssp needs the weighted one
    for name, g in (("pagerank", g0), ("sssp", gw)):
        algo_old = get_algorithm(name, g)
        delta = random_delta(g, frac_add=0.02, seed=5)
        algo_new = remake(algo_old, delta.apply(g))
        prior = run_async_block(algo_old, bs=64)
        r_pal = run_incremental(algo_new, algo_old, prior, bs=64,
                                backend="pallas", max_iters=300)
        r_jax = run_incremental(algo_new, algo_old, prior, bs=64)
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
        r_cold = run_async_block(algo_new, bs=64)
        np.testing.assert_allclose(r_pal.x, r_cold.x, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# the persistent multi-sweep megakernel: sweep batching, in-kernel
# convergence, and active-frontier block skipping
# ---------------------------------------------------------------------------

def _msweep_args(ops):
    return (ops["rowptr"], ops["tilecols"], ops["revptr"], ops["revrows"])


@pytest.mark.parametrize("algo_name,_w", PAIRS)
def test_multisweep_matches_ref_oracle(algo_name, _w):
    """Megakernel vs the numpy sweep-batched frontier oracle: state, the
    per-sweep delta trace, active-block counts, and the exported frontier
    must all agree (bitwise for the lattice semirings)."""
    from repro.kernels.gs_sweep import gs_multisweep_pallas
    from repro.kernels.ref import ref_gs_multisweep

    algo = _contract_algo(algo_name, 1)
    ops = pack_algorithm(algo, bs=32)
    nb = int(ops["rowptr"].shape[0]) - 1
    dirty = jnp.ones((nb,), jnp.int32)
    kw = dict(semiring=ops["semiring"], combine=ops["combine"],
              res_kind=algo.residual, eps=float(algo.eps))
    xk, dk, ak, fk = gs_multisweep_pallas(
        *_msweep_args(ops), dirty, ops["tiles"], ops["c"], ops["x0"],
        ops["fixed"], ops["x"], bs=32, sweeps=6, **kw)
    xr, dr, ar, fr = ref_gs_multisweep(
        *_msweep_args(ops), dirty, ops["tiles"], ops["c"], ops["x0"],
        ops["fixed"], ops["x"], sweeps=6, **kw)
    if algo.semiring.reduce == "sum":
        np.testing.assert_allclose(np.asarray(xk), xr, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(dk), dr, atol=1e-4, rtol=1e-3)
    else:
        np.testing.assert_array_equal(np.asarray(xk), xr)
        np.testing.assert_array_equal(np.asarray(dk), dr)
    np.testing.assert_array_equal(np.asarray(ak)[:, 0], ar)
    np.testing.assert_array_equal(np.asarray(fk), fr)


@pytest.mark.parametrize("algo_name,_w", PAIRS)
@pytest.mark.parametrize("d", [1, 3])
def test_multisweep_engine_matches_per_sweep(algo_name, _w, d):
    """sweeps_per_call=4 must reproduce the per-sweep pallas engine on
    non-divisible n for every fused pair: same per-column round counts, and
    bitwise-equal states for the lattice semirings (skipped blocks are
    bitwise no-ops, so frontier execution IS full-sweep execution)."""
    algo = _contract_algo(algo_name, d)
    r1 = run_async_block_pallas(algo, bs=64, max_iters=300)
    rb = run_async_block_pallas(algo, bs=64, max_iters=300, sweeps_per_call=4)
    assert rb.rounds == r1.rounds
    np.testing.assert_array_equal(rb.col_rounds, r1.col_rounds)
    if algo.semiring.reduce == "sum":
        # batched sweeps keep advancing a converged column until the batch
        # stops (no per-column freezing), each step moving it < eps
        np.testing.assert_allclose(rb.x, r1.x, atol=1e-4, rtol=1e-4)
    else:
        np.testing.assert_array_equal(rb.x, r1.x)
    assert rb.active_block_fraction is not None
    assert len(rb.active_block_fraction) == rb.rounds


@pytest.mark.parametrize("algo_name,_w", PAIRS)
def test_multisweep_warm_start(algo_name, _w):
    """x_init through the sweep-batched path: resume from a 3-round state
    and land where the per-sweep engine lands; resume from a *converged*
    state and early-out in a single batch (1 verification sweep, bitwise
    no-op for the lattice semirings)."""
    algo = _contract_algo(algo_name, 1)
    r_mid = run_async_block(algo, bs=64, max_iters=3)
    r1 = run_async_block_pallas(algo, bs=64, x_init=r_mid.x, max_iters=300)
    rb = run_async_block_pallas(algo, bs=64, x_init=r_mid.x, max_iters=300,
                                sweeps_per_call=16)
    assert rb.rounds == r1.rounds
    if algo.semiring.reduce == "sum":
        np.testing.assert_allclose(rb.x, r1.x, atol=1e-4, rtol=1e-4)
    else:
        np.testing.assert_array_equal(rb.x, r1.x)
    r_cold = run_async_block(algo, bs=64)
    r_res = run_async_block_pallas(algo, bs=64, x_init=r_cold.x,
                                   max_iters=300, sweeps_per_call=16)
    assert r_res.rounds == 1
    if algo.semiring.reduce != "sum":
        np.testing.assert_array_equal(r_res.x, r_cold.x)


def test_multisweep_frontier_skip_bitwise_at_fixpoint():
    """The frontier contract, directly: re-running a converged state with
    an all-dirty frontier (every block updates once — full verification
    sweep) and with a partially-seeded frontier (most blocks skipped) must
    both leave the state bitwise unchanged — a skipped block equals an
    updated one at fixpoint."""
    algo = _contract_algo("sssp", 1)
    r_cold = run_async_block(algo, bs=64)
    # all-dirty: every block verifies
    r_full = run_async_block_pallas(algo, bs=64, x_init=r_cold.x,
                                    sweeps_per_call=4)
    np.testing.assert_array_equal(r_full.x, r_cold.x)
    assert r_full.active_block_fraction[0] == 1.0
    # partial frontier: only the first vertex's block updates, rest skipped
    fr = np.zeros(algo.n, bool)
    fr[0] = True
    r_part = run_async_block_pallas(algo, bs=64, x_init=r_cold.x,
                                    sweeps_per_call=4, frontier=fr)
    np.testing.assert_array_equal(r_part.x, r_cold.x)
    assert 0.0 < r_part.active_block_fraction[0] < 1.0


def test_multisweep_empty_frontier_early_exit():
    """An empty frontier on a converged state is the cheapest possible
    serving no-op: zero blocks touched, convergence declared after one
    batch (rounds == 1), state bitwise untouched."""
    for name in ("pagerank", "sssp"):
        algo = _contract_algo(name, 1)
        r_cold = run_async_block(algo, bs=64)
        r = run_async_block_pallas(algo, bs=64, x_init=r_cold.x,
                                   sweeps_per_call=8,
                                   frontier=np.zeros(algo.n, bool))
        assert r.rounds == 1, name
        assert r.converged
        np.testing.assert_array_equal(
            r.x, np.asarray(r_cold.x, np.float32))
        assert r.active_block_fraction[0] == 0.0


def test_multisweep_frontier_shrinks_during_convergence():
    """The active_block_fraction trace must shrink as SSSP converges (the
    frontier win the bench records): the last sweep touches strictly fewer
    blocks than the first."""
    algo = _contract_algo("sssp", 1)
    r = run_async_block_pallas(algo, bs=16, sweeps_per_call=16)
    af = r.active_block_fraction
    assert af[0] == 1.0
    assert af[-1] < af[0]


def test_incremental_frontier_seeding_through_megakernel():
    """run_incremental(backend='pallas', sweeps_per_call=4): warm-start
    frontiers seeded from the delta-touched blocks must land on the cold
    fixpoint (bitwise for sssp) while skipping untouched regions."""
    from repro.engine import remake, run_incremental
    from repro.graphs.delta import random_delta

    g0 = gen.scrambled(gen.powerlaw_cluster(300, 3, seed=2), seed=3)
    gw = gen.with_random_weights(g0, seed=1)
    for name, g in (("pagerank", g0), ("sssp", gw)):
        algo_old = get_algorithm(name, g)
        delta = random_delta(g, frac_add=0.02, seed=5)
        algo_new = remake(algo_old, delta.apply(g))
        prior = run_async_block(algo_old, bs=64)
        r_batch = run_incremental(algo_new, algo_old, prior, bs=64,
                                  backend="pallas", sweeps_per_call=4,
                                  max_iters=300)
        r_cold = run_async_block(algo_new, bs=64)
        if name == "sssp":
            np.testing.assert_array_equal(r_batch.x, r_cold.x)
            # the seeded frontier must actually skip work somewhere
            assert min(r_batch.active_block_fraction) < 1.0
        else:
            np.testing.assert_allclose(r_batch.x, r_cold.x,
                                       atol=1e-3, rtol=1e-3)


def test_multisweep_knobs_rejected_where_invalid():
    algo = _contract_algo("pagerank", 1)
    with pytest.raises(ValueError):
        run_async_block(algo, bs=64, sweeps_per_call=4)  # jax backend
    with pytest.raises(ValueError):
        run_async_block(algo, bs=64, backend="pallas", sweeps_per_call=0)
    with pytest.raises(NotImplementedError):
        run_async_block(algo, bs=64, backend="pallas", sweeps_per_call=4,
                        extrapolate_every=4)
    with pytest.raises(ValueError):
        # frontier must be vertex-level bool[n]
        run_async_block(algo, bs=64, backend="pallas", sweeps_per_call=4,
                        frontier=np.zeros(3, bool))


def test_delta_metric_matches_algorithm_residuals():
    """kernels.semirings.DELTA_METRIC must agree with the residual kinds the
    algorithm constructors assign, or in-kernel convergence decisions would
    diverge from the host drivers'."""
    from repro.kernels.ops import _KERNEL_SEMIRING
    from repro.kernels.semirings import DELTA_METRIC

    g = gen.with_random_weights(gen.powerlaw_cluster(50, 3, seed=0), seed=1)
    for name in ("pagerank", "sssp", "sswp", "reachability"):
        algo = get_algorithm(name, g)
        semiring = _KERNEL_SEMIRING[(algo.semiring.reduce,
                                     algo.semiring.edge_op)]
        assert DELTA_METRIC[semiring] == algo.residual, name


def test_gs_sweep_uses_fresh_states():
    """The defining property of the fused sweep: a block's update sees
    earlier blocks' THIS-sweep values (positive cross-block edges are fresh,
    Eq. 2 at tile granularity)."""
    from repro.graphs.graph import Graph

    n, bs = 8, 2
    g = Graph(n, np.arange(n - 1, dtype=np.int32),
              np.arange(1, n, dtype=np.int32),
              np.ones(n - 1, np.float32))
    algo = get_algorithm("sssp", g, source=0)
    ops = pack_algorithm(algo, bs=bs)
    args = (ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"],
            ops["x0"], ops["fixed"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"])
    x1 = np.asarray(gs_sweep(*args, ops["x"], **kw))[:n, 0]
    # after ONE sweep: v1 from the initial source; v2 via the cross-block
    # edge 1->2 sees v1's THIS-sweep value (pure Jacobi would leave it BIG);
    # v3's edge is intra-block -> still previous-round (BIG)
    np.testing.assert_allclose(x1[:3], [0.0, 1.0, 2.0], atol=1e-5)
    assert x1[3] >= BIG / 2
    # the chain settles one block per sweep: ceil(n/bs)=4 sweeps total,
    # vs n-1=7 Jacobi rounds
    x = ops["x"]
    for _ in range(4):
        x = gs_sweep(*args, x, **kw)
    np.testing.assert_allclose(np.asarray(x)[:n, 0], np.arange(n), atol=1e-5)
