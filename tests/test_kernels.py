"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + engine parity."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.engine.algorithms import BIG
from repro.engine import get_algorithm, run_async_block
from repro.graphs import generators as gen
from repro.kernels import bsr_spmm, gs_sweep
from repro.kernels.ops import pack_algorithm, run_async_block_pallas
from repro.kernels.ref import ref_bsr_spmm, ref_gs_sweep

RNG = np.random.RandomState(0)


def _operands(bs, d, nb, kmax, dtype, semiring):
    cols = RNG.randint(0, nb, size=(nb, kmax)).astype(np.int32)
    if semiring == "plus_times":
        tiles = (RNG.rand(nb, kmax, bs, bs) *
                 (RNG.rand(nb, kmax, bs, bs) < 0.2)).astype(np.float32)
    else:
        tiles = np.where(RNG.rand(nb, kmax, bs, bs) < 0.8, BIG,
                         RNG.rand(nb, kmax, bs, bs) * 5).astype(np.float32)
    x = RNG.rand(nb * bs, d).astype(np.float32)
    return (jnp.asarray(cols), jnp.asarray(tiles).astype(dtype),
            jnp.asarray(x).astype(dtype))


@pytest.mark.parametrize("bs,d,nb,kmax", [
    (8, 8, 3, 2), (8, 128, 4, 3), (16, 16, 5, 4), (32, 64, 3, 2),
    (128, 128, 2, 2),
])
@pytest.mark.parametrize("semiring", ["plus_times", "min_plus"])
def test_bsr_spmm_shapes(bs, d, nb, kmax, semiring):
    cols, tiles, x = _operands(bs, d, nb, kmax, jnp.float32, semiring)
    y = bsr_spmm(cols, tiles, x, semiring=semiring)
    yref = ref_bsr_spmm(cols, tiles, x, semiring=semiring)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               atol=1e-4, rtol=1e-4)


def test_bsr_spmm_bf16():
    cols, tiles, x = _operands(16, 32, 4, 3, jnp.bfloat16, "plus_times")
    y = bsr_spmm(cols, tiles, x)
    yref = ref_bsr_spmm(cols, tiles, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("algo_name,weighted,bs", [
    ("pagerank", False, 32), ("pagerank", False, 64),
    ("sssp", True, 32), ("bfs", False, 64), ("php", False, 32),
    ("cc", False, 32), ("katz", False, 64),
])
def test_gs_sweep_vs_ref(algo_name, weighted, bs):
    g = gen.powerlaw_cluster(400, 3, seed=1)
    if weighted:
        g = gen.with_random_weights(g, seed=2)
    algo = get_algorithm(algo_name, g)
    ops = pack_algorithm(algo, bs=bs)
    args = (ops["cols"], ops["tiles"], ops["c"], ops["x0"], ops["fixed"], ops["x"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"])
    xk = gs_sweep(*args, **kw)
    xr = ref_gs_sweep(*args, **kw)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               atol=1e-4, rtol=1e-4)


def test_pallas_engine_matches_jax_engine():
    g = gen.scrambled(gen.powerlaw_cluster(600, 4, seed=3), seed=7)
    for name, graph in [("pagerank", g), ("sssp", gen.with_random_weights(g, seed=1))]:
        algo = get_algorithm(name, graph)
        r_pal = run_async_block_pallas(algo, bs=64, max_iters=300)
        r_jax = run_async_block(algo, bs=64)
        # float accumulation-order noise near eps can shift convergence by one
        assert abs(r_pal.rounds - r_jax.rounds) <= 1, name
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(r_pal.x, algo.exact(), atol=2e-4, rtol=1e-3)


def test_gs_sweep_uses_fresh_states():
    """The defining property of the fused sweep: a block's update sees
    earlier blocks' THIS-sweep values (positive cross-block edges are fresh,
    Eq. 2 at tile granularity)."""
    import numpy as np
    from repro.engine.algorithms import BIG
    from repro.graphs.graph import Graph

    n, bs = 8, 2
    g = Graph(n, np.arange(n - 1, dtype=np.int32),
              np.arange(1, n, dtype=np.int32),
              np.ones(n - 1, np.float32))
    algo = get_algorithm("sssp", g, source=0)
    ops = pack_algorithm(algo, bs=bs)
    x1 = gs_sweep(ops["cols"], ops["tiles"], ops["c"], ops["x0"], ops["fixed"],
                  ops["x"], semiring=ops["semiring"], combine=ops["combine"])
    x1 = np.asarray(x1)[:n, 0]
    # after ONE sweep: v1 from the initial source; v2 via the cross-block
    # edge 1->2 sees v1's THIS-sweEP value (pure Jacobi would leave it BIG);
    # v3's edge is intra-block -> still previous-round (BIG)
    np.testing.assert_allclose(x1[:3], [0.0, 1.0, 2.0], atol=1e-5)
    assert x1[3] >= BIG / 2
    # the chain settles one block per sweep: ceil(n/bs)=4 sweeps total,
    # vs n-1=7 Jacobi rounds
    x = ops["x"]
    for _ in range(4):
        x = gs_sweep(ops["cols"], ops["tiles"], ops["c"], ops["x0"],
                     ops["fixed"], x, semiring=ops["semiring"],
                     combine=ops["combine"])
    np.testing.assert_allclose(np.asarray(x)[:n, 0], np.arange(n), atol=1e-5)
