"""Pallas kernels vs pure-numpy oracles: flat-BSR shape/semiring sweeps,
engine parity, and the padding contract across every supported
semiring/combine pair (non-divisible n, batched d > 1, warm-start x_init)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.engine.algorithms import BIG
from repro.engine import get_algorithm, run_async_block
from repro.graphs import generators as gen
from repro.kernels import bsr_spmm, gs_sweep
from repro.kernels.ops import pack_algorithm, run_async_block_pallas
from repro.kernels.ref import ref_bsr_spmm, ref_gs_sweep

RNG = np.random.RandomState(0)

SEMIRINGS = ["plus_times", "min_plus", "max_min", "max_times"]

# every fused pair the kernels implement, with a graph workload that
# exercises it (weighted graphs where the semiring needs real weights)
PAIRS = [
    ("pagerank", False),      # plus_times / replace
    ("sssp", True),           # min_plus  / min_old
    ("sswp", True),           # max_min   / max_old
    ("reachability", False),  # max_times / max_old
]


def _rand_tiles(nnz, bs, semiring):
    """Random tiles: ~20% real entries, the rest the semiring's in-tile fill."""
    from repro.kernels.semirings import TILE_FILL

    real = RNG.rand(nnz, bs, bs) < 0.2
    vals = (RNG.rand(nnz, bs, bs) * 5).astype(np.float32)
    return np.where(real, vals, np.float32(TILE_FILL[semiring])).astype(np.float32)


def _flat_operands(bs, d, nb, kmax, dtype, semiring):
    """Random ragged flat-BSR operands: row i owns i%(kmax+1) tiles (so some
    rows are empty — the layout's whole point) with random column blocks."""
    counts = np.arange(nb) % (kmax + 1)
    rowptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    nnz = int(rowptr[-1])
    tilecols = RNG.randint(0, nb, size=max(1, nnz)).astype(np.int32)
    tilerows = (np.repeat(np.arange(nb), counts).astype(np.int32)
                if nnz else np.zeros(1, np.int32))
    tiles = _rand_tiles(max(1, nnz), bs, semiring)
    x = RNG.rand(nb * bs, d).astype(np.float32)
    return (jnp.asarray(rowptr), jnp.asarray(tilerows), jnp.asarray(tilecols),
            jnp.asarray(tiles).astype(dtype), jnp.asarray(x).astype(dtype))


@pytest.mark.parametrize("bs,d,nb,kmax", [
    (8, 8, 3, 2), (8, 128, 4, 3), (16, 16, 5, 4), (32, 64, 3, 2),
    (128, 128, 2, 2),
])
@pytest.mark.parametrize("semiring", SEMIRINGS)
def test_bsr_spmm_shapes(bs, d, nb, kmax, semiring):
    rowptr, tilerows, tilecols, tiles, x = _flat_operands(
        bs, d, nb, kmax, jnp.float32, semiring)
    y = bsr_spmm(rowptr, tilerows, tilecols, tiles, x, semiring=semiring)
    yref = ref_bsr_spmm(rowptr, tilecols, tiles, x, semiring=semiring)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               atol=1e-4, rtol=1e-4)


def test_bsr_spmm_empty_rows_get_identity():
    """Row-blocks with no tiles never enter the grid; the wrapper must still
    write the reduce identity into their output rows."""
    for semiring, ident in [("plus_times", 0.0), ("min_plus", BIG),
                            ("max_min", -BIG), ("max_times", -BIG)]:
        rowptr, tilerows, tilecols, tiles, x = _flat_operands(
            8, 4, 5, 2, jnp.float32, semiring)
        y = np.asarray(bsr_spmm(rowptr, tilerows, tilecols, tiles, x,
                                semiring=semiring))
        rp = np.asarray(rowptr)
        for i in range(len(rp) - 1):
            if rp[i] == rp[i + 1]:
                np.testing.assert_array_equal(
                    y[i * 8:(i + 1) * 8], np.float32(ident))


def test_bsr_spmm_bf16():
    rowptr, tilerows, tilecols, tiles, x = _flat_operands(
        16, 32, 4, 3, jnp.bfloat16, "plus_times")
    y = bsr_spmm(rowptr, tilerows, tilecols, tiles, x)
    yref = ref_bsr_spmm(rowptr, tilecols,
                        np.asarray(tiles, np.float32),
                        np.asarray(x, np.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32), yref,
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("algo_name,weighted,bs", [
    ("pagerank", False, 32), ("pagerank", False, 64),
    ("sssp", True, 32), ("bfs", False, 64), ("php", False, 32),
    ("cc", False, 32), ("katz", False, 64),
    ("sswp", True, 32), ("reachability", False, 64),
])
def test_gs_sweep_vs_ref(algo_name, weighted, bs):
    g = gen.powerlaw_cluster(400, 3, seed=1)
    if weighted:
        g = gen.with_random_weights(g, seed=2)
    algo = get_algorithm(algo_name, g)
    ops = pack_algorithm(algo, bs=bs)
    args = (ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"],
            ops["x0"], ops["fixed"], ops["x"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"])
    xk = gs_sweep(*args, **kw)
    xr = ref_gs_sweep(*args, **kw)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               atol=1e-4, rtol=1e-4)


def test_pack_algorithm_tiles_are_nnz_proportional():
    """The flat layout's contract: tile memory is nnz_blocks * bs^2 * 4, not
    nb * k_max * bs^2 * 4 (the hub row-block is paid for once)."""
    g = gen.scrambled(gen.powerlaw_cluster(600, 4, seed=3), seed=7)
    ops = pack_algorithm(get_algorithm("pagerank", g), bs=16)
    s = ops["bsr_stats"]
    assert ops["tiles"].shape[0] == s["nnz_blocks"]
    assert s["tile_bytes"] == s["nnz_blocks"] * 16 * 16 * 4
    assert s["nnz_blocks"] < s["nb"] * s["k_max"]  # real skew on powerlaw
    assert s["padding_waste"] > 0.0
    assert s["tile_bytes_saved"] == s["dense_tile_bytes"] - s["tile_bytes"]


@pytest.mark.parametrize("algo_name,weighted", PAIRS)
def test_pallas_engine_matches_jax_engine(algo_name, weighted):
    g = gen.scrambled(gen.powerlaw_cluster(600, 4, seed=3), seed=7)
    graph = gen.with_random_weights(g, seed=1) if weighted else g
    algo = get_algorithm(algo_name, graph)
    r_pal = run_async_block_pallas(algo, bs=64, max_iters=300)
    r_jax = run_async_block(algo, bs=64)
    # float accumulation-order noise near eps can shift convergence by one
    assert abs(r_pal.rounds - r_jax.rounds) <= 1, algo_name
    if algo.semiring.reduce == "sum":
        # block-matmul vs edge-segment-sum accumulation order differs
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
    else:
        # min/max reductions are order-free: the kernels must be bitwise
        # equal to the pure-JAX engine
        np.testing.assert_array_equal(r_pal.x, r_jax.x)
    np.testing.assert_allclose(r_pal.x, algo.exact(), atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# the padding contract, for every supported pair: non-block-divisible n,
# batched d > 1, and warm-start x_init must ride the pallas backend without
# padding rows ever leaking into real states
# ---------------------------------------------------------------------------

def _contract_algo(algo_name, d):
    """An instance on a graph whose n (311) is not divisible by any test bs;
    d > 1 uses the batched constructors where they exist and column broadcast
    otherwise."""
    g = gen.scrambled(gen.powerlaw_cluster(311, 3, seed=9), seed=4)
    gw = gen.with_random_weights(g, seed=6)
    if d == 1:
        return get_algorithm(algo_name, gw if algo_name in ("sssp", "sswp") else g)
    if algo_name == "pagerank":
        return get_algorithm("ppr", g, seeds=list(range(d)))
    if algo_name == "sssp":
        return get_algorithm("ms_sssp", gw, sources=list(range(d)))
    # sswp / reachability have no batched constructor: run d independent
    # single-query columns by stacking the scalar instance's vectors
    import dataclasses

    algo = get_algorithm(algo_name, gw if algo_name == "sswp" else g)
    return dataclasses.replace(
        algo,
        x0=np.repeat(algo.x0, d, axis=1),
        c=np.repeat(algo.c, d, axis=1),
        fixed=np.repeat(algo.fixed, d, axis=1),
        exact_fn=None,
    )


@pytest.mark.parametrize("algo_name,_w", PAIRS)
@pytest.mark.parametrize("d", [1, 3])
def test_padding_contract_all_pairs(algo_name, _w, d):
    """bs=64 does not divide n=311: the last block is padding-heavy, and the
    result must still match the pure-JAX engine for every fused pair."""
    algo = _contract_algo(algo_name, d)
    r_pal = run_async_block_pallas(algo, bs=64, max_iters=300)
    r_jax = run_async_block(algo, bs=64)
    if algo.semiring.reduce == "sum":
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
    else:
        np.testing.assert_array_equal(r_pal.x, r_jax.x)
    np.testing.assert_array_equal(r_pal.col_rounds, r_jax.col_rounds)


@pytest.mark.parametrize("algo_name,_w", PAIRS)
def test_warm_start_contract_all_pairs(algo_name, _w):
    """x_init through the pallas backend: resuming from a mid-run jax-engine
    state must land on the same fixpoint as the jax engine resumed from the
    same state, and resuming from a *converged* state must be a bitwise
    no-op verification sweep (rounds == 1)."""
    algo = _contract_algo(algo_name, 1)
    r_cold = run_async_block(algo, bs=64)
    # mid-run resume: 3 rounds cold, then both backends finish from there
    r_mid = run_async_block(algo, bs=64, max_iters=3)
    r_pal = run_async_block_pallas(algo, bs=64, x_init=r_mid.x, max_iters=300)
    r_jax = run_async_block(algo, bs=64, x_init=r_mid.x)
    if algo.semiring.reduce == "sum":
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
    else:
        np.testing.assert_array_equal(r_pal.x, r_jax.x)
    # converged resume: one verification sweep, state bitwise unchanged
    r_resume = run_async_block_pallas(algo, bs=64, x_init=r_cold.x, max_iters=300)
    assert r_resume.rounds == 1
    np.testing.assert_array_equal(r_resume.x, r_cold.x)


def test_incremental_warm_start_through_pallas_backend():
    """run_incremental(engine='async_block', backend='pallas'): the warm
    state and the delta instance both ride the flat-BSR kernel path."""
    from repro.engine import remake, run_incremental
    from repro.graphs.delta import random_delta

    g0 = gen.scrambled(gen.powerlaw_cluster(300, 3, seed=2), seed=3)
    gw = gen.with_random_weights(g0, seed=1)
    # pagerank needs the unweighted graph (random weights up to 10 make the
    # iteration matrix non-contractive); sssp needs the weighted one
    for name, g in (("pagerank", g0), ("sssp", gw)):
        algo_old = get_algorithm(name, g)
        delta = random_delta(g, frac_add=0.02, seed=5)
        algo_new = remake(algo_old, delta.apply(g))
        prior = run_async_block(algo_old, bs=64)
        r_pal = run_incremental(algo_new, algo_old, prior, bs=64,
                                backend="pallas", max_iters=300)
        r_jax = run_incremental(algo_new, algo_old, prior, bs=64)
        np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
        r_cold = run_async_block(algo_new, bs=64)
        np.testing.assert_allclose(r_pal.x, r_cold.x, atol=1e-3, rtol=1e-3)


def test_gs_sweep_uses_fresh_states():
    """The defining property of the fused sweep: a block's update sees
    earlier blocks' THIS-sweep values (positive cross-block edges are fresh,
    Eq. 2 at tile granularity)."""
    from repro.graphs.graph import Graph

    n, bs = 8, 2
    g = Graph(n, np.arange(n - 1, dtype=np.int32),
              np.arange(1, n, dtype=np.int32),
              np.ones(n - 1, np.float32))
    algo = get_algorithm("sssp", g, source=0)
    ops = pack_algorithm(algo, bs=bs)
    args = (ops["rowptr"], ops["tilecols"], ops["tiles"], ops["c"],
            ops["x0"], ops["fixed"])
    kw = dict(semiring=ops["semiring"], combine=ops["combine"])
    x1 = np.asarray(gs_sweep(*args, ops["x"], **kw))[:n, 0]
    # after ONE sweep: v1 from the initial source; v2 via the cross-block
    # edge 1->2 sees v1's THIS-sweep value (pure Jacobi would leave it BIG);
    # v3's edge is intra-block -> still previous-round (BIG)
    np.testing.assert_allclose(x1[:3], [0.0, 1.0, 2.0], atol=1e-5)
    assert x1[3] >= BIG / 2
    # the chain settles one block per sweep: ceil(n/bs)=4 sweeps total,
    # vs n-1=7 Jacobi rounds
    x = ops["x"]
    for _ in range(4):
        x = gs_sweep(*args, x, **kw)
    np.testing.assert_allclose(np.asarray(x)[:n, 0], np.arange(n), atol=1e-5)
