import numpy as np
from hypothesis import given, settings, strategies as st

from repro.graphs import generators as gen
from repro.graphs.graph import order_to_rank
from repro.graphs.blocked import (
    num_blocks, pack_bsr, pack_bsr_flat, pack_in_edges,
)
from repro.graphs import io as gio


def small_graph():
    return gen.powerlaw_cluster(300, 3, seed=0)


def test_graph_basics():
    g = small_graph()
    assert g.n == 300 and g.m > 0
    assert g.out_degrees().sum() == g.m
    assert g.in_degrees().sum() == g.m
    indptr, idx, eid = g.csr()
    assert indptr[-1] == g.m
    # CSR row v holds out-neighbors of v
    for v in (0, 5, 100):
        nbrs = set(g.out_neighbors(v).tolist())
        assert nbrs == set(g.dst[g.src == v].tolist())


def test_relabel_roundtrip():
    g = small_graph()
    rng = np.random.default_rng(0)
    rank = rng.permutation(g.n)
    g2 = g.relabel(rank)
    # edges are preserved under relabeling
    e1 = set(zip((rank[g.src]).tolist(), (rank[g.dst]).tolist(), strict=True))
    e2 = set(zip(g2.src.tolist(), g2.dst.tolist(), strict=True))
    assert e1 == e2


def test_order_rank_involution():
    order = np.array([3, 1, 0, 2])
    rank = order_to_rank(order)
    assert rank.tolist() == [2, 1, 3, 0]
    assert order_to_rank(rank).tolist() == order.tolist()


@given(st.integers(10, 200), st.integers(1, 4), st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_generators_valid(n, m, seed):
    g = gen.barabasi_albert(max(n, m + 2), min(m, n - 2) or 1, seed=seed)
    assert g.src.min() >= 0 and g.src.max() < g.n
    assert g.dst.min() >= 0 and g.dst.max() < g.n
    # no self loops, no duplicate edges
    assert not np.any(g.src == g.dst)
    key = g.src.astype(np.int64) * g.n + g.dst
    assert len(np.unique(key)) == g.m


def test_pack_in_edges_complete():
    g = small_graph()
    bs = 32
    be = pack_in_edges(g, bs)
    assert be.nb == num_blocks(g.n, bs)
    assert int(be.emask.sum()) == g.m
    # reconstruct edges and compare
    recon = []
    for i in range(be.nb):
        for j in range(be.e_max):
            if be.emask[i, j]:
                recon.append((int(be.esrc[i, j]), int(be.edst[i, j]) + i * bs))
    assert sorted(recon) == sorted(zip(g.src.tolist(), g.dst.tolist(), strict=True))


def test_pack_bsr_matches_dense():
    g = gen.erdos_renyi(100, 3.0, seed=1)
    gw = gen.with_random_weights(g, seed=2)
    bs = 16
    bsr = pack_bsr(gw, bs, fill=0.0)
    n_pad = bsr.nb * bs
    dense = np.zeros((n_pad, n_pad), np.float32)
    dense[gw.dst, gw.src] = gw.weights  # A[dst, src]
    recon = np.zeros_like(dense)
    for i in range(bsr.nb):
        for k in range(bsr.k_max):
            if bsr.colmask[i, k]:
                c = bsr.cols[i, k]
                recon[i * bs:(i + 1) * bs, c * bs:(c + 1) * bs] = bsr.tiles[i, k]
    assert np.allclose(dense, recon)
    stats = bsr.stats()
    assert stats["nnz_blocks"] >= 1
    assert 0.0 <= stats["padding_waste"] < 1.0
    assert abs(stats["padding_waste"]
               - (1 - stats["nnz_blocks"] / (bsr.nb * bsr.k_max))) < 1e-12


def test_pack_bsr_flat_matches_dense_layout():
    """The flat layout holds exactly the dense layout's real tiles, in the
    same (row, col) order, with no padding tiles."""
    g = gen.erdos_renyi(100, 3.0, seed=1)
    gw = gen.with_random_weights(g, seed=2)
    for bs in (8, 16, 32):
        dense = pack_bsr(gw, bs, fill=0.5)
        flat = pack_bsr_flat(gw, bs, fill=0.5)
        nnz = int(dense.colmask.sum())
        assert flat.nnz_blocks == nnz
        assert flat.tiles.shape == (nnz, bs, bs)  # proportional to nnz_blocks
        np.testing.assert_array_equal(flat.tiles, dense.tiles[dense.colmask])
        np.testing.assert_array_equal(flat.tilecols, dense.cols[dense.colmask])
        np.testing.assert_array_equal(
            flat.tilerows, np.repeat(np.arange(flat.nb), np.diff(flat.rowptr)))
        per_row = np.diff(flat.rowptr)
        np.testing.assert_array_equal(per_row, dense.colmask.sum(axis=1))
        s, sd = flat.stats(), dense.stats()
        assert s["nnz_blocks"] == sd["nnz_blocks"]
        assert s["k_max"] == sd["k_max"]
        assert s["diag_fraction"] == sd["diag_fraction"]
        assert s["tile_bytes"] == nnz * bs * bs * 4
        assert s["dense_tile_bytes"] == sd["tile_bytes"]
        assert s["tile_bytes_saved"] == sd["tile_bytes"] - s["tile_bytes"]


def test_pack_bsr_flat_empty_graph():
    """An edgeless graph packs to rowptr == 0 with one never-referenced pad
    tile so device buffers are never zero-sized."""
    from repro.graphs.graph import Graph

    g = Graph(10, np.zeros(0, np.int32), np.zeros(0, np.int32),
              np.zeros(0, np.float32))
    flat = pack_bsr_flat(g, 4, fill=3.0)
    assert flat.nnz_blocks == 0
    assert flat.tiles.shape == (1, 4, 4)
    assert np.all(flat.rowptr == 0)
    assert flat.stats()["padding_waste"] == 1.0


def test_io_roundtrip(tmp_path):
    g = small_graph()
    p = str(tmp_path / "g.txt")
    with open(p, "w") as f:
        f.write("# comment line\n")
        for u, v in zip(g.src, g.dst, strict=True):
            f.write(f"{u} {v}\n")
    g2 = gio.load_edge_list(p)
    assert g2.n == g.n and g2.m == g.m
    p2 = str(tmp_path / "g.npz")
    gio.save_npz(g2, p2)
    g3 = gio.load_npz(p2)
    assert np.array_equal(g2.src, g3.src) and np.array_equal(g2.dst, g3.dst)
