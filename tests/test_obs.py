"""The observability layer (PR tentpole): tracer, metrics registry,
convergence telemetry, and the serving wiring over them.

Four contracts:

1. **Zero-cost-when-disabled** — a ``None``/disabled tracer hands every
   call site the shared ``NULL_SPAN`` singleton and records nothing; a
   registry is inert until something observes into it.
2. **Telemetry is free and exact** — every engine's ``RunResult`` carries a
   ``convergence_trace`` built purely from already-transferred host data:
   length equals the round count, the final residual is the number that
   decided convergence (``<= eps`` iff converged within budget), and
   turning tracing ON changes nothing — bitwise for min/max semirings,
   identical round counts, on both jax and pallas backends, under
   ``transfer_guard="disallow"``.
3. **Exporters are honest** — ``summary()`` is a superset of the
   pre-registry `ServerStats` dict, and ``prometheus_text()`` emits
   parseable text exposition with cumulative histogram buckets.
4. **The cache-hit fix** — a cache hit contributes 0.0 to the *wait*
   population too (it used to skip it, overstating measured waits).
"""
import io
import json
import re

import numpy as np
import pytest

from repro import EngineOptions, EngineOptionsError, get_algorithm, solve
from repro.graphs import generators as gen
from repro.obs import (
    NULL_SPAN,
    ConvergenceTrace,
    MetricsRegistry,
    Tracer,
    active_columns_per_round,
    bounded_append,
    percentile,
    tspan,
)
from repro.serving.server import GraphServer
from repro.serving.stats import ServerStats

N = 300
BS = 64


@pytest.fixture(scope="module")
def gw():
    g = gen.scrambled(gen.powerlaw_cluster(N, 4, p=0.4, seed=1), seed=9)
    return gen.with_random_weights(g, lo=0.1, hi=1.0, seed=2)


# ------------------------------------------------------------- percentile


def test_percentile_edges():
    vals = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(vals, 0) == 1.0      # rank clamps to 1 -> min
    assert percentile(vals, 100) == 5.0    # -> max
    assert percentile(vals, 50) == 3.0
    assert percentile([7.25], 0) == 7.25   # single sample for every q
    assert percentile([7.25], 99) == 7.25
    assert percentile([], 50) == 0.0       # empty -> 0.0, never raises


def test_percentile_is_an_observed_sample():
    vals = [0.1 * k for k in range(1, 101)]
    for q in (1, 37, 50, 90, 99, 100):
        assert percentile(vals, q) in vals


def test_bounded_append_window_halving():
    samples = []
    for v in range(10):
        bounded_append(samples, v, max_samples=6)
    # each overflow drops the oldest half; the tail is always the newest
    assert len(samples) <= 6
    assert samples[-1] == 9
    assert samples == sorted(samples)


def test_stats_module_reexports_percentile():
    # layering: serving's percentile IS the obs one (single implementation)
    from repro.serving import stats

    assert stats.percentile is percentile


# ----------------------------------------------------------------- tracer


def test_disabled_tracer_is_null_span():
    tr = Tracer(enabled=False)
    sp = tr.span("solve", algo="pagerank")
    assert sp is NULL_SPAN
    with sp as s:
        s.set(rounds=3)   # no-op, never raises
    assert len(tr.spans) == 0
    tr.event("resolve", rounds=1)
    assert len(tr.spans) == 0
    assert tspan(None, "batch") is NULL_SPAN
    assert tspan(tr, "batch") is NULL_SPAN


def test_ring_buffer_keeps_most_recent():
    tr = Tracer(ring=4)
    for k in range(7):
        tr.event("batch", k=k)
    assert len(tr.spans) == 4
    assert [s.attrs["k"] for s in tr.spans] == [3, 4, 5, 6]
    assert [s.attrs["k"] for s in tr.find("batch")] == [3, 4, 5, 6]
    assert tr.find("solve") == []


def test_jsonl_sink_flushes_per_span():
    sink = io.StringIO()
    tr = Tracer(jsonl=sink)
    with tr.span("solve", algo="sssp", engine="push") as sp:
        sp.set(rounds=5, converged=True)
    # flushed at exit: a live reader sees the line immediately
    lines = sink.getvalue().splitlines()
    assert len(lines) == 1
    rec = json.loads(lines[0])
    assert rec["name"] == "solve"
    assert rec["algo"] == "sssp" and rec["engine"] == "push"
    assert rec["rounds"] == 5 and rec["converged"] is True
    assert rec["duration_s"] >= 0.0 and "t_start" in rec
    tr.event("resolve", tenant="default")
    assert len(sink.getvalue().splitlines()) == 2


def test_span_attrs_set_mid_span_land_in_record():
    tr = Tracer()
    with tr.span("batch", tenant="a") as sp:
        sp.set(rounds=8)
    (rec,) = tr.spans
    assert rec.attrs == {"tenant": "a", "rounds": 8}
    assert rec.duration_s >= 0.0


# --------------------------------------------------------------- registry


def test_counter_roundtrip_and_rollups():
    reg = MetricsRegistry()
    c = reg.counter("q_total", "queries", ("tenant",))
    c.inc(tenant="a")
    c.inc(2, tenant="b")
    assert c.value(tenant="a") == 1.0
    assert c.total() == 3.0
    assert c.per_label("tenant") == {"a": 1.0, "b": 2.0}
    with pytest.raises(ValueError):
        c.inc(-1, tenant="a")
    # get-or-create: same declaration returns the same family,
    # a mismatched one is rejected loudly
    assert reg.counter("q_total", "queries", ("tenant",)) is c
    with pytest.raises(ValueError):
        reg.counter("q_total", "queries", ("tenant", "family"))
    with pytest.raises(ValueError):
        reg.gauge("q_total", "queries", ("tenant",))


def test_gauge_set_and_inc():
    reg = MetricsRegistry()
    g = reg.gauge("occ", "occupancy")
    g.set(0.5)
    g.inc(0.25)
    assert g.value() == 0.75


def test_histogram_percentiles_and_merge():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", ("tenant",))
    for v in (1.0, 2.0, 3.0):
        h.observe(v, tenant="a")
    h.observe(100.0, tenant="b")
    assert h.percentile(50, tenant="a") == 2.0
    assert h.count(tenant="a") == 3 and h.total_count() == 4
    # label-less percentile on a labeled family merges every child window
    assert h.percentile(100) == 100.0
    assert h.per_label("tenant")["b"] == [100.0]


def test_histogram_wrong_labels_rejected():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", ("tenant",))
    with pytest.raises(ValueError):
        h.observe(1.0, nottenant="a")
    with pytest.raises(ValueError):
        h.observe(1.0)


_LABEL = r'[a-zA-Z_]+="(\\.|[^"\\])*"'
_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
    rf"(\{{{_LABEL}(,{_LABEL})*\}})? -?[0-9.eE+\-]+(inf)?$"
)


def test_prometheus_text_parses():
    reg = MetricsRegistry()
    reg.counter("q_total", "queries served", ("tenant",)).inc(tenant='we"ird')
    reg.gauge("occ", "occupancy").set(0.5)
    h = reg.histogram("lat", "latency seconds", ("tenant",),
                      buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v, tenant="a")
    text = reg.prometheus_text()
    assert text.endswith("\n")
    help_seen, type_seen = set(), set()
    for line in text.splitlines():
        if line.startswith("# HELP"):
            help_seen.add(line.split()[2])
        elif line.startswith("# TYPE"):
            type_seen.add(line.split()[2])
        else:
            assert _SAMPLE_RE.match(line), line
    assert help_seen == type_seen == {"q_total", "occ", "lat"}
    # histogram buckets are cumulative and +Inf equals _count
    assert 'lat_bucket{tenant="a",le="0.1"} 1' in text
    assert 'lat_bucket{tenant="a",le="1"} 2' in text
    assert 'lat_bucket{tenant="a",le="+Inf"} 3' in text
    assert 'lat_count{tenant="a"} 3' in text
    # label escaping: the quote in the tenant name is escaped
    assert 'tenant="we\\"ird"' in text


def test_registry_summary_shapes():
    reg = MetricsRegistry()
    reg.counter("plain", "unlabeled").inc(3)
    reg.counter("labeled", "labeled", ("tenant",)).inc(tenant="a")
    h = reg.histogram("lat", "latency", ("tenant",))
    h.observe(2.0, tenant="a")
    s = reg.summary()
    assert s["plain"] == 3.0
    assert s["labeled"] == {"a": 1.0}
    assert s["lat"]["a"]["count"] == 1 and s["lat"]["a"]["p50"] == 2.0


# --------------------------------------------- EngineOptions.trace knob


def test_options_trace_validation(gw):
    algo = get_algorithm("pagerank", gw)
    with pytest.raises(EngineOptionsError):
        solve(algo, options=EngineOptions(trace="yes please"))
    res = solve(algo, options=EngineOptions(trace=Tracer()))
    assert res.converged


# ------------------------------------------------- convergence telemetry

ENGINE_SPECS = [
    ("sync", {}),
    ("async_block", {"bs": BS, "inner": 2}),
    ("async_block", {"bs": BS, "backend": "pallas"}),
    ("async_block", {"bs": BS, "backend": "pallas", "sweeps_per_call": 4}),
    ("push", {}),
]


@pytest.mark.parametrize("engine,kw", ENGINE_SPECS)
@pytest.mark.parametrize("algo_name,params", [
    ("pagerank", {}), ("sssp", {"source": 3}),
])
def test_convergence_trace_all_engines(gw, engine, kw, algo_name, params):
    if engine == "push" and algo_name == "pagerank":
        pytest.skip("push engine serves selective semirings")
    algo = get_algorithm(algo_name, gw, **params)
    res = solve(algo, engine=engine, **kw)
    tr = res.convergence_trace
    assert isinstance(tr, ConvergenceTrace)
    assert tr.rounds == res.rounds > 0
    assert len(tr.active_fraction) == len(tr.work) == tr.rounds
    assert np.all(tr.active_fraction >= 0) and np.all(tr.active_fraction <= 1)
    assert np.all(tr.work >= 0) and tr.total_work > 0
    # the trace's final residual IS the convergence decision
    assert res.converged
    assert tr.final_residual <= algo.eps
    expected_unit = {
        "sync": "swept_vertex_cols",
        "push": "pushed_vertices",
    }.get(engine, "swept_block_cells"
          if kw.get("sweeps_per_call", 1) > 1 else "swept_vertex_cols")
    assert tr.unit == expected_unit
    j = tr.to_json()
    assert j["rounds"] == tr.rounds and len(j["residual"]) == tr.rounds


def test_active_columns_per_round():
    # cols froze after 1, 3, 3 rounds -> active counts 3,2,2 then 0
    out = active_columns_per_round(np.array([1, 3, 3]), rounds=4)
    np.testing.assert_array_equal(out, [3.0, 2.0, 2.0, 0.0])
    assert active_columns_per_round(np.array([2]), rounds=0).shape == (0,)


def test_trace_final_residual_tracks_nonconvergence(gw):
    algo = get_algorithm("sssp", gw, source=3)
    res = solve(algo, engine="sync", max_iters=2)
    assert not res.converged
    assert res.convergence_trace.rounds == res.rounds == 2
    assert res.convergence_trace.final_residual > algo.eps


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("algo_name,params", [
    ("sssp", {"source": 3}),    # min semiring
    ("sswp", {"source": 3}),    # max semiring
])
def test_trace_on_is_bitwise_invisible(gw, backend, algo_name, params,
                                       transfer_guard_disallow):
    """Enabling tracing must not perturb the solve: identical states
    (bitwise — selective semirings copy, never blend), identical rounds,
    and no unaudited transfer appears (the guard faults if one does)."""
    algo = get_algorithm(algo_name, gw, **params)
    kw = dict(bs=BS, backend=backend,
              sweeps_per_call=4 if backend == "pallas" else 1)
    off = solve(algo, engine="async_block", **kw)
    sink = io.StringIO()
    on = solve(algo, engine="async_block",
               options=EngineOptions(trace=Tracer(jsonl=sink), **kw))
    assert on.rounds == off.rounds
    np.testing.assert_array_equal(on.x, off.x)
    np.testing.assert_array_equal(
        on.convergence_trace.residual, off.convergence_trace.residual
    )
    assert sink.getvalue().count('"name": "solve"') == 1


# ---------------------------------------------------------- serving wiring


def test_cache_hit_populates_wait_population():
    """The fix: a cache hit is a resolve the client waited 0s for, so it
    must appear in the wait histogram (it used to be silently skipped)."""
    st = ServerStats(slots=4)
    st.record_submit(tenant="a")
    st.record_cache_hit(tenant="a", family="sssp")
    s = st.summary()
    assert s["cache_hits"] == 1 and s["resolved"] == 1
    assert st._wait_h.total_count() == 1          # the regression bit
    assert st._latency_h.total_count() == 1
    assert s["wait_p50_s"] == 0.0


def test_stats_summary_superset_and_legacy_surface():
    st = ServerStats(slots=2)
    st.record_submit(tenant="a")
    st.record_batch(2, 8, tenant="a")
    st.record_delta("a")
    st.record_reorder("a")
    st.record_reorder_disabled("a")
    st.record_fail(tenant="b")
    legacy_keys = {
        "submitted", "resolved", "unconverged", "failed", "cache_hits",
        "batches", "rounds_total", "round_slots_total", "deltas_applied",
        "deadline_misses", "tenant_batches", "tenant_rounds", "reorders",
        "reorders_disabled", "elapsed_s", "throughput_qps", "latency_p50_s",
        "latency_p99_s", "wait_p50_s", "wait_p99_s", "rounds_p50",
        "rounds_p99", "occupancy_mean",
    }
    s = st.summary()
    assert legacy_keys <= set(s)
    assert {"per_tenant", "per_family"} <= set(s)
    assert st.rounds_total == 8 and st.round_slots_total == 16
    assert st.tenant_batches == {"a": 1}
    assert st.deltas_applied == 1 and st.failed == 1
    assert st.reorders == {"a": 1}
    assert st.reorders_disabled == {"a": True}
    assert isinstance(st.metrics_text(), str)


def _small_server(**kw):
    rng = np.random.default_rng(0)
    n, m = 150, 900
    g = gen.Graph(n, rng.integers(0, n, m), rng.integers(0, n, m),
                  rng.random(m).astype(np.float32))
    return GraphServer(g, slots=4, bs=32, rounds_per_batch=4, **kw)


def test_traced_serving_end_to_end():
    """Acceptance scenario: a traced server under the transfer sanitizer
    produces spans, Prometheus-parseable metrics, and per-ticket resolve
    events — with zero unaudited transfers."""
    sink = io.StringIO()
    tr = Tracer(jsonl=sink)
    srv = _small_server(transfer_guard="disallow", trace=tr)
    t1 = srv.submit("pagerank", {"damping": 0.85})
    t2 = srv.submit("sssp", {"source": 3})
    srv.run()
    t3 = srv.submit("sssp", {"source": 3})      # cache hit
    assert t1.converged and t2.converged and t3.from_cache
    names = {sp.name for sp in tr.spans}
    assert {"pack", "batch", "resolve"} <= names
    resolves = [json.loads(line) for line in sink.getvalue().splitlines()
                if json.loads(line)["name"] == "resolve"]
    assert len(resolves) == 3
    assert {r["algo"] for r in resolves} == {"pagerank", "sssp"}
    live = [r for r in resolves if not r.get("from_cache")]
    assert all(r["rounds"] > 0 and r["converged"] for r in live)
    # batch spans carry the family attrs _make_family stamped
    batch = tr.find("batch")[0]
    assert batch.attrs["tenant"] == "default"
    assert "family" in batch.attrs and "graph_version" in batch.attrs
    text = srv.metrics_text()
    for line in text.splitlines():
        if not line.startswith("#"):
            assert _SAMPLE_RE.match(line), line
    assert 'repro_queries_resolved_total{tenant="default"} 3' in text
    assert 'repro_cache_hits_total{tenant="default"} 1' in text
    s = srv.stats.summary()
    assert s["per_family"]["sssp"]["rounds_p50"] >= 0
    assert s["per_tenant"]["default"]["resolved"] == 3


def test_server_trace_knob_validated():
    with pytest.raises(TypeError):
        _small_server(trace="not a tracer")


def test_untraced_server_unchanged():
    srv = _small_server(transfer_guard="disallow")
    assert srv.trace is None
    t = srv.submit("sssp", {"source": 1})
    srv.run()
    assert t.converged and t.rounds > 0
