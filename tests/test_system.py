"""End-to-end behaviour tests for the paper's system: the full
reorder -> execute -> converge pipeline, engine cross-agreement, and the
integrated fault-tolerant driver."""
import numpy as np
import pytest

from repro.core import metric
from repro.core.baselines import all_reorderers
from repro.core.gograph import gograph_order
from repro.engine import get_algorithm, run_async_block, run_sync
from repro.engine.priority import run_priority_block
from repro.graphs import generators as gen
from repro.kernels.ops import run_async_block_pallas


@pytest.fixture(scope="module")
def system_graph():
    return gen.scrambled(gen.powerlaw_cluster(2500, 4, seed=5), seed=11)


def test_end_to_end_pipeline(system_graph):
    """The paper's full pipeline: reorder, run async, beat sync in rounds,
    agree with the exact solution."""
    g = system_graph
    rank = gograph_order(g)
    assert metric.metric_m(g, rank) >= g.m / 2  # Theorem 2
    algo = get_algorithm("pagerank", g).relabel(rank)
    r_sync = run_sync(algo)
    r_async = run_async_block(algo, bs=64, inner=2)
    assert r_async.converged and r_sync.converged
    assert r_async.rounds < r_sync.rounds
    np.testing.assert_allclose(r_async.x, algo.exact(), atol=2e-5, rtol=1e-4)


def test_all_engines_agree(system_graph):
    """sync / block-GS / fused-Pallas / priority all reach the same fixpoint."""
    g = system_graph
    rank = gograph_order(g)
    algo = get_algorithm("pagerank", g).relabel(rank)
    xs = {
        "sync": run_sync(algo).x,
        "async": run_async_block(algo, bs=64).x,
        "pallas": run_async_block_pallas(algo, bs=64, max_iters=300).x,
        "priority": run_priority_block(algo, bs=64).x,
    }
    ref = algo.exact()
    for name, x in xs.items():
        np.testing.assert_allclose(x, ref, atol=2e-4, rtol=1e-3, err_msg=name)


def test_every_reorderer_preserves_solutions(system_graph):
    """Reordering must NEVER change results, only the round count."""
    g = system_graph
    algo = get_algorithm("bfs", g)
    base = algo.exact()
    for name, fn in all_reorderers().items():
        rank = fn(g)
        r = run_async_block(algo.relabel(rank), bs=128)
        inv = np.empty(g.n, dtype=np.int64)
        inv[rank] = np.arange(g.n)
        np.testing.assert_allclose(r.x[rank], base, atol=1e-5, err_msg=name)


def test_fault_tolerant_graph_driver(tmp_path):
    """examples/graph_end2end.py's core path: macro-steps + checkpoint +
    injected failure, converging to the exact answer."""
    from repro.ckpt.manager import CheckpointManager
    from repro.runtime.fault import FaultTolerantRunner

    g = gen.scrambled(gen.powerlaw_cluster(1200, 4, seed=2), seed=3)
    algo = get_algorithm("pagerank", g).relabel(gograph_order(g))
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    injected = {"done": False}

    def step_fn(state, step):
        if step == 1 and not injected["done"]:
            injected["done"] = True
            raise RuntimeError("injected")
        r = run_async_block(algo, bs=64, max_iters=5, x_init=state["x"])
        return {"x": r.x, "rounds": state["rounds"] + r.rounds,
                "converged": bool(r.converged)}

    def save_fn(step, state):
        mgr.save(step, {"x": state["x"], "rounds": np.int64(state["rounds"])})

    def restore_fn():
        tree, man = mgr.restore()
        return ({"x": tree["['params']['x']"],
                 "rounds": int(tree["['params']['rounds']"]),
                 "converged": False}, man["step"])

    runner = FaultTolerantRunner(step_fn, save_fn, restore_fn, ckpt_every=1,
                                 max_failures=2)
    state = {"x": algo.x0, "rounds": 0, "converged": False}
    state, _ = runner.run(state, steps=12)
    assert runner.failures == 1
    assert state["converged"]
    np.testing.assert_allclose(state["x"], algo.exact(), atol=2e-5, rtol=1e-4)
