"""Engine correctness + the paper's round-reduction claims."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graphs import generators as gen
from repro.engine import get_algorithm, run_sync, run_async_block
from repro.core.gograph import gograph_order
from repro.core import baselines


@pytest.fixture(scope="module")
def graphs():
    g = gen.scrambled(gen.powerlaw_cluster(1200, 4, seed=1), seed=9)
    gw = gen.with_random_weights(g, seed=2)
    return g, gw


ALGO_GRAPH = [
    ("pagerank", False), ("katz", False), ("php", False), ("adsorption", False),
    ("sssp", True), ("bfs", False), ("cc", False), ("sswp", True),
]


@pytest.mark.parametrize("name,weighted", ALGO_GRAPH)
def test_sync_matches_exact(graphs, name, weighted):
    g, gw = graphs
    algo = get_algorithm(name, gw if weighted else g)
    r = run_sync(algo)
    assert r.converged
    np.testing.assert_allclose(r.x, algo.exact(), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("name,weighted", ALGO_GRAPH)
def test_async_matches_exact(graphs, name, weighted):
    g, gw = graphs
    algo = get_algorithm(name, gw if weighted else g)
    r = run_async_block(algo, bs=128)
    assert r.converged
    np.testing.assert_allclose(r.x, algo.exact(), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("name,weighted", [("pagerank", False), ("sssp", True),
                                           ("php", False), ("bfs", False)])
def test_async_fewer_rounds_than_sync(graphs, name, weighted):
    """Paper observation (Fig. 1/2): async needs fewer rounds than sync."""
    g, gw = graphs
    algo = get_algorithm(name, gw if weighted else g)
    rs = run_sync(algo)
    ra = run_async_block(algo, bs=64)
    assert ra.rounds <= rs.rounds


@pytest.mark.parametrize("name,weighted", [("pagerank", False), ("php", False)])
def test_gograph_reduces_rounds(graphs, name, weighted):
    """The paper's headline: async + GoGraph converges in fewer sweeps than
    async + (scrambled) default order.

    inner=2 is the TPU-native blocked configuration (benchmarks/common.py):
    one local re-iteration makes the intra-block edges that GoGraph
    concentrates fresh — at block granularity with inner=1 those edges stay
    stale and the ordering's advantage can be lost to block-boundary noise.
    """
    g, gw = graphs
    graph = gw if weighted else g
    algo = get_algorithm(name, graph)
    rank = gograph_order(graph)
    r_def = run_async_block(algo, bs=64, inner=2)
    r_gg = run_async_block(algo.relabel(rank), bs=64, inner=2)
    assert r_gg.rounds <= r_def.rounds
    # and the result is still exact
    np.testing.assert_allclose(
        r_gg.x, algo.relabel(rank).exact(), atol=2e-5, rtol=1e-4
    )


def test_relabel_preserves_solution(graphs):
    g, gw = graphs
    algo = get_algorithm("sssp", gw)
    rank = baselines.degree_sort(gw)
    r = run_async_block(algo.relabel(rank), bs=64)
    inv = np.empty(gw.n, dtype=np.int64)
    inv[rank] = np.arange(gw.n)
    # un-relabel and compare to the original exact solution
    np.testing.assert_allclose(r.x[rank], algo.exact(), atol=2e-5, rtol=1e-4)


def test_inner_iterations_reduce_rounds(graphs):
    g, _ = graphs
    algo = get_algorithm("pagerank", g)
    r1 = run_async_block(algo, bs=128, inner=1)
    r2 = run_async_block(algo, bs=128, inner=2)
    assert r2.rounds <= r1.rounds
    np.testing.assert_allclose(r1.x, r2.x, atol=1e-4, rtol=1e-4)


def test_convergence_trace_monotone(graphs):
    """Monotone algorithms (paper Eq. 3): state sums move monotonically."""
    g, _ = graphs
    algo = get_algorithm("pagerank", g)
    r = run_sync(algo)
    sums = r.state_sums
    assert np.all(np.diff(sums) >= -1e-3)  # increasing toward fixpoint


@given(st.integers(30, 150), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_property_sync_async_same_fixpoint(n, seed):
    g = gen.erdos_renyi(n, 3.0, seed=seed)
    if g.m == 0:
        return
    algo = get_algorithm("pagerank", g)
    rs = run_sync(algo)
    ra = run_async_block(algo, bs=32)
    np.testing.assert_allclose(rs.x, ra.x, atol=1e-4, rtol=1e-3)


def test_distributed_engine_subprocess():
    from tests.util import run_with_devices

    run_with_devices("""
import numpy as np
from repro.graphs import generators as gen
from repro.engine import get_algorithm, run_async_block
from repro.engine.distributed import run_distributed
g = gen.powerlaw_cluster(800, 4, seed=1)
algo = get_algorithm('pagerank', g)
r = run_distributed(algo, bs=32)
assert r.converged
np.testing.assert_allclose(r.x, algo.exact(), atol=2e-5, rtol=1e-4)
rb = run_async_block(algo, bs=32)
assert rb.rounds <= r.rounds <= 3 * rb.rounds + 5
print('ok')
""", n_devices=8)


def test_priority_engine_exact_and_saves_work():
    """Priter-style block scheduling: same fixpoint; less work on
    frontier-style workloads (high-diameter SSSP)."""
    from repro.engine.priority import run_priority_block
    from repro.core.gograph import gograph_order

    g = gen.scrambled(gen.barabasi_albert(3000, 1, seed=3), seed=7)
    gw = gen.with_random_weights(g, seed=2)
    rank = gograph_order(g)
    algo = get_algorithm("sssp", gw).relabel(rank)
    rf = run_async_block(algo, bs=64)
    rp = run_priority_block(algo, bs=64, select_frac=0.125)
    assert rp.converged
    np.testing.assert_allclose(rp.x, algo.exact(), atol=2e-5, rtol=1e-4)
    assert rp.rounds < rf.rounds  # strictly less edge-work

    # and on PageRank (uniform convergence) it must still be exact
    algo2 = get_algorithm("pagerank", g).relabel(rank)
    rp2 = run_priority_block(algo2, bs=64, select_frac=0.25)
    assert rp2.converged
    np.testing.assert_allclose(rp2.x, algo2.exact(), atol=2e-4, rtol=1e-3)
