"""Online reordering: incremental metric tracking, regional re-rank, and the
live order swap in the serving loop (PR 9).

The load-bearing contracts:

* `MetricTracker` is *exact* — ``tracker.M == metric_m(g, rank)`` after any
  `GraphDelta` sequence (property-tested), because old edges' positivity
  depends only on the relative order of their endpoints, which order-
  preserving extensions keep.
* `extend_rank` / `RankMaintainer` always emit valid permutations and never
  move existing vertices relative to each other.
* `regional_rerank` recovers M on a decayed order while non-members keep
  their exact relative order.
* An order swap is invisible to a query's value trajectory: a ranked (or
  re-ranked mid-flight) GraphServer resolves every ticket with exactly the
  solo engine's result — bitwise for min/max semirings, within eps for sum —
  including the pallas megakernel under ``transfer_guard="disallow"``.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import metric
from repro.core.gograph import RankMaintainer, extend_rank, regional_rerank
from repro.core.metric import MetricTracker, metric_m, metric_m_jax
from repro.engine.api import EngineOptions, EngineOptionsError, solve
from repro.engine.algorithms import get_algorithm
from repro.graphs import generators as gen
from repro.graphs.delta import GraphDelta, random_delta
from repro.graphs.graph import Graph, check_permutation
from repro.serving.server import GraphServer, _ReorderTuner


def _weighted(g, seed=0):
    rng = np.random.default_rng(seed)
    return dataclasses.replace(
        g, w=rng.uniform(0.1, 1.0, g.m).astype(np.float32)
    )


def _shuffled_path(n, seed=7):
    """Directed path over shuffled ids + its perfect rank (chain order)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    g = Graph(
        n=n, src=perm[:-1].astype(np.int64), dst=perm[1:].astype(np.int64),
        w=np.ones(n - 1, np.float32),
    )
    rank = np.empty(n, np.int64)
    rank[perm] = np.arange(n)
    return g, rank, perm


def _reverse_segment(perm, lo, hi):
    """Delta reversing the chain segment at positions [lo, hi]."""
    seg = perm[lo:hi + 1]
    return GraphDelta(
        del_src=seg[:-1].astype(np.int64), del_dst=seg[1:].astype(np.int64),
        add_src=seg[1:].astype(np.int64), add_dst=seg[:-1].astype(np.int64),
        add_w=np.ones(hi - lo, np.float32),
    )


@st.composite
def delta_scripts(draw):
    """A start graph + a seed-script of mixed random deltas."""
    n = draw(st.integers(12, 80))
    g = gen.erdos_renyi(n, draw(st.floats(1.5, 4.0)), seed=draw(st.integers(0, 30)))
    steps = []
    for _ in range(draw(st.integers(1, 6))):
        steps.append(dict(
            frac_add=draw(st.floats(0.0, 0.15)),
            frac_del=draw(st.floats(0.0, 0.15)),
            frac_rew=draw(st.floats(0.0, 0.2)),
            n_add_vertices=draw(st.integers(0, 4)),
            seed=draw(st.integers(0, 1000)),
        ))
    return g, steps


# --------------------------------------------------------------- the tracker

@given(delta_scripts(), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_tracker_matches_recompute_exactly(script, seed):
    """tracker.M == metric_m(g, rank) after every delta in the sequence, and
    the per-region counts sum to (M, m)."""
    g, steps = script
    rank = np.random.default_rng(seed).permutation(g.n).astype(np.int64)
    tr = MetricTracker(g, rank, regions=5)
    maint = RankMaintainer(rank)
    for kw in steps:
        d = random_delta(g, **kw)
        g = d.apply(g)
        if d.n_add:
            rank = maint.extend(g)
            tr.apply_delta(d, rank_new=rank)
        else:
            tr.apply_delta(d)
        assert tr.M == metric_m(g, rank)
        assert tr.m_edges == g.m
        assert int(tr.region_m.sum()) == tr.M
        assert int(tr.region_edges.sum()) == g.m
        # per-region counts against a reference recount at the tracker's own
        # (rebase-frozen, forward-filled) region assignment
        reg = tr.region_of[g.dst]
        pos = rank[g.src] < rank[g.dst]
        np.testing.assert_array_equal(
            tr.region_m, np.bincount(reg[pos], minlength=tr.regions))
        np.testing.assert_array_equal(
            tr.region_edges, np.bincount(reg, minlength=tr.regions))


def test_tracker_requires_extended_rank_for_appends():
    g = gen.erdos_renyi(20, 2.0, seed=0)
    tr = MetricTracker(g, np.arange(20))
    d = random_delta(g, n_add_vertices=2, seed=1)
    with pytest.raises(ValueError, match="extended rank"):
        tr.apply_delta(d)


def test_tracker_rebase_after_arbitrary_reorder():
    g = gen.powerlaw_cluster(60, 3, seed=2)
    rng = np.random.default_rng(3)
    tr = MetricTracker(g, rng.permutation(g.n).astype(np.int64), regions=4)
    new_rank = rng.permutation(g.n).astype(np.int64)
    tr.rebase(g, new_rank)
    assert tr.M == metric_m(g, new_rank)
    assert np.array_equal(tr.rank, new_rank)


def test_decayed_regions_trigger_is_local():
    g, rank, perm = _shuffled_path(256)
    tr = MetricTracker(g, rank, regions=8)
    assert tr.m_frac == 1.0
    d = _reverse_segment(perm, 64, 112)
    tr.apply_delta(d)
    g2 = d.apply(g)
    assert tr.M == metric_m(g2, rank)
    decayed = tr.decayed_regions(0.9)
    assert len(decayed) >= 1
    # regions far from the reversed span keep fraction 1.0 -> never trigger
    assert tr.fractions()[0] == 1.0 and tr.fractions()[-1] == 1.0
    assert 0 not in decayed and tr.regions - 1 not in decayed


# ------------------------------------------------- extend_rank / maintainer

@given(delta_scripts(), st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_extend_rank_stays_a_permutation(script, seed):
    """Regression: repeated deltas with appended vertices keep the extended
    rank a valid permutation, and existing vertices never move relative to
    each other (the tracker-exactness precondition)."""
    g, steps = script
    rank = np.random.default_rng(seed).permutation(g.n).astype(np.int64)
    for kw in steps:
        kw = dict(kw, n_add_vertices=max(1, kw["n_add_vertices"]))
        d = random_delta(g, **kw)
        g_new = d.apply(g)
        rank_new = extend_rank(g_new, rank)
        check_permutation(rank_new, g_new.n)
        old = np.argsort(rank[:g.n], kind="stable")
        still = np.argsort(rank_new[:g.n], kind="stable")
        np.testing.assert_array_equal(old, still)
        g, rank = g_new, rank_new


def test_maintainer_matches_oneshot_extend_rank():
    g = gen.erdos_renyi(40, 2.5, seed=4)
    rank = np.random.default_rng(5).permutation(g.n).astype(np.int64)
    maint = RankMaintainer(rank)
    for s in range(4):
        d = random_delta(g, frac_add=0.05, n_add_vertices=2, seed=s)
        g_new = d.apply(g)
        np.testing.assert_array_equal(maint.extend(g_new), extend_rank(g_new, rank))
        rank = maint.rank()
        g = g_new


# ----------------------------------------------------------- regional rerank

def test_regional_rerank_recovers_decayed_segment():
    g, rank, perm = _shuffled_path(256)
    tr = MetricTracker(g, rank, regions=8)
    d = _reverse_segment(perm, 64, 112)
    g2 = d.apply(g)
    tr.apply_delta(d)
    members = tr.region_members(tr.decayed_regions(0.9))
    assert len(members)
    rank2 = regional_rerank(g2, rank, members)
    check_permutation(rank2, g2.n)
    m_old, m_new = metric_m(g2, rank), metric_m(g2, rank2)
    assert m_new > m_old
    assert m_new >= g2.m - 1  # a path re-chains to all-but-one positive
    # non-members keep their exact relative order
    is_member = np.zeros(g2.n, bool)
    is_member[members] = True
    rest = np.where(~is_member)[0]
    np.testing.assert_array_equal(
        rest[np.argsort(rank[rest], kind="stable")],
        rest[np.argsort(rank2[rest], kind="stable")],
    )


def test_regional_rerank_empty_members_is_identity():
    g = gen.erdos_renyi(30, 2.0, seed=6)
    rank = np.random.default_rng(7).permutation(g.n).astype(np.int64)
    np.testing.assert_array_equal(
        regional_rerank(g, rank, np.array([], np.int64)), rank)


# ------------------------------------------------------------- metric_m_jax

def test_metric_m_jax_matches_numpy():
    g = gen.powerlaw_cluster(80, 3, seed=8)
    rank = np.random.default_rng(9).permutation(g.n).astype(np.int64)
    got = int(metric_m_jax(g.src, g.dst, np.asarray(rank)))
    assert got == metric_m(g, rank)


def test_metric_m_jax_raises_past_int32_bound(monkeypatch):
    """Past the int32 edge bound without x64, the count must refuse to run
    rather than silently wrap (exercised by shrinking the bound)."""
    import jax
    if jax.config.jax_enable_x64:
        pytest.skip("x64 enabled: int64 accumulation, no bound")
    g = gen.erdos_renyi(30, 3.0, seed=10)
    monkeypatch.setattr(metric, "METRIC_EDGE_BOUND", g.m - 1)
    with pytest.raises(OverflowError, match="int32 accumulation bound"):
        metric_m_jax(g.src, g.dst, np.arange(g.n))


# -------------------------------------------------------- solve(rank=...)

def test_solve_rank_parity_minmax_bitwise():
    g = _weighted(gen.powerlaw_cluster(120, 4, p=0.3, seed=11), seed=11)
    rank = np.random.default_rng(12).permutation(g.n).astype(np.int64)
    q = get_algorithm("sssp", g, source=3)
    base = np.asarray(solve(q).x)
    ranked = np.asarray(solve(q, rank=rank).x)
    np.testing.assert_array_equal(base, ranked)


def test_solve_rank_parity_sum_within_eps():
    g = gen.powerlaw_cluster(100, 3, seed=13)
    rank = np.random.default_rng(14).permutation(g.n).astype(np.int64)
    q = get_algorithm("pagerank", g, eps=1e-6)
    base = np.asarray(solve(q).x)
    ranked = np.asarray(solve(q, rank=rank).x)
    np.testing.assert_allclose(base, ranked, atol=5e-6, rtol=1e-5)


def test_solve_rank_validation():
    g = gen.erdos_renyi(20, 2.0, seed=15)
    q = get_algorithm("sssp", g, source=0)
    with pytest.raises(EngineOptionsError, match="rank"):
        solve(q, options=EngineOptions(rank=np.zeros((2, 2), np.int64)))
    with pytest.raises(EngineOptionsError, match="rank"):
        solve(q, options=EngineOptions(rank=np.arange(g.n - 1)))


# ------------------------------------------------------- serving order swap

def _solo(g, algo, **params):
    return np.asarray(solve(get_algorithm(algo, g, **params)).x)


def test_server_ranked_tenant_solo_exact():
    g, rank, perm = _shuffled_path(128)
    srv = GraphServer(g, slots=4, bs=16, rounds_per_batch=2,
                      transfer_guard="disallow", rank=rank)
    ts = [srv.submit("sssp", {"source": int(perm[i])}) for i in (0, 3, 40)]
    srv.run()
    for t in ts:
        assert t.converged
        np.testing.assert_array_equal(
            t.result, _solo(g, "sssp", source=t.params["source"]))


def test_server_midflight_swap_bitwise_minmax():
    """Converged/warm family state permuted into a new rank resolves every
    in-flight ticket with exactly the solo engine's result."""
    g, rank, perm = _shuffled_path(128)
    srv = GraphServer(g, slots=4, bs=16, rounds_per_batch=2,
                      transfer_guard="disallow")
    ts = [srv.submit("sssp", {"source": int(perm[i])}) for i in (0, 5, 60)]
    srv.step()          # some columns mid-flight, some maybe converged
    srv.swap_order(rank)
    srv.run()
    assert srv.stats.reorders.get("default") == 1
    for t in ts:
        assert t.converged
        np.testing.assert_array_equal(
            t.result, _solo(g, "sssp", source=t.params["source"]))


def test_server_midflight_swap_sum_within_eps():
    g = gen.powerlaw_cluster(96, 3, seed=16)
    rank = np.random.default_rng(17).permutation(g.n).astype(np.int64)
    srv = GraphServer(g, slots=2, bs=16, rounds_per_batch=2,
                      transfer_guard="disallow")
    t = srv.submit("pagerank", {"eps": 1e-6})
    srv.step()
    srv.swap_order(rank)
    srv.run()
    assert t.converged
    np.testing.assert_allclose(
        t.result, _solo(g, "pagerank", eps=1e-6), atol=5e-6, rtol=1e-5)


def test_server_pallas_megakernel_swap_under_disallow():
    g, rank, perm = _shuffled_path(128)
    srv = GraphServer(g, slots=4, bs=16, rounds_per_batch=4,
                      sweeps_per_call=2, backend="pallas",
                      transfer_guard="disallow", rank=rank)
    ts = [srv.submit("sssp", {"source": int(perm[i])}) for i in (0, 10)]
    srv.step()
    new_rank = np.random.default_rng(18).permutation(g.n).astype(np.int64)
    srv.swap_order(new_rank)
    srv.run()
    for t in ts:
        assert t.converged
        np.testing.assert_array_equal(
            t.result, _solo(g, "sssp", source=t.params["source"]))


def test_server_online_rerank_triggers_and_stays_exact():
    g, rank, perm = _shuffled_path(256)
    srv = GraphServer(g, slots=4, bs=16, rounds_per_batch=2,
                      transfer_guard="disallow", rank=rank,
                      reorder_threshold=0.9, reorder_regions=8)
    ts = [srv.submit("sssp", {"source": int(perm[0])})]
    srv.step()
    d = _reverse_segment(perm, 64, 112)
    srv.apply_delta(d)
    g2 = d.apply(g)
    ts.append(srv.submit("sssp", {"source": int(perm[-1])}))
    srv.run()
    assert srv.stats.reorders.get("default", 0) >= 1
    ten = srv.tenants["default"]
    assert ten.tracker.M == metric_m(ten.g, ten.rank)
    for t in ts:
        assert t.converged
        np.testing.assert_array_equal(
            t.result, _solo(g2, "sssp", source=t.params["source"]))


def test_server_delta_with_appended_vertices_ranked():
    g, rank, perm = _shuffled_path(96)
    srv = GraphServer(g, slots=2, bs=16, rounds_per_batch=2,
                      transfer_guard="disallow", rank=rank,
                      reorder_threshold=0.5)
    t0 = srv.submit("sssp", {"source": int(perm[0])})
    srv.step()
    n = g.n
    d = GraphDelta(
        n_add=2,
        add_src=np.array([perm[-1], n], np.int64),
        add_dst=np.array([n, n + 1], np.int64),
        add_w=np.ones(2, np.float32),
    )
    srv.apply_delta(d)
    g2 = d.apply(g)
    t1 = srv.submit("sssp", {"source": int(perm[0])})
    srv.run()
    for t in (t0, t1):
        assert t.converged
        np.testing.assert_array_equal(
            t.result, _solo(g2, "sssp", source=t.params["source"]))


# ------------------------------------------------------------ the auto-tuner

def test_tuner_disables_after_patience_no_gain():
    tu = _ReorderTuner(patience=2, window=4)
    for r in [10, 10, 10, 10]:
        tu.record_resolve(r)
    for _ in range(2):
        tu.note_swap()
        for r in [10, 10, 10, 10]:   # no improvement
            tu.record_resolve(r)
    assert not tu.enabled and tu.strikes == 2


def test_tuner_keeps_going_on_real_gains():
    tu = _ReorderTuner(patience=2, window=4)
    rounds = 16
    for _ in range(4):
        for _ in range(4):
            tu.record_resolve(rounds)
        tu.note_swap()
        rounds //= 2    # every swap halves rounds-per-query
    for _ in range(4):
        tu.record_resolve(rounds)
    assert tu.enabled and tu.strikes == 0


def test_server_records_tuner_disable():
    g, rank, perm = _shuffled_path(64)
    srv = GraphServer(g, slots=2, bs=16, rounds_per_batch=2, cache=False,
                      transfer_guard="disallow", rank=rank,
                      reorder_threshold=0.9, reorder_patience=1)
    ten = srv.tenants["default"]
    ten.tuner.window = 2
    # the same query over and over: rounds-per-query is flat, so a swap
    # measurably gains nothing and one no-gain swap (patience=1) disables
    for _ in range(3):
        srv.submit("sssp", {"source": int(perm[0])})
        srv.run()
    srv.swap_order(rank.copy())
    for _ in range(2):
        srv.submit("sssp", {"source": int(perm[0])})
        srv.run()
    assert not ten.tuner.enabled
    assert srv.stats.reorders_disabled.get("default") is True
    # reordering off: a decaying delta no longer triggers a re-rank
    before = srv.stats.reorders.get("default", 0)
    d = _reverse_segment(perm, 16, 40)
    srv.apply_delta(d)
    assert srv.stats.reorders.get("default", 0) == before
    assert ten.tracker.M == metric_m(ten.g, ten.rank)  # tracker keeps counting


def test_server_reorder_knob_validation():
    g = gen.erdos_renyi(16, 2.0, seed=19)
    with pytest.raises(ValueError, match="reorder_threshold"):
        GraphServer(g, reorder_threshold=1.5)
    with pytest.raises(ValueError, match="reorder_regions"):
        GraphServer(g, reorder_regions=0)
    with pytest.raises(ValueError, match="reorder_patience"):
        GraphServer(g, reorder_patience=0)
    with pytest.raises(ValueError, match="rank"):
        GraphServer(graphs={"a": g}, rank=np.arange(16))
